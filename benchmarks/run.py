"""Benchmark harness — one section per paper table/figure.

Prints ``name,value,derived`` CSV rows and writes per-section JSON
artifacts (BENCH_kernels.json, BENCH_fleet.json, EVAL_scorecard.json) so
the perf trajectory is tracked across PRs.  Usage:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table3,fig2a
    PYTHONPATH=src python -m benchmarks.run --only kernel,fleet --json-dir .
    PYTHONPATH=src python -m benchmarks.run --smoke    # <30 s perf canary

``--smoke`` exercises every benchmark family (kernel, sweep, fleet+eval,
scenario scorecard) at tiny sizes without writing JSON artifacts — the
fail-fast regression canary tier-1 runs via tests/test_bench_smoke.py.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _emit(rows):
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")
    return rows


def _write_json(path: str, rows) -> None:
    doc = {name: {"value": value, "derived": derived}
           for name, value, derived in rows}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path} ({len(doc)} rows)", file=sys.stderr)


def smoke() -> list:
    """All perf-path families at tiny sizes: kernel microbench, engine
    sweep, fleet + event-batched eval, and the scenario scorecard (parity
    bits + headline operational metrics).  Returns the combined rows (also
    printed as CSV)."""
    from benchmarks import fleetbench, kernelbench, scorecard

    rows = _emit(kernelbench.kernel_microbench(B=4, M=8, N=256, K=10,
                                               detect_h=64))
    rows += _emit(kernelbench.tile_sweep_rows())
    rows += _emit(fleetbench.sweep_rows(n_trials=1, reps=1))
    rows += _emit(fleetbench.sweep_slab_rows(n_per_class=1, reps=1,
                                             fleet_hosts=32))
    rows += _emit(fleetbench.fleet_rows(batch_sizes=(16,), reps=1,
                                        sequential_baseline=False))
    rows += _emit(fleetbench.shard_rows(parity_hosts=24, storm_hosts=(64,),
                                        shard_hosts=16, reps=1))
    rows += _emit(fleetbench.incremental_rows(batch_sizes=(8,),
                                              shard_batch=0))
    rows += _emit(fleetbench.live_rows(n_hosts=4, reps=1, storm_s=0.2))
    rows += _emit(fleetbench.eval_rows(n_per_class=1, reps=1))
    rows += _emit(fleetbench.chaos_rows(reps=1))
    rows += _emit(fleetbench.restart_rows(reps=1))
    rows += _emit(scorecard.smoke_rows())
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated section prefixes to run")
    ap.add_argument("--json-dir", default=".",
                    help="directory for BENCH_*.json artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny-size run of all perf families, no JSON")
    args = ap.parse_args()
    want = [s for s in args.only.split(",") if s]

    def on(name: str) -> bool:
        return not want or any(name.startswith(w) for w in want)

    t0 = time.time()
    print("name,value,derived")

    if args.smoke:
        smoke()
        print(f"# smoke total {time.time() - t0:.1f}s", file=sys.stderr)
        return

    from benchmarks import diagnostics, fleetbench, kernelbench, roofline

    if on("table3"):
        _emit(diagnostics.table3_diagnostic())
    if on("table2"):
        _emit(diagnostics.table2_comparison())
    if on("table4"):
        _emit(diagnostics.table4_confusion())
    if on("fig2a"):
        _emit(diagnostics.fig2_overhead())
    if on("ablation"):
        _emit(diagnostics.ablation_probes())
    if on("kernel"):
        rows = _emit(kernelbench.kernel_microbench())
        rows += _emit(kernelbench.tile_sweep_rows())
        _write_json(os.path.join(args.json_dir, "BENCH_kernels.json"), rows)
    if on("fleet"):
        rows = _emit(fleetbench.sweep_rows())
        rows += _emit(fleetbench.sweep_slab_rows())
        rows += _emit(fleetbench.fleet_rows())
        rows += _emit(fleetbench.shard_rows())
        rows += _emit(fleetbench.incremental_rows())
        rows += _emit(fleetbench.live_rows())
        rows += _emit(fleetbench.eval_rows())
        rows += _emit(fleetbench.chaos_rows())
        rows += _emit(fleetbench.restart_rows())
        _write_json(os.path.join(args.json_dir, "BENCH_fleet.json"), rows)
    if on("roofline"):
        _emit(roofline.roofline_rows())
    if on("scorecard"):
        from benchmarks import scorecard
        doc = scorecard.build_scorecard()
        _emit(scorecard.scorecard_rows(doc))
        scorecard.write(doc, os.path.join(args.json_dir,
                                          "EVAL_scorecard.json"))

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
