"""Benchmark harness — one section per paper table/figure.

Prints ``name,value,derived`` CSV rows.  Usage:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run --only table3,fig2a
"""
from __future__ import annotations

import argparse
import sys
import time


def _emit(rows):
    for name, value, derived in rows:
        print(f"{name},{value:.6g},{derived}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma-separated section prefixes to run")
    args = ap.parse_args()
    want = [s for s in args.only.split(",") if s]

    def on(name: str) -> bool:
        return not want or any(name.startswith(w) for w in want)

    t0 = time.time()
    print("name,value,derived")

    from benchmarks import diagnostics, kernelbench, roofline

    if on("table3"):
        _emit(diagnostics.table3_diagnostic())
    if on("table2"):
        _emit(diagnostics.table2_comparison())
    if on("table4"):
        _emit(diagnostics.table4_confusion())
    if on("fig2a"):
        _emit(diagnostics.fig2_overhead())
    if on("ablation"):
        _emit(diagnostics.ablation_probes())
    if on("kernel"):
        _emit(kernelbench.kernel_microbench())
    if on("roofline"):
        _emit(roofline.roofline_rows())

    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
