"""Roofline analysis from recorded dry-run artifacts (EXPERIMENTS §Roofline).

Terms per (arch x shape x mesh), all PER-DEVICE (the dry-run records the
SPMD-partitioned program of one participant):

  compute_s    = flops / 197e12          (bf16 peak per v5e chip)
  memory_s     = hbm_bytes / 819e9       (HBM bandwidth)
  collective_s = coll_bytes / 50e9       (per-link ICI; conservative 1 link)

MODEL_FLOPS uses 6*N_active*D for training (D = global tokens) and
2*N_active*D for prefill/decode; the ratio MODEL_FLOPS / (flops * chips)
shows how much compiled compute is "useful" (remat recompute, attention
quadratic terms and dispatch overhead push it below 1).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = Path(__file__).parent / "results" / "dryrun"

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,          # one token per sequence
    "long_500k": 1,
}
SHAPE_MODE = {"train_4k": "train", "prefill_32k": "prefill",
              "decode_32k": "decode", "long_500k": "decode"}


def load_records() -> List[dict]:
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        recs.append(json.loads(f.read_text()))
    return recs


def analytic_memory_bytes(rec: dict) -> float:
    """Per-device HBM traffic model from the cell's buffer inventory.

    The instruction-level traffic sum (hbm_bytes, recorded) is a 100-200x
    overcount on this backend: CPU fusion boundaries materialize tensors a
    TPU keeps in VMEM/registers.  The roofline memory term instead counts
    the traffic a well-fused TPU program must do:

      train  : 3 passes over gathered weights (fwd, remat recompute, bwd)
               + grad write/read (fp32) + optimizer state read/write
               + remat carry stack write+read + logits fp32
      prefill: 1 pass over gathered weights + KV-cache write + activations
      decode : 1 pass over gathered weights + KV-cache/state read+write
    """
    import sys
    from pathlib import Path as _P
    sys.path.insert(0, str(_P(__file__).parents[1] / "src"))
    from repro.configs import SHAPES, get_config

    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    chips = rec.get("n_chips", 256)
    tp = 16
    dp = chips // tp
    n_total = rec.get("params", 0)
    B, S = shape.global_batch, shape.seq_len
    B_loc = max(B // dp, 1)

    gathered = 2.0 * n_total / tp          # bf16 weights seen per device
    if shape.kind == "train":
        mb = max(cfg.train_microbatch, 1)
        weights = 3.0 * gathered * mb      # fwd + remat + bwd, per microstep
        opt = (4.0 + 8.0 + 8.0) * n_total / chips   # grad fp32 + m,v r/w
        # remat carry stack (sequence-parallel residual stream)
        e = cfg.d_model
        act = 2.0 * cfg.n_layers * (B_loc / mb) * (S / tp) * e * 2.0 * mb
        logits = 4.0 * (B_loc / mb) * S * cfg.vocab_padded / tp * 2.0 * mb
        return weights + opt + act + logits
    if shape.kind == "prefill":
        kv = (2.0 * cfg.n_layers * B_loc
              * min(S, cfg.window or S) * max(cfg.n_kv, 1) * cfg.head_dim
              * 2.0)
        act = 2.0 * cfg.n_layers * B_loc * (S / tp) * cfg.d_model * 2.0
        return gathered + kv + act
    # decode: one token step
    if cfg.family == "ssm":
        state = (cfg.n_layers * B_loc * cfg.ssm_nheads * cfg.ssm_headdim
                 * cfg.ssm_state * 4.0) * 2.0
    else:
        s_eff = min(S, cfg.window) if cfg.window else S
        state = (2.0 * cfg.n_layers * B_loc * s_eff / tp
                 * max(cfg.n_kv, 1) * cfg.head_dim * 2.0) * 1.5
        if cfg.family == "hybrid":
            state = state * (1 / 8) + (cfg.n_layers * 7 / 8) * B_loc * \
                cfg.ssm_nheads * cfg.ssm_headdim * cfg.ssm_state * 4.0 * 2.0
    return gathered + state


def roofline_row(rec: dict) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    flops = rec["flops"]
    hbm = analytic_memory_bytes(rec)
    coll = sum(rec["collective_bytes"].values())
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll / LINK_BW
    dom = max(("compute", compute_s), ("memory", memory_s),
              ("collective", coll_s), key=lambda kv: kv[1])[0]
    tokens = SHAPE_TOKENS[rec["shape"]]
    n_active = rec.get("params_active", rec.get("params", 0))
    mult = 6 if SHAPE_MODE[rec["shape"]] == "train" else 2
    model_flops = mult * n_active * tokens
    chips = rec.get("n_chips", 256)
    useful = model_flops / (flops * chips) if flops else 0.0
    step_s = max(compute_s, memory_s, coll_s)
    mfu = (model_flops / chips / step_s) / PEAK_FLOPS if step_s > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dom,
        "model_flops": model_flops, "useful_ratio": useful,
        "roofline_mfu": mfu,
        "temp_gb": rec["mem"]["temp_bytes"] / 1e9,
        "fits_16g": rec["mem"]["temp_bytes"] / 1e9 < 16.0,
    }


def roofline_rows() -> List[Tuple[str, float, str]]:
    out = []
    for rec in load_records():
        if rec.get("tag"):
            continue
        r = roofline_row(rec)
        key = f"{rec['arch']}|{rec['shape']}|{rec['mesh']}"
        if r is None:
            if rec.get("status") == "skip":
                out.append((f"roofline/{key}/skip", 0.0,
                            rec.get("reason", "")[:60]))
            continue
        out.append((f"roofline/{key}/dominant_{r['dominant']}",
                    max(r["compute_s"], r["memory_s"], r["collective_s"]),
                    f"mfu={r['roofline_mfu']:.3f}"))
    return out


def markdown_table(mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table body."""
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| useful | roofline-MFU | temp GB | fits 16G |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records():
        if rec["mesh"] != mesh or rec.get("tag"):
            continue
        if rec.get("status") == "skip":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        r = roofline_row(rec)
        if r is None:
            lines.append(f"| {rec['arch']} | {rec['shape']} | FAIL | | | | "
                         f"| | | |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_mfu']:.3f} | {r['temp_gb']:.1f} | "
            f"{'yes' if r['fits_16g'] else 'NO'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table("16x16"))
    print()
    print(markdown_table("2x16x16"))
