"""Parity regression gate — the third CI step.

The invariants PRs 1-4 established are *exact*: batched fleet detection is
byte-identical to the seed oracle, event-batched / slab Layer 3 predicts
and timestamps identically to the per-event path, and a no-fault soak
produces zero verdicts.  This gate makes every commit prove them again:

  1. the committed ``EVAL_scorecard.json`` is structurally sound — every
     scenario class present, parity bits exactly 1.0, soak AND the
     pure-corruption chaos classes verdict-free, chaos_overlap inside the
     5 s / 8 s latency targets at single-fault recall, the overlap
     classes at multi-hypothesis recall (every concurrent fault gets its
     own verdict), latency percentiles finite where events exist;
  2. a fresh tiny run reproduces them on THIS commit's code: the bench
     parity rows (``fleet/detect_parity``, ``fleet/shard_parity`` — the
     sharded rack->fleet candidate tree reproducing the single-slab
     verdict fingerprint byte-exactly, quarantine/degraded/deferred
     fields included — ``eval/pred_parity``, ``eval/store_pred_parity``,
     and ``eval/sweep_parity`` — the slab detection sweep reproducing
     the per-row oracle's events and timestamps byte-exactly), the
     chaos invariants
     (``fleetbench.chaos_rows``: zero verdicts under pure corruption,
     all-true-mask byte-parity, bounded sanitize overhead), the
     survivability invariants (``fleetbench.restart_rows``: crash/restore
     replay parity, zero duplicate verdicts, degraded-mode shedding and
     re-arm) and a smoke scorecard with the same class set as the
     committed artifact.

Exit status is nonzero on any break, with one line per failure.  Usage::

  PYTHONPATH=src python -m benchmarks.regress                # full gate
  PYTHONPATH=src python -m benchmarks.regress --skip-fresh   # artifact only
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List

#: bench rows that must be exactly 1.0 (prefix match, any suffix such as
#: the batch-size tag)
PARITY_ROW_PREFIXES = (
    "fleet/detect_parity",
    "fleet/shard_parity",
    "fleet/incremental_parity",
    "eval/pred_parity",
    "eval/store_pred_parity",
    "eval/sweep_parity",
)

#: scorecard parity bits that must be present AND exactly 1.0
#: (``replay``: crash/checkpoint/restore verdict stream byte-identical to
#: the uninterrupted streaming run)
SCORECARD_PARITY_KEYS = ("batched_pred", "batched_ts",
                         "slab_pred", "slab_ts", "replay")

#: classes with NO injected host fault — any verdict is a false positive.
#: ``soak`` is the ambient control; the chaos trio corrupts the telemetry
#: itself (NaN/freeze/drop), so a verdict there means a broken probe was
#: diagnosed as a broken host.
SOAK_LIKE_CLASSES = ("soak", "chaos_soak", "frozen_channel",
                     "crash_restart")

#: chaos_overlap operational gates: a real fault under telemetry
#: corruption must still be caught — with recall no worse than the clean
#: single-fault control and every event inside the paper's latency targets
CHAOS_DETECT_MAX_S = 5.0
CHAOS_RCA_MAX_S = 8.0

#: concurrent-fault floor for the overlap classes: with multi-hypothesis
#: Layer 2 every co-occurring fault must earn its own verdict, so recall
#: near 1.0 — not the one-verdict-per-incident ~0.5 of a single-pending
#: detector.  Applied to the committed artifact AND the fresh smoke run.
OVERLAP_RECALL_MIN = 0.9
OVERLAP_CLASSES = ("overlap_pair", "overlap_full")

#: clean-path sanitization must cost less than the sweep it guards
SANITIZE_OVERHEAD_MAX = 0.9

#: crash_during_incident operational gates: a verdict stuck behind 4-8 s
#: of monitor downtime plus the restore round cannot meet 5 s / 8 s —
#: these relaxed-but-explicit bounds (mirroring the scorecard's
#: ``crash_*_target_s`` protocol fields) cap the downtime-charged
#: latencies instead
CRASH_DETECT_MAX_S = 15.0
CRASH_RCA_MAX_S = 16.0


def check_scorecard(doc: Dict[str, object], *, label: str) -> List[str]:
    """Structural + invariant checks on one scorecard document."""
    bad: List[str] = []
    try:
        classes = set(doc["protocol"]["classes"])
        scen_doc = doc["scenarios"]
        parity = doc["parity"]
    except (KeyError, TypeError) as e:
        return [f"{label}: malformed scorecard ({e!r})"]

    from repro.sim.scenarios import SCENARIO_CLASSES
    want = set(SCENARIO_CLASSES)
    if classes != want:
        bad.append(f"{label}: protocol classes {sorted(classes)} != "
                   f"{sorted(want)}")
    for name in want:
        if name not in scen_doc:
            bad.append(f"{label}: scenario class {name!r} missing")
    for key in SCORECARD_PARITY_KEYS:
        if key not in parity:
            bad.append(f"{label}: parity/{key} missing — invariant no "
                       "longer recorded")
    for key, val in parity.items():
        if val != 1.0:
            bad.append(f"{label}: parity/{key} = {val} (want 1.0) — "
                       "batched/slab path diverged from per-event")
    for name in SOAK_LIKE_CLASSES:
        blk = scen_doc.get(name)
        if blk is None:
            continue
        if blk.get("false_verdicts", -1) != 0 or blk.get("n_verdicts", -1) != 0:
            bad.append(f"{label}: {name} produced verdicts "
                       f"({blk.get('n_verdicts')}) — false-positive break")
        if blk.get("n_truth_events", -1) != 0:
            bad.append(f"{label}: {name} has truth events")
    for name in OVERLAP_CLASSES:
        blk = scen_doc.get(name)
        if blk is None:
            continue
        r = blk.get("recall")
        if not (isinstance(r, (int, float)) and r >= OVERLAP_RECALL_MIN):
            bad.append(f"{label}: {name} recall = {r!r} (want >= "
                       f"{OVERLAP_RECALL_MIN}) — a concurrent fault lost "
                       "its verdict")
    overlap = scen_doc.get("chaos_overlap")
    if overlap is not None:
        single = scen_doc.get("single", {})
        sr, orr = single.get("recall"), overlap.get("recall")
        if sr is not None and (orr is None or orr < sr):
            bad.append(f"{label}: chaos_overlap recall {orr!r} < single "
                       f"recall {sr!r} — corruption degraded detection")
        for lat_key, bound in (("detect_latency_s", CHAOS_DETECT_MAX_S),
                               ("rca_latency_s", CHAOS_RCA_MAX_S)):
            pcts = overlap.get(lat_key) or {}
            worst = pcts.get("max")
            if not (isinstance(worst, (int, float)) and worst <= bound):
                bad.append(f"{label}: chaos_overlap {lat_key} max = "
                           f"{worst!r} (target <= {bound} s)")
    for name, blk in scen_doc.items():
        if name in SOAK_LIKE_CLASSES:
            continue
        if blk.get("n_truth_events", 0) <= 0:
            bad.append(f"{label}: {name} has no truth events")
            continue
        for lat_key in ("detect_latency_s", "rca_latency_s"):
            pcts = blk.get(lat_key)
            if not pcts:
                bad.append(f"{label}: {name} has no {lat_key} percentiles")
                continue
            for p, v in pcts.items():
                if not (isinstance(v, (int, float)) and math.isfinite(v)):
                    bad.append(f"{label}: {name}.{lat_key}.{p} = {v!r}")
        if blk.get("recall") in (None, 0):
            bad.append(f"{label}: {name} recall = {blk.get('recall')!r} — "
                       "detector found nothing on an injected class")
    crash = scen_doc.get("crash_during_incident")
    if crash is not None:
        for lat_key, bound in (("detect_latency_s", CRASH_DETECT_MAX_S),
                               ("rca_latency_s", CRASH_RCA_MAX_S)):
            worst = (crash.get(lat_key) or {}).get("max")
            if not (isinstance(worst, (int, float)) and worst <= bound):
                bad.append(f"{label}: crash_during_incident {lat_key} max "
                           f"= {worst!r} (target <= {bound} s incl. "
                           "downtime)")
    restart = doc.get("restart")
    if restart is None:
        bad.append(f"{label}: restart block missing — survivability "
                   "invariants no longer recorded")
    else:
        if restart.get("replay_parity") != 1.0:
            bad.append(f"{label}: restart replay_parity = "
                       f"{restart.get('replay_parity')!r} (want 1.0) — "
                       "crash/restore stream diverged from uninterrupted")
        if restart.get("restart_duplicates") != 0:
            bad.append(f"{label}: restart_duplicates = "
                       f"{restart.get('restart_duplicates')!r} (want 0) — "
                       "replay re-delivered an already-delivered verdict")
        if not restart.get("restores"):
            bad.append(f"{label}: restart harness performed no warm "
                       "restore — crash path not exercised")
    fleet = doc.get("fleet")
    if fleet is None:
        bad.append(f"{label}: fleet block missing")
    elif fleet.get("flagged_recall") in (None, 0):
        bad.append(f"{label}: fleet flagged_recall = "
                   f"{fleet.get('flagged_recall')!r}")
    return bad


def check_chaos_rows(rows) -> List[str]:
    """Chaos-hardening invariants over fresh ``fleetbench.chaos_rows``."""
    bad: List[str] = []
    seen = {"chaos/soak_false_verdicts": False, "chaos/masked_parity": False,
            "chaos/sanitize_overhead_frac": False}
    for name, value, _ in rows:
        if name == "chaos/soak_false_verdicts":
            seen[name] = True
            if value != 0.0:
                bad.append(f"fresh bench: {name} = {value} (want 0) — "
                           "corrupted telemetry produced a fault verdict")
        elif name == "chaos/masked_parity":
            seen[name] = True
            if value != 1.0:
                bad.append(f"fresh bench: {name} = {value} (want 1.0) — "
                           "all-true mask no longer byte-identical")
        elif name == "chaos/sanitize_overhead_frac":
            seen[name] = True
            if not (math.isfinite(value)
                    and value <= SANITIZE_OVERHEAD_MAX):
                bad.append(f"fresh bench: {name} = {value} (bound "
                           f"{SANITIZE_OVERHEAD_MAX}) — sanitization cost "
                           "regressed")
    for name, hit in seen.items():
        if not hit:
            bad.append(f"fresh bench: no row matched {name}")
    return bad


def check_restart_rows(rows) -> List[str]:
    """Survivability invariants over fresh ``fleetbench.restart_rows``."""
    bad: List[str] = []
    want = {
        "restart/fleet_replay_parity":
            (lambda v: v == 1.0, "want 1.0 — crash/restore verdicts "
             "diverged from uninterrupted session"),
        "restart/duplicate_verdicts":
            (lambda v: v == 0.0, "want 0 — replay re-delivered a verdict"),
        "restart/shed_rounds":
            (lambda v: v >= 1.0, "want >= 1 — degraded mode never shed"),
        "restart/deferred_rca":
            (lambda v: v >= 1.0, "want >= 1 — degraded mode never "
             "deferred a fresh host's RCA"),
        "restart/rearmed":
            (lambda v: v == 1.0, "want 1.0 — budget hysteresis stuck "
             "degraded after load lifted"),
    }
    seen = {name: False for name in want}
    for name, value, _ in rows:
        if name in want:
            seen[name] = True
            ok, why = want[name]
            if not ok(value):
                bad.append(f"fresh bench: {name} = {value} ({why})")
    for name, hit in seen.items():
        if not hit:
            bad.append(f"fresh bench: no row matched {name}")
    return bad


def check_bench_parity(rows) -> List[str]:
    """Exact-1.0 check over the parity rows of a fresh bench run."""
    bad: List[str] = []
    seen = {p: False for p in PARITY_ROW_PREFIXES}
    for name, value, _ in rows:
        for p in PARITY_ROW_PREFIXES:
            if name.startswith(p):
                seen[p] = True
                if value != 1.0:
                    bad.append(f"fresh bench: {name} = {value} (want 1.0)")
    for p, hit in seen.items():
        if not hit:
            bad.append(f"fresh bench: no row matched {p}")
    return bad


def check_committed_bench(doc: Dict[str, object], *,
                          label: str) -> List[str]:
    """Parity rows of the committed BENCH_fleet.json artifact.

    The fresh run proves this commit's *code*; this proves the committed
    *artifact* was produced by it — a stale or hand-edited JSON (parity
    row perturbed or deleted) fails even when the code is healthy."""
    rows = [(name, blk.get("value"), blk.get("derived", ""))
            for name, blk in doc.items() if isinstance(blk, dict)]
    return [msg.replace("fresh bench", label)
            for msg in check_bench_parity(rows)]


def fresh_failures() -> List[str]:
    """Re-prove the invariants on this commit's code at tiny sizes."""
    from benchmarks import fleetbench, scorecard

    rows = fleetbench.fleet_rows(batch_sizes=(8,), reps=1,
                                 sequential_baseline=False)
    rows += fleetbench.shard_rows(parity_hosts=24, storm_hosts=(48,),
                                  shard_hosts=16, reps=1)
    rows += fleetbench.incremental_rows(batch_sizes=(8,), shard_batch=0)
    rows += fleetbench.eval_rows(n_per_class=1, reps=1)
    rows += fleetbench.sweep_slab_rows(n_per_class=1, reps=1,
                                       fleet_hosts=32)
    bad = check_bench_parity(rows)
    bad += check_chaos_rows(fleetbench.chaos_rows(reps=1))
    bad += check_restart_rows(fleetbench.restart_rows(reps=1))
    doc = scorecard.build_scorecard(n_per_class=1, n_hosts=4, n_affected=2)
    bad += check_scorecard(doc, label="fresh scorecard")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--artifact", default="EVAL_scorecard.json",
                    help="committed scorecard to validate")
    ap.add_argument("--bench-artifact", default="BENCH_fleet.json",
                    help="committed fleet bench artifact to validate")
    ap.add_argument("--skip-fresh", action="store_true",
                    help="validate the committed artifact only")
    args = ap.parse_args(argv)

    failures: List[str] = []
    try:
        with open(args.artifact) as f:
            committed = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        failures.append(f"cannot read {args.artifact}: {e}")
        committed = None
    if committed is not None:
        failures += check_scorecard(committed, label=args.artifact)
    try:
        with open(args.bench_artifact) as f:
            bench_doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        failures.append(f"cannot read {args.bench_artifact}: {e}")
        bench_doc = None
    if bench_doc is not None:
        failures += check_committed_bench(bench_doc,
                                          label=args.bench_artifact)
    if not args.skip_fresh:
        failures += fresh_failures()

    if failures:
        for msg in failures:
            print(f"REGRESS FAIL: {msg}", file=sys.stderr)
        return 1
    print("regress: all parity/scorecard invariants hold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
