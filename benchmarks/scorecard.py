"""Scenario-suite scorecard: operational metrics on harder-than-paper
timelines, with batched/slab execution parity — the numbers CI gates on.

Runs the multi-fault scenario suite (``repro.sim.scenarios``) through THREE
executions of the same engine and scores the per-event verdict streams
(``repro.sim.scoring``):

  per-event   ``CorrelationEngine.process`` per trial — the oracle;
  batched     ``process_batch`` — every event of every trial stacked into
              one fused Layer-3 dispatch;
  slab        ``process_store`` — same, evidence gathered by columnar
              slab indexing over the ``TrialStore``.

All three run on the shared f32 store rows, so predictions AND the
deterministic timestamps (``t_onset`` / ``t_detect`` / ``t_ready``) must be
*identical* across paths — the ``parity`` block records that as 1.0 bits,
and ``benchmarks/regress.py`` fails CI when any bit drops.

Emits ``EVAL_scorecard.json``::

  protocol    suite configuration (classes, seeds, grid, tolerance)
  scenarios   per-class block: precision / recall / accuracy under
              nearest-truth matching, detection-latency and RCA-latency
              percentiles (p50/p90/max) plus within-target fractions
              (5 s detect, 8 s RCA — the paper's operational claims)
  fleet       cross-host correlated incident: flagged-set precision /
              recall and top-cause accuracy of ``diagnose_fleet`` on the
              stacked (hosts, C, T) slab
  parity      batched/slab vs per-event: prediction and timestamp bits
  overall     the per-class blocks pooled

Usage::

  PYTHONPATH=src python -m benchmarks.scorecard                 # full suite
  PYTHONPATH=src python -m benchmarks.scorecard --smoke --out x.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import sanitize as sanitize_mod
from repro.core.engine import CorrelationEngine, StreamState
from repro.monitor import checkpoint as ckpt_mod
from repro.monitor.fleet import FleetMonitor
from repro.sim import scenarios as scen
from repro.sim import scoring
from repro.sim.scenario import TrialStore

#: suite seed — fixed so the committed artifact is reproducible
SUITE_SEED = 41

#: default artifact path (repo root, committed + CI-diffed)
ARTIFACT = "EVAL_scorecard.json"

#: restart-harness streaming cadence: one detection round every 2.5 s
RESTART_ROUND_S = 2.5
#: checkpoint every 4th round (10 s) — deliberately sparser than the round
#: cadence, so a crash can lose already-delivered rounds and the replay
#: must re-derive (and duplicate-suppress) their verdicts
RESTART_CKPT_EVERY = 4
#: relaxed-but-explicit operational targets for the crash classes: a
#: verdict stuck behind 4-8 s of monitor downtime plus the restore round
#: cannot meet the paper's 5 s / 8 s, but must still land within these
CRASH_DETECT_TARGET_S = 15.0
CRASH_RCA_TARGET_S = 16.0


def _diag_sig(diags) -> List[Tuple[str, float, float, float]]:
    """The deterministic signature of a diagnosis stream: predictions and
    virtual-time stamps, excluding wall-clock fields (``t_rca`` carries the
    measured analysis wall and legitimately differs between runs)."""
    return [(d.top_cause.value, d.event.t_onset, d.event.t_detect,
             d.t_ready) for d in diags]


def _parity(per_event, other) -> Tuple[float, float]:
    """(prediction bit, timestamp bit): fraction of trials whose verdict
    streams match the oracle exactly — event count and order included."""
    pred_ok = ts_ok = 0
    for a, b in zip(per_event, other):
        sa, sb = _diag_sig(a), _diag_sig(b)
        pred_ok += [s[0] for s in sa] == [s[0] for s in sb]
        ts_ok += [s[1:] for s in sa] == [s[1:] for s in sb]
    n = max(len(per_event), 1)
    return pred_ok / n, ts_ok / n


def _fleet_block(trials: List[scen.ScenarioTrial], rate_hz: float,
                 use_kernels: bool) -> Optional[Dict[str, object]]:
    """Score ``diagnose_fleet`` on every fleet scenario's (hosts, C, T)
    slab, clipped shortly after the shared burst so the trailing detection
    window contains it (the streaming deployment's snapshot timing)."""
    groups: Dict[int, List[scen.ScenarioTrial]] = {}
    for t in trials:
        if t.scenario == "fleet_nic":
            groups.setdefault(t.group, []).append(t)
    if not groups:
        return None
    mon = FleetMonitor(use_kernels=use_kernels)
    tp = fp = fn = correct = 0
    for members in groups.values():
        members.sort(key=lambda t: t.host)
        affected = {t.host for t in members if t.truth}
        burst = next(t.truth[0] for t in members if t.truth)
        t_hi = int((burst.t_on + 6.0) * rate_hz)
        slab = np.ascontiguousarray(
            np.stack([t.data[:, :t_hi] for t in members]), np.float32)
        fd = mon.diagnose_fleet(members[0].ts[:t_hi], slab,
                                members[0].channels)
        flagged = set(fd.flagged_hosts)
        tp += len(flagged & affected)
        fp += len(flagged - affected)
        fn += len(affected - flagged)
        correct += sum(1 for h in (flagged & affected)
                       if fd.diagnoses[h].top_cause == burst.kind)
    return {
        "n_incidents": len(groups),
        "flagged_precision": tp / (tp + fp) if (tp + fp) else None,
        "flagged_recall": tp / (tp + fn) if (tp + fn) else None,
        "top_cause_accuracy": correct / tp if tp else None,
    }


def _event_sig(ev, rca_t: int) -> Tuple[float, float, float, int]:
    return (float(ev.t_onset), float(ev.t_detect), float(ev.score),
            int(rca_t))


def _stream_trial(eng: CorrelationEngine, trial: scen.ScenarioTrial,
                  crash: Optional[scen.MonitorEvent], ckpt_path: str,
                  ) -> Dict[str, object]:
    """One round-boundary streaming run over a trial's timeline.

    Without ``crash`` this is the uninterrupted oracle: the detector walks
    growing prefixes at :data:`RESTART_ROUND_S` cadence through one
    :class:`StreamState`, checkpointing every
    :data:`RESTART_CKPT_EVERY` rounds.  With ``crash`` the in-memory state
    is *discarded* at ``crash.t``, rounds falling inside the downtime are
    skipped, and the first surviving round warm-restores from the last
    on-disk checkpoint and replays forward — re-derived verdicts already
    delivered before the crash are suppressed by signature and counted.
    """
    ts, data, channels = trial.ts, trial.data, trial.channels
    T = ts.shape[0]
    state = StreamState()
    emitted: List[tuple] = []       # (event, rca_index), delivery order
    sigs: set = set()
    dups = 0
    alive = True
    restored = False
    t_restore = None
    save_ms = restore_ms = 0.0
    ckpt_bytes = 0
    boundaries = np.arange(RESTART_ROUND_S, float(ts[-1]) + RESTART_ROUND_S,
                           RESTART_ROUND_S)
    crashed = False
    for k, b in enumerate(boundaries):
        if crash is not None and not crashed and b >= crash.t:
            crashed = True          # fires once
            alive = False           # process killed: in-memory state gone
            state = None
        if not alive:
            if b < crash.t_end:
                continue            # monitor down: round never runs
            # warm restore from the last checkpoint, then replay below
            w0 = time.perf_counter()
            payload = ckpt_mod.load_checkpoint(ckpt_path)
            state = StreamState.from_dict(payload["stream"])
            restore_ms = (time.perf_counter() - w0) * 1e3
            alive, restored = True, True
            t_restore = float(b)
        hi = min(int(np.searchsorted(ts, float(b), side="right")), T)
        for ev, rca_t in eng.detect_events(ts[:hi], data[:, :hi],
                                           channels, state=state):
            s = _event_sig(ev, rca_t)
            if s in sigs:
                dups += 1           # replay re-derived a delivered verdict
                continue
            sigs.add(s)
            emitted.append((ev, rca_t))
        if (k + 1) % RESTART_CKPT_EVERY == 0:
            w0 = time.perf_counter()
            ckpt_bytes = max(ckpt_bytes, ckpt_mod.save_checkpoint(
                ckpt_path, {"stream": state.to_dict()}))
            save_ms = max(save_ms, (time.perf_counter() - w0) * 1e3)
    for flushed in state.flush(T):
        s = _event_sig(*flushed)
        if s in sigs:
            dups += 1
        else:
            sigs.add(s)
            emitted.append(flushed)
    return {"events": emitted, "duplicates": dups, "restored": restored,
            "t_restore": t_restore, "ckpt_bytes": ckpt_bytes,
            "save_ms": save_ms, "restore_ms": restore_ms}


def _restart_block(trials: List[scen.ScenarioTrial], tol_s: float,
                   ) -> Optional[Dict[str, object]]:
    """Crash-class harness: uninterrupted vs crash/checkpoint/restore
    streaming runs, per trial.

    The replay-parity bit is the fraction of crash trials whose delivered
    verdict stream (pre-crash verdicts + post-restore replay, duplicates
    suppressed) is *byte-identical* — onset/detect/score stamps and RCA
    indices — to the uninterrupted run over the same timeline.  Latency
    scoring charges the downtime: verdict times inside the restart window
    shift to the restore round (``scoring.score_trial`` restart windows).
    """
    crash_trials = [t for t in trials
                    if any(m.kind == "monitor_crash" for m in t.monitor)]
    if not crash_trials:
        return None
    eng = CorrelationEngine()
    parity_ok = 0
    dups = 0
    restores = 0
    ckpt_bytes = 0
    save_ms = restore_ms = 0.0
    by_class: Dict[str, List[scoring.TrialScore]] = {}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "monitor.ckpt")
        for t in crash_trials:
            crash = next(m for m in t.monitor
                         if m.kind == "monitor_crash")
            base = _stream_trial(eng, t, None, path)
            run = _stream_trial(eng, t, crash, path)
            sig = lambda r: [_event_sig(ev, rt) for ev, rt in r["events"]]
            parity_ok += sig(base) == sig(run)
            dups += run["duplicates"]
            restores += bool(run["restored"])
            ckpt_bytes = max(ckpt_bytes, run["ckpt_bytes"])
            save_ms = max(save_ms, run["save_ms"])
            restore_ms = max(restore_ms, run["restore_ms"])
            data = t.data
            if run["events"]:
                data = sanitize_mod.forward_fill(np.asarray(data))
            diags = eng.diagnose_events_batch(
                [(t.ts, data, list(t.channels), rca_t, ev)
                 for ev, rca_t in run["events"]])
            # same reconciliation pass the non-streaming paths run: with
            # concurrent hypotheses, raw per-event diagnoses are not yet
            # the verdict stream
            diags = eng.finalize_trial(
                t.ts, data, list(t.channels), diags,
                [rca_t for _, rca_t in run["events"]])
            windows = ([(float(crash.t), float(run["t_restore"]))]
                       if run["t_restore"] is not None else [])
            by_class.setdefault(t.scenario, []).append(scoring.score_trial(
                t.truth, scoring.verdict_events(diags), tol_s,
                restart_windows=windows))
    n = len(crash_trials)
    classes = {
        name: dict(scoring.summarize(by_class[name],
                                     detect_target_s=CRASH_DETECT_TARGET_S,
                                     rca_target_s=CRASH_RCA_TARGET_S),
                   description=scen.scenario_spec(name).description,
                   multi_fault=scen.scenario_spec(name).multi_fault)
        for name in by_class
    }
    return {
        "n_trials": n,
        "replay_parity": parity_ok / n,
        "restart_duplicates": dups,
        "restores": restores,
        "round_s": RESTART_ROUND_S,
        "checkpoint_every_rounds": RESTART_CKPT_EVERY,
        "checkpoint_bytes": ckpt_bytes,
        "checkpoint_save_ms_max": save_ms,
        "checkpoint_restore_ms_max": restore_ms,
        "classes": classes,
    }


def build_scorecard(n_per_class: int = 4, seed: int = SUITE_SEED, *,
                    duration_s: float = scen.DURATION_S,
                    rate_hz: float = 100.0, tol_s: float = scoring.TOL_S,
                    n_hosts: int = 6, n_affected: int = 2,
                    use_kernels: bool = False) -> Dict[str, object]:
    trials = scen.build_suite(n_per_class, seed, duration_s=duration_s,
                              rate_hz=rate_hz, n_hosts=n_hosts,
                              n_affected=n_affected)
    store = TrialStore.from_trials(trials)
    eng = CorrelationEngine()
    rows = store.rows()

    per_event = [eng.process(*r) for r in rows]
    batched = eng.process_batch(rows)
    slab = eng.process_store(store.ts, store.slab, store.channels)
    bp, bt = _parity(per_event, batched)
    sp, st = _parity(per_event, slab)

    by_class: Dict[str, List[scoring.TrialScore]] = {}
    for t, diags in zip(trials, per_event):
        verds = scoring.verdict_events(diags)
        by_class.setdefault(t.scenario, []).append(
            scoring.score_trial(t.truth, verds, tol_s))
    scenarios_doc = {
        name: dict(scoring.summarize(by_class[name]),
                   description=scen.scenario_spec(name).description,
                   multi_fault=scen.scenario_spec(name).multi_fault)
        for name in by_class
    }
    restart = _restart_block(trials, tol_s)
    if restart is not None:
        # the crash classes are scored by the restart harness — restart-
        # window-aware latencies and relaxed targets replace the generic
        # (downtime-blind) block
        scenarios_doc.update(restart["classes"])
    return {
        "protocol": {
            "suite_seed": seed,
            "n_per_class": n_per_class,
            "classes": list(scen.SCENARIO_CLASSES),
            "duration_s": duration_s,
            "rate_hz": rate_hz,
            "match_tolerance_s": tol_s,
            "detect_target_s": scoring.DETECT_TARGET_S,
            "rca_target_s": scoring.RCA_TARGET_S,
            "n_trials": len(trials),
            "fleet_hosts": n_hosts,
            "fleet_affected": n_affected,
            "use_kernels": use_kernels,
            "crash_detect_target_s": CRASH_DETECT_TARGET_S,
            "crash_rca_target_s": CRASH_RCA_TARGET_S,
        },
        "scenarios": scenarios_doc,
        "fleet": _fleet_block(trials, rate_hz, use_kernels),
        "restart": restart,
        "parity": {
            "batched_pred": bp, "batched_ts": bt,
            "slab_pred": sp, "slab_ts": st,
            "replay": (restart["replay_parity"]
                       if restart is not None else 1.0),
        },
        "overall": scoring.summarize(
            [s for ss in by_class.values() for s in ss]),
    }


def scorecard_rows(doc: Dict[str, object]) -> List[Tuple[str, float, str]]:
    """Flatten the headline scorecard numbers into benchmark CSV rows."""
    rows: List[Tuple[str, float, str]] = []
    for k, v in doc["parity"].items():
        rows.append((f"scorecard/parity/{k}", float(v),
                     "1.0 = verdict stream identical to per-event"))
    for name, blk in doc["scenarios"].items():
        for key in ("recall", "accuracy"):
            if blk[key] is not None:
                rows.append((f"scorecard/{key}/{name}", float(blk[key]), ""))
        rows.append((f"scorecard/false_verdicts/{name}",
                     float(blk["false_verdicts"]), ""))
        if blk["detect_latency_s"]:
            rows.append((f"scorecard/detect_p50_s/{name}",
                         blk["detect_latency_s"]["p50"], "vs 5 s target"))
            rows.append((f"scorecard/rca_p50_s/{name}",
                         blk["rca_latency_s"]["p50"], "vs 8 s target"))
    if doc["fleet"]:
        for k, v in doc["fleet"].items():
            if v is not None:
                rows.append((f"scorecard/fleet/{k}", float(v), ""))
    if doc.get("restart"):
        r = doc["restart"]
        rows.append(("scorecard/restart/duplicates",
                     float(r["restart_duplicates"]),
                     "replay re-derivations suppressed (must be 0)"))
        rows.append(("scorecard/restart/restores", float(r["restores"]),
                     "warm restores from checkpoint"))
        rows.append(("scorecard/restart/checkpoint_bytes",
                     float(r["checkpoint_bytes"]), ""))
    return rows


def smoke_rows() -> List[Tuple[str, float, str]]:
    """Tiny-suite scorecard rows for ``benchmarks/run.py --smoke`` and the
    ``bench_smoke`` pytest canary."""
    doc = build_scorecard(n_per_class=1, n_hosts=4, n_affected=2)
    return scorecard_rows(doc)


def write(doc: Dict[str, object], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--n-per-class", type=int, default=4)
    ap.add_argument("--seed", type=int, default=SUITE_SEED)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny suite (1 per class, 4-host fleet)")
    args = ap.parse_args()
    if args.smoke:
        doc = build_scorecard(n_per_class=1, seed=args.seed, n_hosts=4,
                              n_affected=2)
    else:
        doc = build_scorecard(n_per_class=args.n_per_class, seed=args.seed)
    for name, value, derived in scorecard_rows(doc):
        print(f"{name},{value:.6g},{derived}")
    write(doc, args.out)


if __name__ == "__main__":
    main()
