"""Scenario-suite scorecard: operational metrics on harder-than-paper
timelines, with batched/slab execution parity — the numbers CI gates on.

Runs the multi-fault scenario suite (``repro.sim.scenarios``) through THREE
executions of the same engine and scores the per-event verdict streams
(``repro.sim.scoring``):

  per-event   ``CorrelationEngine.process`` per trial — the oracle;
  batched     ``process_batch`` — every event of every trial stacked into
              one fused Layer-3 dispatch;
  slab        ``process_store`` — same, evidence gathered by columnar
              slab indexing over the ``TrialStore``.

All three run on the shared f32 store rows, so predictions AND the
deterministic timestamps (``t_onset`` / ``t_detect`` / ``t_ready``) must be
*identical* across paths — the ``parity`` block records that as 1.0 bits,
and ``benchmarks/regress.py`` fails CI when any bit drops.

Emits ``EVAL_scorecard.json``::

  protocol    suite configuration (classes, seeds, grid, tolerance)
  scenarios   per-class block: precision / recall / accuracy under
              nearest-truth matching, detection-latency and RCA-latency
              percentiles (p50/p90/max) plus within-target fractions
              (5 s detect, 8 s RCA — the paper's operational claims)
  fleet       cross-host correlated incident: flagged-set precision /
              recall and top-cause accuracy of ``diagnose_fleet`` on the
              stacked (hosts, C, T) slab
  parity      batched/slab vs per-event: prediction and timestamp bits
  overall     the per-class blocks pooled

Usage::

  PYTHONPATH=src python -m benchmarks.scorecard                 # full suite
  PYTHONPATH=src python -m benchmarks.scorecard --smoke --out x.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.engine import CorrelationEngine
from repro.monitor.fleet import FleetMonitor
from repro.sim import scenarios as scen
from repro.sim import scoring
from repro.sim.scenario import TrialStore

#: suite seed — fixed so the committed artifact is reproducible
SUITE_SEED = 41

#: default artifact path (repo root, committed + CI-diffed)
ARTIFACT = "EVAL_scorecard.json"


def _diag_sig(diags) -> List[Tuple[str, float, float, float]]:
    """The deterministic signature of a diagnosis stream: predictions and
    virtual-time stamps, excluding wall-clock fields (``t_rca`` carries the
    measured analysis wall and legitimately differs between runs)."""
    return [(d.top_cause.value, d.event.t_onset, d.event.t_detect,
             d.t_ready) for d in diags]


def _parity(per_event, other) -> Tuple[float, float]:
    """(prediction bit, timestamp bit): fraction of trials whose verdict
    streams match the oracle exactly — event count and order included."""
    pred_ok = ts_ok = 0
    for a, b in zip(per_event, other):
        sa, sb = _diag_sig(a), _diag_sig(b)
        pred_ok += [s[0] for s in sa] == [s[0] for s in sb]
        ts_ok += [s[1:] for s in sa] == [s[1:] for s in sb]
    n = max(len(per_event), 1)
    return pred_ok / n, ts_ok / n


def _fleet_block(trials: List[scen.ScenarioTrial], rate_hz: float,
                 use_kernels: bool) -> Optional[Dict[str, object]]:
    """Score ``diagnose_fleet`` on every fleet scenario's (hosts, C, T)
    slab, clipped shortly after the shared burst so the trailing detection
    window contains it (the streaming deployment's snapshot timing)."""
    groups: Dict[int, List[scen.ScenarioTrial]] = {}
    for t in trials:
        if t.scenario == "fleet_nic":
            groups.setdefault(t.group, []).append(t)
    if not groups:
        return None
    mon = FleetMonitor(use_kernels=use_kernels)
    tp = fp = fn = correct = 0
    for members in groups.values():
        members.sort(key=lambda t: t.host)
        affected = {t.host for t in members if t.truth}
        burst = next(t.truth[0] for t in members if t.truth)
        t_hi = int((burst.t_on + 6.0) * rate_hz)
        slab = np.ascontiguousarray(
            np.stack([t.data[:, :t_hi] for t in members]), np.float32)
        fd = mon.diagnose_fleet(members[0].ts[:t_hi], slab,
                                members[0].channels)
        flagged = set(fd.flagged_hosts)
        tp += len(flagged & affected)
        fp += len(flagged - affected)
        fn += len(affected - flagged)
        correct += sum(1 for h in (flagged & affected)
                       if fd.diagnoses[h].top_cause == burst.kind)
    return {
        "n_incidents": len(groups),
        "flagged_precision": tp / (tp + fp) if (tp + fp) else None,
        "flagged_recall": tp / (tp + fn) if (tp + fn) else None,
        "top_cause_accuracy": correct / tp if tp else None,
    }


def build_scorecard(n_per_class: int = 4, seed: int = SUITE_SEED, *,
                    duration_s: float = scen.DURATION_S,
                    rate_hz: float = 100.0, tol_s: float = scoring.TOL_S,
                    n_hosts: int = 6, n_affected: int = 2,
                    use_kernels: bool = False) -> Dict[str, object]:
    trials = scen.build_suite(n_per_class, seed, duration_s=duration_s,
                              rate_hz=rate_hz, n_hosts=n_hosts,
                              n_affected=n_affected)
    store = TrialStore.from_trials(trials)
    eng = CorrelationEngine()
    rows = store.rows()

    per_event = [eng.process(*r) for r in rows]
    batched = eng.process_batch(rows)
    slab = eng.process_store(store.ts, store.slab, store.channels)
    bp, bt = _parity(per_event, batched)
    sp, st = _parity(per_event, slab)

    by_class: Dict[str, List[scoring.TrialScore]] = {}
    for t, diags in zip(trials, per_event):
        verds = scoring.verdict_events(diags)
        by_class.setdefault(t.scenario, []).append(
            scoring.score_trial(t.truth, verds, tol_s))
    scenarios_doc = {
        name: dict(scoring.summarize(by_class[name]),
                   description=scen.scenario_spec(name).description,
                   multi_fault=scen.scenario_spec(name).multi_fault)
        for name in by_class
    }
    return {
        "protocol": {
            "suite_seed": seed,
            "n_per_class": n_per_class,
            "classes": list(scen.SCENARIO_CLASSES),
            "duration_s": duration_s,
            "rate_hz": rate_hz,
            "match_tolerance_s": tol_s,
            "detect_target_s": scoring.DETECT_TARGET_S,
            "rca_target_s": scoring.RCA_TARGET_S,
            "n_trials": len(trials),
            "fleet_hosts": n_hosts,
            "fleet_affected": n_affected,
            "use_kernels": use_kernels,
        },
        "scenarios": scenarios_doc,
        "fleet": _fleet_block(trials, rate_hz, use_kernels),
        "parity": {
            "batched_pred": bp, "batched_ts": bt,
            "slab_pred": sp, "slab_ts": st,
        },
        "overall": scoring.summarize(
            [s for ss in by_class.values() for s in ss]),
    }


def scorecard_rows(doc: Dict[str, object]) -> List[Tuple[str, float, str]]:
    """Flatten the headline scorecard numbers into benchmark CSV rows."""
    rows: List[Tuple[str, float, str]] = []
    for k, v in doc["parity"].items():
        rows.append((f"scorecard/parity/{k}", float(v),
                     "1.0 = verdict stream identical to per-event"))
    for name, blk in doc["scenarios"].items():
        for key in ("recall", "accuracy"):
            if blk[key] is not None:
                rows.append((f"scorecard/{key}/{name}", float(blk[key]), ""))
        rows.append((f"scorecard/false_verdicts/{name}",
                     float(blk["false_verdicts"]), ""))
        if blk["detect_latency_s"]:
            rows.append((f"scorecard/detect_p50_s/{name}",
                         blk["detect_latency_s"]["p50"], "vs 5 s target"))
            rows.append((f"scorecard/rca_p50_s/{name}",
                         blk["rca_latency_s"]["p50"], "vs 8 s target"))
    if doc["fleet"]:
        for k, v in doc["fleet"].items():
            if v is not None:
                rows.append((f"scorecard/fleet/{k}", float(v), ""))
    return rows


def smoke_rows() -> List[Tuple[str, float, str]]:
    """Tiny-suite scorecard rows for ``benchmarks/run.py --smoke`` and the
    ``bench_smoke`` pytest canary."""
    doc = build_scorecard(n_per_class=1, n_hosts=4, n_affected=2)
    return scorecard_rows(doc)


def write(doc: Dict[str, object], path: str) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--out", default=ARTIFACT)
    ap.add_argument("--n-per-class", type=int, default=4)
    ap.add_argument("--seed", type=int, default=SUITE_SEED)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny suite (1 per class, 4-host fleet)")
    args = ap.parse_args()
    if args.smoke:
        doc = build_scorecard(n_per_class=1, seed=args.seed, n_hosts=4,
                              n_affected=2)
    else:
        doc = build_scorecard(n_per_class=args.n_per_class, seed=args.seed)
    for name, value, derived in scorecard_rows(doc):
        print(f"{name},{value:.6g},{derived}")
    write(doc, args.out)


if __name__ == "__main__":
    main()
