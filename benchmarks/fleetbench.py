"""Fleet-RCA throughput + detection-sweep benchmarks (perf trajectory).

Two sections, both emitted into BENCH_fleet.json by run.py:

  sweep/  — full-trial ``CorrelationEngine.process`` wall time, rolling-
            statistics fast path vs the seed scalar per-tick path, at the
            default boundary cadence and at the 10-sample streaming cadence.
  fleet/  — batched ``FleetMonitor.diagnose_fleet`` vs B sequential
            per-host ``engine.process`` replays, at B in {16, 64, 256,
            1024}: hosts/sec, speedup, and per-stage wall time.

The batched fleet path runs the fused spike+xcorr math through the jit'd
XLA reference (`use_kernels=False`) — on CPU the Pallas kernels execute in
interpret mode, which validates numerics but is not a timing path; kernel
parity is covered by tests/test_fused.py.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import numpy as np

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.monitor.fleet import FleetMonitor
from repro.sim.scenario import make_trial

_CLIP_S = 46.0     # trailing snapshot: event at t_on=40 s is inside it


def _median_wall(fn, reps: int = 3) -> float:
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


# ---------------------------------------------------------------- sweep bench
def sweep_rows(n_trials: int = 8, reps: int = 3,
               ) -> List[Tuple[str, float, str]]:
    """Rolling-stats engine sweep vs seed scalar path, same trials."""
    trials = [make_trial(7000 + i, ["io", "cpu", "nic", "gpu"][i % 4])
              for i in range(n_trials)]
    rows: List[Tuple[str, float, str]] = []
    for tag, cfg in (("boundary", EngineConfig()),
                     ("10ms", EngineConfig(eval_every=10))):
        eng = CorrelationEngine(cfg)

        def run(fast: bool) -> None:
            for t in trials:
                eng.process(t.ts, t.data, t.channels, fast=fast)

        fast_s = _median_wall(lambda: run(True), reps)
        scalar_s = _median_wall(lambda: run(False), reps)
        rows.append((f"sweep/rolling_s/{tag}", fast_s,
                     f"{n_trials} trials, 90s @100Hz"))
        rows.append((f"sweep/scalar_s/{tag}", scalar_s, "seed per-tick path"))
        rows.append((f"sweep/speedup/{tag}", scalar_s / fast_s,
                     "scalar / rolling"))
    return rows


# ---------------------------------------------------------------- fleet bench
def _make_fleet(n_hosts: int, bad_host: int, seed: int = 0,
                n_unique: int = 16, cls: str = "nic"):
    """(ts, (hosts, C, T) data, channels).  Quiet hosts cycle over
    ``n_unique`` distinct ambient trials (fleet-size-independent setup
    cost); one injected straggler."""
    quiet = [make_trial(seed + u, cls, intensity=0.0, t_on=40.0,
                        confuser_prob=0.0)
             for u in range(min(n_unique, n_hosts))]
    bad = make_trial(seed + 777, cls, intensity=2.0, t_on=40.0,
                     confuser_prob=0.0)
    t_hi = int(_CLIP_S * quiet[0].rate_hz)
    data = np.stack([(bad if h == bad_host else quiet[h % len(quiet)])
                     .data[:, :t_hi] for h in range(n_hosts)])
    return quiet[0].ts[:t_hi], data, quiet[0].channels


def fleet_rows(batch_sizes: Sequence[int] = (16, 64, 256, 1024),
               reps: int = 3) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for B in batch_sizes:
        ts, data, channels = _make_fleet(B, bad_host=B // 2)
        mon = FleetMonitor(use_kernels=False)
        mon.diagnose_fleet(ts, data, channels)          # jit warm-up
        mon._strikes = {}

        def batched() -> None:
            mon._strikes = {}
            batched.fd = mon.diagnose_fleet(ts, data, channels)

        batched_s = _median_wall(batched, reps)
        fd = batched.fd
        eng = CorrelationEngine()

        def sequential() -> None:
            for h in range(B):
                eng.process(ts, data[h], channels)

        seq_s = _median_wall(sequential, max(1, reps - 1))
        rows.append((f"fleet/batched_s/B{B}", batched_s,
                     f"{len(fd.flagged_hosts)} flagged, straggler="
                     f"{fd.straggler_host}"))
        rows.append((f"fleet/sequential_s/B{B}", seq_s,
                     "B x engine.process (rolling fast path)"))
        rows.append((f"fleet/hosts_per_s/B{B}", B / batched_s, "batched"))
        rows.append((f"fleet/speedup/B{B}", seq_s / batched_s,
                     "sequential / batched"))
        for stage, wall in fd.stage_seconds.items():
            rows.append((f"fleet/stage_s/{stage}/B{B}", wall, ""))
    return rows
