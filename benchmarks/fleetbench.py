"""Fleet-RCA throughput + detection-sweep benchmarks (perf trajectory).

Four sections, all emitted into BENCH_fleet.json by run.py:

  sweep/  — full-trial ``CorrelationEngine.process`` wall time, rolling-
            statistics fast path vs the seed scalar per-tick path, at the
            default boundary cadence and at the 10-sample streaming cadence.
  fleet/  — batched ``FleetMonitor.diagnose_fleet`` vs B sequential
            per-host ``engine.process`` replays, at B in {16, 64, 256,
            1024}: hosts/sec, speedup, per-stage wall time, plus the
            streaming-detect kernel (one dispatch over the f32 slab) vs the
            seed detect path (spike dispatch + f64 ``detect_rows`` replay)
            with a byte-exact flagged/onset parity check.
  fleet/live_* — the live path: ``FleetAggregator`` staging (seqlock
            read_window into a preallocated slab) vs per-host
            ``window(copy=True)`` snapshots + ``np.stack``, and the
            torn-read retry rate of the seqlock reader under a
            writer-storm thread.
  eval/   — event-batched Layer 3: ``run_eval`` with all trials' events in
            ONE fused dispatch per diagnoser vs the per-event path, plus
            the columnar TrialStore path (slab-indexed evidence gather,
            ``SLICE_OPS``-counted).

The batched fleet path runs the fused spike+xcorr math through the jit'd
XLA reference (`use_kernels=False`) — on CPU the Pallas kernels execute in
interpret mode, which validates numerics but is not a timing path; kernel
parity is covered by tests/test_fused.py.
"""
from __future__ import annotations

import sys
import threading
import time
from typing import List, Sequence, Tuple

import numpy as np

from repro.core import engine as engine_mod
from repro.core.baselines import make_baseline
from repro.core.engine import CorrelationEngine, EngineConfig
from repro.monitor.aggregator import FleetAggregator
from repro.monitor.fleet import FleetMonitor
from repro.sim.scenario import (
    N_PER_CLASS, PROTOCOL_CLASSES, TrialStore, make_trial,
)
from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import SimCollector
from repro.telemetry.ringbuffer import MultiChannelRing

_CLIP_S = 46.0     # trailing snapshot: event at t_on=40 s is inside it


def _median_wall(fn, reps: int = 3) -> float:
    walls = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls))


def _median_stages(mon: FleetMonitor, ts, data, channels, reps: int,
                   ):
    """(median wall, per-stage median seconds, last FleetDiagnosis) over
    ``reps`` diagnose_fleet calls — stage attribution from one run is a
    single sample and too noisy to report."""
    walls, stages = [], {}
    fd = None
    for _ in range(reps):
        mon._strikes = {}
        t0 = time.perf_counter()
        fd = mon.diagnose_fleet(ts, data, channels)
        walls.append(time.perf_counter() - t0)
        for k, v in fd.stage_seconds.items():
            stages.setdefault(k, []).append(v)
    med = {k: float(np.median(v)) for k, v in stages.items()}
    return float(np.median(walls)), med, fd


# ---------------------------------------------------------------- sweep bench
def sweep_rows(n_trials: int = 8, reps: int = 3,
               ) -> List[Tuple[str, float, str]]:
    """Rolling-stats engine sweep vs seed scalar path, same trials."""
    trials = [make_trial(7000 + i,
                         PROTOCOL_CLASSES[i % len(PROTOCOL_CLASSES)])
              for i in range(n_trials)]
    rows: List[Tuple[str, float, str]] = []
    for tag, cfg in (("boundary", EngineConfig()),
                     ("10ms", EngineConfig(eval_every=10))):
        eng = CorrelationEngine(cfg)

        def run(fast: bool) -> None:
            for t in trials:
                eng.process(t.ts, t.data, t.channels, fast=fast)

        fast_s = _median_wall(lambda: run(True), reps)
        scalar_s = _median_wall(lambda: run(False), reps)
        rows.append((f"sweep/rolling_s/{tag}", fast_s,
                     f"{n_trials} trials, 90s @100Hz"))
        rows.append((f"sweep/scalar_s/{tag}", scalar_s, "seed per-tick path"))
        rows.append((f"sweep/speedup/{tag}", scalar_s / fast_s,
                     "scalar / rolling"))
    return rows


# ----------------------------------------------------------- slab sweep bench
def _event_sig(evs) -> list:
    """Deterministic signature of one row's (event, rca_index) list."""
    return [(ev.t_onset, ev.t_detect, ev.score, int(t)) for ev, t in evs]


def sweep_slab_rows(n_per_class: int = 4, reps: int = 3,
                    fleet_hosts: int = 256,
                    ) -> List[Tuple[str, float, str]]:
    """Suite-scale Layer-2: per-trial ``detect_events`` loop vs the one-
    dispatch slab sweep (``detect_events_store``), on the full multi-fault
    scenario suite (the scorecard's trials), at the boundary cadence and
    the 10-sample streaming cadence.

    ``eval/sweep_parity`` is the byte-exact invariant CI gates on: the
    slab path must reproduce the per-row oracle's event sets —
    ``t_onset`` / ``t_detect`` stamps, scores AND rca indices — across
    every trial of the suite (cooldown, pending flush and multi-event
    trials included), at both cadences.  ``fleet/sweep_single_tick``
    records the fleet-detect reuse of the same sweep core (one tick at
    the slab edge) against the f64 ``detect_rows`` oracle.
    """
    from repro.core.spike import detect_rows
    from repro.kernels.detect import ops as detect_ops
    from repro.sim import scenarios as scen

    rows: List[Tuple[str, float, str]] = []
    trials = scen.build_suite(n_per_class, 41)
    store = TrialStore.from_trials(trials)
    parity = 1.0
    for tag, cfg in (("boundary", EngineConfig()),
                     ("10ms", EngineConfig(eval_every=10))):
        eng = CorrelationEngine(cfg)

        def loop():
            return [eng.detect_events(store.ts, store.slab[i],
                                      store.channels)
                    for i in range(len(store))]

        def slab():
            return eng.detect_events_store(store.ts, store.slab,
                                           store.channels)

        ref, got = loop(), slab()
        parity = min(parity, float(
            [_event_sig(e) for e in ref] == [_event_sig(e) for e in got]))
        loop_s = _median_wall(loop, reps)
        slab_s = _median_wall(slab, reps)
        n_ev = sum(len(e) for e in ref)
        rows.append((f"eval/sweep_loop_s/{tag}", loop_s,
                     f"per-trial detect_events loop, {len(store)} trials, "
                     f"{n_ev} events"))
        rows.append((f"eval/sweep_slab_s/{tag}", slab_s,
                     "one batched sweep dispatch + numpy resolve"))
        rows.append((f"eval/sweep_speedup/{tag}", loop_s / slab_s,
                     "per-trial loop / slab sweep"))
    rows.append(("eval/sweep_parity", parity,
                 "1.0 = slab events byte-exact vs per-row oracle "
                 "(stamps, scores, rca indices; both cadences)"))

    # fleet reuse: the same sweep core at a single tick IS the streaming
    # fleet detect — time it on a fleet slab and re-prove detect_rows parity
    cfg = EngineConfig()
    wn, bn = cfg.window_n, cfg.baseline_n
    H = int(fleet_hosts)
    ts, data, channels = _make_fleet(H, bad_host=H // 2)
    li = list(channels).index(cfg.latency_metric)
    T = data.shape[-1]
    tail = np.ascontiguousarray(data[:, li, T - wn - bn:], np.float32)

    def single_tick():
        # the CPU deployment path: masked-XLA ref (the Pallas kernel runs
        # in interpret mode on CPU — a correctness, not a timing, path)
        return detect_ops.detect_hosts_slab(tail, wn, bn, cfg.threshold,
                                            cfg.persistence,
                                            use_kernel=False)

    single_tick()                                          # jit warm-up
    tick_s = _median_wall(single_tick, reps)
    fire, _, onset = single_tick()
    t64 = np.asarray(tail, np.float64)
    f0, _, o0 = detect_rows(t64[:, bn:], t64[:, :bn], cfg.threshold,
                            cfg.persistence)
    rows.append((f"fleet/sweep_single_tick_s/H{H}", tick_s,
                 "fleet detect through the shared sweep core, one tick"))
    rows.append((f"fleet/sweep_single_tick_parity/H{H}",
                 float(np.array_equal(fire, f0)
                       and np.array_equal(onset, o0)),
                 "1.0 = fire/onset byte-exact vs f64 detect_rows"))
    return rows


# ---------------------------------------------------------------- fleet bench
def _make_fleet(n_hosts: int, bad_host: int, seed: int = 0,
                n_unique: int = 16, cls: str = "nic",
                bad_every: int = 0):
    """(ts, (hosts, C, T) data, channels).  Quiet hosts cycle over
    ``n_unique`` distinct ambient trials (fleet-size-independent setup
    cost); one injected straggler.  ``bad_every`` > 0 additionally injects
    every bad_every'th host (the incident-storm profile: the seed detect
    path re-slices every candidate in f64, so its cost scales with the
    flagged fraction)."""
    quiet = [make_trial(seed + u, cls, intensity=0.0, t_on=40.0,
                        confuser_prob=0.0)
             for u in range(min(n_unique, n_hosts))]
    n_bad = min(8, n_hosts)
    bad = [make_trial(seed + 777 + u, cls, intensity=2.0, t_on=40.0,
                      confuser_prob=0.0) for u in range(n_bad)]
    t_hi = int(_CLIP_S * quiet[0].rate_hz)

    def pick(h):
        if h == bad_host or (bad_every and h % bad_every == 0):
            return bad[h % n_bad]
        return quiet[h % len(quiet)]

    data = np.stack([pick(h).data[:, :t_hi] for h in range(n_hosts)])
    return quiet[0].ts[:t_hi], data, quiet[0].channels


def _detect_compare_rows(B: int, ts, data, data32, channels, reps: int,
                         tag: str = "") -> Tuple[list, float, dict, object]:
    """Streaming-detect vs seed-detect stage rows for one fleet slab.

    Returns (rows, batched wall, median stages, FleetDiagnosis of the
    fast path)."""
    mon = FleetMonitor(use_kernels=False)
    oracle = FleetMonitor(use_kernels=False, fast_detect=False)
    mon.diagnose_fleet(ts, data32, channels)            # jit warm-up
    oracle.diagnose_fleet(ts, data, channels)

    batched_s, stages, fd = _median_stages(mon, ts, data32, channels, reps)
    _, stages_o, fd_o = _median_stages(oracle, ts, data, channels, reps)
    parity = float(
        fd.flagged_hosts == fd_o.flagged_hosts
        and all(fd.diagnoses[h].event.t_onset
                == fd_o.diagnoses[h].event.t_onset
                for h in fd.flagged_hosts))
    det_f, det_o = stages["detect"], stages_o["detect"]
    rows = [
        (f"fleet/detect_fast_s{tag}/B{B}", det_f,
         f"one streaming-detect dispatch, f32 slab; "
         f"{len(fd.flagged_hosts)} flagged"),
        (f"fleet/detect_oracle_s{tag}/B{B}", det_o,
         "seed: spike dispatch + f64 detect_rows replay"),
        (f"fleet/detect_speedup{tag}/B{B}", det_o / det_f,
         "oracle / streaming"),
        (f"fleet/detect_parity{tag}/B{B}", parity,
         "1.0 = flagged hosts + onsets byte-exact"),
    ]
    return rows, batched_s, stages, fd


def fleet_rows(batch_sizes: Sequence[int] = (16, 64, 256, 1024),
               reps: int = 3, sequential_baseline: bool = True,
               ) -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    for B in batch_sizes:
        ts, data, channels = _make_fleet(B, bad_host=B // 2)
        # the columnar deployment hands the monitor the ring's f32 slab;
        # the oracle monitor replays the seed path on the seed's f64 slab
        data32 = np.ascontiguousarray(data, np.float32)
        det_rows, batched_s, stages, fd = _detect_compare_rows(
            B, ts, data, data32, channels, reps)
        rows.append((f"fleet/batched_s/B{B}", batched_s,
                     f"{len(fd.flagged_hosts)} flagged, straggler="
                     f"{fd.straggler_host}"))
        rows.append((f"fleet/hosts_per_s/B{B}", B / batched_s, "batched"))
        rows += det_rows
        if sequential_baseline:
            eng = CorrelationEngine()

            def sequential() -> None:
                for h in range(B):
                    eng.process(ts, data[h], channels)

            seq_s = _median_wall(sequential, max(1, reps - 1))
            rows.append((f"fleet/sequential_s/B{B}", seq_s,
                         "B x engine.process (rolling fast path, f64)"))
            rows.append((f"fleet/speedup/B{B}", seq_s / batched_s,
                         "f64 sequential / f32-columnar batched (PR 2 "
                         "redefined the batched side; detect_* rows are "
                         "the like-for-like comparison)"))
        for stage, wall in stages.items():
            rows.append((f"fleet/stage_s/{stage}/B{B}", wall, ""))
    # incident-storm profile at the largest B: ~1/4 of the fleet degraded.
    # The seed path re-slices every candidate host in f64 and replays the
    # scalar rule over it, so its detect cost grows with the flagged
    # fraction; the streaming kernel's one-dispatch cost does not.
    B = max(batch_sizes)
    ts, data, channels = _make_fleet(B, bad_host=B // 2, bad_every=4)
    data32 = np.ascontiguousarray(data, np.float32)
    det_rows, _, _, _ = _detect_compare_rows(B, ts, data, data32, channels,
                                             reps, tag="_storm")
    rows += det_rows
    return rows


# -------------------------------------------------------------- sharded bench
def _shard_pool(seed: int = 9300, cls: str = "nic", t_on: float = 28.0,
                clip_s: float = 34.0):
    """(ts, (n_unique+n_bad, C, T') f32 trial pool, channels, n_quiet).

    The provider-fed storm rows assemble each shard's slab from this
    fixed pool, so fleet size costs shard-slab assembly — the full
    (B, C, T) array never exists (the point of the provider API).
    Trials are clipped tighter than ``_CLIP_S`` (event at ``t_on`` still
    inside the trailing window) to keep the 64k-host row affordable."""
    quiet = [make_trial(seed + u, cls, intensity=0.0, t_on=t_on,
                        confuser_prob=0.0) for u in range(16)]
    bad = [make_trial(seed + 777 + u, cls, intensity=2.0, t_on=t_on,
                      confuser_prob=0.0) for u in range(8)]
    t_hi = int(clip_s * quiet[0].rate_hz)
    pool = np.stack([t.data[:, :t_hi] for t in quiet + bad]
                    ).astype(np.float32)
    return quiet[0].ts[:t_hi], pool, quiet[0].channels, len(quiet)


def shard_rows(parity_hosts: int = 96,
               storm_hosts: Sequence[int] = (16384, 65536),
               shard_hosts: int = None, reps: int = 3,
               ) -> List[Tuple[str, float, str]]:
    """Sharded fleet monitor: byte-exact parity bit + 10k+-host scale-out.

    Two sections:

      fleet/shard_parity      the CI-gated bit (``benchmarks/regress.py``
                              requires exactly 1.0): single-slab vs
                              sharded ``verdict_fingerprint`` equality on
                              a ragged 3-shard plan across a clean round,
                              two corruption rounds (quarantine entry),
                              an incident-storm round with an RCA top-K
                              cap (deferral), and the provider path with
                              late-surfacing corruption (oracle re-visit
                              of fast-path shards);
      fleet/shard_*/B{B}      storm-profile throughput + cross-shard
                              traffic at 16k-64k hosts through
                              ``diagnose_sharded`` — shard slabs are
                              materialized one at a time from a fixed
                              trial pool, and the rows record what
                              actually crossed the rack->fleet tree
                              (candidate scalars + pruned evidence
                              blocks) against the raw-slab
                              counterfactual.
    """
    from repro.kernels import tuning
    from repro.monitor.shard import (
        ShardPlan, ShardedFleetMonitor, verdict_fingerprint,
    )

    rows: List[Tuple[str, float, str]] = []
    cfg = EngineConfig()

    # ---- parity scenario on a deliberately ragged plan
    H = int(parity_hosts)
    cut1, cut2 = H // 3, 2 * H // 3 + 1
    plan = ShardPlan.from_bounds([(0, cut1), (cut1, cut2), (cut2, H)],
                                 rack_shards=2)
    ts, clean, channels = _make_fleet(H, bad_host=cut1 + 1, seed=9400)
    _, storm, _ = _make_fleet(H, bad_host=cut1 + 1, seed=9400, bad_every=5)
    li = list(channels).index(cfg.latency_metric)
    valid = np.ones(clean.shape, bool)
    valid[H - 2, li, -1200:] = False      # ~half the detect tail invalid

    mono = FleetMonitor(use_kernels=False, rca_top_k=4)
    shard = ShardedFleetMonitor(plan, use_kernels=False, rca_top_k=4)
    parity, n_rounds = 1.0, 0
    fd = None
    for data, v in ((clean, None), (clean, valid), (clean, valid),
                    (storm, None)):
        a = mono.diagnose_fleet(ts, data, channels, valid=v)
        fd = shard.diagnose_fleet(ts, data, channels, valid=v)
        parity = min(parity, float(
            verdict_fingerprint(a) == verdict_fingerprint(fd)))
        n_rounds += 1
    covered = bool(a.quarantined) and bool(a.deferred_hosts)
    # provider path: corruption on the LAST shard only — the fast-path
    # shards must be re-visited through the oracle and still match the
    # single-slab masked round
    pvalid = np.ones(clean.shape, bool)
    pvalid[H - 2, li, -200:] = False      # below the quarantine threshold
    calls: List[int] = []

    def provider(s: int):
        calls.append(s)
        a0, b0 = plan.bounds[s]
        return clean[a0:b0], pvalid[a0:b0]

    shard2 = ShardedFleetMonitor(plan, use_kernels=False)
    fdp = shard2.diagnose_sharded(ts, provider, channels)
    ref = FleetMonitor(use_kernels=False).diagnose_fleet(
        ts, clean, channels, valid=pvalid)
    revisited = len(calls) == plan.n_shards + 2
    parity = min(parity, float(
        verdict_fingerprint(fdp) == verdict_fingerprint(ref)
        and revisited and covered))
    rows.append(("fleet/shard_parity", parity,
                 f"1.0 = sharded verdicts byte-exact vs single slab over "
                 f"{n_rounds + 1} rounds (ragged shards, quarantine, "
                 "top-K deferral, oracle re-visit)"))
    tr = shard.last_traffic
    rows.append((f"fleet/shard_xfer_frac/H{H}",
                 tr.total_bytes / tr.raw_bytes,
                 "storm round, rca_top_k=4: bytes crossing the tree / "
                 "raw shard slabs"))

    # ---- storm-profile scale-out through the provider API
    sh = tuning.shard_hosts(shard_hosts)
    topk = tuning.shard_topk()
    pts, pool, pchannels, n_quiet = _shard_pool()
    n_pool = pool.shape[0]

    def make_provider(plan_b, bad_host, bad_every):
        def prov(s: int):
            a0, b0 = plan_b.bounds[s]
            idx = np.array(
                [n_quiet + h % (n_pool - n_quiet)
                 if (h == bad_host or (bad_every and h % bad_every == 0))
                 else h % n_quiet
                 for h in range(a0, b0)])
            return pool[idx], None
        return prov

    # jit warm-up at one shard so the timed rounds hit the compile cache
    warm = ShardedFleetMonitor(
        ShardPlan.from_bounds([(0, min(sh, 64))], rack_shards=1),
        use_kernels=False)
    warm.diagnose_sharded(
        pts, make_provider(warm.plan, bad_host=1, bad_every=16), pchannels)

    for B in storm_hosts:
        B = int(B)
        plan_b = ShardPlan.for_fleet(B, shard_hosts=sh)
        mon = ShardedFleetMonitor(plan_b, use_kernels=False,
                                  rca_top_k=topk)
        prov = make_provider(plan_b, bad_host=1, bad_every=16)
        walls = []
        fd = None
        for _ in range(max(1, reps - 2)):
            mon._strikes = {}
            t0 = time.perf_counter()
            fd = mon.diagnose_sharded(pts, prov, pchannels)
            walls.append(time.perf_counter() - t0)
        round_s = float(np.median(walls))
        tr = mon.last_traffic
        tag = f"B{B}"
        rows.append((f"fleet/shard_round_s/{tag}", round_s,
                     f"{plan_b.n_shards} shards x {sh} hosts, "
                     f"{plan_b.n_racks} racks, storm bad_every=16, "
                     f"rca_top_k={topk}, {len(fd.flagged_hosts)} flagged"))
        rows.append((f"fleet/shard_hosts_per_s/{tag}", B / round_s,
                     "provider-fed sharded round (slab assembly included)"))
        rows.append((f"fleet/shard_stage_detect_s/{tag}",
                     fd.stage_seconds.get("detect", 0.0),
                     "sum of per-shard detect dispatches"))
        rows.append((f"fleet/shard_stage_reduce_s/{tag}",
                     fd.stage_seconds.get("reduce", 0.0),
                     "rack->fleet candidate merge + evidence pruning"))
        rows.append((f"fleet/shard_xfer_bytes/{tag}",
                     float(tr.total_bytes),
                     f"{tr.n_candidates} candidate records + "
                     f"{tr.n_evidence} evidence blocks + per-host scores"))
        rows.append((f"fleet/shard_xfer_frac/{tag}",
                     tr.total_bytes / tr.raw_bytes,
                     "vs shipping every raw shard slab to the fleet level"))
    return rows


# ------------------------------------------- incremental streaming moments
def _drive_incremental(mons, ts, data32, channels, round_ticks,
                       chaos_round: int, li: int):
    """Drive monitors in lockstep over the growing-window schedule.

    Each round every monitor sees the identical slab slice back to back
    (interleaving keeps allocator/page-cache warming symmetric between
    the warm and cold variants).  Returns per-monitor
    ``(detect_s, wall_s, fingerprints)`` lists plus the rounds in which
    ``mons[0]``'s incremental state re-anchored.  Round ``chaos_round``
    carries a validity mask with a corrupted latency tail on one host —
    the masked-oracle round that must invalidate the incremental state
    without moving any verdict.
    """
    from repro.monitor.shard import verdict_fingerprint
    inc = getattr(mons[0], "_inc", None)
    det = [[] for _ in mons]
    walls = [[] for _ in mons]
    fps = [[] for _ in mons]
    re_rounds = []
    B = data32.shape[0]
    for i, tk in enumerate(round_ticks):
        vmask = None
        if i == chaos_round:
            vmask = np.ones((B, len(channels), tk), bool)
            vmask[B // 2, li, -200:] = False
        re0 = inc.reanchors if inc is not None else 0
        for j, mon in enumerate(mons):
            t0 = time.perf_counter()
            fd = mon.diagnose_fleet(ts[:tk], data32[:, :, :tk], channels,
                                    valid=vmask)
            walls[j].append(time.perf_counter() - t0)
            det[j].append(fd.stage_seconds["detect"])
            fps[j].append(verdict_fingerprint(fd))
        if inc is not None and inc.reanchors > re0:
            re_rounds.append(i)
    return det, walls, fps, re_rounds


def incremental_rows(batch_sizes: Sequence[int] = (256, 1024),
                     shard_batch: int = 16384,
                     start_s: float = 36.0, step_s: float = 0.5,
                     reanchor_every: int = 6, chaos_round: int = 8,
                     ) -> List[Tuple[str, float, str]]:
    """Incremental O(delta) streaming moments vs the per-round direct pass.

    Emits, per quiet fleet size B (plus a storm profile at the largest B
    and a provider-fed sharded fleet at ``shard_batch``):

      fleet/incremental_speedup/*   warm incremental monitor vs the same
                                    monitor recomputing moments from
                                    scratch every round
                                    (``incremental=False`` — the PR 9
                                    detect stage), median over a
                                    growing-window round schedule.
      fleet/incremental_parity      the CI-gated bit (exactly 1.0):
                                    every re-anchor bitwise-matched the
                                    carried block state, the chaos round
                                    forced invalidation + rebuild, and
                                    the incremental monitor's verdict
                                    fingerprints equal the from-scratch
                                    monitor's on every round (masked
                                    round included) — plain and sharded.
      fleet/incremental_reanchor_s  detect-stage cost of a re-anchor
                                    round (state rebuilt AND compared).
      fleet/incremental_round_cpu_frac/B*  full monitor round as a
                                    fraction of the round period — the
                                    analysis-side cousin of the paper's
                                    1.21 % collection overhead.

    The schedule appends ``step_s`` of fresh ticks per round — the live
    cadence the incremental state is built for — with one masked chaos
    round in the middle and ``reanchor_every`` small enough that several
    re-anchors land inside the window.
    """
    cfg = EngineConfig()
    rate = cfg.rate_hz
    rows: List[Tuple[str, float, str]] = []
    parity_ok = True
    reanchor_costs: List[float] = []

    def schedule(t_end_s: float):
        return list(range(int(start_s * rate), int(t_end_s * rate) + 1,
                          max(1, int(step_s * rate))))

    def keep(idx, n, re_rounds=()):
        # round 0 pays the one-time cold build (and the process-wide XLA
        # compile); the chaos round is the masked oracle on both
        # monitors and the round after it is the forced full rebuild;
        # re-anchor rounds are costed separately by
        # fleet/incremental_reanchor_s (the bench runs them 5x denser
        # than the REPRO_REANCHOR_ROUNDS=32 default to exercise the
        # parity machinery) — none of these is the quiet steady state
        drop = {0, idx, idx + 1} | set(re_rounds)
        return [i for i in range(n) if i not in drop]

    def compare(tag: str, ts, data32, channels, make_warm, make_cold,
                round_ticks):
        nonlocal parity_ok
        li = list(channels).index(cfg.latency_metric)
        mon_w, mon_c = make_warm(), make_cold()
        det, walls, fps, re_rounds = _drive_incremental(
            [mon_w, mon_c], ts, data32, channels, round_ticks,
            chaos_round, li)
        (det_w, det_c), (fp_w, fp_c) = det, fps
        st = mon_w.incremental_stats() or {}
        if fp_w != fp_c or st.get("parity") != 1.0 \
                or not re_rounds or not st.get("forced_invalidations"):
            parity_ok = False
        reanchor_costs.extend(det_w[i] for i in re_rounds if i != 0)
        ok = keep(chaos_round, len(round_ticks), re_rounds)
        sp = (float(np.median([det_c[i] for i in ok]))
              / float(np.median([det_w[i] for i in ok])))
        rows.append((f"fleet/incremental_speedup/{tag}", round(sp, 3),
                     "detect stage, warm block-cached moments vs "
                     "from-scratch per round, median over "
                     f"{len(ok)} appended-delta rounds"))
        return float(np.median([walls[0][i] for i in ok]))

    for B in batch_sizes:
        ts, data, channels = _make_fleet(B, bad_host=min(3, B - 1))
        data32 = np.ascontiguousarray(data, np.float32)

        def warm():
            m = FleetMonitor(use_kernels=False)
            m._inc.reanchor_every = reanchor_every
            return m

        wall = compare(f"B{B}", ts, data32, channels, warm,
                       lambda: FleetMonitor(use_kernels=False,
                                            incremental=False),
                       schedule(_CLIP_S))
        rows.append((f"fleet/incremental_round_cpu_frac/B{B}",
                     round(wall / step_s, 4),
                     "median full monitor round / round period "
                     f"({step_s} s cadence) — analysis-side overhead, "
                     "paper's collection target is 1.21%"))

    if batch_sizes:
        B = max(batch_sizes)
        ts, data, channels = _make_fleet(B, bad_host=3, bad_every=4)
        data32 = np.ascontiguousarray(data, np.float32)

        def warm_storm():
            m = FleetMonitor(use_kernels=False)
            m._inc.reanchor_every = reanchor_every
            return m

        compare(f"B{B}_storm", ts, data32, channels, warm_storm,
                lambda: FleetMonitor(use_kernels=False, incremental=False),
                schedule(_CLIP_S))

    if shard_batch:
        from repro.monitor.shard import (ShardedFleetMonitor, ShardPlan,
                                         verdict_fingerprint)
        ts_p, pool, channels_p, n_quiet = _shard_pool()
        plan = ShardPlan.for_fleet(shard_batch)
        li_p = list(channels_p).index(cfg.latency_metric)
        t_hi = pool.shape[2]
        rt = list(range(int(30.0 * rate), t_hi + 1,
                        max(1, int(step_s * rate))))
        cr = min(chaos_round, len(rt) - 2)

        # provider path: the full (B, C, T) slab never exists — each
        # shard's slab is tiled from the fixed trial pool on demand;
        # both monitors run interleaved on identical provider output
        mon_w = ShardedFleetMonitor(plan, use_kernels=False)
        mon_c = ShardedFleetMonitor(plan, use_kernels=False,
                                    incremental=False)
        # the shared round counter advances once per SHARD call; scale
        # the period so one shard re-anchors roughly every
        # ``reanchor_every`` fleet rounds (rotating re-anchor)
        mon_w._inc.reanchor_every = reanchor_every * plan.n_shards
        det_w, det_c, fp_w, fp_c, re_rounds = [], [], [], [], []
        for i, tk in enumerate(rt):
            def provider(s, tk=tk, chaos=(i == cr)):
                a, b = plan.bounds[s]
                idx = np.arange(a, b) % n_quiet
                if a <= 7 < b:
                    idx[7 - a] = n_quiet          # one bad straggler
                sl = np.ascontiguousarray(pool[idx, :, :tk])
                v = None
                if chaos and s == 0:
                    v = np.ones(sl.shape, bool)
                    v[0, li_p, -200:] = False
                return sl, v
            re0 = mon_w._inc.reanchors
            fd_w = mon_w.diagnose_sharded(ts_p[:tk], provider, channels_p)
            fd_c = mon_c.diagnose_sharded(ts_p[:tk], provider, channels_p)
            det_w.append(fd_w.stage_seconds["detect"])
            det_c.append(fd_c.stage_seconds["detect"])
            fp_w.append(verdict_fingerprint(fd_w))
            fp_c.append(verdict_fingerprint(fd_c))
            if mon_w._inc.reanchors > re0:
                re_rounds.append(i)
        st = mon_w.incremental_stats() or {}
        if fp_w != fp_c or st.get("parity") != 1.0 \
                or not re_rounds or not st.get("forced_invalidations"):
            parity_ok = False
        reanchor_costs.extend(det_w[i] for i in re_rounds if i != 0)
        ok = keep(cr, len(rt), re_rounds)
        sp = (float(np.median([det_c[i] for i in ok]))
              / float(np.median([det_w[i] for i in ok])))
        rows.append((f"fleet/incremental_speedup/B{shard_batch}",
                     round(sp, 3),
                     "sharded provider path (1024-host shards, "
                     "per-shard incremental state keyed by absolute "
                     "host id), detect stage, warm vs from-scratch, "
                     f"median over {len(ok)} appended-delta rounds"))

    rows.append(("fleet/incremental_parity", 1.0 if parity_ok else 0.0,
                 "bitwise re-anchor vs carried state + chaos-round "
                 "invalidation + verdict fingerprints equal to the "
                 "from-scratch monitor on every round (plain + sharded); "
                 "restore-path re-anchor covered by restart/"
                 "fleet_replay_parity and tests/test_rolling.py"))
    if reanchor_costs:
        rows.append(("fleet/incremental_reanchor_s",
                     float(np.median(reanchor_costs)),
                     "detect stage on a re-anchor round: from-scratch "
                     "rebuild + bitwise compare + sweep"))
    return rows


# ------------------------------------------------------------ live fleet bench
def live_rows(n_hosts: int = 8, window_s: float = 20.0, reps: int = 5,
              storm_s: float = 0.4) -> List[Tuple[str, float, str]]:
    """Live fleet path: aggregator staging vs per-host copying snapshots.

    The agents are virtual-clock driven past the ring wrap point so the
    staged window spans the wrap (the expensive case for a naive gather);
    the storm rows push from a real thread while a reader loops
    ``read_window`` and report the seqlock retry rate.
    """
    rows: List[Tuple[str, float, str]] = []
    trials = [make_trial(8200 + h, "nic",
                         intensity=(2.0 if h == n_hosts // 2 else 0.0),
                         t_on=40.0, confuser_prob=0.0)
              for h in range(n_hosts)]
    agents = []
    for t in trials:
        sim = SimCollector(t.channels, t.ts, t.data)
        agents.append(TelemetryAgent([sim], rate_hz=100.0,
                                     history_s=window_s + 10.0))
    agg = FleetAggregator(agents, window_s=window_s)
    agg.run_virtual(0.0, 46.0)          # wraps the (window+10)s rings
    agg.assemble()                       # warm-up

    assemble_s = _median_wall(agg.assemble, reps)

    def copies() -> None:
        # the seed deployment snapshot: one allocating consistent copy per
        # host, then a stacking copy into the (hosts, C, T) slab
        np.stack([a.window(window_s)[1] for a in agents])

    copy_s = _median_wall(copies, reps)
    H = n_hosts
    rows.append((f"fleet/live_assemble_s/H{H}", assemble_s,
                 f"aggregator staging, {window_s:.0f}s window, wrapped"))
    rows.append((f"fleet/live_copy_s/H{H}", copy_s,
                 "per-host window(copy=True) + np.stack"))
    rows.append((f"fleet/live_speedup/H{H}", copy_s / assemble_s,
                 "copying snapshots / aggregator staging"))

    mon = FleetMonitor(use_kernels=False)
    agg.diagnose(mon)                    # jit warm-up
    diag_s = _median_wall(lambda: agg.diagnose(mon), max(1, reps - 2))
    rows.append((f"fleet/live_diagnose_s/H{H}", diag_s,
                 "assemble + diagnose_fleet on the staged slab"))

    # torn-read retry rate under a writer storm (ring-level, wall-clock)
    ring = MultiChannelRing([f"c{i}" for i in range(8)], capacity=2048)
    stop = threading.Event()

    def writer() -> None:
        i = 0
        keys = {f"c{j}": 0.0 for j in range(8)}
        while not stop.is_set():
            ring.push_row(float(i), keys)
            i += 1

    old = sys.getswitchinterval()
    sys.setswitchinterval(1e-5)
    th = threading.Thread(target=writer, daemon=True)
    reads = 0
    try:
        th.start()
        t_end = time.perf_counter() + storm_s
        while time.perf_counter() < t_end:
            ring.read_window(512)
            reads += 1
    finally:
        stop.set()
        th.join(timeout=5.0)
        sys.setswitchinterval(old)
    rows.append(("fleet/live_storm_reads_per_s", reads / storm_s,
                 "read_window loop against a hot writer thread"))
    rows.append(("fleet/live_torn_retry_rate",
                 ring.torn_retries / max(reads, 1),
                 f"{ring.torn_retries} retries / {reads} reads — every "
                 "returned snapshot validated consistent"))
    return rows


# ---------------------------------------------------------------- chaos bench
def chaos_rows(reps: int = 3) -> List[Tuple[str, float, str]]:
    """Chaos-hardening invariants + clean-path sanitization overhead.

    Three rows CI gates on (``benchmarks/regress.py``):

      chaos/soak_false_verdicts   verdict count over one trial of each
                                  pure-corruption chaos class — a poisoned
                                  telemetry stream must yield ZERO
                                  GPU/host-fault verdicts;
      chaos/masked_parity         sweep_rows / sweep_rows_exact with an
                                  all-true validity mask vs no mask —
                                  must be byte-identical (the clean path
                                  pays for chaos hardening with nothing);
      chaos/sanitize_overhead_frac  wall cost of the per-row validity
                                  scan relative to the detection sweep it
                                  guards, on clean suite rows — bounded
                                  so sanitization stays a rounding error.
    """
    from repro.core import sanitize
    from repro.core.spike import MASK_NEG  # noqa: F401  (kernel sentinel)
    from repro.kernels.sweep import ops as sweep_ops
    from repro.sim import scenarios as scen
    from repro.sim.scenario import protocol_seed

    rows: List[Tuple[str, float, str]] = []
    cfg = EngineConfig()
    eng = CorrelationEngine(cfg)

    # 1) pure-corruption trio through the full engine: zero verdicts
    classes = list(scen.SCENARIO_CLASSES)
    n_verd = n_trials = 0
    for name in ("chaos_soak", "frozen_channel", "crash_restart"):
        t = scen.make_scenario(
            protocol_seed(41, classes.index(name), 0), name)[0]
        n_verd += len(eng.process(t.ts, t.data, t.channels))
        n_trials += 1
    rows.append(("chaos/soak_false_verdicts", float(n_verd),
                 f"verdicts over {n_trials} pure-corruption chaos trials "
                 "(must be 0)"))

    # 2) all-true mask vs no mask: byte-identical sweep outputs
    rng = np.random.default_rng(17)
    wn, bn = cfg.window_n, cfg.baseline_n
    T = bn + 3 * wn
    lat = rng.normal(10.0, 1.0, (8, T))
    lat[3, bn + wn:bn + 2 * wn] += 8.0          # one genuine spike
    ticks = np.arange(bn + wn, T + 1, wn, dtype=np.int64)
    ones = np.ones_like(lat, bool)
    parity = 1.0
    for exact in (False, True):
        fn = sweep_ops.sweep_rows_exact if exact else sweep_ops.sweep_rows
        a = fn(lat, wn, bn, ticks, cfg.threshold, cfg.persistence)
        b = fn(lat, wn, bn, ticks, cfg.threshold, cfg.persistence,
               valid=ones)
        parity = min(parity, float(all(
            np.array_equal(x, y) for x, y in zip(a, b))))
    rows.append(("chaos/masked_parity", parity,
                 "1.0 = all-true validity mask byte-identical to no mask "
                 "(sweep_rows + sweep_rows_exact)"))

    # 3) clean-path sanitization overhead vs the sweep it guards
    big = rng.normal(10.0, 1.0, (16, T))

    def scan() -> None:
        for r in range(big.shape[0]):
            sanitize.validity_mask(big[r])
            sanitize.forward_fill(big[r])

    def sweep() -> None:
        sweep_ops.sweep_rows(big, wn, bn, ticks, cfg.threshold,
                             cfg.persistence)

    sweep()                                     # jit warm-up
    scan_s = _median_wall(scan, reps)
    sweep_s = _median_wall(sweep, reps)
    rows.append(("chaos/sanitize_overhead_frac", scan_s / sweep_s,
                 "validity scan + fill wall / detection sweep wall, "
                 "clean rows (CI bound: <= 0.9)"))
    return rows


# ------------------------------------------------------------- restart bench
def restart_rows(reps: int = 1) -> List[Tuple[str, float, str]]:
    """Monitor survivability: fleet-level crash/restore replay parity,
    checkpoint wall costs, and deadline-aware shedding.

    Three invariants CI gates on (``benchmarks/regress.py``):

      restart/fleet_replay_parity  a session crashed mid-incident and
                                   warm-restored from its checkpoint must
                                   deliver the *byte-identical* verdict
                                   stream of an uninterrupted session;
      restart/duplicate_verdicts   the delivered stream (pre-crash verdicts
                                   + post-restore replay) must contain no
                                   repeated verdict signature — the
                                   restored cooldown map IS the dedup;
      restart/shed_rounds, restart/deferred_rca
                                   the degraded-mode path must actually
                                   shed (detect-only rounds) and defer RCA
                                   for unproven hosts under overload.
    """
    import os
    import tempfile

    from repro.monitor.checkpoint import MonitorSession

    rows: List[Tuple[str, float, str]] = []
    H = 8
    ts, data, channels = _make_fleet(H, bad_host=H // 2)
    slab = np.ascontiguousarray(data, np.float32)
    rate = 1.0 / float(ts[1] - ts[0])
    # one diagnosis round per second from first-full-baseline to trial end;
    # the injected fault (t_on = 40 s) enters the trailing window mid-run
    round_ticks = [min(int(r * rate), ts.shape[0])
                   for r in range(36, int(_CLIP_S) + 1)]

    def run_uninterrupted():
        sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
        out = []
        for hi in round_ticks:
            out += sess.tick(ts[:hi], slab[:, :, :hi])[1]
        return out, sess

    base_verdicts, _ = run_uninterrupted()

    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "fleet.ckpt")
        sess = MonitorSession(FleetMonitor(use_kernels=False), channels)
        delivered = []
        save_ms = []
        ckpt_bytes = 0
        crash_at = None
        for k, hi in enumerate(round_ticks):
            delivered += sess.tick(ts[:hi], slab[:, :, :hi])[1]
            t0 = time.perf_counter()
            ckpt_bytes = max(ckpt_bytes, sess.save(path))
            save_ms.append((time.perf_counter() - t0) * 1e3)
            if delivered and crash_at is None:
                crash_at = k      # crash right after the first verdict
                break
        # the process dies here: a FRESH monitor + session warm-restores
        sess2 = MonitorSession(FleetMonitor(use_kernels=False), channels)
        t0 = time.perf_counter()
        restored = sess2.restore(path)
        restore_ms = (time.perf_counter() - t0) * 1e3
        for _ in range(max(0, reps - 1)):       # timing stability only
            t0 = time.perf_counter()
            MonitorSession(FleetMonitor(use_kernels=False),
                           channels).restore(path)
            restore_ms = min(restore_ms, (time.perf_counter() - t0) * 1e3)
        for k, hi in enumerate(round_ticks):
            if crash_at is not None and k <= crash_at:
                continue          # rounds the dead process already served
            delivered += sess2.tick(ts[:hi], slab[:, :, :hi],
                                    replay=(k == (crash_at or -1) + 1))[1]

    sigs = [v.sig() for v in delivered]
    parity = float(restored
                   and sigs == [v.sig() for v in base_verdicts])
    dup = len(sigs) - len(set(sigs))
    rows.append(("restart/fleet_replay_parity", parity,
                 "1.0 = crash/restore verdict stream byte-identical to "
                 "uninterrupted session"))
    rows.append(("restart/duplicate_verdicts", float(dup),
                 "repeated verdict signatures in the delivered stream "
                 "(must be 0)"))
    rows.append(("restart/suppressed_replay",
                 float(sess2.stats.duplicates_suppressed),
                 "re-derivations deduped by the restored cooldown map"))
    rows.append(("restart/replay_ticks", float(sess2.stats.replay_ticks),
                 "samples re-driven through the restored state"))
    rows.append(("restart/checkpoint_bytes", float(ckpt_bytes), ""))
    rows.append(("restart/checkpoint_save_ms", float(np.median(save_ms)),
                 "atomic tmp+fsync+rename write"))
    rows.append(("restart/checkpoint_restore_ms", float(restore_ms),
                 "validate (magic/version/CRC) + full state apply"))

    # degraded mode: overload the loop before the fault arrives, keep it
    # overloaded while the incident enters the window (fresh host -> RCA
    # deferred), then lift the load and let the budget re-arm
    mon = FleetMonitor(use_kernels=False, budget_s=0.05, shed_after=2,
                       rearm_after=3)
    sess3 = MonitorSession(mon, channels)
    for k, hi in enumerate(round_ticks):
        cost = 1.0 if k < 6 else 0.0
        sess3.tick(ts[:hi], slab[:, :, :hi], extra_cost_s=cost)
    rows.append(("restart/shed_rounds", float(mon.shed_rounds),
                 "degraded (detect-only) rounds under synthetic overload"))
    rows.append(("restart/deferred_rca", float(mon.deferred_rca),
                 "flagged-host RCA deferrals while degraded"))
    rows.append(("restart/rearmed", float(not mon.degraded),
                 "1.0 = budget hysteresis re-armed after load lifted"))
    return rows


# ----------------------------------------------------------------- eval bench
def eval_rows(n_per_class: int = 4, reps: int = 3,
              ) -> List[Tuple[str, float, str]]:
    """Event-batched Layer 3 (one fused dispatch per diagnoser) vs the
    per-event sequential diagnosis, same trials, identical predictions.

    Trial *generation* is excluded from the timed region — this isolates
    the diagnosis path ``run_eval`` drives (detection sweep + Layer 3).
    """
    rows: List[Tuple[str, float, str]] = []
    trials = [make_trial(7100 + N_PER_CLASS * ci + k, cls)
              for ci, cls in enumerate(PROTOCOL_CLASSES)
              for k in range(n_per_class)]
    inputs = [(t.ts, t.data, t.channels) for t in trials]
    dg = make_baseline("ours")
    dg.diagnose_trials(inputs)              # ragged-dispatch jit warm-up

    batched_s = _median_wall(lambda: dg.diagnose_trials(inputs), reps)
    seq_s = _median_wall(
        lambda: [dg.diagnose_trial(*t) for t in inputs], reps)
    rb = dg.diagnose_trials(inputs)
    rs = [dg.diagnose_trial(*t) for t in inputs]
    match = float(all(a.pred == b.pred for a, b in zip(rb, rs)))
    rows.append(("eval/batched_s", batched_s,
                 f"{len(trials)} trials, one fused Layer-3 dispatch"))
    rows.append(("eval/sequential_s", seq_s, "one _diagnose per event"))
    rows.append(("eval/speedup", seq_s / batched_s, "sequential / batched"))
    rows.append(("eval/pred_parity", match,
                 "1.0 = per-trial predictions identical"))

    # columnar trial store: the whole eval as one f32 (trials, C, T) slab,
    # evidence gathered by slab indexing instead of per-event reslicing
    store = TrialStore.from_trials(trials)
    dg.diagnose_store(store)            # warm-up
    store_s = _median_wall(lambda: dg.diagnose_store(store), reps)
    c0 = engine_mod.SLICE_OPS
    rstore = dg.diagnose_store(store)
    ops_store = engine_mod.SLICE_OPS - c0
    c0 = engine_mod.SLICE_OPS
    dg.diagnose_trials(inputs)
    ops_event = engine_mod.SLICE_OPS - c0
    match_store = float(all(a.pred == b.pred for a, b in zip(rstore, rs)))
    rows.append(("eval/store_s", store_s,
                 "TrialStore slab path, one fused dispatch"))
    rows.append(("eval/store_speedup", seq_s / store_s,
                 "sequential / store"))
    rows.append(("eval/store_pred_parity", match_store,
                 "1.0 = per-trial predictions identical to per-event"))
    rows.append(("eval/slice_ops_per_event", float(ops_event),
                 "python-level evidence reslices, batched per-event path"))
    rows.append(("eval/slice_ops_store", float(ops_store),
                 "slab fancy-index gathers, store path"))
    return rows
