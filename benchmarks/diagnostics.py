"""Paper-table benchmarks: Tables 2/3/4 + Fig 2 + ablation.

Each function returns rows of (name, value, derived) and prints CSV via
run.py.  The 68-trial evaluation (17 x 4 classes, paper §3) is shared.
"""
from __future__ import annotations

import time
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.baselines import make_baseline
from repro.core.engine import EngineConfig
from repro.core.taxonomy import CauseClass
from repro.sim.scenario import (
    N_PER_CLASS, accuracy_by_class, confusion_matrix, mean_accuracy,
    rca_time_by_class, run_eval,
)

CLASSES = [CauseClass.IO, CauseClass.CPU, CauseClass.NIC, CauseClass.GPU]
_CACHE: Dict[int, list] = {}


def _records(seed: int = 0, n: int = N_PER_CLASS):
    key = (seed, n)
    if key not in _CACHE:
        dgs = [make_baseline(x) for x in ["ours", "b1", "b2", "b3"]]
        _CACHE[key] = run_eval(dgs, n_per_class=n, seed=seed)
    return _CACHE[key]


def table3_diagnostic() -> List[Tuple[str, float, str]]:
    """Paper Table 3: per-class accuracy (%) and Time-to-RCA (s)."""
    recs = _records()
    acc = accuracy_by_class(recs, "ours")
    rca = rca_time_by_class(recs, "ours")
    paper_acc = {CauseClass.IO: 86.2, CauseClass.CPU: 82.9,
                 CauseClass.NIC: 88.1, CauseClass.GPU: 81.4}
    paper_rca = {CauseClass.IO: 6.5, CauseClass.CPU: 6.2,
                 CauseClass.NIC: 7.5, CauseClass.GPU: 8.1}
    rows = []
    for c in CLASSES:
        rows.append((f"table3/acc_pct/{c.value}", 100 * acc.get(c, 0.0),
                     f"paper={paper_acc[c]}"))
        rows.append((f"table3/rca_s/{c.value}", rca.get(c, float('nan')),
                     f"paper={paper_rca[c]}"))
    rows.append(("table3/acc_pct/mean", 100 * mean_accuracy(recs, "ours"),
                 "paper=84.7"))
    return rows


def table2_comparison() -> List[Tuple[str, float, str]]:
    """Paper Table 2: accuracy / RCA-time / overhead per approach."""
    recs = _records()
    paper = {"ours": (84.7, "6-8s"), "B1-gpu-centric": (62.8, "45-60s"),
             "B2-cluster": (68.3, "30-50s"),
             "B3-deep-profiling": (82.1, "10-15s")}
    rows = []
    for dg in ("ours", "B1-gpu-centric", "B2-cluster", "B3-deep-profiling"):
        acc = 100 * mean_accuracy(recs, dg)
        rcas = [r.time_to_rca for r in recs
                if r.diagnoser == dg and r.time_to_rca is not None
                and r.pred == r.truth]
        rows.append((f"table2/acc_pct/{dg}", acc, f"paper={paper[dg][0]}"))
        rows.append((f"table2/rca_s/{dg}",
                     float(np.mean(rcas)) if rcas else float("nan"),
                     f"paper={paper[dg][1]}"))
    # overheads: B1-B3 literature-reported; ours measured by fig2 benchmark
    for dg, oh in (("B1-gpu-centric", 0.3), ("B2-cluster", 2.3),
                   ("B3-deep-profiling", 1.1)):
        rows.append((f"table2/overhead_pct/{dg}", oh, "literature"))
    return rows


def table4_confusion() -> List[Tuple[str, float, str]]:
    """Paper Table 4: 4x4 confusion (+unknown) row-normalized."""
    recs = _records()
    classes, cm = confusion_matrix(recs, "ours")
    paper = np.array([[86.2, 5.9, 4.4, 3.5], [7.1, 82.9, 6.2, 3.8],
                      [3.5, 4.7, 88.1, 3.7], [7.6, 6.3, 4.7, 81.4]])
    rows = []
    names = [c.value for c in classes] + ["unknown"]
    for i, ci in enumerate(classes):
        for j in range(5):
            ref = f"paper={paper[i][j]}" if j < 4 else "paper=0"
            rows.append((f"table4/{ci.value}->{names[j]}",
                         100 * cm[i, j], ref))
    return rows


def fig2_overhead(rates=(10.0, 25.0, 50.0, 100.0, 250.0),
                  duration_s: float = 8.0) -> List[Tuple[str, float, str]]:
    """Fig 2a: measured collector CPU overhead + detection latency vs rate.

    Overhead is MEASURED live: a real ProcCollector sampled at each rate on
    this host, busy-fraction accounted by the agent.  Detection latency is
    the evaluation mean at that sampling rate (window mechanics dominate).
    """
    from repro.telemetry.agent import TelemetryAgent
    from repro.telemetry.collectors import ProcCollector
    rows = []
    for hz in rates:
        agent = TelemetryAgent([ProcCollector()], rate_hz=hz,
                               history_s=duration_s + 1)
        agent.run_background()
        time.sleep(duration_s)
        stats = agent.stop()
        rows.append((f"fig2a/overhead_pct/{int(hz)}hz",
                     100 * stats.overhead_frac,
                     "paper=1.21@100hz (measured live)"))
    # detection latency at 100 Hz: measured directly from the engine's
    # detection events over strong confuser-free trials
    from repro.core.engine import CorrelationEngine
    from repro.sim.scenario import make_trial
    lats = []
    for i, cls in enumerate(("io", "cpu", "nic", "gpu") * 4):
        t = make_trial(9000 + i, cls, intensity=1.5, confuser_prob=0.0)
        ds = CorrelationEngine().process(t.ts, t.data, t.channels)
        if ds:
            lats.append(ds[0].event.t_detect - t.t_on)
    rows.append(("fig2a/detect_latency_s/100hz",
                 float(np.mean(lats)) if lats else float("nan"),
                 "paper~5.1s (measured from injection to detection)"))
    return rows


def ablation_probes() -> List[Tuple[str, float, str]]:
    """§4 ablation: remove a probe's channels, re-evaluate its class.

    Our channel registry is denser than the paper's probe set (five NET
    channels vs their NET_RX + queue length), so we ablate the channels a
    given probe *produces* while keeping the group's other probes — the
    same degradation semantics as the paper's "remove one probe group"
    (their groups retained redundant evidence from adjacent probes).
    """
    from repro.core.baselines import OurDiagnoser
    from repro.sim.scenario import run_eval as _run
    from repro.telemetry.schema import METRIC_REGISTRY

    probes = {
        "net_rx": (["net_rx_softirq", "net_tx_softirq", "nic_rx_bytes",
                    "nic_tx_bytes"],
                   CauseClass.NIC, 7.0),
        "net_group": (["net_rx_softirq", "net_tx_softirq", "nic_rx_bytes",
                       "nic_tx_bytes", "nic_rx_drops"],
                      CauseClass.NIC, 7.0),
        "sched": (["cpu_util_other", "involuntary_ctx"],
                  CauseClass.CPU, 5.0),
        "blkio": (["blkio_write_bytes", "blkio_read_bytes", "iowait_frac"],
                  CauseClass.IO, 5.0),
    }
    base = _records()
    rows = []
    for gname, (drop, cls, paper_delta) in probes.items():
        allowed = [m for m in METRIC_REGISTRY if m not in drop]
        dg = OurDiagnoser(evidence_channels=allowed)
        dg.name = f"ours-minus-{gname}"
        recs = _run([dg], n_per_class=N_PER_CLASS, seed=0)
        a0 = accuracy_by_class(base, "ours")[cls]
        a1 = accuracy_by_class(recs, dg.name).get(cls, 0.0)
        rows.append((f"ablation/drop_{gname}/delta_{cls.value}_pts",
                     100 * (a0 - a1), f"paper~-{paper_delta}pts"))
    return rows
