"""Kernel microbenchmarks: wall time of the fleet-scale correlation math.

CPU wall-times here are indicative only (TPU is the target); the benchmark
exists to (a) exercise the jit'd wrappers end-to-end, (b) record the
fleet-scale problem sizes from DESIGN.md §6, and (c) compare kernel
(interpret) vs pure-jnp reference paths for parity.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.fused.ops import fused_rca
from repro.kernels.spike.ops import spike_scores
from repro.kernels.welford.ops import welford
from repro.kernels.xcorr.ops import lagged_xcorr


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    for leaf in jax.tree.leaves(out):
        leaf.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_microbench() -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    # fleet-scale: 256 hosts x 16 metrics x 512-sample windows
    B, M, N, K = 256, 16, 512, 20
    L = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    Mx = jnp.asarray(rng.standard_normal((B, M, N)), jnp.float32)
    us_ref = _time(lambda a, b: lagged_xcorr(a, b, K, use_kernel=False), L, Mx)
    rows.append((f"kernel/xcorr_ref_jnp/{B}x{M}x{N}", us_ref,
                 f"{2 * B * M * (2 * K + 1) * N / 1e6:.1f}MFLOP"))
    us_k = _time(lambda a, b: lagged_xcorr(a, b, K, use_kernel=True,
                                           interpret=True), L, Mx)
    rows.append((f"kernel/xcorr_pallas_interp/{B}x{M}x{N}", us_k,
                 "interpret-mode (CPU correctness path)"))
    W = jnp.asarray(rng.standard_normal((B, M, N)), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((B, M, 4 * N)), jnp.float32)
    rows.append((f"kernel/spike_ref_jnp/{B}x{M}", _time(
        lambda a, b: spike_scores(a, b, use_kernel=False), W, Bs), ""))
    rows.append((f"kernel/welford_ref_jnp/{B}x{M}", _time(
        lambda a: welford(a, use_kernel=False), Bs), ""))
    # fused spike+xcorr (single pass over each tile) vs the two dispatches
    us_sep = _time(lambda a, b, c: (spike_scores(b, c, use_kernel=False),
                                    lagged_xcorr(a, b, K, use_kernel=False)),
                   L, Mx, Bs)
    us_fused = _time(lambda a, b, c: fused_rca(a, b, c, K, use_kernel=False),
                     L, Mx, Bs)
    rows.append((f"kernel/fused_ref_jnp/{B}x{M}x{N}", us_fused,
                 "one pass: stats+spike+xcorr"))
    rows.append((f"kernel/fused_vs_separate/{B}x{M}x{N}", us_sep / us_fused,
                 "separate spike+xcorr dispatches / fused"))
    rows.append((f"kernel/fused_pallas_interp/{B}x{M}x{N}", _time(
        lambda a, b, c: fused_rca(a, b, c, K, use_kernel=True,
                                  interpret=True), L, Mx, Bs),
        "interpret-mode (CPU correctness path)"))
    return rows
