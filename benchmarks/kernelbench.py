"""Kernel microbenchmarks: wall time of the fleet-scale correlation math.

CPU wall-times here are indicative only (TPU is the target); the benchmark
exists to (a) exercise the jit'd wrappers end-to-end, (b) record the
fleet-scale problem sizes from DESIGN.md §6, and (c) compare kernel
(interpret) vs pure-jnp reference paths for parity.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import tuning
from repro.kernels.detect.ops import detect_hosts
from repro.kernels.fused.fused import fused_rca_pallas
from repro.kernels.fused.ops import fused_rca
from repro.kernels.spike.ops import spike_scores
from repro.kernels.welford.ops import welford
from repro.kernels.xcorr.ops import lagged_xcorr


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def kernel_microbench(B: int = 256, M: int = 16, N: int = 512,
                      K: int = 20, detect_h: int = 1024,
                      ) -> List[Tuple[str, float, str]]:
    rng = np.random.default_rng(0)
    rows = []
    # fleet-scale default: 256 hosts x 16 metrics x 512-sample windows
    L = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    Mx = jnp.asarray(rng.standard_normal((B, M, N)), jnp.float32)
    us_ref = _time(lambda a, b: lagged_xcorr(a, b, K, use_kernel=False), L, Mx)
    rows.append((f"kernel/xcorr_ref_jnp/{B}x{M}x{N}", us_ref,
                 f"{2 * B * M * (2 * K + 1) * N / 1e6:.1f}MFLOP"))
    us_k = _time(lambda a, b: lagged_xcorr(a, b, K, use_kernel=True,
                                           interpret=True), L, Mx)
    rows.append((f"kernel/xcorr_pallas_interp/{B}x{M}x{N}", us_k,
                 "interpret-mode (CPU correctness path)"))
    W = jnp.asarray(rng.standard_normal((B, M, N)), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((B, M, 4 * N)), jnp.float32)
    rows.append((f"kernel/spike_ref_jnp/{B}x{M}", _time(
        lambda a, b: spike_scores(a, b, use_kernel=False), W, Bs), ""))
    rows.append((f"kernel/welford_ref_jnp/{B}x{M}", _time(
        lambda a: welford(a, use_kernel=False), Bs), ""))
    # fused spike+xcorr (single pass over each tile) vs the two dispatches
    us_sep = _time(lambda a, b, c: (spike_scores(b, c, use_kernel=False),
                                    lagged_xcorr(a, b, K, use_kernel=False)),
                   L, Mx, Bs)
    us_fused = _time(lambda a, b, c: fused_rca(a, b, c, K, use_kernel=False),
                     L, Mx, Bs)
    rows.append((f"kernel/fused_ref_jnp/{B}x{M}x{N}", us_fused,
                 "one pass: stats+spike+xcorr"))
    rows.append((f"kernel/fused_vs_separate/{B}x{M}x{N}", us_sep / us_fused,
                 "separate spike+xcorr dispatches / fused"))
    rows.append((f"kernel/fused_pallas_interp/{B}x{M}x{N}", _time(
        lambda a, b, c: fused_rca(a, b, c, K, use_kernel=True,
                                  interpret=True), L, Mx, Bs),
        "interpret-mode (CPU correctness path)"))
    # streaming detect: score + persistence gate + onset, one dispatch over
    # the (hosts, wn) slab (vs spike dispatch + f64 detect_rows replay)
    H = detect_h
    Wd = jnp.asarray(rng.standard_normal((H, 500)) + 4, jnp.float32)
    Bd = jnp.asarray(rng.standard_normal((H, 2000)) + 4, jnp.float32)
    rows.append((f"kernel/detect_ref_jnp/{H}x500", _time(
        lambda a, b: detect_hosts(a, b, 3.0, 0.35, use_kernel=False),
        Wd, Bd), "fleet Layer-2: one streaming dispatch"))
    rows.append((f"kernel/detect_pallas_interp/{H}x500", _time(
        lambda a, b: detect_hosts(a, b, 3.0, 0.35, use_kernel=True),
        Wd, Bd, reps=1), "interpret-mode (CPU correctness path)"))
    return rows


def tile_sweep_rows(interpret: bool = True) -> List[Tuple[str, float, str]]:
    """Interpret-mode block_m sweep for the fused kernel (the TPU-tuning
    hook): candidate tile sizes from kernels.tuning, one row each, so a
    hardware run (interpret=False) starts from a measured grid.  CPU
    interpret-mode walls rank dispatch/trace overhead only — trends, not
    absolutes.
    """
    rng = np.random.default_rng(1)
    B, M, N, Nb, K = 8, 16, 512, 512, 20
    L = jnp.asarray(rng.standard_normal((B, N)), jnp.float32)
    Mx = jnp.asarray(rng.standard_normal((B, M, N)), jnp.float32)
    Bs = jnp.asarray(rng.standard_normal((B, M, Nb)) + 5, jnp.float32)
    rows: List[Tuple[str, float, str]] = []
    for bm in tuning.BLOCK_M_CANDIDATES:
        fn = jax.jit(lambda a, b, c, _bm=bm: fused_rca_pallas(
            a, b, c, K, block_m=_bm, interpret=interpret))
        us = _time(fn, L, Mx, Bs, reps=1)
        rows.append((f"kernel/tile_sweep/fused_block_m{bm}/{B}x{M}x{N}", us,
                     f"REPRO_BLOCK_M={bm} candidate"
                     + (" (interpret)" if interpret else "")))
    return rows
