"""Fleet-level RCA: the paper's §5.1 multi-node extension, implemented.

Per-host agents stream (host x metric x time) windows to one correlation
engine.  The batched Layer-2/Layer-3 math (spike scores over every host's
channels, lagged correlation against each host's latency series) runs
through the Pallas kernels — at 1000+ hosts this is the compute hot-spot
the kernels exist for.  Straggler localization = arg-max spike score across
the host axis; the per-host diagnosis then explains *why* that host is
slow, and the verdict maps to a mitigation hint consumed by the training
loop (fault tolerance wiring).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.taxonomy import CauseClass, Diagnosis
from repro.kernels.spike import ops as spike_ops
from repro.kernels.xcorr import ops as xcorr_ops
from repro.telemetry.schema import METRIC_REGISTRY, ORIENTATION


class Mitigation(str, enum.Enum):
    NONE = "none"
    REBALANCE_INPUT = "rebalance_input_pipeline"   # IO verdict
    REPIN_CPU = "repin_or_isolate_cpu"             # CPU verdict
    HIERARCHICAL_ALLREDUCE = "fallback_hierarchical_allreduce"  # NIC/DCN
    EXCLUDE_AND_RESCALE = "checkpoint_exclude_host_rescale"     # persistent
    THROTTLE_REVIEW = "review_power_thermal_policy"             # GPU verdict


VERDICT_TO_MITIGATION = {
    CauseClass.IO: Mitigation.REBALANCE_INPUT,
    CauseClass.CPU: Mitigation.REPIN_CPU,
    CauseClass.NIC: Mitigation.HIERARCHICAL_ALLREDUCE,
    CauseClass.GPU: Mitigation.THROTTLE_REVIEW,
    CauseClass.UNKNOWN: Mitigation.NONE,
}


@dataclasses.dataclass
class FleetDiagnosis:
    straggler_host: int
    straggler_score: float
    diagnosis: Optional[Diagnosis]
    mitigation: Mitigation
    per_host_scores: np.ndarray      # (hosts,) latency spike scores


class FleetMonitor:
    """Aggregates per-host telemetry windows and runs cluster RCA."""

    def __init__(self, config: Optional[EngineConfig] = None,
                 use_kernels: bool = True,
                 persistent_threshold: int = 3):
        self.cfg = config or EngineConfig()
        self.engine = CorrelationEngine(self.cfg)
        self.use_kernels = use_kernels
        self.persistent_threshold = persistent_threshold
        self._strikes: Dict[int, int] = {}

    # ------------------------------------------------------------- batched L2
    def host_spike_scores(self, latency_windows: np.ndarray,
                          latency_baselines: np.ndarray) -> np.ndarray:
        """(hosts,) spike scores of each host's latency series.

        latency_windows (hosts, N), baselines (hosts, Nb) — kernel path is
        the batched spike kernel with M=1.
        """
        w = np.asarray(latency_windows, np.float32)[:, None, :]
        b = np.asarray(latency_baselines, np.float32)[:, None, :]
        s = spike_ops.spike_scores(w, b, use_kernel=self.use_kernels)
        return np.asarray(s)[:, 0]

    def batched_correlations(self, latency_windows: np.ndarray,
                             metric_windows: np.ndarray) -> np.ndarray:
        """rho (hosts, metrics, 2K+1) via the Pallas xcorr kernel."""
        return np.asarray(xcorr_ops.lagged_xcorr(
            np.asarray(latency_windows, np.float32),
            np.asarray(metric_windows, np.float32),
            max_lag=self.cfg.max_lag, use_kernel=self.use_kernels))

    # ------------------------------------------------------------- fleet RCA
    def diagnose_fleet(self, ts: np.ndarray, host_data: np.ndarray,
                       channels: Sequence[str]) -> FleetDiagnosis:
        """host_data: (hosts, C, T) aligned windows; finds the straggler and
        explains it."""
        hosts, C, T = host_data.shape
        li = list(channels).index(self.cfg.latency_metric)
        wn, bn = self.cfg.window_n, self.cfg.baseline_n
        wn = min(wn, T // 2)
        bn = min(bn, T - wn)
        lat = host_data[:, li, :]
        scores = self.host_spike_scores(lat[:, T - wn:],
                                        lat[:, T - wn - bn:T - wn])
        straggler = int(np.argmax(scores))
        diag: Optional[Diagnosis] = None
        mit = Mitigation.NONE
        if scores[straggler] > self.cfg.threshold:
            diags = self.engine.process(ts, host_data[straggler], channels)
            if diags:
                diag = diags[0]
                self._strikes[straggler] = self._strikes.get(straggler, 0) + 1
                if self._strikes[straggler] >= self.persistent_threshold:
                    mit = Mitigation.EXCLUDE_AND_RESCALE
                else:
                    mit = VERDICT_TO_MITIGATION[diag.top_cause]
        else:
            self._strikes = {}
        return FleetDiagnosis(straggler_host=straggler,
                              straggler_score=float(scores[straggler]),
                              diagnosis=diag, mitigation=mit,
                              per_host_scores=scores)
