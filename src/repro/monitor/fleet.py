"""Fleet-level RCA: the paper's §5.1 multi-node extension, implemented.

Per-host agents stream (host x metric x time) windows to one correlation
engine.  The batched Layer-2/Layer-3 math (spike scores over every host's
channels, lagged correlation against each host's latency series) runs
through the Pallas kernels — at 1000+ hosts this is the compute hot-spot
the kernels exist for.  Straggler localization = arg-max spike score across
the host axis.

Diagnosis is batched end to end: every host whose latency spike score
clears the threshold is explained in ONE fused-kernel dispatch
(hosts x metrics x lags via kernels.fused) with confidence ranking
vectorized over the host axis — the seed fell back to a per-host scalar
``engine.process`` replay for the single worst straggler, which is exactly
the per-node scaling wall at fleet size.  Verdicts map to mitigation hints
consumed by the training loop (fault tolerance wiring).

The columnar fast path (default, ``fast_detect=True``) keeps the pipeline
f32-contiguous from the telemetry ring to the verdict: Layer 2 is ONE
streaming-detect dispatch (kernels.detect — since PR 5 a single-tick view
of the suite-scale sweep core in kernels.sweep, so the fleet and the eval
share one sweep implementation) and the Layer-3 evidence gather stays f32
into the fused kernel.  ``fast_detect=False`` keeps the seed path — a
spike-kernel dispatch, then an f64 re-slice + scalar-rule ``detect_rows``
replay over the candidates, and an f64 evidence gather — as the parity
oracle: flagged hosts and onsets match the fast path byte-exactly *by
construction* (the sweep core's epsilon guard re-decides any host whose
window holds a z within the guard band of the threshold through the f64
oracle; the persistence gate compares an integer count), asserted by
tests and recorded in BENCH_fleet.json.

``stage_seconds`` reports *disjoint* pipeline stages (detect / gather /
kernel / rank / assemble) so benchmark attribution sums to the wall total.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import confidence as conf_mod
from repro.core import rolling
from repro.core import sanitize as sanitize_mod
from repro.core.engine import (
    MIN_BASELINE_N, EngineConfig, evidence_layout,
    orient_about_baseline, pick_baseline_slice,
)
from repro.core.reconcile import CO_GAP, symptom_table
from repro.core.spike import detect_rows
from repro.core.taxonomy import CauseClass, Diagnosis, SpikeEvent
from repro.kernels.detect import ops as detect_ops
from repro.kernels.fused import ops as fused_ops
from repro.kernels.spike import ops as spike_ops
from repro.kernels.xcorr import ops as xcorr_ops


class Mitigation(str, enum.Enum):
    """Operator action recommended for a verdict class (paper §6)."""
    NONE = "none"
    REBALANCE_INPUT = "rebalance_input_pipeline"   # IO verdict
    REPIN_CPU = "repin_or_isolate_cpu"             # CPU verdict
    HIERARCHICAL_ALLREDUCE = "fallback_hierarchical_allreduce"  # NIC/DCN
    EXCLUDE_AND_RESCALE = "checkpoint_exclude_host_rescale"     # persistent
    THROTTLE_REVIEW = "review_power_thermal_policy"             # GPU verdict
    RESTART_TELEMETRY = "restart_telemetry_agent"  # telemetry-fault verdict


VERDICT_TO_MITIGATION = {
    CauseClass.IO: Mitigation.REBALANCE_INPUT,
    CauseClass.CPU: Mitigation.REPIN_CPU,
    CauseClass.NIC: Mitigation.HIERARCHICAL_ALLREDUCE,
    CauseClass.GPU: Mitigation.THROTTLE_REVIEW,
    CauseClass.TELEMETRY: Mitigation.RESTART_TELEMETRY,
    CauseClass.UNKNOWN: Mitigation.NONE,
}


@dataclasses.dataclass
class FleetDiagnosis:
    """One fleet diagnosis round — the operator-facing verdict record.

    Field-by-field reading guide: ``docs/OPERATIONS.md``.
    """
    straggler_host: int
    straggler_score: float
    diagnosis: Optional[Diagnosis]
    mitigation: Mitigation
    per_host_scores: np.ndarray      # (hosts,) latency spike scores
    #: every host above threshold, worst first (the straggler leads)
    flagged_hosts: List[int] = dataclasses.field(default_factory=list)
    #: host -> diagnosis for ALL flagged hosts (one fused dispatch)
    diagnoses: Dict[int, Diagnosis] = dataclasses.field(default_factory=dict)
    mitigations: Dict[int, Mitigation] = dataclasses.field(default_factory=dict)
    #: host -> ordered verdict causes, primary first.  With
    #: ``cfg.max_hypotheses > 1`` a diagnosed host may carry co-causes:
    #: runner-up ranked causes whose symptom channel is corroborated on the
    #: evidence window and whose confidence sits within the per-cause
    #: ``reconcile.CO_GAP`` of the top cause (concurrent faults on ONE
    #: host).  With a single hypothesis every list is just the primary.
    causes: Dict[int, List[CauseClass]] = dataclasses.field(default_factory=dict)
    #: wall seconds per pipeline stage, disjoint (detect / gather / kernel /
    #: rank / assemble) — they sum to the diagnose_fleet wall total
    stage_seconds: Dict[str, float] = dataclasses.field(default_factory=dict)
    #: hosts whose telemetry is quarantined this round (persistently-bad
    #: validity) — fire suppressed, score zeroed, mitigation
    #: RESTART_TELEMETRY; never reported as stragglers
    quarantined: List[int] = dataclasses.field(default_factory=list)
    #: this round ran in deadline-degraded (detect-only) mode: the latency
    #: budget was blown on consecutive rounds, so Layer-3 RCA was shed for
    #: every flagged host without strike history — a first-class signal,
    #: never a silently-missed 5 s target
    degraded: bool = False
    #: flagged hosts whose RCA was deferred by degraded mode this round
    #: (they still accrue strikes, so they lead the next full round)
    deferred_hosts: List[int] = dataclasses.field(default_factory=list)


class FleetMonitor:
    """Aggregates per-host telemetry windows and runs cluster RCA."""

    def __init__(self, config: Optional[EngineConfig] = None,
                 use_kernels: bool = True,
                 persistent_threshold: int = 3,
                 fast_detect: bool = True,
                 quarantine_enter_frac: float = 0.25,
                 quarantine_exit_frac: float = 0.05,
                 quarantine_enter_rounds: int = 2,
                 quarantine_backoff_init: int = 2,
                 quarantine_backoff_max: int = 16,
                 budget_s: Optional[float] = None,
                 shed_after: int = 2,
                 rearm_after: int = 3,
                 rca_top_k: Optional[int] = None,
                 incremental: bool = True):
        self.cfg = config or EngineConfig()
        self.use_kernels = use_kernels
        self.persistent_threshold = persistent_threshold
        #: cap on Layer-3 RCA candidates per round (None = explain every
        #: flagged host).  Under an incident storm the monitor explains the
        #: ``rca_top_k`` worst flagged hosts (score order, host-id
        #: tie-break) and defers the rest into
        #: ``FleetDiagnosis.deferred_hosts`` — they still accrue strikes,
        #: exactly like deadline-degraded deferral, so persistent
        #: stragglers escalate even while the storm is being triaged.
        #: This is also the fleet-level contract the sharded monitor's
        #: rack->fleet candidate tree bounds its cross-shard traffic with.
        self.rca_top_k = None if rca_top_k is None else int(rca_top_k)
        #: columnar fast path: one streaming-detect dispatch + f32 gather;
        #: False = seed spike-dispatch + f64 detect_rows replay (oracle)
        self.fast_detect = fast_detect
        # incremental O(delta) streaming moments (core/rolling.py): the
        # fast path's baseline moments come from persistent per-(host,
        # block) state instead of an O(rows * bn) direct pass each round.
        # Only engaged on clean on-grid rounds; masked/chaos rounds,
        # reset_host, and checkpoint restore cold-invalidate the affected
        # rows (they rebuild from scratch on the next clean round), and a
        # periodic exact re-anchor bitwise-proves the carried state
        # (``fleet/incremental_parity``).  ``incremental=False`` restores
        # the direct per-round moment pass (the PR 9 behaviour) — the
        # bench's cold baseline.
        self._inc = (rolling.IncrementalMoments(cap_ticks=self.cfg.baseline_n)
                     if (fast_detect and incremental) else None)
        self._strikes: Dict[int, int] = {}
        # telemetry quarantine (hysteresis): a host whose latency-channel
        # invalid fraction exceeds `enter_frac` for `enter_rounds`
        # consecutive rounds is quarantined — its telemetry is the fault,
        # so it must never fire as a straggler.  Re-admission needs
        # `backoff` consecutive clean rounds (invalid fraction at or below
        # `exit_frac`); the backoff doubles on every re-quarantine up to
        # `backoff_max`, so a flapping agent converges to quarantined.
        self.quarantine_enter_frac = float(quarantine_enter_frac)
        self.quarantine_exit_frac = float(quarantine_exit_frac)
        self.quarantine_enter_rounds = int(quarantine_enter_rounds)
        self.quarantine_backoff_init = int(quarantine_backoff_init)
        self.quarantine_backoff_max = int(quarantine_backoff_max)
        self._quarantined: set = set()
        self._bad_streak: Dict[int, int] = {}    # candidate bad rounds
        self._clean_streak: Dict[int, int] = {}  # quarantined clean rounds
        self._quar_backoff: Dict[int, int] = {}  # clean rounds required
        # deadline-aware degraded mode (hysteresis): `shed_after`
        # consecutive rounds over `budget_s` drop the monitor to
        # detect-only — Layer-3 RCA runs only for flagged hosts already
        # carrying strikes, the rest is deferred; `rearm_after`
        # consecutive on-budget rounds re-arm full diagnosis.  budget_s
        # None disables the state machine entirely (every round is full).
        self.budget_s = None if budget_s is None else float(budget_s)
        self.shed_after = int(shed_after)
        self.rearm_after = int(rearm_after)
        self._over_streak = 0
        self._on_streak = 0
        self._degraded = False
        self.shed_rounds = 0       # rounds executed in detect-only mode
        self.deferred_rca = 0      # flagged hosts whose RCA was deferred

    # ------------------------------------------------------------- batched L2
    def host_spike_scores(self, latency_windows: np.ndarray,
                          latency_baselines: np.ndarray) -> np.ndarray:
        """(hosts,) spike scores of each host's latency series.

        latency_windows (hosts, N), baselines (hosts, Nb) — kernel path is
        the batched spike kernel with M=1.
        """
        w = np.asarray(latency_windows, np.float32)[:, None, :]
        b = np.asarray(latency_baselines, np.float32)[:, None, :]
        s = spike_ops.spike_scores(w, b, use_kernel=self.use_kernels)
        return np.asarray(s)[:, 0]

    def batched_correlations(self, latency_windows: np.ndarray,
                             metric_windows: np.ndarray) -> np.ndarray:
        """rho (hosts, metrics, 2K+1) via the Pallas xcorr kernel."""
        return np.asarray(xcorr_ops.lagged_xcorr(
            np.asarray(latency_windows, np.float32),
            np.asarray(metric_windows, np.float32),
            max_lag=self.cfg.max_lag, use_kernel=self.use_kernels))

    # ----------------------------------------------------------- quarantine
    def _update_quarantine(self, bad_frac: np.ndarray,
                           base: int = 0) -> np.ndarray:
        """Advance the per-host quarantine state machine one round.

        ``bad_frac`` (hosts,) is the invalid fraction of each host's
        latency channel over the detection tail.  Returns the (hosts,)
        bool mask of hosts quarantined THIS round.

        ``base`` offsets the state-machine keys: a sharded round advances
        each shard's hosts with ``base=shard_start`` so the per-host
        hysteresis state stays keyed by *absolute* host id.  The machine
        is per-host independent, so advancing shard by shard is the same
        state trajectory as one full-fleet call."""
        H = int(bad_frac.size)
        quar = np.zeros(H, bool)
        for j in range(H):
            h = j + int(base)
            bf = float(bad_frac[j])
            if h in self._quarantined:
                if bf <= self.quarantine_exit_frac:
                    self._clean_streak[h] = self._clean_streak.get(h, 0) + 1
                    need = self._quar_backoff.get(
                        h, self.quarantine_backoff_init)
                    if self._clean_streak[h] >= need:
                        # re-admitted: participates again from this round
                        self._quarantined.discard(h)
                        self._clean_streak.pop(h, None)
                        self._bad_streak.pop(h, None)
                        continue
                else:
                    self._clean_streak[h] = 0
                quar[j] = True
            elif bf > self.quarantine_enter_frac:
                self._bad_streak[h] = self._bad_streak.get(h, 0) + 1
                if self._bad_streak[h] >= self.quarantine_enter_rounds:
                    self._quarantined.add(h)
                    self._clean_streak[h] = 0
                    prev = self._quar_backoff.get(h)
                    self._quar_backoff[h] = (
                        self.quarantine_backoff_init if prev is None
                        else min(prev * 2, self.quarantine_backoff_max))
                    quar[j] = True
            else:
                self._bad_streak.pop(h, None)
        return quar

    # -------------------------------------------------------- survivability
    @property
    def degraded(self) -> bool:
        """True while the deadline hysteresis holds the monitor in
        detect-only mode."""
        return self._degraded

    def reset_host(self, host: int) -> None:
        """Forget one host's strike/quarantine history.

        Called when the host's telemetry agent is replaced or restarted: a
        fresh probe is not a relapsing probe, so its quarantine re-entry
        backoff re-bases to the initial value instead of doubling from the
        old agent's record, and stale strikes cannot escalate the new
        agent's first flag straight to EXCLUDE_AND_RESCALE."""
        h = int(host)
        self._strikes.pop(h, None)
        self._quarantined.discard(h)
        self._bad_streak.pop(h, None)
        self._clean_streak.pop(h, None)
        self._quar_backoff.pop(h, None)
        if self._inc is not None:
            # the replacement agent's ring shares no history with the old
            # one — its cached moment blocks are another process's data
            self._inc.invalidate([h])

    def _update_budget(self, round_cost_s: float) -> None:
        """Advance the deadline hysteresis one round."""
        if self.budget_s is None:
            return
        if round_cost_s > self.budget_s:
            self._over_streak += 1
            self._on_streak = 0
            if not self._degraded and self._over_streak >= self.shed_after:
                self._degraded = True
        else:
            self._on_streak += 1
            self._over_streak = 0
            if self._degraded and self._on_streak >= self.rearm_after:
                self._degraded = False
                self._on_streak = 0

    def state_dict(self) -> Dict[str, object]:
        """All mutable diagnosis state, JSON-serializable (checkpointing).

        Keys of the per-host dicts are stringified so the payload survives
        a JSON round trip; :meth:`load_state_dict` converts them back."""
        return {
            "strikes": {str(k): int(v) for k, v in self._strikes.items()},
            "quarantined": sorted(int(h) for h in self._quarantined),
            "bad_streak": {str(k): int(v)
                           for k, v in self._bad_streak.items()},
            "clean_streak": {str(k): int(v)
                             for k, v in self._clean_streak.items()},
            "quar_backoff": {str(k): int(v)
                             for k, v in self._quar_backoff.items()},
            "over_streak": int(self._over_streak),
            "on_streak": int(self._on_streak),
            "degraded": bool(self._degraded),
            "shed_rounds": int(self.shed_rounds),
            "deferred_rca": int(self.deferred_rca),
        }

    def load_state_dict(self, d: Dict[str, object]) -> None:
        """Restore :meth:`state_dict` output — full replacement, never a
        merge.  Every field is parsed before any is assigned, so a
        malformed payload raises without leaving a half-restored
        monitor."""
        strikes = {int(k): int(v) for k, v in d["strikes"].items()}
        quarantined = {int(h) for h in d["quarantined"]}
        bad = {int(k): int(v) for k, v in d["bad_streak"].items()}
        clean = {int(k): int(v) for k, v in d["clean_streak"].items()}
        backoff = {int(k): int(v) for k, v in d["quar_backoff"].items()}
        over, on = int(d["over_streak"]), int(d["on_streak"])
        degraded = bool(d["degraded"])
        shed, deferred = int(d["shed_rounds"]), int(d["deferred_rca"])
        self._strikes = strikes
        self._quarantined = quarantined
        self._bad_streak = bad
        self._clean_streak = clean
        self._quar_backoff = backoff
        self._over_streak = over
        self._on_streak = on
        self._degraded = degraded
        self.shed_rounds = shed
        self.deferred_rca = deferred
        if self._inc is not None:
            # incremental moments are deliberately NOT serialized
            # (checkpoint bytes stay flat); a restored monitor starts
            # cold and its first clean round re-anchors from scratch
            self._inc.invalidate_all()

    # ------------------------------------------------------------- fleet RCA
    def diagnose_fleet(self, ts: np.ndarray, host_data: np.ndarray,
                       channels: Sequence[str],
                       valid: Optional[np.ndarray] = None,
                       extra_cost_s: float = 0.0) -> FleetDiagnosis:
        """host_data: (hosts, C, T) aligned windows; finds every straggler
        above threshold and explains all of them in one batched dispatch.

        A window too short to leave ``MIN_BASELINE_N`` baseline samples
        after clamping returns a quiet verdict carrying a zero-valued
        ``short_baseline_skip`` entry in ``stage_seconds`` — detection on a
        sigma-floored micro-baseline would flag quiet hosts.

        ``valid`` (hosts, C, T) bool marks per-cell telemetry validity
        (chaos hardening).  Invalid latency cells are excluded from
        detection via the masked oracle (never enter baselines, never
        fire); invalid evidence cells are forward-filled before the RCA
        gather.  Hosts whose latency channel stays persistently invalid
        are *quarantined* by a hysteresis state machine: their telemetry
        is the fault, so they are suppressed from straggler detection and
        reported in ``FleetDiagnosis.quarantined`` with mitigation
        ``RESTART_TELEMETRY`` — a telemetry fault must never surface as a
        GPU/host-interference verdict.  An all-true (or absent) mask
        leaves the clean path byte-identical.

        ``extra_cost_s`` is added to the measured round cost before the
        deadline-budget hysteresis update (a harness models external load
        with it; a deployment passes assembly/IO time).  While degraded,
        the round is detect-only: Layer-3 RCA runs solely for flagged
        hosts already carrying strikes, every other flagged host is
        reported in ``deferred_hosts`` (still accruing a strike, so it
        leads the RCA queue once re-armed or escalates to
        EXCLUDE_AND_RESCALE on persistence).  With ``rca_top_k`` set, at
        most that many hosts get Layer-3 RCA per round (worst first) and
        the overflow is deferred the same way.

        The round is assembled from overridable stages —
        :meth:`_detect_round` (Layer 2 + quarantine over the latency
        tail), an evidence-gather callback, and :meth:`_finish_round`
        (flag ordering, strike/mitigation lifecycle, Layer-3 RCA, budget
        hysteresis) — so the sharded monitor
        (:class:`repro.monitor.shard.ShardedFleetMonitor`) can run
        detection and evidence extraction per shard while reusing the
        exact fleet-level verdict logic, keeping the two byte-identical
        by construction."""
        hosts, C, T = host_data.shape
        li = list(channels).index(self.cfg.latency_metric)
        vfull = None
        if valid is not None:
            v = np.asarray(valid, bool)
            if v.shape != host_data.shape:
                raise ValueError(f"valid {v.shape} vs data {host_data.shape}")
            if not v.all():
                vfull = v
        wn, bn = self.cfg.window_n, self.cfg.baseline_n
        wn = min(wn, T // 2)
        bn = min(bn, T - wn)
        if bn < MIN_BASELINE_N:
            return self._quiet_round(hosts, extra_cost_s)
        tick_end = self._tick_end(ts, T)
        t_detect = time.perf_counter()
        scores, cand, onset_rel, qhosts = self._detect_round(
            host_data, vfull, li, T, wn, bn, tick_end=tick_end)
        stage = {"detect": time.perf_counter() - t_detect}

        def evidence_for(geom: "EvidenceGeometry", rca_hosts: np.ndarray,
                         ) -> np.ndarray:
            return self._gather_evidence(host_data, rca_hosts, geom, vfull)

        return self._finish_round(ts, channels, li, T, wn, bn, scores,
                                  cand, onset_rel, qhosts, stage,
                                  extra_cost_s, evidence_for)

    def _quiet_round(self, hosts: int, extra_cost_s: float) -> FleetDiagnosis:
        """Short-snapshot quiet verdict (baseline too thin to trust).

        The clamped baseline cannot estimate ambient statistics, and the
        sigma-floored z-score would flag perfectly quiet hosts — so the
        round reports nothing, with an explicit ``short_baseline_skip``
        stage marker instead of spurious stragglers.  A quiet round clears
        strike history exactly like a quiet full window (no host was
        flagged THIS round)."""
        self._strikes.clear()
        self._update_budget(extra_cost_s)
        return FleetDiagnosis(
            straggler_host=0, straggler_score=0.0, diagnosis=None,
            mitigation=Mitigation.NONE,
            per_host_scores=np.zeros(hosts, np.float32),
            stage_seconds={"detect": 0.0, "short_baseline_skip": 0.0},
            degraded=self._degraded)

    def _tick_end(self, ts: np.ndarray, T: int) -> Optional[int]:
        """Exclusive absolute tick index of the round's newest sample.

        The incremental moment cache is keyed to the absolute 100 Hz tick
        grid, so the round's timestamps must sit on it: the newest sample
        must round cleanly to a tick index and the window span must equal
        ``T - 1`` tick periods (no dropped ticks, no clock jumps).  Any
        off-grid round returns None — the detect stage then takes the
        direct moment pass and the cache is left untouched, so irregular
        wall clocks degrade to PR 9 behaviour instead of mis-anchoring.
        """
        if self._inc is None or len(ts) < 2:
            return None
        rate = self.cfg.rate_hz
        e_f = float(ts[-1]) * rate
        e = round(e_f)
        span = (float(ts[-1]) - float(ts[0])) * rate
        if abs(e_f - e) > 0.25 or abs(span - (T - 1)) > 0.25:
            return None
        return int(e) + 1

    def incremental_stats(self) -> Optional[dict]:
        """Counters of the incremental moment state (None when the
        direct moment pass is in use): rounds, re-anchors, the parity
        bit, and cache traffic — surfaced for ops dashboards and the
        ``fleet/incremental_*`` bench rows."""
        return None if self._inc is None else self._inc.stats()

    def _detect_round(self, host_data: np.ndarray,
                      vfull: Optional[np.ndarray], li: int,
                      T: int, wn: int, bn: int,
                      force_oracle: bool = False, device=None,
                      base: int = 0,
                      quar: Optional[np.ndarray] = None,
                      tick_end: Optional[int] = None,
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                 np.ndarray]:
        """Layer-2 detection + telemetry quarantine over the latency tail.

        Returns ``(scores, cand, onset_rel, qhosts)``: per-host spike
        scores (quarantined hosts zeroed), the unordered flagged host
        indices, their onsets relative to the detection window, and the
        hosts quarantined this round — all indexed relative to
        ``host_data`` (the sharded caller offsets them by its shard
        base).

        The shard parameters keep a per-shard invocation byte-identical
        to the corresponding rows of one full-slab call: ``base`` keys
        the quarantine state machine by absolute host id,
        ``force_oracle`` routes a clean shard through the masked f64
        oracle when some OTHER shard saw corruption (a single-slab round
        with any invalid cell takes the oracle for every host),
        ``device`` pins the detect dispatch to the shard's mesh device,
        and ``quar`` substitutes precomputed quarantine decisions so a
        shard re-visited for oracle forcing does not advance the
        hysteresis twice.

        ``tick_end`` (from :meth:`_tick_end`) anchors the incremental
        moment cache to the absolute tick grid.  On a clean round the
        baseline moments come from :class:`~repro.core.rolling.
        IncrementalMoments` at O(delta); a masked/forced-oracle round
        routes through the masked f64 oracle instead *and invalidates*
        the visited rows' incremental state (their slab may carry
        masked/zeroed cells, so carried blocks are no longer trusted) —
        which also means an oracle re-visit of a shard never advances
        the moment state twice."""
        hosts = host_data.shape[0]
        lat = host_data[:, li, :]
        # telemetry quarantine: invalid fraction of the latency channel
        # over the detection tail drives the hysteresis state machine; the
        # update runs every full round (clean rounds advance re-admission)
        lvt = None
        if vfull is not None:
            lvt = np.ascontiguousarray(vfull[:, li, T - wn - bn:T])
            if lvt.all():
                lvt = None
        bad_frac = (np.zeros(hosts) if lvt is None
                    else 1.0 - lvt.mean(axis=1))
        if quar is None:
            quar = self._update_quarantine(bad_frac, base=base)
        qhosts = np.flatnonzero(quar)
        # persistence gate, the scalar spike.detect rule batched over hosts:
        # a host is a straggler only if `persistence` of its window sits
        # above mu + thr*sigma — bare max-z over 500 correlated ambient
        # samples trips routinely.  The gate also yields each survivor's
        # onset estimate for Layer 3.
        if self.fast_detect or lvt is not None or force_oracle:
            # one streaming-detect dispatch over the trailing slab view:
            # score + gate + onset per host, one host->device copy, no
            # candidate re-slice.  A masked round routes through this call
            # on BOTH detect paths — the mask branch IS the f64 oracle, so
            # fast and oracle stay trivially byte-identical under chaos.
            moments = None
            if self._inc is not None:
                if lvt is None and not force_oracle and tick_end is not None:
                    moments = self._inc.moments(
                        lat[:, T - wn - bn:T], tick_end, wn, bn, base=base)
                else:
                    self._inc.invalidate(np.arange(base, base + hosts))
            fire, scores, onset_all = detect_ops.detect_hosts_slab(
                lat[:, T - wn - bn:T], wn, bn,
                self.cfg.threshold, self.cfg.persistence,
                use_kernel=self.use_kernels, valid=lvt,
                force_oracle=force_oracle, device=device, moments=moments)
            if qhosts.size:
                fire[qhosts] = False
                scores[qhosts] = 0.0
            cand = np.flatnonzero(fire)
            onset_rel = onset_all[cand]
        else:
            scores = self.host_spike_scores(lat[:, T - wn:],
                                            lat[:, T - wn - bn:T - wn])
            if qhosts.size:
                scores = np.array(scores)   # kernel output may be readonly
                scores[qhosts] = 0.0
            cand = np.flatnonzero(scores > self.cfg.threshold)
            onset_rel = np.empty(0, dtype=np.intp)
            if cand.size:
                latc = np.asarray(lat[cand], dtype=np.float64)
                keep, _, onset_rel = detect_rows(
                    latc[:, T - wn:], latc[:, T - wn - bn:T - wn],
                    self.cfg.threshold, self.cfg.persistence)
                cand, onset_rel = cand[keep], onset_rel[keep]
        return scores, cand, onset_rel, qhosts

    def _rca_selection(self, flagged: np.ndarray, onset_rel: np.ndarray,
                       ) -> Tuple[np.ndarray, np.ndarray, List[int]]:
        """Which flagged hosts get Layer-3 RCA this round, and which defer.

        ``flagged`` must already be in fleet RCA order (score-descending,
        host-id tie-break).  Applies the degraded-mode strike priority
        (detect-only rounds explain only hosts with strike history) and
        the ``rca_top_k`` storm cap; returns ``(rca_hosts, rca_onsets,
        deferred)``.  Pure — no monitor state is touched — so the sharded
        monitor can run the same selection per shard/rack to decide which
        evidence blocks to ship, guaranteeing every host the fleet level
        will RCA has its evidence on hand (the fleet's selection over a
        superset picks a subset of each part's local selection)."""
        rca_hosts, rca_onsets = flagged, onset_rel
        deferred: List[int] = []
        if self._degraded:
            # detect-only round: RCA only for hosts whose flag is
            # *persistent* (strike history) — everything else is
            # deferred, explicitly, instead of silently late
            pri = np.fromiter(
                (self._strikes.get(int(h), 0) > 0 for h in flagged),
                dtype=bool, count=flagged.size)
            rca_hosts, rca_onsets = flagged[pri], onset_rel[pri]
            deferred = [int(h) for h in flagged[~pri]]
        if self.rca_top_k is not None and rca_hosts.size > self.rca_top_k:
            # incident-storm triage: explain the worst ``rca_top_k``
            # hosts this round, defer the rest explicitly (they keep
            # accruing strikes, so persistence still escalates)
            k = self.rca_top_k
            deferred += [int(h) for h in rca_hosts[k:]]
            rca_hosts, rca_onsets = rca_hosts[:k], rca_onsets[:k]
        return rca_hosts, rca_onsets, deferred

    def _finish_round(self, ts: np.ndarray, channels: Sequence[str],
                      li: int, T: int, wn: int, bn: int,
                      scores: np.ndarray, cand: np.ndarray,
                      onset_rel: np.ndarray, qhosts: np.ndarray,
                      stage: Dict[str, float], extra_cost_s: float,
                      evidence_for) -> FleetDiagnosis:
        """Fleet-level verdict assembly shared by every execution layout.

        Orders the flagged hosts (score-descending, host-id tie-break —
        deterministic so sharded and single-slab rounds agree), applies
        the degraded-mode and ``rca_top_k`` RCA deferrals, runs batched
        Layer-3 RCA through ``evidence_for`` (a callback returning the
        gathered evidence slab for exactly the RCA'd hosts, in order —
        the single-slab path slices ``host_data``, the sharded path
        reassembles blocks shipped from shards), advances the
        strike/mitigation lifecycle and the deadline-budget hysteresis,
        and returns the round's :class:`FleetDiagnosis`."""
        # deterministic flag order: score-descending with ascending host id
        # on ties (``cand`` is ascending) — a plain argsort would order
        # tied scores arbitrarily and split the sharded/single-slab paths
        order = np.argsort(-scores[cand], kind="stable")
        flagged, onset_rel = cand[order], onset_rel[order]
        diagnoses: Dict[int, Diagnosis] = {}
        causes: Dict[int, List[CauseClass]] = {}
        mitigations: Dict[int, Mitigation] = {}
        # strike lifecycle: a host that recovered (not flagged THIS round)
        # loses its strike history immediately, even while other hosts stay
        # flagged — otherwise churn leaves stale counts behind forever and
        # the dict grows unbounded with fleet size
        flagged_set = {int(h) for h in flagged}
        for h in [h for h in self._strikes if h not in flagged_set]:
            del self._strikes[h]
        degraded = self._degraded
        deferred: List[int] = []
        if flagged.size:
            rca_hosts, rca_onsets, deferred = self._rca_selection(
                flagged, onset_rel)
            self.deferred_rca += len(deferred)
            if rca_hosts.size:
                geom = self._evidence_geometry(channels, li, T, wn, bn)
                if geom is not None:
                    t_gather = time.perf_counter()
                    X = evidence_for(geom, rca_hosts)
                    stage["gather"] = (stage.get("gather", 0.0)
                                       + time.perf_counter() - t_gather)
                    diagnoses, causes = self._rca_from_evidence(
                        ts, X, geom, rca_hosts, (T - wn) + rca_onsets,
                        scores, stage)
            deferred_set = set(deferred)
            for h in flagged:
                h = int(h)
                d = diagnoses.get(h)
                if d is None and h not in deferred_set:
                    # no evidence channels: verdict-less host
                    mitigations[h] = Mitigation.NONE
                    continue
                self._strikes[h] = self._strikes.get(h, 0) + 1
                if self._strikes[h] >= self.persistent_threshold:
                    mitigations[h] = Mitigation.EXCLUDE_AND_RESCALE
                elif d is None:    # deferred: verdict comes once re-armed
                    mitigations[h] = Mitigation.NONE
                else:
                    mitigations[h] = VERDICT_TO_MITIGATION[d.top_cause]
        # quarantined hosts carry the telemetry-fault verdict: fire was
        # suppressed and score zeroed above, so they can neither lead the
        # flagged list nor accrue strikes — the only actionable output is
        # "restart that host's telemetry agent"
        for h in qhosts:
            mitigations[int(h)] = Mitigation.RESTART_TELEMETRY
        # the worst *persistent* host; bare arg-max only as the quiet-fleet
        # readout (a transient max-z glitch must not name a straggler)
        straggler = int(flagged[0]) if flagged.size else int(np.argmax(scores))
        if degraded:
            self.shed_rounds += 1
        self._update_budget(sum(stage.values()) + float(extra_cost_s))
        return FleetDiagnosis(
            straggler_host=straggler,
            straggler_score=float(scores[straggler]),
            diagnosis=diagnoses.get(straggler),
            mitigation=mitigations.get(straggler, Mitigation.NONE),
            per_host_scores=scores,
            flagged_hosts=[int(h) for h in flagged],
            diagnoses=diagnoses, mitigations=mitigations, causes=causes,
            stage_seconds=stage,
            quarantined=[int(h) for h in qhosts],
            degraded=degraded,
            deferred_hosts=deferred)

    # ----------------------------------------------------- batched Layer 3+4
    def _evidence_geometry(self, channels: Sequence[str], li: int,
                           T: int, wn: int, bn: int,
                           ) -> "Optional[EvidenceGeometry]":
        """Resolve the shared RCA evidence layout for this round.

        All flagged hosts share the trailing RCA window [T-rn, T): an onset
        is only ever *observed* inside the trailing detection window, so
        reaching ``pre_onset_s`` before it always saturates at the snapshot
        edge — one contiguous slice covers every host, with a common
        baseline window preceding it.  Returns None when the channel set
        carries no evidence channels (verdict-less rounds)."""
        cfg = self.cfg
        rate = cfg.rate_hz
        pre_n = int(cfg.pre_onset_s * rate)
        rca_n = int(cfg.rca_extra_s * rate)
        rn = int(min(T, pre_n + wn + rca_n))
        nb = int(min(bn, T - rn))
        if nb < MIN_BASELINE_N:
            nb = 0
        names, idx, orient = evidence_layout(
            tuple(channels), cfg.latency_metric)
        if not names:
            return None
        return EvidenceGeometry(
            names=tuple(names), orient=orient,
            rows=np.concatenate(([li], idx)),
            cols=np.arange(T - rn - nb, T), rn=rn, nb=nb)

    def _gather_evidence(self, host_data: np.ndarray, flagged: np.ndarray,
                         geom: "EvidenceGeometry",
                         valid: Optional[np.ndarray] = None) -> np.ndarray:
        """Stage the (len(flagged), 1 + M, nb + rn) evidence slab.

        Row 0 is the latency channel, rows 1.. the evidence channels, the
        column span ``geom.cols`` the shared baseline + RCA window.  This
        is the per-host-independent half of Layer 3 — the sharded monitor
        runs it on each shard and ships only these blocks (its top-K
        candidates' evidence) across the shard boundary, never the raw
        (hosts, C, T) telemetry.

        The columnar mode gathers straight to f32 (the fused kernel's
        input dtype) — no f64 round-trip of the evidence slab; the oracle
        path keeps the seed's f64 gather.  Invalid evidence cells
        (crashed collector, frozen channel) must not skew orientation
        means or correlations: they are NaN'd out, then the last valid
        reading is carried forward — degraded evidence, never fabricated
        spikes."""
        gather_dtype = np.float32 if self.fast_detect else np.float64
        sel = np.ix_(flagged, geom.rows, geom.cols)
        X = host_data[sel].astype(gather_dtype)     # (H, 1+M, nb+rn)
        if valid is not None:
            X[~valid[sel]] = np.nan
        return sanitize_mod.forward_fill(X)

    def _rca_from_evidence(self, ts: np.ndarray, X: np.ndarray,
                           geom: "EvidenceGeometry", flagged: np.ndarray,
                           onset_idx: np.ndarray, scores: np.ndarray,
                           stage: Dict[str, float],
                           ) -> "Tuple[Dict[int, Diagnosis], Dict[int, List[CauseClass]]]":
        """Explain every RCA'd host with one fused-kernel dispatch.

        ``X`` is the gathered evidence slab (:meth:`_gather_evidence`, in
        ``flagged`` order), ``onset_idx`` each host's absolute onset
        sample (from the detection gate's stats) — it only timestamps the
        events; for an anomaly older than the window it clamps to the
        window start, the best a streaming trailing-window view can
        report.  Returns ``(diagnoses, causes)``: per host the Diagnosis
        plus its ordered verdict-cause list (primary first; co-causes
        appended only with ``cfg.max_hypotheses > 1`` — see
        :class:`FleetDiagnosis`).

        This half of Layer 3 is deliberately *cross-host coupled* (the
        orientation baseline slice depends on the minimum onset over all
        RCA'd hosts) and therefore always runs at fleet level, on the
        gathered candidates — never per shard."""
        cfg = self.cfg
        t_gather = time.perf_counter()
        rate = cfg.rate_hz
        nb, rn = geom.nb, geom.rn
        names = geom.names
        names_pos = {n: m for m, n in enumerate(names)}
        T = int(geom.cols[-1]) + 1
        L_win = X[:, 0, nb:]                                    # (H, rn)
        Xm = X[:, 1:, :]                                        # (H, M, nb+rn)

        # orientation about the baseline-region mean, batched over hosts —
        # same slice/orientation policy as engine._diagnose (shared helpers)
        head = int(np.min(onset_idx) - (T - rn))
        b_sl = pick_baseline_slice(nb, head, nb + rn)
        XO = orient_about_baseline(Xm, geom.orient, b_sl)
        W = XO[:, :, nb:]                                       # (H, M, rn)
        Bm = XO[:, :, b_sl]                                     # (H, M, nb')
        # multi-hypothesis co-cause corroboration over the SAME gathered
        # slab: per cause, does some symptom channel show a two-sided raw-z
        # deviation at/above its floor (reconcile's corroboration test,
        # vectorized over hosts)?  Computed in f64 on the raw (unoriented,
        # forward-filled) evidence so the fast f32 gather and the f64
        # oracle agree on every verdict-cause list.
        sym_ok: Dict[CauseClass, np.ndarray] = {}
        if cfg.max_hypotheses > 1:
            for cause, chans in symptom_table().items():
                ok = np.zeros(flagged.size, bool)
                for name, floor in chans:
                    m = names_pos.get(name)
                    if m is None:
                        continue
                    seg = np.asarray(Xm[:, m, :], np.float64)
                    B, Wr = seg[:, b_sl], seg[:, nb:]
                    if B.shape[1] == 0 or Wr.shape[1] == 0:
                        continue
                    mb = B.mean(axis=1)
                    sd = np.maximum(B.std(axis=1),
                                    np.maximum(1e-3 * np.abs(mb), 1e-9))
                    ok |= np.abs(Wr.mean(axis=1) - mb) / sd >= floor
                sym_ok[cause] = ok
        stage["gather"] = (stage.get("gather", 0.0)
                           + time.perf_counter() - t_gather)

        # one fused dispatch: spike scores + max-|rho| + arg-max lag
        t_kernel = time.perf_counter()
        s, c, lags = fused_ops.fused_rca_max(
            np.asarray(L_win, np.float32), np.asarray(W, np.float32),
            np.asarray(Bm, np.float32), max_lag=cfg.max_lag,
            use_kernel=self.use_kernels)
        s, c, lags = np.asarray(s), np.asarray(c), np.asarray(lags)
        stage["kernel"] = time.perf_counter() - t_kernel

        t_rank = time.perf_counter()
        ranked_all = conf_mod.rank_causes_batch(
            names, s, c, lags / rate, cfg.alpha, details=False)
        # operators drill into the worst host (flagged[0]): full per-metric
        # detail for it only, via the same ranker
        ranked_all[0] = conf_mod.rank_causes_batch(
            names, s[:1], c[:1], lags[:1] / rate, cfg.alpha, details=True)[0]
        t_assemble = time.perf_counter()
        # disjoint stages: "rank" is the confidence fusion only; the
        # Diagnosis-object assembly below is its own stage, so benchmark
        # attribution sums to the wall total with no double counting
        stage["rank"] = t_assemble - t_rank
        out: Dict[int, Diagnosis] = {}
        causes: Dict[int, List[CauseClass]] = {}
        now = float(ts[T - 1])
        # Layer-3/4 compute cost, shared by the whole batch (paper's
        # Time-to-RCA includes analysis compute)
        analysis = t_assemble - t_kernel
        for j, h in enumerate(flagged):
            h = int(h)
            ranked, per_metric = ranked_all[j]
            ev = SpikeEvent(t_onset=float(ts[int(onset_idx[j])]),
                            t_detect=now, score=float(scores[h]),
                            metric=cfg.latency_metric)
            out[h] = Diagnosis(event=ev, ranked=ranked,
                               per_metric=per_metric, t_rca=now + analysis,
                               analysis_seconds=analysis, t_ready=now)
            cl = [ranked[0].cause] if ranked else []
            if ranked and cfg.max_hypotheses > 1:
                # co-causes: corroborated runners within their per-cause
                # confidence gap of the primary, rank order preserved
                top = ranked[0].confidence
                for rc in ranked[1:]:
                    ok = sym_ok.get(rc.cause)
                    if ok is None or not bool(ok[j]):
                        continue
                    if top - rc.confidence > CO_GAP.get(rc.cause, 0.0):
                        continue
                    cl.append(rc.cause)
            causes[h] = cl
        stage["assemble"] = time.perf_counter() - t_assemble
        return out, causes


@dataclasses.dataclass(frozen=True)
class EvidenceGeometry:
    """The round-shared RCA evidence layout (:meth:`FleetMonitor.
    _evidence_geometry`): which slab rows and columns every RCA'd host's
    evidence block is cut from.  Shipping this to shards instead of
    recomputing it there keeps the shard-side gather and the single-slab
    gather trivially identical."""

    #: evidence channel names, fused-kernel metric order
    names: Tuple[str, ...]
    #: per-metric orientation signs (``engine.evidence_layout``)
    orient: np.ndarray
    #: slab row indices to gather: ``[latency, *evidence_channels]``
    rows: np.ndarray
    #: slab column indices: the shared baseline + RCA window, contiguous
    cols: np.ndarray
    #: RCA window length in samples
    rn: int
    #: baseline samples preceding the RCA window (0 = too thin, skipped)
    nb: int
