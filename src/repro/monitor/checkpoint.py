"""Monitor survivability: crash-safe checkpoint/restore + deterministic
ring replay for the live fleet-diagnosis loop.

The paper's operational claim (detect <= 5 s, RCA <= 8 s) only holds while
the monitor itself stays up: its engine cooldowns, per-host strikes,
quarantine hysteresis and rolling baselines are all mutable state that
evaporates on a crash, turning every in-flight incident into a duplicate
verdict or a miss.  This module makes that state durable:

* **Checkpoint file format** — a fixed binary envelope (magic, version,
  payload length, CRC32) around a JSON payload.  Writes are atomic
  (``tmp + fsync + os.replace``), so a crash mid-write leaves the previous
  checkpoint intact.  Loads are *all-or-nothing*: a truncated file, a
  flipped byte, or a version skew raises :class:`CheckpointError` — a
  half-restored hybrid is worse than a cold start, so nothing is applied
  until the whole payload has parsed.

* **MonitorSession** — the warm-restartable round loop above
  :class:`~repro.monitor.fleet.FleetMonitor`.  It owns the cross-round
  state ``diagnose_fleet`` cannot: the verdict cooldown map that turns a
  per-round diagnosis stream into *events* (one verdict per ``(host,
  cause)`` incident, the engine's cooldown discipline at fleet level —
  concurrent causes on one host dedup independently), and per-host streaming
  baseline moments (Welford chunk merges over each round's newly-seen
  ticks).  ``save``/``restore`` snapshot it together with the monitor's
  strike/quarantine/degraded state.

* **Deterministic replay** — after a restore, re-driving the trailing
  ring contents through ``tick(..., replay=True)`` re-converges to the
  verdict stream of an uninterrupted run byte-for-byte: every round's
  diagnosis is a pure function of (window, restored state), and the
  restored cooldown map suppresses re-emission of any verdict already
  delivered before the crash — zero duplicates by construction, gated as
  ``restart/fleet_replay_parity`` in the benchmarks.
"""
from __future__ import annotations

import binascii
import dataclasses
import json
import os
import struct
import warnings
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.monitor.fleet import FleetDiagnosis, FleetMonitor

#: checkpoint envelope magic — 8 bytes, never reused across formats
MAGIC = b"RPROCKPT"

#: envelope version; a reader only accepts exactly its own version
#: (state schemas are not forward/backward compatible across PRs).
#: v2: the verdict cooldown map is keyed per (host, cause) — a v1
#: checkpoint's per-host map cannot express concurrent-cause dedup, so
#: v1 loads are rejected loudly into a cold start.
VERSION = 2

_HEADER = struct.Struct("<8sIQI")   # magic, version, payload len, crc32


class CheckpointError(Exception):
    """A checkpoint failed validation — corrupt, truncated, or wrong
    version.  The caller must fall back to a cold start."""


def save_checkpoint(path: str, payload: Dict[str, object]) -> int:
    """Atomically write ``payload`` under the versioned CRC envelope.

    Returns the byte size written.  The temp file lives in the target
    directory so ``os.replace`` stays a same-filesystem atomic rename; a
    crash at any point leaves either the old checkpoint or none — never a
    torn file that a later restore could half-trust.
    """
    body = json.dumps(payload, sort_keys=True).encode("utf-8")
    blob = _HEADER.pack(MAGIC, VERSION, len(body),
                        binascii.crc32(body) & 0xFFFFFFFF) + body
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    tmp = os.path.join(d, f".{os.path.basename(path)}.tmp.{os.getpid()}")
    try:
        with open(tmp, "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return len(blob)


def load_checkpoint(path: str) -> Dict[str, object]:
    """Read and fully validate a checkpoint; raise :class:`CheckpointError`
    on ANY defect.  Validation order matters: magic before version before
    length before CRC, so the error names the outermost failure."""
    try:
        with open(path, "rb") as f:
            blob = f.read()
    except OSError as e:
        raise CheckpointError(f"cannot read checkpoint {path!r}: {e}")
    if len(blob) < _HEADER.size:
        raise CheckpointError(
            f"truncated checkpoint {path!r}: {len(blob)} bytes < "
            f"{_HEADER.size}-byte header")
    magic, version, body_len, crc = _HEADER.unpack_from(blob)
    if magic != MAGIC:
        raise CheckpointError(f"bad magic {magic!r} in {path!r}")
    if version != VERSION:
        raise CheckpointError(
            f"checkpoint version {version} != supported {VERSION} "
            f"({path!r}) — refusing a cross-version restore")
    body = blob[_HEADER.size:]
    if len(body) != body_len:
        raise CheckpointError(
            f"truncated checkpoint {path!r}: payload {len(body)} bytes, "
            f"header promises {body_len}")
    if binascii.crc32(body) & 0xFFFFFFFF != crc:
        raise CheckpointError(f"CRC mismatch in {path!r} — corrupt payload")
    try:
        payload = json.loads(body.decode("utf-8"))
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise CheckpointError(f"unparseable checkpoint payload: {e}")
    if not isinstance(payload, dict):
        raise CheckpointError("checkpoint payload is not an object")
    return payload


@dataclasses.dataclass
class SessionStats:
    """Survivability counters, mirrored into benchmarks and tests."""

    rounds: int = 0                 # diagnosis rounds executed
    restarts: int = 0               # warm restarts (successful restores)
    checkpoints_written: int = 0
    checkpoints_rejected: int = 0   # corrupt/truncated/version-skewed loads
    replay_ticks: int = 0           # samples re-driven during replay rounds
    duplicates_suppressed: int = 0  # verdicts deduped by the cooldown map


@dataclasses.dataclass(frozen=True)
class FleetVerdict:
    """One deduplicated fleet verdict — the session's event-level output
    (the per-round ``FleetDiagnosis`` re-reports an incident every round
    its spike is still inside the trailing window)."""

    host: int
    pred: str            # top cause, CauseClass.value
    t_onset: float
    t_detect: float
    t_ready: float

    def sig(self) -> Tuple[int, str, float, float, float]:
        """The deterministic replay-parity signature (same discipline as
        the scorecard's ``_diag_sig``: virtual-time fields only)."""
        return (self.host, self.pred, self.t_onset, self.t_detect,
                self.t_ready)


class MonitorSession:
    """A crash-restartable fleet-diagnosis loop.

    Drives a :class:`FleetMonitor` one trailing window per ``tick``, and
    owns every piece of cross-round mutable state: the monitor's
    strike/quarantine/degraded machinery (checkpointed via its
    ``state_dict``), the verdict cooldown map, and per-host streaming
    baseline moments.  ``save``/``restore`` make the whole bundle durable;
    after a restore, re-presenting the trailing windows (ring replay)
    yields byte-identical verdicts to an uninterrupted session with zero
    duplicates.
    """

    def __init__(self, monitor: FleetMonitor, channels: Sequence[str],
                 cooldown_s: Optional[float] = None):
        self.monitor = monitor
        self.channels = list(channels)
        #: verdict dedup horizon; defaults to the engine's cooldown
        self.cooldown_s = (float(cooldown_s) if cooldown_s is not None
                           else float(monitor.cfg.cooldown_s))
        self.stats = SessionStats()
        # verdict dedup per (host, cause): with concurrent hypotheses a
        # host may carry several true causes at once, and a second cause
        # surfacing mid-incident must not be swallowed by the first
        # cause's cooldown
        self._cooldown_until: Dict[Tuple[int, str], float] = {}
        self._t_seen = -np.inf        # newest sample time already processed
        # per-host streaming baseline moments (Welford chunk merge over
        # newly-seen ticks): host -> (n, mean, M2), each (C,) float64
        self._base_n: Dict[int, np.ndarray] = {}
        self._base_mean: Dict[int, np.ndarray] = {}
        self._base_m2: Dict[int, np.ndarray] = {}

    # -------------------------------------------------------------- moments
    def baseline_moments(self, host: int,
                         ) -> Optional[Tuple[np.ndarray, np.ndarray,
                                             np.ndarray]]:
        """(n, mean, variance) per channel for one host, or None."""
        h = int(host)
        if h not in self._base_n:
            return None
        n, mean, m2 = self._base_n[h], self._base_mean[h], self._base_m2[h]
        var = np.where(n > 1, m2 / np.maximum(n, 1), 0.0)
        return n.copy(), mean.copy(), var

    def _update_moments(self, ts: np.ndarray, slab: np.ndarray,
                        valid: Optional[np.ndarray], new_lo: int) -> None:
        """Merge each host's newly-seen columns into its running moments.

        Chunk-merge Welford (the welford kernel's combine rule) over the
        same chunk sequence is deterministic, so an uninterrupted run and
        a restore+replay — which see identical chunk boundaries — converge
        to bit-identical moments.  Invalid cells are excluded per channel.
        """
        if new_lo >= ts.shape[0]:
            return
        H, C, _ = slab.shape
        chunk = np.asarray(slab[:, :, new_lo:], np.float64)
        if valid is not None:
            ok = np.asarray(valid[:, :, new_lo:], bool)
        else:
            ok = np.isfinite(chunk)
        w = np.where(ok, chunk, 0.0)
        cn = ok.sum(axis=2).astype(np.float64)              # (H, C)
        cmean = np.divide(w.sum(axis=2), np.maximum(cn, 1.0))
        cm2 = np.where(ok, (chunk - cmean[:, :, None]) ** 2, 0.0).sum(axis=2)
        for h in range(H):
            if h not in self._base_n:
                self._base_n[h] = np.zeros(C)
                self._base_mean[h] = np.zeros(C)
                self._base_m2[h] = np.zeros(C)
            n0, mu0, m20 = (self._base_n[h], self._base_mean[h],
                            self._base_m2[h])
            n1, mu1, m21 = cn[h], cmean[h], cm2[h]
            n = n0 + n1
            safe = np.maximum(n, 1.0)
            delta = mu1 - mu0
            self._base_mean[h] = mu0 + delta * (n1 / safe)
            self._base_m2[h] = m20 + m21 + delta * delta * (n0 * n1 / safe)
            self._base_n[h] = n

    # ----------------------------------------------------------------- tick
    def tick(self, ts: np.ndarray, slab: np.ndarray,
             valid: Optional[np.ndarray] = None,
             extra_cost_s: float = 0.0, replay: bool = False,
             ) -> Tuple[FleetDiagnosis, List[FleetVerdict]]:
        """One diagnosis round over a trailing (hosts, C, T) window.

        Returns the raw per-round :class:`FleetDiagnosis` plus the
        *deduplicated* verdicts: one per ``(host, cause)`` in the round's
        verdict-cause lists (primary first, then any corroborated
        co-causes when the engine runs concurrent hypotheses), emitted
        only when its detection time has cleared that pair's cooldown —
        the same incident re-reported by later rounds (or re-derived by a
        post-restore replay) is suppressed and counted, while a *new*
        cause surfacing on an already-diagnosed host is not.
        """
        fd = self.monitor.diagnose_fleet(ts, slab, self.channels,
                                         valid=valid,
                                         extra_cost_s=extra_cost_s)
        self.stats.rounds += 1
        new_lo = int(np.searchsorted(ts, self._t_seen, side="right"))
        if replay:
            self.stats.replay_ticks += ts.shape[0] - new_lo
        self._update_moments(ts, slab, valid, new_lo)
        verdicts: List[FleetVerdict] = []
        for h in sorted(fd.diagnoses):
            d = fd.diagnoses[h]
            td = float(d.event.t_detect)
            for cause in fd.causes.get(h, [d.top_cause]):
                key = (int(h), cause.value)
                if td < self._cooldown_until.get(key, -np.inf):
                    self.stats.duplicates_suppressed += 1
                    continue
                self._cooldown_until[key] = td + self.cooldown_s
                verdicts.append(FleetVerdict(
                    host=int(h), pred=cause.value,
                    t_onset=float(d.event.t_onset), t_detect=td,
                    t_ready=float(d.t_ready if d.t_ready is not None
                                  else d.t_rca)))
        if ts.shape[0]:
            self._t_seen = max(self._t_seen, float(ts[-1]))
        return fd, verdicts

    # ---------------------------------------------------------- persistence
    def state_dict(self) -> Dict[str, object]:
        """JSON-serializable session state: the monitor's state (strikes,
        quarantine, shard plan when sharded), per-(host, cause) verdict
        cooldowns, streaming baseline moments, and counters."""
        return {
            "monitor": self.monitor.state_dict(),
            "cooldown_until": {f"{h}|{cause}": float(v)
                               for (h, cause), v
                               in self._cooldown_until.items()},
            "t_seen": float(self._t_seen),
            "baseline": {
                str(h): {"n": self._base_n[h].tolist(),
                         "mean": self._base_mean[h].tolist(),
                         "m2": self._base_m2[h].tolist()}
                for h in sorted(self._base_n)
            },
            "stats": dataclasses.asdict(self.stats),
        }

    def save(self, path: str) -> int:
        """Atomically checkpoint the session; returns bytes written."""
        n = save_checkpoint(path, self.state_dict())
        self.stats.checkpoints_written += 1
        return n

    def restore(self, path: str) -> bool:
        """Warm-restore from ``path``; cold start on any rejection.

        All-or-nothing: the payload is parsed into locals completely
        before any session/monitor field is touched, so a malformed
        payload can never leave a half-restored hybrid.  Returns True on
        a warm restore; False (with a loud warning and a counted
        rejection) means the session keeps its cold-start state.

        Shard-plan skew lands here too: a
        :class:`~repro.monitor.shard.ShardedFleetMonitor` whose plan
        does not match the checkpoint's recorded ``shard_plan`` raises
        ``ValueError`` from ``load_state_dict``, which this catch turns
        into a counted cold start — resharding the fleet between runs
        deliberately invalidates prior strike/quarantine state rather
        than misattributing it across the new shard boundaries.
        """
        try:
            payload = load_checkpoint(path)
            mon_state = payload["monitor"]
            cooldown: Dict[Tuple[int, str], float] = {}
            for k, v in payload["cooldown_until"].items():
                h, _, cause = k.partition("|")
                if not cause:
                    raise CheckpointError(
                        f"cooldown key {k!r} is not host|cause")
                cooldown[(int(h), cause)] = float(v)
            t_seen = float(payload["t_seen"])
            base_n: Dict[int, np.ndarray] = {}
            base_mean: Dict[int, np.ndarray] = {}
            base_m2: Dict[int, np.ndarray] = {}
            for k, blk in payload["baseline"].items():
                h = int(k)
                base_n[h] = np.asarray(blk["n"], np.float64)
                base_mean[h] = np.asarray(blk["mean"], np.float64)
                base_m2[h] = np.asarray(blk["m2"], np.float64)
            # monitor state parses/applies atomically inside
            # load_state_dict (full replacement)
            self.monitor.load_state_dict(mon_state)
        except (CheckpointError, KeyError, TypeError, ValueError) as e:
            self.stats.checkpoints_rejected += 1
            warnings.warn(f"monitor checkpoint rejected, cold start: {e}",
                          RuntimeWarning, stacklevel=2)
            return False
        self._cooldown_until = cooldown
        self._t_seen = t_seen
        self._base_n, self._base_mean, self._base_m2 = (base_n, base_mean,
                                                        base_m2)
        self.stats.restarts += 1
        return True
