"""Live fleet assembly: N per-host agents -> one (hosts, C, T) slab.

This is the missing live path of the paper's §5.1 fleet extension: the
benchmarks drive ``FleetMonitor.diagnose_fleet`` with pre-stacked slabs,
but a deployment has N :class:`TelemetryAgent` s — each sampling from its
own background thread (or the virtual clock in trials) — and the monitor
must read *while they write*.  :class:`FleetAggregator` owns the agents
and assembles the monitor's (hosts, C, T) f32 slab from each host's ring
via the seqlock reader (:meth:`MultiChannelRing.read_window`):

  * **one bounded copy per host** — each host's trailing window lands
    straight from the ring's zero-copy views into a row of a preallocated
    f32 staging slab (no per-assembly allocation); a wrapped span costs
    the same copy split in two, and only a torn read (writer collided
    mid-copy) repeats it,
  * **clock alignment** — hosts are right-aligned on the newest timestamp
    every live host has reached (``t_common``); hosts that have sampled
    past it contribute their window *ending at* ``t_common``,
  * **ragged tolerance** — late joiners with short rings are backfilled
    with their oldest sample (a flat, quiet baseline) and their true
    length reported in ``valid``; hosts whose newest sample is older than
    ``dead_after_s`` (agent died mid-run) are zeroed out of the slab and
    listed in ``skipped`` so a stale spike cannot masquerade as live.

``diagnose`` feeds the staged slab directly to a
:class:`~repro.monitor.fleet.FleetMonitor` — the training loop's
per-diagnosis defensive full-window copy is gone.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.monitor.fleet import FleetDiagnosis, FleetMonitor
from repro.telemetry.agent import TelemetryAgent


@dataclasses.dataclass
class AggregatorStats:
    """Cumulative aggregator health counters (one snapshot per fleet)."""
    assemblies: int = 0
    torn_retries: int = 0       # seqlock validate-retry loops across hosts
    torn_giveups: int = 0       # reads that exhausted retries (host skipped)
    ragged_hosts: int = 0       # short (late-joiner) rows staged
    dead_hosts: int = 0         # stale rows zeroed out of the slab
    masked_hosts: int = 0       # young rows masked out of a diagnosis
    hung_agents: int = 0        # agent threads that outlived stop()'s join
    agent_restarts: int = 0     # agents re-armed or replaced in place
    host_resets: int = 0        # monitor reset_host calls delivered
    unchanged_skips: int = 0    # rows reused untouched (seqlock watermark)
    delta_reads: int = 0        # rows advanced by a delta read, not T ticks
    full_restages: int = 0      # live rows that took the full T-tick copy


@dataclasses.dataclass
class FleetSnapshot:
    """One staged (hosts, C, T) assembly: slab, clock, validity, skips."""
    ts: np.ndarray              # (T,) reference clock, newest at T-1
    slab: np.ndarray            # (hosts, C, T) f32 — the staging buffer
    valid: np.ndarray           # (hosts,) true sample count per row
    skipped: List[int]          # dead/stale hosts (rows zeroed)
    retries: int                # torn-read retries during this assembly
    #: (hosts, C, T) bool — per-cell validity of the staged slab.  False
    #: marks cells a collector failed to deliver (the agent writes NaN for
    #: crashed/backoff-skipped collectors); zeroed dead/skipped rows stay
    #: all-True — their zeros are deliberate quiet, not corruption.
    valid_mask: Optional[np.ndarray] = None
    #: live hosts too young to fill the diagnosed span — rows zeroed by
    #: ``diagnose`` for that round (NOT flagged-eligible; an operator must
    #: not read their zero spike score as "monitored and healthy")
    masked: List[int] = dataclasses.field(default_factory=list)


class FleetAggregator:
    """Owns per-host agents and stages their windows for fleet RCA."""

    def __init__(self, agents: Sequence[TelemetryAgent], window_s: float,
                 dead_after_s: Optional[float] = None, min_samples: int = 2):
        """Preallocate the staging slab for ``agents`` (which must agree
        on channel layout and sampling rate); ``window_s`` fixes the
        staged span T and ``dead_after_s`` the staleness horizon past
        which a host's row is zeroed and skipped."""
        if not agents:
            raise ValueError("need at least one agent")
        self.agents: List[TelemetryAgent] = list(agents)
        self.channels: List[str] = list(agents[0].channels)
        self.rate_hz = float(agents[0].rate_hz)
        for a in self.agents[1:]:
            if list(a.channels) != self.channels:
                raise ValueError("agents disagree on channel layout")
            if float(a.rate_hz) != self.rate_hz:
                raise ValueError("agents disagree on sampling rate")
        self.window_s = float(window_s)
        self.window_n = int(self.window_s * self.rate_hz)
        if self.window_n <= 0:
            raise ValueError("window shorter than one sample period")
        period = 1.0 / self.rate_hz
        #: a host whose newest sample lags the fleet by more than this is
        #: considered dead (agent thread gone) and masked from the slab
        self.dead_after_s = (float(dead_after_s) if dead_after_s is not None
                             else max(10.0 * period, 0.5))
        self.min_samples = int(min_samples)
        H, C, T = len(self.agents), len(self.channels), self.window_n
        # preallocated staging: every assembly reuses these buffers, so the
        # steady-state cost is one bounded memcpy per host and zero allocs
        self._slab = np.zeros((H, C, T), np.float32)
        self._ts_rows = np.zeros((H, T), np.float64)
        self._scratch = np.empty((C, T), np.float32)
        self._ts_scratch = np.empty(T, np.float64)
        self._valid = np.ones((H, C, T), bool)
        # delta-staging bookkeeping: a row whose last stage was a full
        # clean T-tick window (no trim, no backfill, no masking since)
        # records the seqlock sequence + newest staged tick; the next
        # assembly then reuses the row untouched (sequence unchanged) or
        # left-shifts it and reads only the delta ticks out of the ring
        self._staged_seq = np.full(H, -1, np.int64)
        self._staged_last = np.full(H, -np.inf)
        self._staged_full = np.zeros(H, bool)
        self.stats = AggregatorStats()
        self.last_snapshot: Optional[FleetSnapshot] = None
        self._stopped = False
        # hosts whose agent was restarted/replaced since the last
        # diagnosis: the next diagnose() delivers monitor.reset_host for
        # them (fresh probe != relapsing probe — quarantine backoff and
        # strikes re-base)
        self._pending_resets: set = set()

    # ------------------------------------------------------------ lifecycle
    def start_background(self) -> None:
        """Start every agent's sampling thread (live deployment mode)."""
        self._stopped = False
        for a in self.agents:
            a.run_background()

    def stop(self, timeout: float = 5.0) -> None:
        """Stop every agent; idempotent and bounded.

        Each agent's join waits at most ``timeout`` seconds — a collector
        wedged in a syscall cannot hang fleet shutdown; such threads are
        counted in ``stats.hung_agents`` and left daemonized.  A second
        ``stop`` is a no-op.
        """
        if self._stopped:
            return
        self._stopped = True
        for a in self.agents:
            a.stop(timeout=timeout)
            if a.hung:
                self.stats.hung_agents += 1

    def run_virtual(self, t_start: float, t_end: float) -> None:
        """Drive every agent over the span on the shared virtual clock."""
        for a in self.agents:
            a.run_virtual(t_start, t_end)

    # --------------------------------------------------------- agent restart
    def restart_agent(self, host: int, timeout: float = 5.0) -> None:
        """Re-arm host's agent in place (the RESTART_TELEMETRY action).

        Stops the sampling thread (bounded), clears the agent's crash
        state via :meth:`TelemetryAgent.restart`, and — if the fleet is
        running in background mode — starts it again.  Marks the host for
        a monitor-side :meth:`~repro.monitor.fleet.FleetMonitor.reset_host`
        at the next diagnosis: a freshly-restarted probe must not inherit
        the dead probe's quarantine backoff or strike history."""
        a = self.agents[int(host)]
        was_live = a._thread is not None
        a.stop(timeout=timeout)
        if a.hung:
            self.stats.hung_agents += 1
        a.restart()
        if was_live and not self._stopped:
            a.run_background()
        self.stats.agent_restarts += 1
        self._pending_resets.add(int(host))
        self._staged_full[int(host)] = False  # fresh probe, fresh stage

    def replace_agent(self, host: int, agent: TelemetryAgent,
                      timeout: float = 5.0) -> TelemetryAgent:
        """Swap in a brand-new agent for ``host``; returns the old one.

        The replacement must agree on channel layout and rate (the staging
        slab is preallocated on both).  Like :meth:`restart_agent`, the
        host's monitor-side strike/quarantine history is scheduled for
        reset at the next diagnosis."""
        h = int(host)
        if list(agent.channels) != self.channels:
            raise ValueError("replacement agent disagrees on channel layout")
        if float(agent.rate_hz) != self.rate_hz:
            raise ValueError("replacement agent disagrees on sampling rate")
        old = self.agents[h]
        was_live = old._thread is not None
        old.stop(timeout=timeout)
        if old.hung:
            self.stats.hung_agents += 1
        self.agents[h] = agent
        if was_live and not self._stopped:
            agent.run_background()
        self.stats.agent_restarts += 1
        self._pending_resets.add(h)
        self._staged_full[h] = False  # new ring: staged row is orphaned
        return old

    # ------------------------------------------------------------- assembly
    def _stage_delta(self, h: int, agent: TelemetryAgent, skip: int,
                     count: int, seq: int, t_common: float, period: float,
                     ) -> tuple:
        """O(delta) staging attempt for one live host row.

        Preconditions for even trying: the row's previous stage was a
        full clean T-tick window (``_staged_full``), this round wants the
        un-skipped steady-state alignment (``skip == 0``), and the ring
        holds a full window.  Then either the seqlock sequence is
        unchanged — nothing was pushed, the staged row *is* this round's
        window, zero ring reads — or the new right edge sits a whole
        number of ticks ahead: the row (values, timestamps, validity) is
        left-shifted and only the ``delta`` new columns are read out of
        the ring.  Both outcomes are bitwise-identical to the full
        restage they replace (ring history is append-only, so the
        overlapping columns could not have changed).  Any gap, torn
        read, or off-grid timestamp voids the attempt — the caller falls
        back to the full restage.  Returns ``(staged, retries)``.
        """
        T = self.window_n
        if not self._staged_full[h] or skip != 0 or count < T:
            return False, 0
        if seq >= 0 and seq == self._staged_seq[h] \
                and abs(self._staged_last[h] - t_common) <= 0.5 * period:
            self.stats.unchanged_skips += 1
            return True, 0
        gap = t_common - self._staged_last[h]
        di = int(round(gap / period))
        if not (0 < di < T and abs(gap - di * period) <= 0.25 * period):
            return False, 0
        row, tsr, vrow = self._slab[h], self._ts_rows[h], self._valid[h]
        # overlapping left-shift: numpy buffers overlapping assignments,
        # so this is the memmove it looks like
        row[:, :T - di] = row[:, di:]
        tsr[:T - di] = tsr[di:]
        vrow[:, :T - di] = vrow[:, di:]
        ts_n, _, r = agent.ring.read_window(di, out_ts=tsr[T - di:],
                                            out=row[:, T - di:])
        if (ts_n.size != di
                or abs(float(ts_n[0]) - (self._staged_last[h] + period))
                > 0.25 * period
                or abs(float(ts_n[-1]) - t_common) > 0.5 * period):
            # writer raced past the watermark or ticks were dropped: the
            # shifted row no longer lines up — void it and restage fully
            self._staged_full[h] = False
            return False, r
        np.isfinite(row[:, T - di:], out=vrow[:, T - di:])
        self._staged_seq[h] = seq
        self._staged_last[h] = float(tsr[-1])
        self.stats.delta_reads += 1
        return True, r

    def assemble(self) -> FleetSnapshot:
        """Stage every host's trailing window into the (hosts, C, T) slab.

        Safe against concurrent background writers: each host row is a
        seqlock-validated consistent snapshot.  Returns the snapshot whose
        ``slab`` IS the internal staging buffer — consume it before the
        next ``assemble`` call.
        """
        H, T = len(self.agents), self.window_n
        period = 1.0 / self.rate_hz
        retries = 0
        giveups0 = sum(a.ring.torn_giveups for a in self.agents)

        # phase 1: consistent (seq, count, newest-ts) probe per host to
        # pick the common right edge of the fleet window; the seqlock
        # sequence doubles as the delta-staging change detector
        counts = np.zeros(H, np.int64)
        lasts = np.full(H, -np.inf)
        seqs = np.full(H, -1, np.int64)
        for h, a in enumerate(self.agents):
            seqs[h], counts[h], lasts[h] = a.ring.watermark()
        have = counts >= max(self.min_samples, 1)
        if not have.any():
            snap = FleetSnapshot(ts=np.zeros(0), slab=self._slab[:0],
                                 valid=np.zeros(H, np.int64),
                                 skipped=list(range(H)), retries=0)
            self.last_snapshot = snap
            return snap
        t_latest = float(lasts[have].max())
        alive = have & (lasts >= t_latest - self.dead_after_s)
        t_common = float(lasts[alive].min())

        # phase 2: one bounded copy per live host, right-aligned at t_common
        valid = np.zeros(H, np.int64)
        skipped: List[int] = []
        ref_host = -1
        for h, a in enumerate(self.agents):
            if not alive[h]:
                # dead or empty: a stale window must not be diagnosed as
                # live telemetry — zero the row (flat => never flagged)
                self._slab[h] = 0.0
                self._ts_rows[h] = 0.0
                self._valid[h] = True
                self._staged_full[h] = False
                skipped.append(h)
                self.stats.dead_hosts += int(have[h])
                continue
            skip = max(0, int(round((lasts[h] - t_common) / period)))
            # O(delta) staging first: a row whose previous stage was a
            # full clean window is reused untouched (seqlock sequence
            # unchanged) or left-shifted + topped up with only the new
            # ticks — byte-identical to the full restage it replaces,
            # falling back to it on any raggedness, race, or gap
            staged, r0 = self._stage_delta(h, a, skip, int(counts[h]),
                                           int(seqs[h]), t_common, period)
            retries += r0
            if staged:
                valid[h] = T
                if ref_host < 0 or T > valid[ref_host]:
                    ref_host = h
                continue
            # full-window hosts (the steady state) stage straight into
            # their slab row — ONE bounded copy out of the ring; the
            # scratch detour only happens for ragged/trimmed rows
            direct = counts[h] - skip >= T
            out_ts = self._ts_rows[h] if direct else self._ts_scratch
            out_d = self._slab[h] if direct else self._scratch
            ts_h, d_h, r = a.ring.read_window(T, out_ts=out_ts, out=out_d,
                                              skip_newest=skip)
            retries += r
            # a live writer may have pushed between peek() and the read,
            # making the stale `skip` land past t_common — re-derive the
            # common-edge trim from the timestamps actually returned
            k = int(np.searchsorted(ts_h, t_common + 0.5 * period,
                                    side="right"))
            ts_h, d_h = ts_h[:k], d_h[:, :k]
            if k < self.min_samples:
                self._slab[h] = 0.0
                self._valid[h] = True
                self._staged_full[h] = False
                skipped.append(h)
                continue
            row = self._slab[h]
            if not (direct and k == T):
                if direct:
                    # short/trimmed read landed left-aligned in the slab
                    # row itself: move it through scratch to right-align
                    self._scratch[:, :k] = d_h
                    self._ts_scratch[:k] = ts_h
                    d_h = self._scratch[:, :k]
                    ts_h = self._ts_scratch[:k]
                row[:, T - k:] = d_h
                self._ts_rows[h, T - k:] = ts_h
            if k < T:
                # late joiner: backfill the missing head with its oldest
                # sample — a flat stretch that reads as a quiet baseline
                row[:, :T - k] = d_h[:, :1]
                self._ts_rows[h, :T - k] = (
                    ts_h[0] - period * np.arange(T - k, 0, -1))
                self.stats.ragged_hosts += 1
            valid[h] = k
            # per-cell validity: the agent marks failed/backoff-skipped
            # collectors' channels NaN, so finiteness IS the delivery mask
            np.isfinite(row, out=self._valid[h])
            # only a full clean direct window seeds the next round's
            # delta path — trimmed/backfilled rows must restage
            full = bool(direct and k == T)
            self._staged_full[h] = full
            if full:
                self._staged_seq[h] = int(seqs[h])
                self._staged_last[h] = float(self._ts_rows[h, -1])
            self.stats.full_restages += 1
            if ref_host < 0 or k > valid[ref_host]:
                ref_host = h

        self.stats.assemblies += 1
        self.stats.torn_retries += retries
        self.stats.torn_giveups += (
            sum(a.ring.torn_giveups for a in self.agents) - giveups0)
        snap = FleetSnapshot(ts=self._ts_rows[ref_host], slab=self._slab,
                             valid=valid, skipped=skipped, retries=retries,
                             valid_mask=self._valid)
        self.last_snapshot = snap
        return snap

    # ------------------------------------------------------------- sharding
    def shard_plan(self, shard_hosts: Optional[int] = None,
                   rack_shards: Optional[int] = None):
        """A :class:`~repro.monitor.shard.ShardPlan` covering this fleet.

        Convenience for building the matching
        :class:`~repro.monitor.shard.ShardedFleetMonitor`: the plan's
        host count is the aggregator's agent count, cut into
        ``shard_hosts``-sized contiguous shards (``REPRO_SHARD_HOSTS``
        default) grouped ``rack_shards`` per rack (``REPRO_RACK_SHARDS``
        default).  :meth:`diagnose` then works unchanged — a sharded
        monitor's ``diagnose_fleet`` processes the staged slab shard by
        shard through per-shard views, no extra copies."""
        from repro.monitor.shard import ShardPlan
        return ShardPlan.for_fleet(len(self.agents), shard_hosts,
                                   rack_shards)

    # ------------------------------------------------------------ diagnosis
    def diagnose(self, monitor: FleetMonitor, min_valid_s: float = 0.0,
                 ) -> Optional[FleetDiagnosis]:
        """Assemble and run fleet RCA on the staged slab (no extra copy).

        Returns None when no host has accumulated ``min_valid_s`` seconds
        of telemetry yet (startup / all agents dead).  The diagnosed span
        is the one the most-established host genuinely supports
        (``valid.max()``, capped by the window); live hosts too young to
        fill it are masked out of THIS round — rows zeroed, like
        ``assemble``'s dead-host masking, and reported via
        ``last_snapshot.masked`` / ``stats.masked_hosts``.  That closes
        two failure modes at once: a backfilled flat head never enters
        the diagnosed slab (the constant would hit the sigma floor and
        flag a perfectly healthy late joiner as a straggler — max-valid
        clamping *without* masking had exactly that hole), and a single
        restarting agent can neither narrow every established host's
        baseline nor collapse the span into ``diagnose_fleet``'s
        short-baseline quiet verdict (which would wipe a real straggler's
        strike history fleet-wide while the newcomer refills)."""
        # agent-restart wiring: a host whose probe was restarted/replaced
        # since the last round gets its monitor-side strike/quarantine
        # history re-based BEFORE this diagnosis — delivered exactly once
        for h in sorted(self._pending_resets):
            monitor.reset_host(h)
            self.stats.host_resets += 1
        self._pending_resets.clear()
        snap = self.assemble()
        if snap.slab.shape[0] == 0 or not snap.valid.size:
            return None
        k = int(snap.valid.max())
        if k < max(int(min_valid_s * self.rate_hz), 1):
            return None
        for h in np.flatnonzero((snap.valid > 0) & (snap.valid < k)):
            snap.slab[h] = 0.0      # cannot fill the span: quiet this round
            if snap.valid_mask is not None:
                snap.valid_mask[h] = True   # zeros are deliberate quiet
            snap.masked.append(int(h))
            # the staged row was just overwritten in place — it can no
            # longer seed a delta read; force a full restage next round
            self._staged_full[h] = False
        self.stats.masked_hosts += len(snap.masked)
        T = self.window_n
        vm = snap.valid_mask
        if k < T:
            return monitor.diagnose_fleet(
                snap.ts[T - k:], snap.slab[:, :, T - k:], self.channels,
                valid=None if vm is None else vm[:, :, T - k:])
        return monitor.diagnose_fleet(snap.ts, snap.slab, self.channels,
                                      valid=vm)
