"""Telemetry <-> training integration: per-step hooks, fleet-level RCA."""
from repro.monitor.hooks import StepTelemetry
from repro.monitor.fleet import FleetMonitor, FleetDiagnosis, Mitigation
from repro.monitor.aggregator import (
    AggregatorStats, FleetAggregator, FleetSnapshot,
)
from repro.monitor.shard import (
    ShardCandidates, ShardPlan, ShardTraffic, ShardedFleetMonitor,
    verdict_fingerprint,
)

__all__ = ["StepTelemetry", "FleetMonitor", "FleetDiagnosis", "Mitigation",
           "FleetAggregator", "FleetSnapshot", "AggregatorStats",
           "ShardPlan", "ShardCandidates", "ShardTraffic",
           "ShardedFleetMonitor", "verdict_fingerprint"]
