"""Sharded fleet monitoring: the 10k+-host scale-out of ``diagnose_fleet``.

The single-slab :class:`~repro.monitor.fleet.FleetMonitor` stages the
whole fleet as one (hosts, C, T) array — at 64k hosts that slab alone is
tens of gigabytes, and one detect dispatch on one device is the scaling
wall the paper's multi-node extension (§5.1) runs into first.  This
module splits the fleet into contiguous host shards (:class:`ShardPlan`),
runs Layer-2 detection per shard through the one-dispatch sweep core
(whose cost does not scale with the flagged fraction — PR 5), and merges
shard results through a two-level rack → fleet candidate tree:

  shard   detect + quarantine on its own (H_s, C, T) slab, on its own
          mesh device (``parallel.fleet``); ships a
          :class:`ShardCandidates` — flagged host ids, scores, onsets,
          plus *evidence blocks* for its locally-selected RCA candidates
          — never the raw slab;
  rack    merges its member shards' candidate lists and prunes the
          evidence set to the rack-level RCA selection (same total
          order);
  fleet   concatenates rack candidates and runs the unchanged
          fleet-level verdict logic (:meth:`FleetMonitor._finish_round`)
          over them.

Byte-exactness is by construction, not by tolerance:

  * detection is per-host independent, and the shard dispatch is the
    same ``detect_hosts_slab`` call the single-slab path makes — a
    shard's rows see bit-identical inputs;
  * a corrupt cell ANYWHERE routes every shard through the masked f64
    oracle (``force_oracle``), exactly as one full-slab call with any
    invalid cell takes the oracle for every host — the fast/oracle split
    can never follow shard boundaries;
  * candidate ordering is a total order (score descending, host id
    ascending on ties, ``kind="stable"``), so the fleet-level selection
    over the merged candidates picks exactly the hosts one full-slab
    round would, and each is guaranteed to be in its shard's and rack's
    local selection (a top-K of a superset is a subset of each part's
    top-K);
  * the cross-host-coupled half of Layer 3 (the orientation baseline
    slice depends on the *minimum onset over all RCA'd hosts*) never
    runs per shard — shards only gather their hosts' evidence blocks
    (per-host independent), and the fused RCA kernel runs once at fleet
    level on the assembled blocks.

``verdict_fingerprint`` canonicalizes the deterministic fields of a
:class:`~repro.monitor.fleet.FleetDiagnosis` (everything except wall-time
measurements) so tests, the bench, and the CI parity gate share one
definition of "byte-exact".
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import MIN_BASELINE_N
from repro.kernels import tuning
from repro.monitor.fleet import FleetDiagnosis, FleetMonitor

__all__ = [
    "ShardPlan", "ShardCandidates", "ShardTraffic", "ShardedFleetMonitor",
    "verdict_fingerprint",
]

#: bytes per candidate scalar record crossing the tree: host id (int64),
#: score (f64), onset (int64)
_CAND_RECORD_BYTES = 24


class _ShortBaseline(Exception):
    """Internal: first shard's window is too short for a trusted baseline
    (the round refuses before any shard state advances)."""


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """How the fleet's host axis is cut into shards and racks.

    ``bounds[s] = (start, end)`` is shard ``s``'s contiguous, half-open
    absolute host range; shards tile ``[0, hosts)`` in order with no gaps
    (ragged sizes allowed — the last shard of a fleet that does not
    divide evenly is simply shorter).  ``racks[r]`` lists the shard
    indices reduced together at the rack level; racks partition the
    shards.  The plan is part of the monitor's checkpointed identity:
    restore validates it, because per-shard execution order is what makes
    the quarantine/strike maps partitionable.
    """

    #: per-shard (start, end) absolute host ranges, contiguous ascending
    bounds: Tuple[Tuple[int, int], ...]
    #: rack -> member shard indices (a partition of ``range(n_shards)``)
    racks: Tuple[Tuple[int, ...], ...]

    def __post_init__(self) -> None:
        if not self.bounds:
            raise ValueError("ShardPlan needs at least one shard")
        pos = 0
        for s, (a, b) in enumerate(self.bounds):
            if a != pos or b <= a:
                raise ValueError(
                    f"shard {s} bounds ({a}, {b}) must tile [0, hosts) "
                    f"contiguously (expected start {pos})")
            pos = b
        seen = [s for rack in self.racks for s in rack]
        if sorted(seen) != list(range(len(self.bounds))):
            raise ValueError(f"racks {self.racks} must partition "
                             f"{len(self.bounds)} shards")

    @property
    def hosts(self) -> int:
        """Total fleet size the plan covers."""
        return self.bounds[-1][1]

    @property
    def n_shards(self) -> int:
        """Number of shard slabs."""
        return len(self.bounds)

    @property
    def n_racks(self) -> int:
        """Number of rack-level reduce groups."""
        return len(self.racks)

    @classmethod
    def for_fleet(cls, hosts: int, shard_hosts: Optional[int] = None,
                  rack_shards: Optional[int] = None) -> "ShardPlan":
        """Even plan: ``shard_hosts`` hosts per shard (last shard ragged),
        ``rack_shards`` shards per rack — both defaulting to the
        ``REPRO_SHARD_HOSTS`` / ``REPRO_RACK_SHARDS`` tuning knobs."""
        hosts = int(hosts)
        if hosts <= 0:
            raise ValueError(f"hosts must be positive, got {hosts}")
        sh = tuning.shard_hosts(shard_hosts)
        bounds = tuple((a, min(a + sh, hosts))
                       for a in range(0, hosts, sh))
        return cls.from_bounds(bounds, rack_shards)

    @classmethod
    def from_bounds(cls, bounds: Sequence[Tuple[int, int]],
                    rack_shards: Optional[int] = None) -> "ShardPlan":
        """Plan from explicit (possibly ragged) shard bounds, racks cut
        every ``rack_shards`` shards."""
        bounds = tuple((int(a), int(b)) for a, b in bounds)
        rk = tuning.rack_shards(rack_shards)
        racks = tuple(tuple(range(i, min(i + rk, len(bounds))))
                      for i in range(0, len(bounds), rk))
        return cls(bounds=bounds, racks=racks)

    def shard_of(self, host: int) -> int:
        """Index of the shard owning an absolute host id."""
        h = int(host)
        for s, (a, b) in enumerate(self.bounds):
            if a <= h < b:
                return s
        raise ValueError(f"host {h} outside plan [0, {self.hosts})")

    def to_dict(self) -> Dict[str, object]:
        """JSON-serializable form (checkpoint payload)."""
        return {"bounds": [[int(a), int(b)] for a, b in self.bounds],
                "racks": [[int(s) for s in rack] for rack in self.racks]}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "ShardPlan":
        """Inverse of :meth:`to_dict`."""
        return cls(bounds=tuple((int(a), int(b)) for a, b in d["bounds"]),
                   racks=tuple(tuple(int(s) for s in rack)
                               for rack in d["racks"]))


@dataclasses.dataclass
class ShardCandidates:
    """What one shard (or one rack) ships up the aggregation tree.

    Scalars for *every* flagged host — ids, scores, onsets are 24 bytes a
    host, cheap enough to never prune — plus gathered evidence blocks for
    the locally-selected RCA candidates only (the expensive part:
    ``(1 + M) * (nb + rn)`` floats each).  Raw telemetry never crosses.
    """

    #: absolute flagged host ids, ascending
    idx: np.ndarray
    #: their detection scores (f64)
    score: np.ndarray
    #: their onsets relative to the detection window
    onset: np.ndarray
    #: absolute ids of hosts quarantined this round
    qhosts: np.ndarray
    #: abs host id -> (1 + M, nb + rn) gathered evidence block, for the
    #: local RCA selection only
    evidence: Dict[int, np.ndarray]

    @property
    def scalar_bytes(self) -> int:
        """Wire size of the always-shipped scalar records."""
        return (self.idx.size * _CAND_RECORD_BYTES
                + self.qhosts.size * 8)

    @property
    def evidence_bytes(self) -> int:
        """Wire size of the shipped evidence blocks."""
        return sum(int(b.nbytes) for b in self.evidence.values())


@dataclasses.dataclass
class ShardTraffic:
    """Cross-shard traffic accounting for one sharded round.

    ``raw_bytes`` is the counterfactual — what shipping every shard's
    full (H_s, C, T) slab to the fleet level would have cost — so
    ``total_bytes / raw_bytes`` is the tree's traffic reduction, the
    bench's bounded-cross-shard-traffic claim."""

    #: shard->rack bytes: scalar candidate records
    shard_scalar_bytes: int = 0
    #: shard->rack bytes: evidence blocks
    shard_evidence_bytes: int = 0
    #: rack->fleet bytes: scalar candidate records (post rack merge)
    rack_scalar_bytes: int = 0
    #: rack->fleet bytes: evidence blocks (post rack-level pruning)
    rack_evidence_bytes: int = 0
    #: per-host scores shipped for the FleetDiagnosis readout (8 B/host)
    score_bytes: int = 0
    #: counterfactual: total raw slab bytes that did NOT cross
    raw_bytes: int = 0
    #: flagged candidates that crossed shard->rack
    n_candidates: int = 0
    #: evidence blocks that crossed rack->fleet
    n_evidence: int = 0

    @property
    def total_bytes(self) -> int:
        """Everything that actually crossed the tree."""
        return (self.shard_scalar_bytes + self.shard_evidence_bytes
                + self.rack_scalar_bytes + self.rack_evidence_bytes
                + self.score_bytes)


def _fhex(x: float) -> str:
    """Byte-exact float canonicalization (hex survives JSON round trips
    losslessly, unlike repr-at-17-digits corner cases)."""
    return float(x).hex()


def verdict_fingerprint(fd: FleetDiagnosis) -> Dict[str, object]:
    """Canonical deterministic content of a :class:`FleetDiagnosis`.

    Includes every field the sharded/single-slab parity contract covers —
    straggler, per-host scores, flagged order, mitigations, multi-cause
    lists, quarantine, degraded/deferred fields, and the deterministic
    parts of each Diagnosis (event timestamps/scores, ranked causes with
    confidences, per-metric evidence) — and excludes only wall-time
    measurements (``stage_seconds``, ``t_rca``, ``analysis_seconds``),
    which no two executions ever share.  Floats are hex-encoded so the
    comparison is bitwise.
    """
    def diag_fp(d) -> Dict[str, object]:
        return {
            "event": {"t_onset": _fhex(d.event.t_onset),
                      "t_detect": _fhex(d.event.t_detect),
                      "score": _fhex(d.event.score),
                      "metric": d.event.metric},
            "ranked": [{"cause": rc.cause.value,
                        "confidence": _fhex(rc.confidence),
                        "top_metric": rc.top_metric,
                        "spike_score": _fhex(rc.spike_score),
                        "correlation": _fhex(rc.correlation),
                        "lag_s": _fhex(rc.lag_s)} for rc in d.ranked],
            "per_metric": {name: {k: _fhex(v) for k, v in sorted(m.items())}
                           for name, m in sorted(d.per_metric.items())},
            "t_ready": None if d.t_ready is None else _fhex(d.t_ready),
        }

    scores = np.ascontiguousarray(
        np.asarray(fd.per_host_scores, np.float64))
    return {
        "straggler_host": int(fd.straggler_host),
        "straggler_score": _fhex(fd.straggler_score),
        "mitigation": fd.mitigation.value,
        "per_host_scores_sha256": hashlib.sha256(
            scores.tobytes()).hexdigest(),
        "flagged_hosts": [int(h) for h in fd.flagged_hosts],
        "mitigations": {str(h): m.value
                        for h, m in sorted(fd.mitigations.items())},
        "causes": {str(h): [c.value for c in cl]
                   for h, cl in sorted(fd.causes.items())},
        "diagnoses": {str(h): diag_fp(d)
                      for h, d in sorted(fd.diagnoses.items())},
        "quarantined": [int(h) for h in fd.quarantined],
        "degraded": bool(fd.degraded),
        "deferred_hosts": [int(h) for h in fd.deferred_hosts],
    }


#: provider contract for :meth:`ShardedFleetMonitor.diagnose_sharded` —
#: ``provider(shard_index) -> (host_data, valid_or_None)`` for that
#: shard's host range
ShardProvider = Callable[
    [int], Tuple[np.ndarray, Optional[np.ndarray]]]


class ShardedFleetMonitor(FleetMonitor):
    """A :class:`FleetMonitor` whose rounds execute shard by shard.

    Drop-in: :meth:`diagnose_fleet` accepts the same in-memory
    (hosts, C, T) slab and returns a verdict-identical
    :class:`FleetDiagnosis` (see :func:`verdict_fingerprint`); the fleet
    is internally processed as ``plan.n_shards`` independent slabs, each
    detect dispatch pinned to its mesh device.  At the scales the plan
    exists for, use :meth:`diagnose_sharded` instead: a *provider*
    callback materializes one shard's slab at a time, so the full fleet
    slab never exists in memory (64k hosts × 10 channels × 3100 ticks is
    ~8 GB as one array; one 1024-host shard is ~127 MB).

    All verdict state — strikes, quarantine hysteresis, degraded mode —
    lives in the base class keyed by absolute host id, advanced shard by
    shard; the plan itself is carried in :meth:`state_dict` and validated
    on restore, so a checkpoint cannot silently re-partition the fleet.
    """

    def __init__(self, plan: ShardPlan,
                 devices: Optional[Sequence[object]] = None,
                 **kwargs):
        """Bind the monitor to ``plan``; ``devices`` (default: the JAX
        device pool) are assigned round-robin per shard, and ``kwargs``
        pass through to :class:`FleetMonitor` unchanged."""
        super().__init__(**kwargs)
        #: the shard/rack layout this monitor executes
        self.plan = plan
        from repro.parallel.fleet import shard_devices
        #: per-shard detect-dispatch device (round-robin over the pool)
        self.devices = shard_devices(plan.n_shards, devices)
        #: traffic accounting of the most recent sharded round
        self.last_traffic: Optional[ShardTraffic] = None

    # ------------------------------------------------------------ execution
    def diagnose_fleet(self, ts: np.ndarray, host_data: np.ndarray,
                       channels: Sequence[str],
                       valid: Optional[np.ndarray] = None,
                       extra_cost_s: float = 0.0) -> FleetDiagnosis:
        """Single-slab signature, shard-by-shard execution.

        ``host_data`` must cover exactly ``plan.hosts`` hosts; shards are
        views into it (no copy).  Knowing the whole mask upfront lets the
        round pick the oracle/fast path once instead of re-visiting
        shards (see :meth:`diagnose_sharded`)."""
        host_data = np.asarray(host_data)
        if host_data.shape[0] != self.plan.hosts:
            raise ValueError(f"host_data covers {host_data.shape[0]} hosts,"
                             f" plan covers {self.plan.hosts}")
        vfull = None
        if valid is not None:
            v = np.asarray(valid, bool)
            if v.shape != host_data.shape:
                raise ValueError(f"valid {v.shape} vs data "
                                 f"{host_data.shape}")
            if not v.all():
                vfull = v
        li = list(channels).index(self.cfg.latency_metric)
        T = host_data.shape[2]
        wn = min(self.cfg.window_n, T // 2)
        bn = min(self.cfg.baseline_n, T - wn)
        any_invalid = (vfull is not None
                       and not vfull[:, li, T - wn - bn:T].all())

        def provider(s: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
            a, b = self.plan.bounds[s]
            return (host_data[a:b],
                    None if vfull is None else vfull[a:b])

        return self._diagnose_shards(ts, provider, channels, extra_cost_s,
                                     any_invalid=any_invalid)

    def diagnose_sharded(self, ts: np.ndarray, provider: ShardProvider,
                         channels: Sequence[str],
                         extra_cost_s: float = 0.0) -> FleetDiagnosis:
        """One fleet round with lazily-materialized shard slabs.

        ``provider(s)`` returns shard ``s``'s ``(host_data, valid)`` —
        ``host_data`` of shape ``(bounds[s][1] - bounds[s][0], C, T)``,
        ``valid`` a same-shape bool mask or None.  The provider must be
        deterministic within the round: when one shard reports telemetry
        corruption, shards that already ran the fast path are re-visited
        through the masked f64 oracle (the single-slab masked round takes
        the oracle for *every* host), which calls the provider a second
        time for those shards.  Clean rounds visit each shard exactly
        once."""
        return self._diagnose_shards(ts, provider, channels, extra_cost_s,
                                     any_invalid=None)

    def _diagnose_shards(self, ts: np.ndarray, provider: ShardProvider,
                         channels: Sequence[str], extra_cost_s: float,
                         any_invalid: Optional[bool]) -> FleetDiagnosis:
        """Shared sharded-round core (see class docstring for the tree).

        ``any_invalid`` None means "unknown until shards are visited"
        (provider mode, re-visit clean shards if corruption turns up);
        a bool means the caller inspected the full mask upfront."""
        plan = self.plan
        li = list(channels).index(self.cfg.latency_metric)
        per_shard: List[Optional[ShardCandidates]] = [None] * plan.n_shards
        shard_scores: List[Optional[np.ndarray]] = [None] * plan.n_shards
        quar_saved: List[Optional[np.ndarray]] = [None] * plan.n_shards
        ran_oracle = [False] * plan.n_shards
        saw_invalid = [False] * plan.n_shards
        traffic = ShardTraffic()
        stage: Dict[str, float] = {"detect": 0.0}
        geom = None
        dims: Optional[Tuple[int, int, int]] = None  # (C, T) + wn, bn
        tick_end: Optional[int] = None  # one grid anchor for every shard

        def visit(s: int, force_oracle: bool) -> None:
            nonlocal geom, dims, tick_end
            a, b = plan.bounds[s]
            slab, val = provider(s)
            slab = np.asarray(slab)
            if slab.ndim != 3 or slab.shape[0] != b - a:
                raise ValueError(f"shard {s} slab {slab.shape} vs bounds "
                                 f"({a}, {b})")
            if dims is None:
                T = slab.shape[2]
                wn = min(self.cfg.window_n, T // 2)
                bn = min(self.cfg.baseline_n, T - wn)
                if bn < MIN_BASELINE_N:
                    raise _ShortBaseline
                dims = (T, wn, bn)
                geom = self._evidence_geometry(channels, li, T, wn, bn)
                tick_end = self._tick_end(ts, T)
            T, wn, bn = dims
            if slab.shape[2] != T:
                raise ValueError(f"shard {s} T={slab.shape[2]} vs {T}")
            vfull = None
            if val is not None:
                v = np.asarray(val, bool)
                if v.shape != slab.shape:
                    raise ValueError(f"shard {s} valid {v.shape} vs slab "
                                     f"{slab.shape}")
                if not v.all():
                    vfull = v
            saw_invalid[s] = (
                vfull is not None
                and not vfull[:, li, T - wn - bn:T].all())
            t0 = time.perf_counter()
            # base=a keys the incremental moment rows (and quarantine
            # state) by absolute host id; a forced-oracle re-visit
            # invalidates rather than advances them, so a shard visited
            # twice in one round cannot double-advance the moment state
            scores, cand, onset_rel, qloc = self._detect_round(
                slab, vfull, li, T, wn, bn,
                force_oracle=force_oracle, device=self.devices[s],
                base=a, quar=quar_saved[s], tick_end=tick_end)
            stage["detect"] += time.perf_counter() - t0
            if quar_saved[s] is None:
                qmask = np.zeros(b - a, bool)
                qmask[qloc] = True
                quar_saved[s] = qmask
            ran_oracle[s] = force_oracle or saw_invalid[s]
            # local RCA selection mirrors the fleet's (same total order,
            # same degraded/top-K policy) so every evidence block the
            # fleet level will need is shipped — see _rca_selection
            order = np.argsort(-scores[cand], kind="stable")
            sel, _, _ = self._rca_selection(
                cand[order] + a, onset_rel[order])
            evidence: Dict[int, np.ndarray] = {}
            if geom is not None and sel.size:
                t1 = time.perf_counter()
                X = self._gather_evidence(slab, sel - a, geom, vfull)
                stage["gather"] = (stage.get("gather", 0.0)
                                   + time.perf_counter() - t1)
                evidence = {int(h): X[k] for k, h in enumerate(sel)}
            per_shard[s] = ShardCandidates(
                idx=cand + a, score=scores[cand], onset=onset_rel,
                qhosts=qloc + a, evidence=evidence)
            shard_scores[s] = scores

        force_all = bool(any_invalid)
        try:
            visit(0, force_oracle=force_all)
        except _ShortBaseline:
            # same short-snapshot refusal as the single-slab path, decided
            # before any shard state advances
            self.last_traffic = ShardTraffic()
            return self._quiet_round(plan.hosts, extra_cost_s)
        for s in range(1, plan.n_shards):
            visit(s, force_oracle=force_all)
        if any_invalid is None and any(saw_invalid):
            # corruption surfaced after some shards took the fast path:
            # re-visit exactly those through the oracle so the round
            # matches what one full-slab masked call would have computed
            for s in range(plan.n_shards):
                if not ran_oracle[s]:
                    visit(s, force_oracle=True)

        # rack-level reduce: merge member candidate lists, prune evidence
        # to the rack's own RCA selection
        t2 = time.perf_counter()
        rack_cands: List[ShardCandidates] = []
        for rack in plan.racks:
            members = [per_shard[s] for s in rack]
            for m in members:
                traffic.shard_scalar_bytes += m.scalar_bytes
                traffic.shard_evidence_bytes += m.evidence_bytes
                traffic.n_candidates += int(m.idx.size)
            idx = np.concatenate([m.idx for m in members])
            score = np.concatenate([m.score for m in members])
            onset = np.concatenate([m.onset for m in members])
            qh = np.concatenate([m.qhosts for m in members])
            order = np.argsort(-score, kind="stable")
            sel, _, _ = self._rca_selection(idx[order], onset[order])
            merged_ev: Dict[int, np.ndarray] = {}
            for m in members:
                merged_ev.update(m.evidence)
            rc = ShardCandidates(
                idx=idx, score=score, onset=onset, qhosts=qh,
                evidence={int(h): merged_ev[int(h)] for h in sel
                          if int(h) in merged_ev})
            traffic.rack_scalar_bytes += rc.scalar_bytes
            traffic.rack_evidence_bytes += rc.evidence_bytes
            traffic.n_evidence += len(rc.evidence)
            rack_cands.append(rc)

        # fleet level: concatenate rack candidates (shard order keeps
        # absolute ids ascending) and hand the merged round to the
        # unchanged fleet verdict logic
        scores = np.concatenate([shard_scores[s]
                                 for s in range(plan.n_shards)])
        cand = np.concatenate([rc.idx for rc in rack_cands])
        onset_rel = np.concatenate([rc.onset for rc in rack_cands])
        qhosts = np.concatenate([rc.qhosts for rc in rack_cands])
        blocks: Dict[int, np.ndarray] = {}
        for rc in rack_cands:
            blocks.update(rc.evidence)
        stage["reduce"] = time.perf_counter() - t2
        traffic.score_bytes = int(scores.size) * 8
        # counterfactual: what shipping every raw f32 shard slab would cost
        T, wn, bn = dims
        traffic.raw_bytes = plan.hosts * len(channels) * T * 4
        self.last_traffic = traffic

        def evidence_for(geom_, rca_hosts: np.ndarray) -> np.ndarray:
            missing = [int(h) for h in rca_hosts if int(h) not in blocks]
            if missing:
                raise RuntimeError(
                    f"evidence blocks missing for hosts {missing}: "
                    "shard/rack selection failed to cover the fleet "
                    "RCA set (top-K superset invariant violated)")
            return np.stack([blocks[int(h)] for h in rca_hosts])

        return self._finish_round(ts, channels, li, T, wn, bn, scores,
                                  cand, onset_rel, qhosts, stage,
                                  extra_cost_s, evidence_for)

    # ------------------------------------------------------------ checkpoint
    def state_dict(self) -> Dict[str, object]:
        """Base monitor state plus the shard plan (restore validates it)."""
        d = super().state_dict()
        d["shard_plan"] = self.plan.to_dict()
        return d

    def load_state_dict(self, d: Dict[str, object]) -> None:
        """Restore, refusing a checkpoint partitioned under a different
        plan — the quarantine/strike maps are keyed by absolute host id,
        so they survive *identical* re-partitioning only.  A payload
        without a plan (written by a single-slab monitor) is accepted:
        absolute host ids make single-slab state shard-agnostic."""
        if "shard_plan" in d:
            their = ShardPlan.from_dict(d["shard_plan"])
            if their != self.plan:
                raise ValueError(
                    f"checkpoint shard plan {their.to_dict()} does not "
                    f"match monitor plan {self.plan.to_dict()}; "
                    "cold-start or rebuild the monitor with the "
                    "checkpointed plan")
        super().load_state_dict(d)
