"""Per-step telemetry hook for training/serving loops.

Wraps a step function: stamps wall time per step and per-phase marks (the
NCCL-phase analogue), pushes them into a :class:`DeviceMetricSource`, and
runs a background :class:`TelemetryAgent` sampling host probes at 100 Hz —
the deployment wiring of the paper's agent inside a training job.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, Optional

from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import DeviceMetricSource, ProcCollector


class StepTelemetry:
    """Training-loop telemetry: step timing plus a background host agent.

    ``step_begin``/``step_end`` bracket each training step and push the
    measured latency (and any phase marks) into the device source;
    ``start`` runs the host-probe agent at ``rate_hz`` in the background.
    The agent's ring is what a :class:`~repro.monitor.aggregator.
    FleetAggregator` later stages for fleet diagnosis.
    """

    def __init__(self, rate_hz: float = 100.0, history_s: float = 300.0,
                 use_proc: bool = True, background: bool = True):
        """Build the agent; ``background=False`` samples only on
        ``step_end`` (deterministic tests), ``use_proc=False`` drops the
        /proc collector for device-only telemetry."""
        self.device_src = DeviceMetricSource()
        collectors = [self.device_src]
        if use_proc:
            collectors.append(ProcCollector())
        self.agent = TelemetryAgent(collectors, rate_hz=rate_hz,
                                    history_s=history_s)
        self._background = background
        self._running = False
        self._step_t0: Optional[float] = None

    def start(self) -> None:
        """Start the background sampling thread (idempotent)."""
        if self._background and not self._running:
            self.agent.run_background()
            self._running = True

    def stop(self):
        """Stop background sampling; returns the agent's stats."""
        if self._running:
            self.agent.stop()
            self._running = False
        return self.agent.stats

    # -- step instrumentation ------------------------------------------------
    def step_begin(self) -> None:
        """Stamp the start of a training step."""
        self._step_t0 = time.perf_counter()

    def step_end(self, **phase_ms: float) -> float:
        """Record step completion; returns step latency in ms.

        ``phase_ms`` carries phase marks, e.g. ``coll_allreduce_ms=...``
        when the collective phase is measured separately.
        """
        if self._step_t0 is None:
            return 0.0
        ms = (time.perf_counter() - self._step_t0) * 1e3
        self.device_src.push(step_latency_ms=ms,
                             coll_allreduce_ms=phase_ms.get(
                                 "coll_allreduce_ms", ms))
        for k, v in phase_ms.items():
            if k != "coll_allreduce_ms":
                self.device_src.push(**{k: v})
        if not self._background:
            self.agent.step()
        return ms

    def wrap(self, step_fn: Callable) -> Callable:
        """Return ``step_fn`` bracketed by ``step_begin``/``step_end``."""
        def wrapped(*a, **kw):
            self.step_begin()
            out = step_fn(*a, **kw)
            self.step_end()
            return out
        return wrapped
