"""Telemetry collection agent (paper §2: "telemetry collection agent").

Samples every registered collector at a configurable rate into one
:class:`MultiChannelRing`, converts cumulative counters to rates, and keeps
precise **overhead accounting** — the CPU seconds spent inside the sampling
path divided by wall time is the paper's "CPU Overhead" metric (1.21 % at
100 Hz, Fig 2a).

Two drive modes:
  * ``step(now)`` — virtual-clock stepping, used by the simulation harness
    (deterministic, reproducible trials);
  * ``run_background()`` — a real thread at ``rate_hz`` against the wall
    clock, used by the training loop and the overhead benchmark.

Columnar fast path: when every collector supports ``sample_block`` (the
replay-style ``SimCollector`` does), ``run_virtual`` ingests the whole
span as one f32 (C, n) block via ``MultiChannelRing.push_block`` — no
per-tick dict construction, f32 end to end into the ring, exact-parity
with the per-tick path.  Counter channels are rate-converted vectorized
inside the block, and the block hands its last raw column to
``_prev_raw`` so columnar spans and per-tick steps interleave with exact
rate parity.  Real probes (``ProcCollector``, ``DeviceMetricSource``) and
the derived jiffy channels fall back to the per-tick ``step`` loop, which
stays the parity oracle (``run_virtual(..., columnar=False)`` forces it).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.telemetry.collectors import Collector
from repro.telemetry.ringbuffer import MultiChannelRing
from repro.telemetry.schema import MetricSpec


@dataclasses.dataclass
class AgentStats:
    samples: int = 0
    busy_seconds: float = 0.0      # CPU time inside the sampling path
    overruns: int = 0              # ticks where sampling exceeded the period
    collector_errors: int = 0      # collector.sample raised (crash isolated)
    backoff_skips: int = 0         # collector ticks skipped while backing off
    watchdog_trips: int = 0        # collector samples over the tick deadline
    counter_resets: int = 0        # negative counter deltas seen (and zeroed)
    clock_anomalies: int = 0       # non-positive dt ticks (clock jumped back)
    restarts: int = 0              # in-place re-arms after a stop/hang
    #: wall seconds of *completed* live/virtual segments; the in-flight
    #: background segment is accounted by ``live_t0``
    wall_accum: float = 0.0
    #: perf_counter anchor of the running background segment (None when
    #: not live) — lets ``wall_seconds``/``overhead_frac`` read correctly
    #: MID-run, not only after stop()
    live_t0: Optional[float] = None

    @property
    def wall_seconds(self) -> float:
        """Wall time the agent has been live, including the running
        background segment (the seed only accumulated at stop(), so live
        overhead monitoring read 0.0 mid-run)."""
        w = self.wall_accum
        if self.live_t0 is not None:
            w += time.perf_counter() - self.live_t0
        return w

    @property
    def overhead_frac(self) -> float:
        """CPU overhead fraction (paper Fig 2a y-axis) — live-readable."""
        wall = self.wall_seconds
        if wall <= 0:
            return 0.0
        return self.busy_seconds / wall


class TelemetryAgent:
    def __init__(self, collectors: Sequence[Collector], rate_hz: float = 100.0,
                 history_s: float = 120.0):
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        self.collectors: List[Collector] = list(collectors)
        self.rate_hz = float(rate_hz)
        specs: Dict[str, MetricSpec] = {}
        for c in self.collectors:
            for m in c.metrics:
                specs[m.name] = m
        # internal helper channels (underscore-prefixed) are allowed through
        self._counter_channels = {n for n, m in specs.items() if m.monotonic_counter}
        self.channel_specs = specs
        capacity = int(history_s * rate_hz)
        self.ring = MultiChannelRing(sorted(specs), capacity=capacity)
        self._prev_raw: Dict[str, float] = {}
        self._prev_ts: Optional[float] = None
        self.stats = AgentStats()
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        # crash isolation (chaos hardening): per-collector consecutive
        # failure streaks drive an exponential sampling backoff, and the
        # collector's channels are written as NaN (explicitly invalid)
        # instead of silently carrying stale values forward
        self._fail_streak = [0] * len(self.collectors)
        self._backoff_left = [0] * len(self.collectors)
        self._chan_names = [[m.name for m in c.metrics
                             if not m.name.startswith("_")]
                            for c in self.collectors]
        #: sampling watchdog: a collector answering slower than one tick
        #: period trips the watchdog and sits out the next tick
        self.watchdog_s = 1.0 / self.rate_hz
        #: last stop() join timed out (sampling thread hung)
        self.hung = False

    # ------------------------------------------------------------------ core
    def step(self, now: Optional[float] = None) -> Dict[str, float]:
        """One sampling tick; returns the row written to the ring."""
        t0 = time.perf_counter()
        now = t0 if now is None else now
        raw: Dict[str, float] = {}
        invalid: set = set()
        for ci, c in enumerate(self.collectors):
            if self._backoff_left[ci] > 0:
                # crash isolation: a recently-failed (or deadline-blowing)
                # collector sits out its backoff; its channels are marked
                # invalid, not carried stale
                self._backoff_left[ci] -= 1
                self.stats.backoff_skips += 1
                invalid.update(self._chan_names[ci])
                continue
            tc = time.perf_counter()
            try:
                raw.update(c.sample(now))
            except Exception:
                # A failing probe must never take the agent down (paper's
                # deployability constraint) — isolate, back off, mark its
                # channels invalid, keep sampling everything else.
                self.stats.collector_errors += 1
                self._fail_streak[ci] += 1
                self._backoff_left[ci] = min(
                    1 << min(self._fail_streak[ci], 8), 256)
                invalid.update(self._chan_names[ci])
                continue
            self._fail_streak[ci] = 0
            if time.perf_counter() - tc > self.watchdog_s:
                # sampling watchdog: the values arrived (keep them) but
                # the probe blew the tick budget — sit out the next tick
                # so one slow device node cannot starve the whole agent
                self.stats.watchdog_trips += 1
                self._backoff_left[ci] = 1
        row = self._postprocess(now, raw)
        for name in invalid:
            row[name] = float("nan")
        self.ring.push_row(now, row)
        self.stats.samples += 1
        self.stats.busy_seconds += time.perf_counter() - t0
        return row

    def _postprocess(self, now: float, raw: Dict[str, float]) -> Dict[str, float]:
        """Counters -> rates; derive fractions from jiffy helpers."""
        row: Dict[str, float] = {}
        dt = None
        if self._prev_ts is not None:
            dt_raw = now - self._prev_ts
            if dt_raw <= 0.0:
                # backward/stalled clock jump: a rate over a non-positive
                # dt is garbage (inf or negative) — emit 0.0 this tick,
                # flag it, and let the timeline resume from here
                self.stats.clock_anomalies += 1
            else:
                dt = max(dt_raw, 1e-9)
        for name, v in raw.items():
            if name.startswith("_"):
                continue
            if name in self._counter_channels:
                prev = self._prev_raw.get(name)
                if prev is None or dt is None:
                    row[name] = 0.0
                else:
                    if v < prev:
                        # counter reset (agent/exporter restart): the
                        # delta is meaningless — clamp to 0 and count it
                        self.stats.counter_resets += 1
                    row[name] = max(v - prev, 0.0) / dt
            else:
                row[name] = v
        # derived: cpu_util_other & iowait_frac from jiffy counters
        bt, tt = raw.get("_cpu_busy_jiffies"), raw.get("_cpu_total_jiffies")
        if bt is not None and tt is not None and dt is not None:
            pb = self._prev_raw.get("_cpu_busy_jiffies")
            pt = self._prev_raw.get("_cpu_total_jiffies")
            if pb is not None and pt is not None and tt > pt:
                row["cpu_util_other"] = max(0.0, min(1.0, (bt - pb) / (tt - pt)))
        iw = raw.get("_iowait_jiffies")
        if iw is not None and dt is not None:
            piw = self._prev_raw.get("_iowait_jiffies")
            pt = self._prev_raw.get("_cpu_total_jiffies")
            tt2 = raw.get("_cpu_total_jiffies")
            if piw is not None and pt is not None and tt2 is not None and tt2 > pt:
                row["iowait_frac"] = max(0.0, min(1.0, (iw - piw) / (tt2 - pt)))
        self._prev_raw = raw
        self._prev_ts = now
        return row

    # ----------------------------------------------------------- virtual run
    def _columnar_block(self, grid: np.ndarray,
                        ) -> Optional[Tuple[np.ndarray, Dict[str, float]]]:
        """(C, n) f32 block for the whole grid plus the raw values at the
        grid's last instant, or None if any collector forces the per-tick
        path.

        Counter channels are rate-converted vectorized — the same
        ``max(v - prev, 0) / dt`` rule as ``_postprocess``, seeded from
        ``_prev_raw``/``_prev_ts`` so a block that follows per-tick steps
        continues their rate stream exactly.  The returned raw tail is the
        mirror handoff: ``run_virtual`` installs it as ``_prev_raw`` so the
        first ``step()`` AFTER the block computes its delta from the
        block's end, not from a stale pre-block raw value over a
        post-block dt (the mixed columnar→per-tick rate bug).
        """
        cols: Dict[str, np.ndarray] = {}
        for c in self.collectors:
            try:
                blk = c.sample_block(grid)
            except Exception:
                # same invariant as step(): a failing probe must never take
                # the agent down — fall back to the per-tick path, which
                # skips the offender sample by sample
                return None
            if blk is None:
                return None
            cols.update(blk)
        if any(k.startswith("_") for k in cols):
            # derived jiffy channels (cpu_util_other, iowait_frac) only
            # exist on the per-tick path
            return None
        n = grid.size
        # shared per-block clock geometry: non-positive dts (backward or
        # frozen clock inside the grid) zero the rate at that tick — the
        # same guard as _postprocess, counted once per anomalous tick
        dts = np.diff(np.asarray(grid, np.float64)) if n > 1 else \
            np.empty(0, np.float64)
        dts_ok = dts > 0.0
        if dts.size:
            self.stats.clock_anomalies += int((~dts_ok).sum())
        dt0 = None
        if self._prev_ts is not None:
            dt0_raw = float(grid[0]) - self._prev_ts
            if dt0_raw <= 0.0:
                self.stats.clock_anomalies += 1
            else:
                dt0 = max(dt0_raw, 1e-9)
        block = np.empty((self.ring.n_channels, n), np.float32)
        for i, name in enumerate(self.ring.channels):
            v = cols.get(name)
            if v is None:
                # channel absent from this run's collectors: forward-fill
                # its last ring value (0.0 on a fresh ring) — the same
                # carry semantics as push_row
                last = 0.0
                if len(self.ring):
                    last = float(self.ring.window(1, copy=False)[1][i, -1])
                block[i] = last
            elif name in self._counter_channels:
                raw = np.asarray(v, np.float64)
                rates = np.zeros(n, np.float64)
                if n > 1:
                    d = np.diff(raw)
                    self.stats.counter_resets += int(
                        ((d < 0) & dts_ok).sum())
                    rates[1:] = np.where(
                        dts_ok,
                        np.maximum(d, 0.0) / np.maximum(dts, 1e-9), 0.0)
                prev = self._prev_raw.get(name)
                if prev is not None and dt0 is not None:
                    if float(raw[0]) < prev:
                        self.stats.counter_resets += 1
                    rates[0] = max(float(raw[0]) - prev, 0.0) / dt0
                block[i] = rates
            else:
                block[i] = v
        raw_tail = {name: float(np.asarray(v)[-1]) for name, v in cols.items()}
        return block, raw_tail

    def run_virtual(self, t_start: float, t_end: float,
                    columnar: bool = True) -> None:
        """Drive the agent on a virtual clock (simulation trials).

        ``columnar=True`` (default) ingests the whole span as one f32
        block when every collector supports it; ``False`` forces the
        per-tick ``step`` loop (the parity oracle).
        """
        period = 1.0 / self.rate_hz
        n = int(round((t_end - t_start) / period))
        if columnar and n:
            t0 = time.perf_counter()
            grid = t_start + np.arange(n) * period
            hit = self._columnar_block(grid)
            if hit is not None:
                block, raw_tail = hit
                self.ring.push_block(grid, block)
                self.stats.samples += n
                # per-tick-parity handoff: the next step()/block computes
                # counter deltas from the block's last raw column over the
                # block-end timestamp
                self._prev_raw = raw_tail
                self._prev_ts = float(grid[-1])
                self.stats.busy_seconds += time.perf_counter() - t0
                self.stats.wall_accum += t_end - t_start
                return
        for i in range(n):
            self.step(t_start + i * period)
        self.stats.wall_accum += t_end - t_start

    # -------------------------------------------------------- threaded drive
    def run_background(self) -> None:
        if self._thread is not None:
            raise RuntimeError("agent already running")
        self._stop.clear()
        self.stats.live_t0 = time.perf_counter()

        def loop() -> None:
            period = 1.0 / self.rate_hz
            next_t = time.perf_counter()
            while not self._stop.is_set():
                self.step()
                next_t += period
                sleep = next_t - time.perf_counter()
                if sleep > 0:
                    self._stop.wait(sleep)
                else:
                    self.stats.overruns += 1
                    next_t = time.perf_counter()

        self._thread = threading.Thread(target=loop, name="telemetry-agent",
                                        daemon=True)
        self._thread.start()

    def stop(self, timeout: float = 5.0) -> AgentStats:
        """Stop the background thread (bounded join; idempotent).

        A hung collector cannot hang the caller: after ``timeout`` the
        daemon thread is abandoned (it dies with the process) and the
        stats are folded regardless.  Double-stop is a no-op.
        """
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=timeout)
            #: True when the join timed out — the sampling thread is hung
            #: (the aggregator's bounded stop() counts these)
            self.hung = self._thread.is_alive()
            self._thread = None
        # fold the live segment into the accumulator exactly once — a
        # second stop() (or stop without start) is a no-op, and repeated
        # start/stop cycles sum their segments without double counting
        if self.stats.live_t0 is not None:
            self.stats.wall_accum += time.perf_counter() - self.stats.live_t0
            self.stats.live_t0 = None
        return self.stats

    def restart(self) -> None:
        """Re-arm a stopped (or hung-and-abandoned) agent in place.

        The monitor's RESTART_TELEMETRY mitigation path: clears the hung
        flag, crash-isolation backoffs, and the counter-delta handoff (a
        fresh probe must not compute rates against pre-restart raws), and
        counts the restart in stats.  The ring and its history survive —
        restart recovers the *probe*, not the data.  Refuses while the
        sampling thread is still live."""
        if self._thread is not None:
            raise RuntimeError("stop() the agent before restart()")
        self.hung = False
        self._stop.clear()
        self._fail_streak = [0] * len(self.collectors)
        self._backoff_left = [0] * len(self.collectors)
        self._prev_raw = {}
        self._prev_ts = None
        self.stats.restarts += 1

    # ------------------------------------------------------------- accessors
    def window(self, seconds: float, copy: bool = True,
               ) -> tuple[np.ndarray, np.ndarray]:
        """(ts, (C, n)) snapshot of the trailing ``seconds``.

        ``copy=True`` goes through the ring's seqlock validate-retry read,
        so the snapshot is consistent even while the background sampling
        thread is pushing (the seed's plain gather could pair ts[i] with a
        half-written column).  ``copy=False`` forwards the ring's
        zero-copy f32 view when the span is contiguous — the columnar
        monitor path; under a live writer the caller must bracket it with
        ``ring.read_begin``/``read_retry`` (or use :meth:`read_window`)."""
        n = int(seconds * self.rate_hz)
        if copy:
            ts, data, _ = self.ring.read_window(n)
            return ts, data
        return self.ring.window(n, copy=False)

    def read_window(self, seconds: float,
                    out_ts: Optional[np.ndarray] = None,
                    out: Optional[np.ndarray] = None, skip_newest: int = 0,
                    ) -> tuple[np.ndarray, np.ndarray, int]:
        """Torn-read-safe trailing window straight into caller buffers —
        the :class:`~repro.monitor.aggregator.FleetAggregator` staging
        path.  Returns ``(ts, data, torn_retries)``."""
        n = int(seconds * self.rate_hz)
        return self.ring.read_window(n, out_ts=out_ts, out=out,
                                     skip_newest=skip_newest)

    @property
    def channels(self) -> List[str]:
        return self.ring.channels
