"""Fixed-capacity ring buffers for telemetry samples.

Production constraint (paper §2: "operates with 1.21% CPU overhead at 100
Hz"): the hot path must be allocation-free.  ``RingBuffer`` writes into a
preallocated numpy array; ``MultiChannelRing`` packs all channels of one host
into a single (C, N) array so a window snapshot is one contiguous slice —
that snapshot is exactly the (metrics × window) tile the correlation kernels
consume.

Columnar fast path: ``push_block`` ingests a whole (C, n) f32 block in two
slice writes (no per-tick Python), and ``window(n, copy=False)`` hands the
monitor a zero-copy f32 view of the ring storage whenever the span does not
wrap — end to end f32 from collector to kernel, no f64 round-trip.

Seqlock protocol (single writer, many readers, no locks): the live
deployment samples from a background thread while the monitor reads, so
:class:`MultiChannelRing` carries a monotonically increasing sequence
counter.  The **writer contract**: every mutation (``push_row`` /
``push_block``) bumps the counter to odd before touching storage and back
to even after — the counter is odd exactly while a write is in flight.
The **reader contract**: take ``read_begin()`` (spins past an in-flight
write), consume the window — e.g. copy the ``window(copy=False)`` views
into your own buffer — then check ``read_retry(seq)``; if the sequence
moved, the snapshot may pair samples from different instants (a torn
read) and MUST be discarded and retried.  ``read_window`` packages that
validate-retry loop and always returns a consistent snapshot: the common
case is one bounded copy of the zero-copy views into a caller-supplied
(or freshly allocated) buffer; a wrap or a torn read only repeats that
bounded copy, it never takes a lock.  Under CPython the GIL gives each
bytecode-level load/store sequential consistency, which is all the
protocol needs; the counter itself is only ever written by the single
writer thread.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class RingBuffer:
    """Single-channel ring of (timestamp, value) with O(1) append."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._val = np.zeros(self.capacity, dtype=np.float32)
        self._head = 0          # next write slot
        self._count = 0         # valid samples (<= capacity)

    def __len__(self) -> int:
        return self._count

    @property
    def full(self) -> bool:
        return self._count == self.capacity

    def append(self, ts: float, value: float) -> None:
        self._ts[self._head] = ts
        self._val[self._head] = value
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1

    def extend(self, ts: np.ndarray, values: np.ndarray) -> None:
        """Bulk append: two slice writes (split at the wrap point), not a
        per-sample Python loop."""
        t = np.asarray(ts, dtype=np.float64).ravel()
        v = np.asarray(values, dtype=np.float32).ravel()
        if t.size != v.size:
            raise ValueError(f"ts {t.size} vs values {v.size}")
        n = t.size
        if n == 0:
            return
        if n >= self.capacity:          # only the newest samples survive
            t, v = t[-self.capacity:], v[-self.capacity:]
            n = self.capacity
        first = min(n, self.capacity - self._head)
        self._ts[self._head:self._head + first] = t[:first]
        self._val[self._head:self._head + first] = v[:first]
        rest = n - first
        if rest:
            self._ts[:rest] = t[first:]
            self._val[:rest] = v[first:]
        self._head = (self._head + n) % self.capacity
        self._count = min(self.capacity, self._count + n)

    def view(self, last_n: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Chronologically ordered copy of the newest ``last_n`` samples."""
        n = self._count if last_n is None else min(last_n, self._count)
        if n == 0:
            return (np.empty(0, dtype=np.float64), np.empty(0, dtype=np.float32))
        start = (self._head - n) % self.capacity
        idx = (start + np.arange(n)) % self.capacity
        return self._ts[idx].copy(), self._val[idx].copy()

    def latest(self) -> Tuple[float, float]:
        if self._count == 0:
            raise IndexError("empty ring")
        i = (self._head - 1) % self.capacity
        return float(self._ts[i]), float(self._val[i])


class MultiChannelRing:
    """All channels of one host packed into a (C, N) ring.

    Every ``push_row`` writes one column (one sample instant across all
    channels).  ``window(n)`` returns a contiguous (C, n) snapshot plus the
    timestamp vector — the unit of work handed to the correlation engine.
    """

    def __init__(self, channels: Sequence[str], capacity: int):
        if not channels:
            raise ValueError("need at least one channel")
        self.channels: List[str] = list(channels)
        self.index: Dict[str, int] = {c: i for i, c in enumerate(self.channels)}
        if len(self.index) != len(self.channels):
            raise ValueError("duplicate channel names")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._data = np.full((len(self.channels), self.capacity), np.nan,
                             dtype=np.float32)
        self._head = 0
        self._count = 0
        #: seqlock sequence: odd while the (single) writer is mid-mutation,
        #: even when storage is stable.  See the module docstring for the
        #: writer/reader contract.
        self._seq = 0
        #: reads that observed a concurrent write and had to retry
        self.torn_retries = 0
        #: reads that exhausted their retry budget and returned empty —
        #: the degraded give-up path (a pinned writer must cost one
        #: host-round, never a blocked aggregator)
        self.torn_giveups = 0
        #: row-key tuple -> (positions into the dict, destination channel
        #: rows); the agent emits identically-keyed dicts every tick, so one
        #: cached layout turns push_row into two vectorized writes.
        self._row_layout: Dict[tuple, Tuple[np.ndarray, np.ndarray]] = {}

    def __len__(self) -> int:
        return self._count

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def _layout(self, keys: tuple) -> Tuple[np.ndarray, np.ndarray]:
        hit = self._row_layout.get(keys)
        if hit is None:
            sel = [p for p, k in enumerate(keys) if k in self.index]
            dest = [self.index[keys[p]] for p in sel]
            hit = (np.asarray(sel, np.intp), np.asarray(dest, np.intp))
            self._row_layout[keys] = hit
        return hit

    # ----------------------------------------------------------- seqlock API
    def _write_begin(self) -> None:
        self._seq += 1          # odd: mutation in flight

    def _write_end(self) -> None:
        self._seq += 1          # even: storage stable again

    def read_begin(self, max_spins: int = 100) -> int:
        """Reader entry: returns an even sequence, spinning past any
        in-flight write (the writer's critical section is microseconds).

        Bounded: after ``max_spins`` yields the in-flight (odd) sequence
        is returned as-is.  ``read_retry`` treats an odd entry sequence as
        torn, so a reader stuck above a writer that died or got pinned
        mid-write degrades through its own retry/give-up path instead of
        spinning here forever."""
        for _ in range(int(max_spins)):
            s = self._seq
            if not (s & 1):
                return s
            time.sleep(0)       # yield to the writer thread
        return self._seq

    def read_retry(self, seq: int) -> bool:
        """True if a write overlapped the read that started at ``seq`` —
        the snapshot may be torn and must be retried.  An odd ``seq``
        (bounded ``read_begin`` gave up mid-write) is always torn."""
        return bool(seq & 1) or self._seq != seq

    def read_window(self, n: int, out_ts: Optional[np.ndarray] = None,
                    out: Optional[np.ndarray] = None, skip_newest: int = 0,
                    max_retries: int = 10_000,
                    ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Torn-read-safe consistent snapshot of the newest ``n`` columns.

        Returns ``(ts[k], data[C, k], retries)`` with ``k <= n`` the valid
        count — chronological, consistent even against a concurrent writer
        thread.  ``out_ts`` (>= n f64) / ``out`` ((C, >= n) f32) receive
        the data when given (the returned arrays are views into them), so
        a monitor can stage straight into a preallocated slab with one
        bounded copy and zero allocation; omitted, they are allocated.
        ``skip_newest`` drops that many of the newest columns first (clock
        alignment across hosts).  The validate-retry loop repeats the copy
        until a quiescent sequence brackets it; ``retries`` reports how
        many attempts observed writer contention (also accumulated on
        :attr:`torn_retries`).

        Bounded: after ``max_retries`` torn attempts the read GIVES UP and
        returns an empty ``(ts[:0], data[:, :0], retries)`` snapshot,
        counting :attr:`torn_giveups` — the caller treats the host as
        torn-this-round (degraded) instead of spinning forever under a
        pinned or runaway writer.
        """
        n = int(n)
        if out is None:
            out = np.empty((len(self.channels), n), np.float32)
        if out_ts is None:
            out_ts = np.empty(n, np.float64)
        retries = 0
        while True:
            s0 = self.read_begin()
            # _head/_count may themselves be torn — each is always an
            # in-range int, so the slices below stay valid, and the final
            # sequence check rejects any inconsistent pairing
            avail = max(self._count - int(skip_newest), 0)
            k = min(n, avail)
            if k:
                start = (self._head - int(skip_newest) - k) % self.capacity
                first = min(k, self.capacity - start)
                out_ts[:first] = self._ts[start:start + first]
                out[:, :first] = self._data[:, start:start + first]
                rest = k - first
                if rest:
                    out_ts[first:k] = self._ts[:rest]
                    out[:, first:k] = self._data[:, :rest]
            if not self.read_retry(s0):
                return out_ts[:k], out[:, :k], retries
            retries += 1
            self.torn_retries += 1
            if retries >= max_retries:
                self.torn_giveups += 1
                return out_ts[:0], out[:, :0], retries
            if retries > 32:    # heavy contention: back off a little
                time.sleep(1e-5)

    def push_row(self, ts: float, values: Dict[str, float]) -> None:
        # everything fallible (layout resolution, dict -> f32 conversion)
        # happens before write_begin so an exception can never strand the
        # sequence counter odd
        sel, dest = self._layout(tuple(values))
        vals = np.fromiter(values.values(), dtype=np.float32,
                           count=len(values))
        col = self._head
        self._write_begin()
        self._ts[col] = ts
        # carry the whole previous column forward in one vectorized copy,
        # then overwrite the channels present at this instant — absent
        # channels keep their last value (0.0 on the very first push)
        if self._count > 0:
            self._data[:, col] = self._data[:, (col - 1) % self.capacity]
        else:
            self._data[:, col] = 0.0
        self._data[dest, col] = vals[sel]
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1
        self._write_end()

    def push_block(self, ts: np.ndarray, block: np.ndarray) -> None:
        """Columnar bulk append: ``block`` is (C, n) — n sample instants
        across ALL channels — written in two slice writes (split at the
        wrap point).  Exact-parity counterpart of n ``push_row`` calls with
        full rows; the agent's columnar sampling path feeds this.
        """
        t = np.asarray(ts, dtype=np.float64).ravel()
        b = np.asarray(block, dtype=np.float32)
        if b.shape != (self.n_channels, t.size):
            raise ValueError(f"block {b.shape} vs "
                             f"({self.n_channels}, {t.size})")
        n = t.size
        if n == 0:
            return
        if n >= self.capacity:          # only the newest samples survive
            t, b = t[-self.capacity:], b[:, -self.capacity:]
            n = self.capacity
        self._write_begin()
        first = min(n, self.capacity - self._head)
        self._ts[self._head:self._head + first] = t[:first]
        self._data[:, self._head:self._head + first] = b[:, :first]
        rest = n - first
        if rest:
            self._ts[:rest] = t[first:]
            self._data[:, :rest] = b[:, first:]
        self._head = (self._head + n) % self.capacity
        self._count = min(self.capacity, self._count + n)
        self._write_end()

    def read_since(self, t_after: float, max_retries: int = 10_000,
                   ) -> Tuple[np.ndarray, np.ndarray, int]:
        """Consistent snapshot of every column newer than ``t_after`` —
        the warm-restart replay read.

        A monitor restoring from a checkpoint knows the newest sample
        time it had processed (``t_seen``); the ring — single-writer,
        unaffected by the monitor's crash — still holds the trailing
        history, so ``read_since(t_seen)`` is exactly the backlog to
        re-drive through the restored state.  Returns ``(ts, data,
        n_new)`` with ``n_new == ts.size`` (0 when nothing newer exists,
        e.g. after a torn-read give-up)."""
        ts, data, _ = self.read_window(self.capacity,
                                       max_retries=max_retries)
        lo = int(np.searchsorted(ts, float(t_after), side="right"))
        return ts[lo:], data[:, lo:], int(ts.size - lo)

    def peek(self, max_retries: int = 1000) -> Tuple[int, float]:
        """Consistent ``(count, newest timestamp)`` — seqlock-validated, so
        safe against the background writer.  ``(0, -inf)`` when empty.

        Bounded like :meth:`read_window`: after ``max_retries`` torn
        attempts it gives up with ``(0, -inf)`` (counting
        :attr:`torn_giveups`), which the aggregator reads as a host with
        nothing fresh to stage — degraded, not wedged."""
        retries = 0
        while True:
            s0 = self.read_begin()
            cnt = self._count
            last = (float(self._ts[(self._head - 1) % self.capacity])
                    if cnt else -np.inf)
            if not self.read_retry(s0):
                return cnt, last
            self.torn_retries += 1
            retries += 1
            if retries >= max_retries:
                self.torn_giveups += 1
                return 0, -np.inf

    def watermark(self, max_retries: int = 1000,
                  ) -> Tuple[int, int, float]:
        """Consistent ``(seq, count, newest timestamp)`` — :meth:`peek`
        plus the seqlock sequence the snapshot was taken under.

        The sequence is the ring's cheapest change detector: it advances
        by exactly two per completed write, so a reader that stashed
        ``seq`` can later conclude "nothing was pushed since" from one
        integer compare — the aggregator's delta-staging uses this to
        skip re-reading (and re-validating) a host window that cannot
        have changed.  Gives up like :meth:`peek` with ``(-1, 0, -inf)``
        after ``max_retries`` torn attempts.
        """
        retries = 0
        while True:
            s0 = self.read_begin()
            cnt = self._count
            last = (float(self._ts[(self._head - 1) % self.capacity])
                    if cnt else -np.inf)
            if not self.read_retry(s0):
                return int(s0), cnt, last
            self.torn_retries += 1
            retries += 1
            if retries >= max_retries:
                self.torn_giveups += 1
                return -1, 0, -np.inf

    def window(self, n: int, copy: bool = True, with_seq: bool = False,
               ):
        """Newest ``n`` columns, chronological: (ts[n], data[C, n]).

        ``copy=False`` returns zero-copy f32 views of the ring storage when
        the span is contiguous (no wrap) — the columnar monitor path; the
        views are invalidated by the next push, so consume before pushing.
        A wrapped span is always returned as a copy.

        Against a concurrent writer thread neither variant is safe on its
        own — even the copying gather can pair a timestamp with a
        half-written column.  Either wrap the call in ``read_begin`` /
        ``read_retry`` (``with_seq=True`` appends the read sequence to the
        tuple for exactly that), or use :meth:`read_window`, which owns the
        retry loop.
        """
        # seqlock order: capture an even (stable) sequence before reading
        # head/count — a raw capture could hand back an odd in-flight
        # value that read_retry would then wrongly accept
        seq = self.read_begin() if with_seq else self._seq
        n = min(int(n), self._count)
        if n == 0:
            out = (np.empty(0, np.float64),
                   np.empty((self.n_channels, 0), np.float32))
        else:
            start = (self._head - n) % self.capacity
            if start + n <= self.capacity:      # contiguous: plain slices
                ts = self._ts[start:start + n]
                d = self._data[:, start:start + n]
                out = (ts.copy(), d.copy()) if copy else (ts, d)
            else:
                idx = (start + np.arange(n)) % self.capacity
                out = (self._ts[idx].copy(), self._data[:, idx].copy())
        return out + (seq,) if with_seq else out

    def channel(self, name: str, n: Optional[int] = None) -> np.ndarray:
        ts, data = self.window(self._count if n is None else n)
        del ts
        return data[self.index[name]]
