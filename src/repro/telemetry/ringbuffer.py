"""Fixed-capacity ring buffers for telemetry samples.

Production constraint (paper §2: "operates with 1.21% CPU overhead at 100
Hz"): the hot path must be allocation-free.  ``RingBuffer`` writes into a
preallocated numpy array; ``MultiChannelRing`` packs all channels of one host
into a single (C, N) array so a window snapshot is one contiguous slice —
that snapshot is exactly the (metrics × window) tile the correlation kernels
consume.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class RingBuffer:
    """Single-channel ring of (timestamp, value) with O(1) append."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._val = np.zeros(self.capacity, dtype=np.float32)
        self._head = 0          # next write slot
        self._count = 0         # valid samples (<= capacity)

    def __len__(self) -> int:
        return self._count

    @property
    def full(self) -> bool:
        return self._count == self.capacity

    def append(self, ts: float, value: float) -> None:
        self._ts[self._head] = ts
        self._val[self._head] = value
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1

    def extend(self, ts: np.ndarray, values: np.ndarray) -> None:
        for t, v in zip(np.asarray(ts, dtype=np.float64).ravel(),
                        np.asarray(values, dtype=np.float32).ravel()):
            self.append(float(t), float(v))

    def view(self, last_n: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Chronologically ordered copy of the newest ``last_n`` samples."""
        n = self._count if last_n is None else min(last_n, self._count)
        if n == 0:
            return (np.empty(0, dtype=np.float64), np.empty(0, dtype=np.float32))
        start = (self._head - n) % self.capacity
        idx = (start + np.arange(n)) % self.capacity
        return self._ts[idx].copy(), self._val[idx].copy()

    def latest(self) -> Tuple[float, float]:
        if self._count == 0:
            raise IndexError("empty ring")
        i = (self._head - 1) % self.capacity
        return float(self._ts[i]), float(self._val[i])


class MultiChannelRing:
    """All channels of one host packed into a (C, N) ring.

    Every ``push_row`` writes one column (one sample instant across all
    channels).  ``window(n)`` returns a contiguous (C, n) snapshot plus the
    timestamp vector — the unit of work handed to the correlation engine.
    """

    def __init__(self, channels: Sequence[str], capacity: int):
        if not channels:
            raise ValueError("need at least one channel")
        self.channels: List[str] = list(channels)
        self.index: Dict[str, int] = {c: i for i, c in enumerate(self.channels)}
        if len(self.index) != len(self.channels):
            raise ValueError("duplicate channel names")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._data = np.full((len(self.channels), self.capacity), np.nan,
                             dtype=np.float32)
        self._head = 0
        self._count = 0

    def __len__(self) -> int:
        return self._count

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def push_row(self, ts: float, values: Dict[str, float]) -> None:
        col = self._head
        self._ts[col] = ts
        for name, v in values.items():
            i = self.index.get(name)
            if i is not None:
                self._data[i, col] = np.float32(v)
        # channels absent from this sample instant carry forward last value
        missing = set(self.channels) - set(values)
        if missing and self._count > 0:
            prev = (col - 1) % self.capacity
            for name in missing:
                i = self.index[name]
                self._data[i, col] = self._data[i, prev]
        elif missing:
            for name in missing:
                self._data[self.index[name], col] = 0.0
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1

    def window(self, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Newest ``n`` columns, chronological: (ts[n], data[C, n])."""
        n = min(int(n), self._count)
        if n == 0:
            return (np.empty(0, np.float64),
                    np.empty((self.n_channels, 0), np.float32))
        start = (self._head - n) % self.capacity
        idx = (start + np.arange(n)) % self.capacity
        return self._ts[idx].copy(), self._data[:, idx].copy()

    def channel(self, name: str, n: Optional[int] = None) -> np.ndarray:
        ts, data = self.window(self._count if n is None else n)
        del ts
        return data[self.index[name]]
