"""Fixed-capacity ring buffers for telemetry samples.

Production constraint (paper §2: "operates with 1.21% CPU overhead at 100
Hz"): the hot path must be allocation-free.  ``RingBuffer`` writes into a
preallocated numpy array; ``MultiChannelRing`` packs all channels of one host
into a single (C, N) array so a window snapshot is one contiguous slice —
that snapshot is exactly the (metrics × window) tile the correlation kernels
consume.

Columnar fast path: ``push_block`` ingests a whole (C, n) f32 block in two
slice writes (no per-tick Python), and ``window(n, copy=False)`` hands the
monitor a zero-copy f32 view of the ring storage whenever the span does not
wrap — end to end f32 from collector to kernel, no f64 round-trip.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class RingBuffer:
    """Single-channel ring of (timestamp, value) with O(1) append."""

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._val = np.zeros(self.capacity, dtype=np.float32)
        self._head = 0          # next write slot
        self._count = 0         # valid samples (<= capacity)

    def __len__(self) -> int:
        return self._count

    @property
    def full(self) -> bool:
        return self._count == self.capacity

    def append(self, ts: float, value: float) -> None:
        self._ts[self._head] = ts
        self._val[self._head] = value
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1

    def extend(self, ts: np.ndarray, values: np.ndarray) -> None:
        """Bulk append: two slice writes (split at the wrap point), not a
        per-sample Python loop."""
        t = np.asarray(ts, dtype=np.float64).ravel()
        v = np.asarray(values, dtype=np.float32).ravel()
        if t.size != v.size:
            raise ValueError(f"ts {t.size} vs values {v.size}")
        n = t.size
        if n == 0:
            return
        if n >= self.capacity:          # only the newest samples survive
            t, v = t[-self.capacity:], v[-self.capacity:]
            n = self.capacity
        first = min(n, self.capacity - self._head)
        self._ts[self._head:self._head + first] = t[:first]
        self._val[self._head:self._head + first] = v[:first]
        rest = n - first
        if rest:
            self._ts[:rest] = t[first:]
            self._val[:rest] = v[first:]
        self._head = (self._head + n) % self.capacity
        self._count = min(self.capacity, self._count + n)

    def view(self, last_n: Optional[int] = None) -> Tuple[np.ndarray, np.ndarray]:
        """Chronologically ordered copy of the newest ``last_n`` samples."""
        n = self._count if last_n is None else min(last_n, self._count)
        if n == 0:
            return (np.empty(0, dtype=np.float64), np.empty(0, dtype=np.float32))
        start = (self._head - n) % self.capacity
        idx = (start + np.arange(n)) % self.capacity
        return self._ts[idx].copy(), self._val[idx].copy()

    def latest(self) -> Tuple[float, float]:
        if self._count == 0:
            raise IndexError("empty ring")
        i = (self._head - 1) % self.capacity
        return float(self._ts[i]), float(self._val[i])


class MultiChannelRing:
    """All channels of one host packed into a (C, N) ring.

    Every ``push_row`` writes one column (one sample instant across all
    channels).  ``window(n)`` returns a contiguous (C, n) snapshot plus the
    timestamp vector — the unit of work handed to the correlation engine.
    """

    def __init__(self, channels: Sequence[str], capacity: int):
        if not channels:
            raise ValueError("need at least one channel")
        self.channels: List[str] = list(channels)
        self.index: Dict[str, int] = {c: i for i, c in enumerate(self.channels)}
        if len(self.index) != len(self.channels):
            raise ValueError("duplicate channel names")
        self.capacity = int(capacity)
        self._ts = np.zeros(self.capacity, dtype=np.float64)
        self._data = np.full((len(self.channels), self.capacity), np.nan,
                             dtype=np.float32)
        self._head = 0
        self._count = 0
        #: row-key tuple -> (positions into the dict, destination channel
        #: rows); the agent emits identically-keyed dicts every tick, so one
        #: cached layout turns push_row into two vectorized writes.
        self._row_layout: Dict[tuple, Tuple[np.ndarray, np.ndarray]] = {}

    def __len__(self) -> int:
        return self._count

    @property
    def n_channels(self) -> int:
        return len(self.channels)

    def _layout(self, keys: tuple) -> Tuple[np.ndarray, np.ndarray]:
        hit = self._row_layout.get(keys)
        if hit is None:
            sel = [p for p, k in enumerate(keys) if k in self.index]
            dest = [self.index[keys[p]] for p in sel]
            hit = (np.asarray(sel, np.intp), np.asarray(dest, np.intp))
            self._row_layout[keys] = hit
        return hit

    def push_row(self, ts: float, values: Dict[str, float]) -> None:
        col = self._head
        self._ts[col] = ts
        # carry the whole previous column forward in one vectorized copy,
        # then overwrite the channels present at this instant — absent
        # channels keep their last value (0.0 on the very first push)
        if self._count > 0:
            self._data[:, col] = self._data[:, (col - 1) % self.capacity]
        else:
            self._data[:, col] = 0.0
        sel, dest = self._layout(tuple(values))
        vals = np.fromiter(values.values(), dtype=np.float32,
                           count=len(values))
        self._data[dest, col] = vals[sel]
        self._head = (self._head + 1) % self.capacity
        if self._count < self.capacity:
            self._count += 1

    def push_block(self, ts: np.ndarray, block: np.ndarray) -> None:
        """Columnar bulk append: ``block`` is (C, n) — n sample instants
        across ALL channels — written in two slice writes (split at the
        wrap point).  Exact-parity counterpart of n ``push_row`` calls with
        full rows; the agent's columnar sampling path feeds this.
        """
        t = np.asarray(ts, dtype=np.float64).ravel()
        b = np.asarray(block, dtype=np.float32)
        if b.shape != (self.n_channels, t.size):
            raise ValueError(f"block {b.shape} vs "
                             f"({self.n_channels}, {t.size})")
        n = t.size
        if n == 0:
            return
        if n >= self.capacity:          # only the newest samples survive
            t, b = t[-self.capacity:], b[:, -self.capacity:]
            n = self.capacity
        first = min(n, self.capacity - self._head)
        self._ts[self._head:self._head + first] = t[:first]
        self._data[:, self._head:self._head + first] = b[:, :first]
        rest = n - first
        if rest:
            self._ts[:rest] = t[first:]
            self._data[:, :rest] = b[:, first:]
        self._head = (self._head + n) % self.capacity
        self._count = min(self.capacity, self._count + n)

    def window(self, n: int, copy: bool = True,
               ) -> Tuple[np.ndarray, np.ndarray]:
        """Newest ``n`` columns, chronological: (ts[n], data[C, n]).

        ``copy=False`` returns zero-copy f32 views of the ring storage when
        the span is contiguous (no wrap) — the columnar monitor path; the
        views are invalidated by the next push, so consume before pushing.
        A wrapped span is always returned as a copy.
        """
        n = min(int(n), self._count)
        if n == 0:
            return (np.empty(0, np.float64),
                    np.empty((self.n_channels, 0), np.float32))
        start = (self._head - n) % self.capacity
        if start + n <= self.capacity:          # contiguous: plain slices
            ts = self._ts[start:start + n]
            d = self._data[:, start:start + n]
            return (ts.copy(), d.copy()) if copy else (ts, d)
        idx = (start + np.arange(n)) % self.capacity
        return self._ts[idx].copy(), self._data[:, idx].copy()

    def channel(self, name: str, n: Optional[int] = None) -> np.ndarray:
        ts, data = self.window(self._count if n is None else n)
        del ts
        return data[self.index[name]]
