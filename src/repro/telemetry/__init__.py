"""Telemetry substrate: ring buffers, collectors, sampling agent, clock sync.

Layer 1 of the paper's four-layer pipeline: multi-source signal collection.
Host signals are sampled at 100 Hz (paper: eBPF probes), device signals at
10 Hz (paper: NVML).  All samples carry a monotonic-clock timestamp and are
resampled onto a common 100 Hz timeline by :mod:`repro.telemetry.sync`.
"""
from repro.telemetry.schema import (
    MetricSpec, SignalGroup, METRIC_REGISTRY, HOST_METRICS, DEVICE_METRICS,
    metric_names, metrics_in_group,
)
from repro.telemetry.ringbuffer import RingBuffer, MultiChannelRing
from repro.telemetry.collectors import (
    Collector, ProcCollector, SimCollector, DeviceMetricSource, available_proc_sources,
)
from repro.telemetry.agent import TelemetryAgent, AgentStats
from repro.telemetry.sync import resample_to_grid, align_windows

__all__ = [
    "MetricSpec", "SignalGroup", "METRIC_REGISTRY", "HOST_METRICS", "DEVICE_METRICS",
    "metric_names", "metrics_in_group",
    "RingBuffer", "MultiChannelRing",
    "Collector", "ProcCollector", "SimCollector", "DeviceMetricSource",
    "available_proc_sources",
    "TelemetryAgent", "AgentStats",
    "resample_to_grid", "align_windows",
]
