"""Signal collectors (paper Layer 1).

``ProcCollector`` reads the same kernel subsystems the paper's eBPF probes
attach to — NET_RX softirqs, scheduler activity, block I/O — via ``/proc``,
which needs no privilege and works on any Linux TPU/GPU host.  The per-read
cost is what the agent's overhead accounting (Fig 2a reproduction) measures.

``SimCollector`` replays a synthesized host-signal matrix from
:mod:`repro.sim.hostmodel`; it is the controlled-injection substrate used to
reproduce the paper's evaluation (their testbed injected fio/tc/cpu-pin
disturbances on real hardware; our container has no GPUs or free NICs, so
injection happens in the signal model — same estimator, controlled ground
truth).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.telemetry.schema import (
    HOST_METRICS, DEVICE_METRICS, MetricSpec, SignalGroup,
)

try:  # optional — used for involuntary ctx switches of our own process
    import psutil
except Exception:  # pragma: no cover
    psutil = None


class Collector:
    """Interface: ``sample() -> {metric_name: raw_value}`` at one instant."""

    #: metric specs this collector produces
    metrics: List[MetricSpec] = []

    def sample(self, now: float) -> Dict[str, float]:
        raise NotImplementedError

    def sample_block(self, grid: np.ndarray,
                     ) -> Optional[Dict[str, np.ndarray]]:
        """Columnar sampling: all instants of ``grid`` at once, one f32
        row per channel — or None when this collector can only be read
        tick by tick (real probes).  Replay-style collectors override;
        the agent's columnar ingest path requires every collector to
        answer.
        """
        del grid
        return None

    def close(self) -> None:  # pragma: no cover
        pass


# ---------------------------------------------------------------------------
# Real host collector (/proc)
# ---------------------------------------------------------------------------

def _read(path: str) -> Optional[str]:
    try:
        with open(path, "r") as f:
            return f.read()
    except OSError:
        return None


def available_proc_sources() -> Dict[str, bool]:
    return {
        "softirqs": _read("/proc/softirqs") is not None,
        "stat": _read("/proc/stat") is not None,
        "diskstats": _read("/proc/diskstats") is not None,
        "net_dev": _read("/proc/net/dev") is not None,
        "loadavg": _read("/proc/loadavg") is not None,
    }


class ProcCollector(Collector):
    """Unprivileged host-side probe set.

    Emits cumulative counters for counter-type metrics — the agent converts
    them to rates (`sync.counters_to_rates`).  Groups can be disabled for the
    paper's probe-ablation experiment.
    """

    def __init__(self, enabled_groups: Optional[Sequence[SignalGroup]] = None):
        all_groups = {SignalGroup.NET, SignalGroup.SCHED, SignalGroup.BLOCK_IO,
                      SignalGroup.PCIE}
        self.enabled = set(enabled_groups) if enabled_groups is not None else all_groups
        self.metrics = [m for m in HOST_METRICS if m.group in self.enabled]
        self._proc = psutil.Process(os.getpid()) if psutil is not None else None

    # -- probe readers ------------------------------------------------------
    def _softirqs(self) -> Dict[str, float]:
        txt = _read("/proc/softirqs")
        out: Dict[str, float] = {}
        if txt is None:
            return out
        for line in txt.splitlines():
            parts = line.split()
            if not parts:
                continue
            if parts[0] == "NET_RX:":
                out["net_rx_softirq"] = float(sum(int(x) for x in parts[1:]))
            elif parts[0] == "NET_TX:":
                out["net_tx_softirq"] = float(sum(int(x) for x in parts[1:]))
        return out

    def _net_dev(self) -> Dict[str, float]:
        txt = _read("/proc/net/dev")
        out: Dict[str, float] = {}
        if txt is None:
            return out
        rx = tx = drops = 0
        for line in txt.splitlines()[2:]:
            if ":" not in line:
                continue
            iface, rest = line.split(":", 1)
            if iface.strip() == "lo":
                continue
            f = rest.split()
            if len(f) >= 12:
                rx += int(f[0]); drops += int(f[3]); tx += int(f[8])
        out["nic_rx_bytes"] = float(rx)
        out["nic_tx_bytes"] = float(tx)
        out["nic_rx_drops"] = float(drops)
        return out

    def _sched(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        txt = _read("/proc/stat")
        if txt is not None:
            for line in txt.splitlines():
                if line.startswith("ctxt "):
                    out["sched_switch_rate"] = float(line.split()[1])
                elif line.startswith("procs_running"):
                    out["runqueue_len"] = float(line.split()[1])
                elif line.startswith("cpu "):
                    f = [float(x) for x in line.split()[1:]]
                    # user+nice+system of everyone; agent subtracts own share
                    busy = f[0] + f[1] + f[2]
                    total = sum(f[:8]) if len(f) >= 8 else sum(f)
                    out["_cpu_busy_jiffies"] = busy
                    out["_cpu_total_jiffies"] = total
                    if len(f) >= 5:
                        out["_iowait_jiffies"] = f[4]
        if self._proc is not None:
            try:
                ctx = self._proc.num_ctx_switches()
                out["involuntary_ctx"] = float(ctx.involuntary)
            except Exception:
                pass
        return out

    def _blkio(self) -> Dict[str, float]:
        txt = _read("/proc/diskstats")
        out: Dict[str, float] = {}
        if txt is None:
            return out
        rd = wr = infl = 0
        for line in txt.splitlines():
            f = line.split()
            if len(f) < 14:
                continue
            name = f[2]
            # whole devices only (skip partitions / loop / ram)
            if name.startswith(("loop", "ram")) or name[-1].isdigit() and not name.startswith("nvme"):
                continue
            rd += int(f[5]) * 512     # sectors read -> bytes
            wr += int(f[9]) * 512
            infl += int(f[11])
        out["blkio_read_bytes"] = float(rd)
        out["blkio_write_bytes"] = float(wr)
        out["blkio_inflight"] = float(infl)
        return out

    # -- Collector API -------------------------------------------------------
    def sample(self, now: float) -> Dict[str, float]:
        del now
        out: Dict[str, float] = {}
        if SignalGroup.NET in self.enabled:
            out.update(self._softirqs())
            out.update(self._net_dev())
        if SignalGroup.SCHED in self.enabled:
            out.update(self._sched())
        if SignalGroup.BLOCK_IO in self.enabled:
            out.update(self._blkio())
        # PCIe/DMA counters have no /proc source on a CPU host; the training
        # runtime feeds pcie_* through DeviceMetricSource instead.
        return out


# ---------------------------------------------------------------------------
# Simulated collector (controlled-injection substrate)
# ---------------------------------------------------------------------------

class SimCollector(Collector):
    """Replays a precomputed (C, T) signal matrix indexed by sample clock.

    Built by :class:`repro.sim.scenario.Trial`; ``sample`` returns the column
    at the requested time.  Values are already rates/gauges (not cumulative),
    so specs are re-declared non-counter.
    """

    def __init__(self, channel_names: Sequence[str], ts: np.ndarray,
                 data: np.ndarray):
        if data.shape[0] != len(channel_names):
            raise ValueError("data rows != channels")
        if data.shape[1] != ts.shape[0]:
            raise ValueError("data cols != timestamps")
        self.channel_names = list(channel_names)
        self._ts = np.asarray(ts, dtype=np.float64)
        self._data = np.asarray(data, dtype=np.float32)
        from repro.telemetry.schema import METRIC_REGISTRY
        import dataclasses as _dc
        self.metrics = []
        for c in self.channel_names:
            spec = METRIC_REGISTRY.get(c)
            if spec is not None:
                self.metrics.append(_dc.replace(spec, monotonic_counter=False))

    def sample(self, now: float) -> Dict[str, float]:
        i = int(np.searchsorted(self._ts, now, side="right")) - 1
        i = max(0, min(i, self._ts.size - 1))
        return {c: float(self._data[j, i]) for j, c in enumerate(self.channel_names)}

    def sample_block(self, grid: np.ndarray) -> Dict[str, np.ndarray]:
        """All grid instants in one gather — same right-side ZOH lookup as
        ``sample``, f32 end to end (no per-tick dict/float round trip)."""
        idx = np.searchsorted(self._ts, np.asarray(grid, np.float64),
                              side="right") - 1
        np.clip(idx, 0, self._ts.size - 1, out=idx)
        block = self._data[:, idx]                       # (C, n) f32
        return {c: block[j] for j, c in enumerate(self.channel_names)}


class DeviceMetricSource(Collector):
    """Device/runtime channel: the training or serving loop pushes values.

    Mirrors the paper's NVML (10 Hz) + NCCL phase marks.  `push` is called
    from the step loop (collective latency, step latency, device counters);
    `sample` drains the latest values at agent cadence.
    """

    def __init__(self):
        self.metrics = list(DEVICE_METRICS)
        self._latest: Dict[str, float] = {}

    def push(self, **values: float) -> None:
        for k, v in values.items():
            self._latest[k] = float(v)

    def sample(self, now: float) -> Dict[str, float]:
        del now
        return dict(self._latest)
