"""Metric registry: names, signal groups, cause mapping.

The paper's taxonomy (§2.2) classifies interference into Host System
Interference (CPU contention, I/O pressure), Network Interference (NIC
contention) and Microarchitectural Interference (GPU throttling).  Every
telemetry channel belongs to a :class:`SignalGroup`, and each group maps to
the cause class it is evidence for.  The correlation engine is agnostic to
the concrete channel list — it consumes whatever the registry declares — so
deployments can register extra probes without touching engine code.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional


class SignalGroup(str, enum.Enum):
    """Probe groups.  Mirrors the paper's probe families (§2.1)."""

    NET = "net"            # NET_RX softirqs, NIC queue lengths, rx/tx bytes
    SCHED = "sched"        # sched_switch rate, runqueue length, involuntary ctx
    BLOCK_IO = "block_io"  # block I/O throughput, in-flight ios, io wait
    PCIE = "pcie"          # host-device DMA counters (PCIe on GPU, infeed on TPU)
    DEVICE = "device"      # NVML-like: util, mem, power, temperature, clock
    COLLECTIVE = "collective"  # NCCL/JAX collective phase latency marks
    STEP = "step"          # training/serving step latency (the diagnosed series)


class CauseClass(str, enum.Enum):
    """Root-cause classes (paper Table 3/4 rows)."""

    IO = "io_pressure"
    CPU = "cpu_contention"
    NIC = "nic_contention"
    GPU = "gpu_throttling"
    UNKNOWN = "unknown"
    #: The telemetry itself is broken (frozen/NaN channels, crashed
    #: collectors) — never a GPU/host interference verdict.  Emitted by
    #: FleetMonitor's quarantine path, not by the evidence ranker.
    TELEMETRY = "telemetry_fault"


#: Which signal groups are *evidence for* which cause class.  The paper's
#: rules: NET -> NIC contention, SCHED -> CPU contention, BLOCK_IO/PCIE -> I/O
#: pressure, DEVICE (power/temp/clock) -> GPU throttling.  STEP/COLLECTIVE are
#: the latency series being explained, not evidence.
GROUP_TO_CAUSE: Dict[SignalGroup, CauseClass] = {
    SignalGroup.NET: CauseClass.NIC,
    SignalGroup.SCHED: CauseClass.CPU,
    SignalGroup.BLOCK_IO: CauseClass.IO,
    SignalGroup.PCIE: CauseClass.IO,
    SignalGroup.DEVICE: CauseClass.GPU,
}

#: Device channels that are *symptoms*, not causes: utilisation and memory
#: track load under every interference type, so treating them as
#: GPU-throttling evidence would let the GPU class absorb all diagnoses.
#: The paper's taxonomy uses throttle indicators (power/temp/clock) only.
NON_EVIDENCE: frozenset = frozenset({"dev_util", "dev_mem_used"})

#: Anomaly orientation per channel: +1 a rise is anomalous (default),
#: -1 a drop is anomalous (clock/power collapse under a power cap),
#:  0 two-sided (|deviation|; DMA rates can contend either way).
ORIENTATION: Dict[str, float] = {
    "dev_clock": -1.0,
    "dev_power": -1.0,
    "dev_temp": 1.0,
    "pcie_h2d_bytes": 0.0,
    "pcie_d2h_bytes": 0.0,
}


#: Cause-specific *symptom* channels with their corroboration z floors.
#: A cause is corroborated when one of its symptom channels shows at least
#: this two-sided raw-z deviation from baseline over the evidence window.
#: Floors are per channel because their noise regimes differ wildly:
#: ``nic_rx_drops`` is a bursty counter whose baseline std is inflated by
#: sparse drops (a low floor suffices), ``involuntary_ctx`` sits near zero
#: in quiet streams so even mild CPU confusers push large z (a high floor
#: rejects them), DMA throughput and device temperature move smoothly.
#: Consumed by ``core.reconcile`` (multi-hypothesis verdict reconciliation).
SYMPTOM_FLOORS: Dict[str, float] = {
    "nic_rx_drops": 1.5,       # NIC contention: queue-overflow drops
    "involuntary_ctx": 6.0,    # CPU contention: forced preemptions
    "pcie_h2d_bytes": 1.0,     # I/O pressure: DMA contention (either way)
    "pcie_d2h_bytes": 1.0,
    "dev_temp": 2.0,           # GPU throttling: thermal excursion
}


@dataclasses.dataclass(frozen=True)
class MetricSpec:
    """One telemetry channel."""

    name: str
    group: SignalGroup
    unit: str
    rate_hz: float            # nominal sampling rate (100 host / 10 device)
    monotonic_counter: bool   # True if raw reads are cumulative counters
    description: str = ""

    @property
    def cause(self) -> Optional[CauseClass]:
        if self.name in NON_EVIDENCE:
            return None
        return GROUP_TO_CAUSE.get(self.group)


def _m(name, group, unit, rate, counter, desc) -> MetricSpec:
    return MetricSpec(name, group, unit, rate, counter, desc)


# ---------------------------------------------------------------------------
# Host-side channels (paper: eBPF @100 Hz).  Our ProcCollector reads the same
# kernel subsystems via /proc; SimCollector synthesizes them.
# ---------------------------------------------------------------------------
HOST_METRICS: List[MetricSpec] = [
    # NET group  (paper: NET_RX softirq counts, NIC queue lengths)
    _m("net_rx_softirq", SignalGroup.NET, "events/s", 100.0, True,
       "NET_RX softirq fire rate (per-CPU sum)"),
    _m("net_tx_softirq", SignalGroup.NET, "events/s", 100.0, True,
       "NET_TX softirq fire rate"),
    _m("nic_rx_bytes", SignalGroup.NET, "B/s", 100.0, True, "NIC rx throughput"),
    _m("nic_tx_bytes", SignalGroup.NET, "B/s", 100.0, True, "NIC tx throughput"),
    _m("nic_rx_drops", SignalGroup.NET, "pkts/s", 100.0, True, "rx drops (queue overflow)"),
    # SCHED group  (paper: sched_switch tracing)
    _m("sched_switch_rate", SignalGroup.SCHED, "switch/s", 100.0, True,
       "context-switch rate"),
    _m("runqueue_len", SignalGroup.SCHED, "tasks", 100.0, False,
       "runnable tasks (loadavg-granular proxy)"),
    _m("involuntary_ctx", SignalGroup.SCHED, "switch/s", 100.0, True,
       "involuntary preemptions of the workload process"),
    _m("cpu_util_other", SignalGroup.SCHED, "frac", 100.0, False,
       "CPU utilisation by co-located processes"),
    # BLOCK_IO group
    _m("blkio_read_bytes", SignalGroup.BLOCK_IO, "B/s", 100.0, True, "disk read throughput"),
    _m("blkio_write_bytes", SignalGroup.BLOCK_IO, "B/s", 100.0, True, "disk write throughput"),
    _m("blkio_inflight", SignalGroup.BLOCK_IO, "ios", 100.0, False, "in-flight block requests"),
    _m("iowait_frac", SignalGroup.BLOCK_IO, "frac", 100.0, False, "CPU iowait fraction"),
    # PCIE / host-device DMA group
    _m("pcie_h2d_bytes", SignalGroup.PCIE, "B/s", 100.0, True,
       "host-to-device DMA throughput (TPU infeed)"),
    _m("pcie_d2h_bytes", SignalGroup.PCIE, "B/s", 100.0, True,
       "device-to-host DMA throughput (outfeed)"),
]

# ---------------------------------------------------------------------------
# Device channels (paper: NVML @10 Hz + NCCL phase marks)
# ---------------------------------------------------------------------------
DEVICE_METRICS: List[MetricSpec] = [
    _m("dev_util", SignalGroup.DEVICE, "frac", 10.0, False, "device busy fraction"),
    _m("dev_mem_used", SignalGroup.DEVICE, "B", 10.0, False, "device memory used"),
    _m("dev_power", SignalGroup.DEVICE, "W", 10.0, False, "device power draw"),
    _m("dev_temp", SignalGroup.DEVICE, "C", 10.0, False, "device temperature"),
    _m("dev_clock", SignalGroup.DEVICE, "MHz", 10.0, False,
       "SM/core clock (drops under power-cap throttling)"),
    _m("coll_allreduce_ms", SignalGroup.COLLECTIVE, "ms", 100.0, False,
       "per-iteration all-reduce phase latency (NCCL/JAX mark)"),
    _m("step_latency_ms", SignalGroup.STEP, "ms", 100.0, False,
       "end-to-end step latency — the diagnosed series L(t)"),
]

METRIC_REGISTRY: Dict[str, MetricSpec] = {
    m.name: m for m in HOST_METRICS + DEVICE_METRICS
}

#: The series the engine diagnoses (paper: GPU latency L(t)).
LATENCY_METRIC = "coll_allreduce_ms"


def metric_names(include_device: bool = True) -> List[str]:
    out = [m.name for m in HOST_METRICS]
    if include_device:
        out += [m.name for m in DEVICE_METRICS]
    return out


def metrics_in_group(group: SignalGroup) -> List[MetricSpec]:
    return [m for m in METRIC_REGISTRY.values() if m.group == group]


def evidence_metrics() -> List[MetricSpec]:
    """Channels usable as RCA evidence (everything with a cause mapping)."""
    return [m for m in METRIC_REGISTRY.values() if m.cause is not None]
