"""Temporal synchronization (paper Layer 2, first half).

Signals arrive at heterogeneous rates (100 Hz host, 10 Hz device, per-step
latency marks).  The correlation math needs them on one uniform grid with a
shared monotonic clock.  ``resample_to_grid`` does zero-order-hold
resampling (the right choice for counters-turned-rates and gauges alike:
linear interpolation would smear spike edges, weakening lagged correlation).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np


def resample_to_grid(ts: np.ndarray, values: np.ndarray,
                     grid: np.ndarray) -> np.ndarray:
    """Zero-order-hold resample of (ts, values) onto ``grid``.

    Grid points before the first sample get the first value (cold-start);
    NaNs are forward-filled first so a late-joining channel doesn't poison
    the correlation window.
    """
    ts = np.asarray(ts, dtype=np.float64)
    values = np.asarray(values, dtype=np.float64)
    if ts.size == 0:
        return np.zeros_like(grid, dtype=np.float64)
    # forward-fill NaNs
    if np.isnan(values).any():
        filled = values.copy()
        last = 0.0
        for i in range(filled.size):
            if np.isnan(filled[i]):
                filled[i] = last
            else:
                last = filled[i]
        values = filled
    idx = np.searchsorted(ts, grid, side="right") - 1
    idx = np.clip(idx, 0, ts.size - 1)
    return values[idx]


def make_grid(t_start: float, t_end: float, rate_hz: float) -> np.ndarray:
    n = max(1, int(round((t_end - t_start) * rate_hz)))
    return t_start + np.arange(n, dtype=np.float64) / rate_hz


def align_windows(series: Dict[str, Tuple[np.ndarray, np.ndarray]],
                  rate_hz: float = 100.0,
                  duration_s: float | None = None,
                  ) -> Tuple[np.ndarray, Dict[str, np.ndarray]]:
    """Align a dict of ``name -> (ts, values)`` onto one shared grid.

    The grid covers the *intersection* of all channels' time spans (clipped
    to the trailing ``duration_s`` if given) so no channel is extrapolated
    across its whole window.  Returns ``(grid, {name: resampled})``.
    """
    starts: List[float] = []
    ends: List[float] = []
    for name, (ts, _) in series.items():
        if ts.size == 0:
            continue
        starts.append(float(ts[0]))
        ends.append(float(ts[-1]))
    if not starts:
        raise ValueError("all channels empty")
    t0, t1 = max(starts), min(ends)
    if t1 <= t0:
        # Degenerate overlap (e.g. one channel only just started): fall back
        # to the widest span; ZOH handles the edges.
        t0, t1 = min(starts), max(ends)
    if duration_s is not None:
        t0 = max(t0, t1 - duration_s)
    grid = make_grid(t0, t1, rate_hz)
    out = {name: resample_to_grid(ts, v, grid) for name, (ts, v) in series.items()}
    return grid, out


def counters_to_rates(ts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Convert a cumulative counter series to per-second rates.

    Kernel counters (softirq fires, nic bytes, blkio sectors) are cumulative;
    the correlation engine wants instantaneous rates.  Handles counter resets
    (negative deltas -> 0).
    """
    ts = np.asarray(ts, dtype=np.float64)
    counts = np.asarray(counts, dtype=np.float64)
    if counts.size < 2:
        return np.zeros_like(counts)
    dt = np.diff(ts)
    dt[dt <= 0] = np.finfo(np.float64).eps
    dv = np.diff(counts)
    dv[dv < 0] = 0.0
    rates = dv / dt
    return np.concatenate([[rates[0]], rates])
