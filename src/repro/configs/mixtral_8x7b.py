"""Mixtral 8x7B: 32L MoE 8e top-2, GQA, sliding-window attn. [arXiv:2401.04088]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=32000, head_dim=128,
    act="swiglu", n_experts=8, top_k=2, moe_every=1, window=4096,
    sub_quadratic=True,  # SWA bounds the KV working set
    train_microbatch=2,
    source="arXiv:2401.04088")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                       d_ff=256, vocab=512, head_dim=32, n_experts=4,
                       window=64)
