"""StarCoder2-7B: dense GQA kv4, RoPE. [arXiv:2402.19173; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b", family="dense", n_layers=32, d_model=4608,
    n_heads=36, n_kv=4, d_ff=18432, vocab=49152, head_dim=128,
    act="gelu", source="arXiv:2402.19173")

SMOKE = CONFIG.replace(n_layers=2, d_model=144, n_heads=4, n_kv=2,
                       d_ff=288, vocab=512, head_dim=36)
