"""Mamba2-370m: attention-free SSD. [arXiv:2405.21060]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm", n_layers=48, d_model=1024,
    n_heads=0, n_kv=0, d_ff=0, vocab=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1, ssm_conv=4,
    sub_quadratic=True, source="arXiv:2405.21060")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, ssm_state=16,
                       ssm_headdim=16, vocab=512)
