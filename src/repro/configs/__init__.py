"""Architecture configs (exact assigned sizes) + input-shape sets.

``get_config(name)`` -> full ArchConfig; ``get_config(name, smoke=True)``
-> the reduced same-family variant used by CPU smoke tests.  ``SHAPES``
defines the four assigned input-shape cells; ``cells_for(cfg)`` yields the
eligible (arch x shape) combinations (long_500k only for sub-quadratic
archs — see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Iterator, List, Optional, Tuple

from repro.models.common import ArchConfig

_MODULES = {
    "grok-1-314b": "grok_1_314b",
    "mixtral-8x7b": "mixtral_8x7b",
    "starcoder2-7b": "starcoder2_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "nemotron-4-340b": "nemotron_4_340b",
    "yi-9b": "yi_9b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-base": "whisper_base",
    "mamba2-370m": "mamba2_370m",
    "paligemma-3b": "paligemma_3b",
}

ALL_CONFIGS: Dict[str, str] = dict(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.SMOKE if smoke else mod.CONFIG


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def eligible(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """(runs?, reason-if-skipped)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention arch: 500k decode needs "
                       "sub-quadratic attention (skip per assignment)")
    return True, ""


def cells_for(name: str) -> Iterator[Tuple[ArchConfig, ShapeCell, bool, str]]:
    cfg = get_config(name)
    for shape in SHAPES.values():
        ok, why = eligible(cfg, shape)
        yield cfg, shape, ok, why


def all_cells() -> Iterator[Tuple[str, str, bool, str]]:
    for name in ALL_CONFIGS:
        for cfg, shape, ok, why in cells_for(name):
            yield name, shape.name, ok, why
