"""Nemotron-4 340B: 96L dense GQA kv8, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b", family="dense", n_layers=96, d_model=18432,
    n_heads=96, n_kv=8, d_ff=73728, vocab=256000, head_dim=192,
    act="squared_relu", source="arXiv:2402.16819")

SMOKE = CONFIG.replace(n_layers=2, d_model=192, n_heads=4, n_kv=2,
                       d_ff=384, vocab=512, head_dim=48)
