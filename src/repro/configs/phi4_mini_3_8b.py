"""phi-4-mini 3.8B: dense GQA kv8, RoPE, SwiGLU. [arXiv:2412.08905; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv=8, d_ff=8192, vocab=200064, head_dim=128,
    act="swiglu", source="arXiv:2412.08905")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                       d_ff=256, vocab=512, head_dim=32)
