"""Whisper-base: enc-dec, conv frontend stubbed (input_specs supplies frame
embeddings). [arXiv:2212.04356]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="encdec", n_layers=6, d_model=512,
    n_heads=8, n_kv=8, d_ff=2048, vocab=51865, head_dim=64,
    act="gelu", n_enc_layers=6, source="arXiv:2212.04356")

SMOKE = CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=128, n_heads=4,
                       n_kv=4, d_ff=256, vocab=512, head_dim=32)
