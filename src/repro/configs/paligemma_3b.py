"""PaliGemma-3B: SigLIP frontend (stub patch embeddings) + gemma decoder,
MQA kv=1, prefix-LM attention over image tokens. [arXiv:2407.07726; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv=1, d_ff=16384, vocab=257216, head_dim=256,
    act="geglu", n_img_tokens=256, source="arXiv:2407.07726")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv=1,
                       d_ff=256, vocab=512, head_dim=32, n_img_tokens=16)
