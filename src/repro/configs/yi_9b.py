"""Yi-9B: llama-arch dense GQA kv4. [arXiv:2403.04652; hf]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b", family="dense", n_layers=48, d_model=4096,
    n_heads=32, n_kv=4, d_ff=11008, vocab=64000, head_dim=128,
    act="swiglu", source="arXiv:2403.04652")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                       d_ff=256, vocab=512, head_dim=32)
