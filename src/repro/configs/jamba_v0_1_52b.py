"""Jamba v0.1 52B: Mamba+attention 1:7 hybrid, MoE 16e top-2 every 2nd layer.
[arXiv:2403.19887; hf].  SSM sublayer follows our Mamba-2/SSD formulation
(DESIGN.md notes the Mamba-1 -> SSD substitution; sizes preserved)."""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid", n_layers=32, d_model=4096,
    n_heads=32, n_kv=8, d_ff=14336, vocab=65536, head_dim=128,
    act="swiglu", n_experts=16, top_k=2,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_ngroups=1, ssm_conv=4,
    sub_quadratic=True, source="arXiv:2403.19887")

SMOKE = CONFIG.replace(n_layers=8, d_model=128, n_heads=4, n_kv=2,
                       d_ff=256, vocab=512, head_dim=32, n_experts=4,
                       ssm_state=16, ssm_headdim=16)
