"""grok-1 314B: 64L MoE 8e top-2, GQA 48H/kv8. [hf:xai-org/grok-1; unverified]"""
from repro.models.common import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv=8, d_ff=32768, vocab=131072, head_dim=128,
    act="swiglu", n_experts=8, top_k=2, moe_every=1,
    train_microbatch=4,
    source="hf:xai-org/grok-1")

SMOKE = CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv=2,
                       d_ff=256, vocab=512, head_dim=32, n_experts=4)
