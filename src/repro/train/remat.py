"""Remat (activation checkpointing) policy context.

Model assemblies call ``maybe_remat(body)`` around their scan bodies; the
active policy decides what gets saved:

  none    - save everything (fastest, most memory)
  full    - save only layer boundaries (recompute whole layer on bwd)
  dots    - save matmul outputs, recompute elementwise (middle ground)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable

import jax


class _State(threading.local):
    def __init__(self):
        self.policy = "none"


_STATE = _State()


@contextlib.contextmanager
def remat_policy(policy: str):
    if policy not in ("none", "full", "dots"):
        raise ValueError(f"unknown remat policy {policy!r}")
    prev = _STATE.policy
    _STATE.policy = policy
    try:
        yield
    finally:
        _STATE.policy = prev


def current_remat() -> str:
    return _STATE.policy


def maybe_remat(fn: Callable) -> Callable:
    p = _STATE.policy
    if p == "none":
        return fn
    if p == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
