"""Training stack: optimizers, train-step builder, remat policy, loop."""
from repro.train.optimizer import (
    OptConfig, adamw_init, adamw_update, adafactor_init, adafactor_update,
    make_optimizer,
)
from repro.train.step import TrainState, build_train_step, train_state_logical
from repro.train.remat import remat_policy, current_remat

__all__ = [
    "OptConfig", "adamw_init", "adamw_update", "adafactor_init",
    "adafactor_update", "make_optimizer",
    "TrainState", "build_train_step", "train_state_logical",
    "remat_policy", "current_remat",
]
