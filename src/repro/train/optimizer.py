"""Optimizers: AdamW and Adafactor, pure-pytree, sharding-inheriting.

ZeRO posture: every optimizer state tensor inherits its parameter's
PartitionSpec — and since params are FSDP-sharded over ("data", "model"),
the m/v (or factored) moments are fully sharded with zero extra plumbing.
No fp32 master copy by default (bf16 params + fp32 moments = 10 bytes per
param); flip ``master_fp32`` for the classic 14-byte layout.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    kind: str = "adamw"          # adamw | adafactor
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    warmup_steps: int = 100
    master_fp32: bool = False
    # adafactor
    decay_rate: float = 0.8
    min_dim_factored: int = 128


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum((step.astype(jnp.float32) + 1.0)
                       / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jax.Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw_init(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros32, params),
            "v": jax.tree.map(zeros32, params)}


def adamw_update(cfg: OptConfig, params, grads, state, step):
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    p_leaves, tdef = jax.tree.flatten(params)
    g_leaves = tdef.flatten_up_to(grads)
    m_leaves = tdef.flatten_up_to(state["m"])
    v_leaves = tdef.flatten_up_to(state["v"])
    new_p, new_m, new_v = [], [], []
    for p, g, m, v in zip(p_leaves, g_leaves, m_leaves, v_leaves):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        delta = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        new_p.append((p.astype(jnp.float32) - lr * delta).astype(p.dtype))
        new_m.append(m_new)
        new_v.append(v_new)
    return (jax.tree.unflatten(tdef, new_p),
            {"m": jax.tree.unflatten(tdef, new_m),
             "v": jax.tree.unflatten(tdef, new_v)})


# ---------------------------------------------------------------------------
# Adafactor (factored second moment — the giant-model option)
# ---------------------------------------------------------------------------

def _factored(shape, min_dim) -> bool:
    return len(shape) >= 2 and shape[-1] >= min_dim and shape[-2] >= min_dim


def adafactor_init(params, cfg: OptConfig) -> Dict[str, Any]:
    def init_one(p):
        if _factored(p.shape, cfg.min_dim_factored):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"f": jax.tree.map(init_one, params)}


def adafactor_update(cfg: OptConfig, params, grads, state, step):
    lr = schedule(cfg, step)
    t = step.astype(jnp.float32) + 1.0
    beta2 = 1.0 - t ** (-cfg.decay_rate)
    p_leaves, tdef = jax.tree.flatten(params)
    g_leaves = tdef.flatten_up_to(grads)
    s_leaves = tdef.flatten_up_to(state["f"])
    new_p, new_s = [], []
    for p, g, s in zip(p_leaves, g_leaves, s_leaves):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + 1e-30
        if "vr" in s:
            vr = beta2 * s["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * s["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = (vr[..., None]
                     / jnp.maximum(vr.mean(axis=-1, keepdims=True),
                                   1e-30)[..., None]) * vc[..., None, :]
            upd = g32 / jnp.sqrt(denom + 1e-30)
            s_new = {"vr": vr, "vc": vc}
        else:
            v = beta2 * s["v"] + (1 - beta2) * g2
            upd = g32 / jnp.sqrt(v + 1e-30)
            s_new = {"v": v}
        rms = jnp.sqrt(jnp.mean(upd * upd) + 1e-30)     # Adafactor RMS clip
        upd = upd / jnp.maximum(1.0, rms)
        new_p.append((p.astype(jnp.float32) - lr * upd
                      - lr * cfg.weight_decay * p.astype(jnp.float32)
                      ).astype(p.dtype))
        new_s.append(s_new)
    return (jax.tree.unflatten(tdef, new_p),
            {"f": jax.tree.unflatten(tdef, new_s)})


def make_optimizer(cfg: OptConfig):
    """(init_fn, update_fn) closures over the config."""
    if cfg.kind == "adamw":
        return (adamw_init,
                lambda p, g, s, t: adamw_update(cfg, p, g, s, t))
    if cfg.kind == "adafactor":
        return (lambda p: adafactor_init(p, cfg),
                lambda p, g, s, t: adafactor_update(cfg, p, g, s, t))
    raise ValueError(f"unknown optimizer {cfg.kind!r}")


def opt_state_logical(param_logical, opt_cfg: OptConfig,
                      abstract_params=None):
    """Logical-axis tree for the optimizer state (inherits param axes).

    For Adafactor the factored moments drop one axis; we reproduce the
    same structural transform on the logical tree (needs abstract params
    to know which leaves factored).
    """
    if opt_cfg.kind == "adamw":
        return {"m": param_logical, "v": param_logical}
    assert abstract_params is not None

    def one(logical, p):
        if _factored(p.shape, opt_cfg.min_dim_factored):
            return {"vr": tuple(logical[:-1]),
                    "vc": tuple(logical[:-2]) + tuple(logical[-1:])}
        return {"v": tuple(logical)}

    p_leaves, tdef = jax.tree.flatten(abstract_params)
    l_leaves = tdef.flatten_up_to(param_logical)
    out = [one(l, p) for l, p in zip(l_leaves, p_leaves)]
    return {"f": jax.tree.unflatten(tdef, out)}
