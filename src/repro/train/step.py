"""Train-step builder: grad + clip + optimizer, microbatch accumulation,
telemetry phase marks.

The returned ``step(state, batch)`` is a single jit-able function whose
in/out shardings are derived from the model's logical axes — the dry-run
lowers exactly this function.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.registry import Model
from repro.train.optimizer import (
    OptConfig, clip_by_global_norm, make_optimizer, opt_state_logical,
)


@dataclasses.dataclass
class TrainState:
    step: jax.Array
    params: Any
    opt: Any

    def tree_flatten(self):
        return (self.step, self.params, self.opt), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(*children)


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.step, s.params, s.opt), None),
    lambda aux, c: TrainState(*c))


def init_train_state(model: Model, rng: jax.Array,
                     opt_cfg: OptConfig) -> TrainState:
    params = model.init(rng)
    opt_init, _ = make_optimizer(opt_cfg)
    return TrainState(step=jnp.zeros((), jnp.int32), params=params,
                      opt=opt_init(params))


def train_state_logical(model: Model, opt_cfg: OptConfig) -> Dict[str, Any]:
    """Logical-axis pytree matching TrainState (for sharding derivation)."""
    pl = model.param_logical
    abstract = model.abstract_params()
    return {
        "step": (),
        "params": pl,
        "opt": opt_state_logical(pl, opt_cfg, abstract),
    }


def build_train_step(model: Model, opt_cfg: OptConfig,
                     microbatch: int = 0) -> Callable:
    """Returns step(state, batch) -> (state, metrics).

    ``microbatch`` > 0 splits the batch into that many accumulation chunks
    (sequential grad accumulation inside one jit — the standard trick when
    the per-step batch exceeds activation memory).
    """
    _, opt_update = make_optimizer(opt_cfg)

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatch and microbatch > 1:
            def split(x):
                b = x.shape[0]
                assert b % microbatch == 0, (b, microbatch)
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb_i)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (g, loss_sum), _ = jax.lax.scan(acc_body, (g0, 0.0), mb)
            g = jax.tree.map(lambda x: x / microbatch, g)
            return loss_sum / microbatch, {}, g
        (loss, metrics), g = grad_fn(params, batch)
        return loss, metrics, g

    def step(state: TrainState, batch) -> Tuple[TrainState, Dict[str, Any]]:
        loss, metrics, grads = compute_grads(state.params, batch)
        grads, gnorm = clip_by_global_norm(grads, opt_cfg.grad_clip)
        new_params, new_opt = opt_update(state.params, grads, state.opt,
                                         state.step)
        new_state = TrainState(step=state.step + 1, params=new_params,
                               opt=new_opt)
        out = {"loss": loss, "grad_norm": gnorm}
        out.update({k: v for k, v in metrics.items()
                    if isinstance(v, jax.Array)})
        return new_state, out

    return step
