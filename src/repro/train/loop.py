"""Training loop: data pipeline + jit'd step + telemetry + checkpoint/restart.

This is the deployment wiring of the paper's system: the loop runs the
TelemetryAgent beside the step function, pushes step/collective latency
marks into the device channel, periodically asks the FleetMonitor for a
diagnosis, logs mitigation hints, and survives injected failures through
atomic checkpoints + resume_or_init (restart = run the same command).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer, FailureInjector, resume_or_init
from repro.core.engine import EngineConfig
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.models.registry import Model
from repro.monitor.aggregator import FleetAggregator
from repro.monitor.fleet import FleetMonitor, Mitigation
from repro.monitor.hooks import StepTelemetry
from repro.train.optimizer import OptConfig
from repro.train.remat import remat_policy
from repro.train.step import build_train_step, init_train_state

log = logging.getLogger("repro.train")


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    checkpoint_every: int = 20
    diagnose_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    remat: str = "none"
    telemetry: bool = True
    telemetry_rate_hz: float = 100.0
    seed: int = 0


@dataclasses.dataclass
class LoopResult:
    final_step: int
    losses: List[float]
    step_ms: List[float]
    diagnoses: List[Any]
    telemetry_overhead_pct: Optional[float]


def run_training(model: Model, pipeline: SyntheticLMPipeline,
                 opt_cfg: OptConfig, loop_cfg: LoopConfig,
                 injector: Optional[FailureInjector] = None,
                 monitor: Optional[FleetMonitor] = None) -> LoopResult:
    ckpt = Checkpointer(loop_cfg.ckpt_dir)
    with remat_policy(loop_cfg.remat):
        step_fn = jax.jit(build_train_step(model, opt_cfg,
                                           microbatch=0),
                          donate_argnums=(0,))

        def init():
            return init_train_state(model, jax.random.key(loop_cfg.seed),
                                    opt_cfg)

        state, start = resume_or_init(ckpt, init)
        if start > 0:
            log.info("resumed from checkpoint at step %d", start)

        tele = StepTelemetry(rate_hz=loop_cfg.telemetry_rate_hz) \
            if loop_cfg.telemetry else None
        agg = None
        if tele:
            tele.start()
            if monitor is not None:
                # seqlock staging reader over the live agent ring(s): the
                # diagnosis pass reads while the background sampler writes,
                # with one bounded copy into the aggregator's preallocated
                # slab instead of the seed's defensive full-window copy
                agg = FleetAggregator([tele.agent], window_s=30.0)
        pipeline.start(start_step=start)
        it = iter(pipeline)

        losses: List[float] = []
        step_ms: List[float] = []
        diagnoses: List[Any] = []
        step = start
        try:
            for step in range(start, loop_cfg.steps):
                batch_np = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                if tele:
                    tele.step_begin()
                state, metrics = step_fn(state, batch)
                loss = float(metrics["loss"])
                if tele:
                    ms = tele.step_end()
                    step_ms.append(ms)
                losses.append(loss)
                if injector:
                    injector.maybe_fail(step, "after_step")
                if (step + 1) % loop_cfg.checkpoint_every == 0:
                    if injector:
                        injector.maybe_fail(step, "mid_checkpoint")
                    ckpt.save(step, state)
                # fleet diagnosis pass over the trailing telemetry window,
                # staged torn-read-safe by the aggregator (no full copy)
                if agg is not None and (step + 1) % loop_cfg.diagnose_every == 0:
                    fd = agg.diagnose(monitor, min_valid_s=10.0)
                    if fd is not None:
                        diagnoses.append(fd)
                        if fd.mitigation != Mitigation.NONE:
                            log.warning(
                                "step %d: straggler host %d (S=%.1f) -> %s",
                                step, fd.straggler_host, fd.straggler_score,
                                fd.mitigation.value)
        finally:
            pipeline.stop()
            overhead = None
            if tele:
                stats = tele.stop()
                overhead = 100.0 * stats.overhead_frac
        return LoopResult(final_step=step, losses=losses, step_ms=step_ms,
                          diagnoses=diagnoses,
                          telemetry_overhead_pct=overhead)
