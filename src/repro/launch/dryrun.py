import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  REPRO_DRYRUN_DEVICES overrides for CI-scale runs.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this jits the real step function (train_step / prefill /
decode_step) with in/out shardings derived from the model's logical axes,
compiles it against the production mesh, and records:

  * memory_analysis()  — per-device bytes (proves it fits),
  * cost_analysis()    — HLO FLOPs / bytes for the roofline,
  * collective bytes   — parsed from the compiled HLO (all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute operand
    sizes), split by mesh axis where derivable.

Results land in benchmarks/results/dryrun/<arch>__<shape>__<mesh>.json so
the roofline table (benchmarks/roofline.py) and EXPERIMENTS.md are built
from recorded artifacts, not re-compiles.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

RESULTS_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "results" / "dryrun"


def _collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of collective ops in an HLO module text.

    Counts the *output* shape bytes of each collective instruction (operand
    and output sizes match for all-reduce/permute; for all-gather the output
    is the gathered size — the wire cost; for reduce-scatter the input is
    the wire cost, approximated by output * shards from replica groups).
    """
    dtype_bytes = {
        "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
        "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "f8e4m3": 1,
        "f8e5m2": 1, "s16": 2, "u16": 2,
    }
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    # instruction lines look like:  %x = bf16[16,512]{1,0} all-gather(...)
    pat = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s"
        r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
        r"(?:-start|-done)?\(")
    for m in pat.finditer(hlo_text):
        dt, dims, op = m.group(1), m.group(2), m.group(3)
        if dt not in dtype_bytes:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[op] += n * dtype_bytes[dt]
    return out


def _sharding_trees(model, opt_cfg, mesh, rules):
    from repro.parallel.sharding import param_pspecs
    from repro.train.step import TrainState, train_state_logical
    logical = train_state_logical(model, opt_cfg)
    return TrainState(
        step=jax.sharding.PartitionSpec(),
        params=param_pspecs(logical["params"], rules),
        opt=param_pspecs(logical["opt"], rules))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             save: bool = True, quiet: bool = False,
             overrides: dict | None = None) -> dict:
    """``overrides`` (perf-lab knobs, EXPERIMENTS.md §Perf):
      rules: {logical_axis: mesh_axis|None} patches onto make_rules output
      remat: "none"|"full"|"dots"
      microbatch: int
      causal_triangle: bool  (static triangular attention schedule)
      tag: str suffix for the result file
    """
    from repro.configs import SHAPES, eligible, get_config
    from repro.launch.inputs import (
        decode_inputs, prefill_inputs, train_batch_logical,
        train_batch_specs,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.models.registry import build_model
    from repro.parallel.ctx import mesh_context
    from repro.parallel.sharding import (
        logical_to_pspec, make_rules, param_pspecs,
    )
    from repro.train.optimizer import OptConfig
    from repro.train.remat import remat_policy
    from repro.train.step import build_train_step, init_train_state

    t0 = time.time()
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = eligible(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "skip", "reason": why}
    if not ok:
        if not quiet:
            print(f"[dryrun] SKIP {arch} x {shape_name}: {why}")
        if save:
            _save(rec)
        return rec

    overrides = overrides or {}
    if overrides.get("tag"):
        rec["tag"] = overrides["tag"]
    if overrides.get("causal_triangle"):
        from repro.models import layers as _L
        _L.CAUSAL_TRIANGLE = True
    remat_mode = overrides.get("remat", "full")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mesh)
    if overrides.get("rules"):
        rules = dict(rules, **overrides["rules"])
    if overrides.get("microbatch") is not None:
        cfg = cfg.replace(train_microbatch=overrides["microbatch"])
    model = build_model(cfg)
    n_chips = mesh.devices.size
    B, S = shape.global_batch, shape.seq_len
    # small-batch decode cells (long_500k has B=1) cannot shard batch over
    # the data axis — serve them batch-replicated, KV sharded over model
    dp = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            dp *= mesh.shape[ax]
    if B % dp != 0:
        rules = dict(rules, act_batch=None)
    opt_cfg = OptConfig(kind="adafactor" if cfg.d_model >= 8192 else "adamw")

    P = jax.sharding.PartitionSpec
    NS = lambda spec: jax.sharding.NamedSharding(mesh, spec)

    with mesh_context(mesh, rules), remat_policy(remat_mode):
        if shape.kind == "train":
            step = build_train_step(model, opt_cfg,
                                    microbatch=cfg.train_microbatch)
            state_abs = jax.eval_shape(
                lambda: init_train_state(model, jax.random.key(0), opt_cfg))
            state_ps = _sharding_trees(model, opt_cfg, mesh, rules)
            batch_abs = train_batch_specs(cfg, B, S)
            batch_ps = {k: logical_to_pspec(v, rules)
                        for k, v in train_batch_logical(cfg).items()}
            state_sh = jax.tree.map(NS, state_ps,
                                    is_leaf=lambda x: isinstance(x, P))
            batch_sh = jax.tree.map(NS, batch_ps,
                                    is_leaf=lambda x: isinstance(x, P))
            lowered = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,)).lower(state_abs, batch_abs)
        elif shape.kind == "prefill":
            params_abs = model.abstract_params()
            params_ps = param_pspecs(model.param_logical, rules)
            params_sh = jax.tree.map(NS, params_ps,
                                     is_leaf=lambda x: isinstance(x, P))
            batch_abs = prefill_inputs(model, B, S)
            tok_sh = NS(logical_to_pspec(("act_batch", "act_seq"), rules))
            emb_sh = NS(logical_to_pspec(
                ("act_batch", "act_seq", "act_embed"), rules))
            batch_sh = {k: (emb_sh if v.ndim == 3 else tok_sh)
                        for k, v in batch_abs.items()}
            if model.prefill is not None:
                fn = lambda p, b: model.prefill(p, b, S)
            else:
                # ssm/hybrid prefill: full forward (state capture pending)
                fn = lambda p, b: model.loss(p, dict(
                    b, labels=b["tokens"], mask=None))[0]
            lowered = jax.jit(fn, in_shardings=(params_sh, batch_sh),
                              ).lower(params_abs, batch_abs)
        else:  # decode
            params_abs = model.abstract_params()
            params_ps = param_pspecs(model.param_logical, rules)
            params_sh = jax.tree.map(NS, params_ps,
                                     is_leaf=lambda x: isinstance(x, P))
            token_abs, cache_abs = decode_inputs(model, B, S)
            cache_ps = param_pspecs(model.cache_logical(), rules) \
                if model.cache_logical else jax.tree.map(
                    lambda _: P(), cache_abs)
            cache_sh = jax.tree.map(NS, cache_ps,
                                    is_leaf=lambda x: isinstance(x, P))
            tok_sh = NS(logical_to_pspec(("act_batch", None), rules))
            lowered = jax.jit(
                model.decode,
                in_shardings=(params_sh, tok_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,)).lower(params_abs, token_abs, cache_abs)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.launch.hlo_analysis import analyze
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # newer jaxlib returns one dict/device
        cost = cost[0] if cost else {}
    hlo_text = compiled.as_text()
    corrected = analyze(hlo_text)       # trip-count-corrected (see module doc)
    coll = {k: float(v) for k, v in corrected.coll_bytes.items()}
    rec.update({
        "status": "ok",
        "n_chips": int(n_chips),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "flops": float(corrected.flops),
        "hbm_bytes": float(corrected.hbm_bytes),
        "flops_xla_uncorrected": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "mem": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "collective_bytes": coll,
        "params": model.param_count(),
        "params_active": model.param_count(active_only=True),
    })
    if not quiet:
        print(f"[dryrun] OK {arch} x {shape_name} x {mesh_name} "
              f"flops={rec['flops']:.3e} "
              f"coll={sum(coll.values()):.3e}B "
              f"temp/dev={rec['mem']['temp_bytes']/1e9:.2f}GB "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)")
        print(f"  memory_analysis: {mem}")
    if save:
        _save(rec)
    return rec


def _save(rec: dict) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    if rec.get("tag"):
        name = name.replace(".json", f"__{rec['tag']}.json")
    (RESULTS_DIR / name).write_text(json.dumps(rec, indent=1))


def main() -> int:
    from repro.configs import ALL_CONFIGS, SHAPES
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list(ALL_CONFIGS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if not (args.all or args.arch):
        ap.error("pass --arch or --all")

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                out = RESULTS_DIR / f"{arch}__{shape}__{mesh_name}.json"
                if args.skip_existing and out.exists():
                    st = json.loads(out.read_text()).get("status")
                    if st in ("ok", "skip"):
                        continue
                try:
                    run_cell(arch, shape, mp)
                except Exception as e:  # record failures, keep sweeping
                    traceback.print_exc()
                    _save({"arch": arch, "shape": shape,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}"})
                    failures.append((arch, shape, mesh_name))
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES: {failures}")
        return 1
    print("[dryrun] all requested cells compiled")
    return 0


if __name__ == "__main__":
    sys.exit(main())
