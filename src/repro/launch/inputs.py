"""Abstract input construction for every (arch x shape) cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins (weak-type
correct, shardable, no device allocation) for the function the cell lowers:
train_step / prefill_step / decode_step.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ShapeCell
from repro.models.common import ArchConfig
from repro.models.registry import Model, build_model

I32 = jnp.int32
F32 = jnp.float32
BF16 = jnp.bfloat16


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def train_batch_specs(cfg: ArchConfig, B: int, S: int) -> Dict[str, Any]:
    batch = {
        "tokens": _sds((B, S), I32),
        "labels": _sds((B, S), I32),
        "mask": _sds((B, S), F32),
    }
    if cfg.family == "encdec":
        batch["frames"] = _sds((B, S, cfg.d_model), BF16)
    if cfg.family == "vlm":
        batch["img"] = _sds((B, cfg.n_img_tokens, cfg.d_model), BF16)
    return batch


def train_batch_logical(cfg: ArchConfig) -> Dict[str, Any]:
    tok = ("act_batch", "act_seq")
    out = {"tokens": tok, "labels": tok, "mask": tok}
    if cfg.family == "encdec":
        out["frames"] = ("act_batch", "act_seq", "act_embed")
    if cfg.family == "vlm":
        out["img"] = ("act_batch", "act_seq", "act_embed")
    return out


def decode_inputs(model: Model, B: int, S: int) -> Tuple[Any, Any]:
    """(token_sds, cache_sds) for a decode cell with context length S."""
    cache = jax.eval_shape(lambda: model.init_cache(B, S))
    token = _sds((B, 1), I32)
    return token, cache


def prefill_inputs(model: Model, B: int, S: int) -> Dict[str, Any]:
    cfg = model.cfg
    if cfg.family == "encdec":
        return {"frames": _sds((B, S, cfg.d_model), BF16),
                "tokens": _sds((B, S), I32)}
    batch = {"tokens": _sds((B, S), I32)}
    if cfg.family == "vlm":
        batch["img"] = _sds((B, cfg.n_img_tokens, cfg.d_model), BF16)
    return batch


def make_real_batch(cfg: ArchConfig, B: int, S: int, seed: int = 0,
                    vocab_cap: int | None = None) -> Dict[str, jax.Array]:
    """Concrete random batch (smoke tests / examples)."""
    rng = np.random.default_rng(seed)
    v = vocab_cap or cfg.vocab
    batch = {
        "tokens": jnp.asarray(rng.integers(0, v, (B, S)), I32),
        "labels": jnp.asarray(rng.integers(0, v, (B, S)), I32),
        "mask": jnp.ones((B, S), F32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)) * 0.02, BF16)
    if cfg.family == "vlm":
        batch["img"] = jnp.asarray(
            rng.standard_normal((B, cfg.n_img_tokens, cfg.d_model)) * 0.02,
            BF16)
    return batch
