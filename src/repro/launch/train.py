"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 200 --batch 8 --seq 64

Runs the full stack on the local device(s): synthetic pipeline, jit'd
train step, telemetry agent at 100 Hz, periodic fleet diagnosis, atomic
checkpoints (restart = rerun the command), optional failure injection.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.checkpoint import FailureInjector
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.models.registry import build_model
from repro.monitor.fleet import FleetMonitor
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import OptConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (drill)")
    ap.add_argument("--no-telemetry", action="store_true")
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO,
                        format="%(asctime)s %(name)s %(message)s")
    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    pipe = SyntheticLMPipeline(PipelineConfig(
        batch=args.batch, seq_len=args.seq, vocab=cfg.vocab,
        frames_dim=cfg.d_model if cfg.family == "encdec" else 0,
        img_tokens=cfg.n_img_tokens if cfg.family == "vlm" else 0,
        img_dim=cfg.d_model if cfg.family == "vlm" else 0))
    loop = LoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      telemetry=not args.no_telemetry)
    inj = FailureInjector(args.fail_at) if args.fail_at else None
    res = run_training(model, pipe, OptConfig(lr=args.lr), loop,
                       injector=inj, monitor=FleetMonitor())
    print(f"final step {res.final_step}; "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f}; "
          f"mean step {sum(res.step_ms)/max(len(res.step_ms),1):.1f} ms; "
          f"telemetry overhead "
          f"{res.telemetry_overhead_pct if res.telemetry_overhead_pct is not None else float('nan'):.2f}%")
    for fd in res.diagnoses:
        if fd.diagnosis is not None:
            print("diagnosis:", fd.diagnosis.summary())


if __name__ == "__main__":
    main()
