"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init;
tests run on one device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod ("data" x "model"); multi-pod adds a leading
    DP "pod" axis over DCN: 2 x 16 x 16 = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, n_pod: int = 0):
    """Small host-device mesh for tests (same axis names)."""
    if n_pod:
        return jax.make_mesh((n_pod, n_data, n_model),
                             ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
