"""Serving driver: batched generation with telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m --smoke
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.registry import build_model
from repro.monitor.hooks import StepTelemetry
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    tele = StepTelemetry()
    tele.start()
    eng = ServeEngine(model, params, max_len=args.max_len, telemetry=tele)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab,
                           (args.batch, args.prompt_len)).astype(np.int32)
    res = eng.generate(prompts, n_new=args.new_tokens,
                       temperature=args.temperature)
    stats = tele.stop()
    tok_ms = np.mean(res.per_token_ms)
    print(f"arch={cfg.name} batch={args.batch} "
          f"prefill {res.prefill_ms:.1f} ms; "
          f"{tok_ms:.1f} ms/token "
          f"({1000.0 / tok_ms * args.batch:.1f} tok/s); "
          f"telemetry overhead {100 * stats.overhead_frac:.2f}%")
    print("sample:", res.tokens[0][:16].tolist())


if __name__ == "__main__":
    main()
