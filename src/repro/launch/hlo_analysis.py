"""HLO-text roofline analyzer with while-trip-count correction.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified
empirically: a 10-iteration scan of a matmul reports one matmul's flops),
so any scanned program — which is every model here — undercounts by the
trip count.  This module reparses ``compiled.as_text()``:

  * builds the computation call graph (calls / fusions / while bodies),
  * recovers scan trip counts from loop-condition constants
    (``compare(iter, constant(N)), direction=LT``),
  * attributes per-instruction costs and multiplies through nested loops:
      - FLOPs: dot/convolution terms (2 * prod(out) * contraction);
        elementwise flops are negligible against MXU terms and are modeled
        as bytes, not flops;
      - collective bytes: output-shape bytes of all-gather / all-reduce /
        reduce-scatter / all-to-all / collective-permute;
      - HBM traffic model: sum of operand+output bytes of top-level
        instructions (each fusion reads inputs once, writes outputs once —
        the standard post-fusion traffic approximation).

Used by the dry-run to record corrected roofline terms per cell.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
    "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$")
_CALLED = re.compile(r"(?:calls=|to_apply=|body=|condition=|true_computation=|"
                     r"false_computation=)%?([\w\.\-]+)")
_CONSTANT_INT = re.compile(r"s32\[\]\s+constant\((\d+)\)")


def _shape_bytes(txt: str) -> int:
    """Total bytes of all shapes mentioned in a (possibly tuple) shape str."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(txt):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_elems(dt: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    text: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


def parse_computations(hlo: str) -> Tuple[Dict[str, Computation],
                                          Dict[str, Tuple[str, str]]]:
    """Returns (computations, instruction name -> output (dtype, dims))."""
    comps: Dict[str, Computation] = {}
    shapes: Dict[str, Tuple[str, str]] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        s = line.strip()
        m = _COMP_HDR.match(s)
        if m and ("->" in s):
            cur = Computation(m.group(1), [])
            comps[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None or "=" not in s:
            continue
        mm = re.match(r"(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)", s)
        if not mm:
            continue
        name, rest = mm.groups()
        op = re.search(r"\)?\s*([a-z][a-z0-9\-]*)\(", rest)
        opcode = op.group(1) if op else ""
        cur.instrs.append(Instr(name, opcode, s))
        sm = _SHAPE_RE.search(rest)
        if sm:
            shapes[name] = (sm.group(1), sm.group(2))
    return comps, shapes


def _dot_flops(text: str, shapes: Dict[str, Tuple[str, str]]) -> int:
    """2 * prod(output dims) * prod(contracting dims of lhs)."""
    m = _SHAPE_RE.search(text.split("=", 1)[1])
    if not m:
        return 0
    out_elems = _shape_elems(*m.groups())
    args = text.split("dot(", 1)[-1]
    opnames = re.findall(r"%([\w\.\-]+)", args.split(")")[0])
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", text)
    k = 1
    if opnames and cdims:
        lhs = shapes.get(opnames[0])
        if lhs:
            lhs_dims = lhs[1].split(",") if lhs[1] else []
            for ci in cdims.group(1).split(","):
                if ci and int(ci) < len(lhs_dims):
                    k *= int(lhs_dims[int(ci)])
    return 2 * out_elems * k


_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "while", "call", "fusion", "conditional",
               "custom-call", ""}


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLL_OPS})
    hbm_bytes: float = 0.0

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k,
                    {o: v * k for o, v in self.coll_bytes.items()},
                    self.hbm_bytes * k)

    def add(self, other: "Cost") -> None:
        self.flops += other.flops
        self.hbm_bytes += other.hbm_bytes
        for o, v in other.coll_bytes.items():
            self.coll_bytes[o] += v


def trip_count(cond: Computation) -> int:
    """Recover a scan trip count from the loop condition's constant."""
    consts = []
    for ins in cond.instrs:
        consts += [int(x) for x in _CONSTANT_INT.findall(ins.text)]
    return max(consts) if consts else 1


def analyze(hlo: str) -> Cost:
    comps, shapes = parse_computations(hlo)

    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()  # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[name]
        total = Cost()
        for ins in comp.instrs:
            if ins.opcode == "dot":
                total.flops += _dot_flops(ins.text, shapes)
                # dot HBM traffic: operands + output once
                total.hbm_bytes += _shape_bytes(ins.text)
            elif any(ins.opcode.startswith(c) for c in _COLL_OPS):
                base = next(c for c in _COLL_OPS if ins.opcode.startswith(c))
                if not ins.opcode.endswith("-done"):
                    out_shape = ins.text.split("=", 1)[1]
                    lhs = out_shape.split(base)[0]
                    total.coll_bytes[base] += _shape_bytes(lhs)
                    total.hbm_bytes += _shape_bytes(lhs)
            elif ins.opcode == "while":
                called = _CALLED.findall(ins.text)
                body = cond = None
                mb = re.search(r"body=%?([\w\.\-]+)", ins.text)
                mc = re.search(r"condition=%?([\w\.\-]+)", ins.text)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                n = trip_count(comps[cond]) if cond in comps else 1
                if body:
                    total.add(comp_cost(body).scaled(max(n, 1)))
            elif ins.opcode in ("fusion", "call", "conditional",
                                "custom-call"):
                for callee in _CALLED.findall(ins.text):
                    total.add(comp_cost(callee))
                # traffic for the fusion boundary itself
                if ins.opcode in ("fusion", "custom-call"):
                    total.hbm_bytes += _shape_bytes(
                        ins.text.split("=", 1)[1])
            else:
                if ins.opcode not in _SKIP_BYTES:
                    total.hbm_bytes += _shape_bytes(ins.text.split("=", 1)[1])
        memo[name] = total
        return total

    entry = None
    for line in hlo.splitlines():
        if line.startswith("ENTRY"):
            m = re.search(r"ENTRY\s+%?([\w\.\-]+)", line)
            if m:
                entry = m.group(1)
            break
    if entry is None:
        # fall back: largest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs))
    return comp_cost(entry)
