"""Ambient host-signal model: every telemetry channel's quiet behaviour.

Each channel is ``base + sd * AR(1)`` plus a sparse *nuisance-burst* process
— cron jobs, stray `apt` runs, unrelated network chatter — which is what
makes diagnosis non-trivial: a nuisance burst overlapping a latency spike in
the wrong group is exactly how the paper's confusion matrix gets its
off-diagonal mass.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.telemetry.schema import metric_names


@dataclasses.dataclass(frozen=True)
class ChannelModel:
    base: float
    sd: float
    ar_rho: float = 0.9
    nonneg: bool = True
    # nuisance bursts: Poisson arrivals, lognormal amplitude (x base), exp dur
    burst_rate_hz: float = 0.0       # arrivals per second
    burst_amp: float = 0.0           # mean amplitude as multiple of `sd`
    burst_dur_s: float = 1.0


#: calibrated quiet-host values (4-GPU training node, 10 GbE, NVMe)
DEFAULT_CHANNELS: Dict[str, ChannelModel] = {
    # NET group
    "net_rx_softirq":   ChannelModel(2000.0, 300.0, 0.9, True, 1 / 40.0, 7.0, 0.8),
    "net_tx_softirq":   ChannelModel(1500.0, 250.0, 0.9, True, 1 / 50.0, 6.0, 0.8),
    "nic_rx_bytes":     ChannelModel(5e6, 1.5e6, 0.92, True, 1 / 40.0, 8.0, 1.0),
    "nic_tx_bytes":     ChannelModel(4e6, 1.2e6, 0.92, True, 1 / 50.0, 8.0, 1.0),
    "nic_rx_drops":     ChannelModel(0.5, 0.4, 0.5, True, 1 / 120.0, 6.0, 0.5),
    # SCHED group
    "sched_switch_rate": ChannelModel(9000.0, 900.0, 0.9, True, 1 / 45.0, 6.0, 1.2),
    "runqueue_len":      ChannelModel(2.0, 0.7, 0.85, True, 1 / 60.0, 5.0, 1.5),
    "involuntary_ctx":   ChannelModel(60.0, 20.0, 0.8, True, 1 / 60.0, 6.0, 1.0),
    "cpu_util_other":    ChannelModel(0.12, 0.03, 0.93, True, 1 / 50.0, 5.0, 2.0),
    # BLOCK_IO group
    "blkio_read_bytes":  ChannelModel(2e6, 8e5, 0.88, True, 1 / 35.0, 9.0, 1.0),
    "blkio_write_bytes": ChannelModel(3e6, 1e6, 0.88, True, 1 / 30.0, 9.0, 1.2),
    "blkio_inflight":    ChannelModel(1.0, 0.5, 0.8, True, 1 / 40.0, 6.0, 1.0),
    "iowait_frac":       ChannelModel(0.01, 0.004, 0.9, True, 1 / 45.0, 6.0, 1.0),
    # PCIE / DMA group (training input feed keeps these busy)
    "pcie_h2d_bytes":    ChannelModel(8e9, 6e8, 0.9, True, 1 / 70.0, 4.0, 1.0),
    "pcie_d2h_bytes":    ChannelModel(1e9, 1e8, 0.9, True, 1 / 70.0, 4.0, 1.0),
    # DEVICE group (quiet: pinned clocks, steady load)
    "dev_util":      ChannelModel(0.93, 0.015, 0.95, True, 0.0, 0.0, 0.0),
    "dev_mem_used":  ChannelModel(62e9, 2e8, 0.98, True, 0.0, 0.0, 0.0),
    "dev_power":     ChannelModel(385.0, 6.0, 0.95, True, 1 / 90.0, 3.0, 1.5),
    "dev_temp":      ChannelModel(64.0, 0.6, 0.99, True, 0.0, 0.0, 0.0),
    "dev_clock":     ChannelModel(1410.0, 8.0, 0.9, True, 1 / 90.0, 3.0, 1.0),
}


class HostSignalModel:
    def __init__(self, channels: Optional[Dict[str, ChannelModel]] = None,
                 rate_hz: float = 100.0):
        self.models = dict(channels or DEFAULT_CHANNELS)
        self.rate_hz = float(rate_hz)

    @property
    def channel_names(self) -> List[str]:
        return list(self.models)

    def _ar1(self, rng: np.random.Generator, T: int, rho: float) -> np.ndarray:
        eps = rng.standard_normal(T)
        out = np.empty(T)
        acc = 0.0
        c = np.sqrt(max(1.0 - rho * rho, 1e-12))
        for t in range(T):
            acc = rho * acc + c * eps[t]
            out[t] = acc
        return out

    def _bursts(self, rng: np.random.Generator, T: int,
                m: ChannelModel) -> np.ndarray:
        """Sparse nuisance bursts as an additive series in channel units."""
        out = np.zeros(T)
        if m.burst_rate_hz <= 0 or m.burst_amp <= 0:
            return out
        n_expected = m.burst_rate_hz * T / self.rate_hz
        n = rng.poisson(n_expected)
        for _ in range(n):
            t0 = rng.integers(0, T)
            dur = max(1, int(rng.exponential(m.burst_dur_s) * self.rate_hz))
            amp = m.sd * m.burst_amp * rng.lognormal(0.0, 0.5)
            t1 = min(T, t0 + dur)
            # half-sine envelope — bursts ramp, they don't step
            env = np.sin(np.linspace(0, np.pi, t1 - t0))
            out[t0:t1] += amp * env
        return out

    def generate(self, rng: np.random.Generator, T: int,
                 ) -> Tuple[List[str], np.ndarray]:
        """(channel_names, data (C, T)) of ambient signals."""
        names = self.channel_names
        data = np.empty((len(names), T), dtype=np.float64)
        for i, name in enumerate(names):
            m = self.models[name]
            x = m.base + m.sd * self._ar1(rng, T, m.ar_rho) + self._bursts(rng, T, m)
            if m.nonneg:
                np.maximum(x, 0.0, out=x)
            data[i] = x
        return names, data
