"""W1: all_reduce_perf latency model (paper §3.1).

Ring all-reduce cost model over n devices with message size S:

    L_base(S) = alpha * 2(n-1)  +  2(n-1)/n * S / B_eff

(alpha = per-hop launch+sync latency, B_eff = per-link effective bandwidth).
Per-iteration latency then carries multiplicative lognormal jitter with AR(1)
temporal correlation — matching the heavy-ish right tail real NCCL iteration
timings show — and is modulated by the disturbance multiplier series
(:mod:`repro.sim.disturbances`).
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: all_reduce_perf sweep (paper: 1 KB .. 64 MB)
MESSAGE_SIZES = [2 ** p for p in range(10, 27)]  # 1 KiB .. 64 MiB


@dataclasses.dataclass
class AllReduceWorkload:
    n_devices: int = 4
    msg_bytes: int = 16 * 2 ** 20          # representative default: 16 MiB
    link_bw: float = 220e9                 # NVLink-ish per-link B/s
    alpha_us: float = 6.0                  # per-hop latency
    jitter_cv: float = 0.06                # lognormal coefficient of variation
    ar_rho: float = 0.85                   # AR(1) at 100 Hz (~60 ms memory)

    @property
    def base_latency_ms(self) -> float:
        n, s = self.n_devices, float(self.msg_bytes)
        hops = 2 * (n - 1)
        bw_term = hops / n * s / self.link_bw
        return self.alpha_us * hops * 1e-3 + bw_term * 1e3

    def busbw_gbs(self, latency_ms: float) -> float:
        """all_reduce_perf's 'busbw' for reporting."""
        n, s = self.n_devices, float(self.msg_bytes)
        algbw = s / (latency_ms * 1e-3)
        return algbw * 2 * (n - 1) / n / 1e9

    def latency_series(self, rng: np.random.Generator, T: int,
                       multiplier: np.ndarray | None = None) -> np.ndarray:
        """(T,) per-iteration latency in ms at the telemetry grid rate."""
        sigma = np.sqrt(np.log(1.0 + self.jitter_cv ** 2))
        eps = rng.standard_normal(T)
        ar = np.empty(T)
        acc = 0.0
        c = np.sqrt(1.0 - self.ar_rho ** 2)
        for t in range(T):
            acc = self.ar_rho * acc + c * eps[t]
            ar[t] = acc
        jitter = np.exp(sigma * ar - 0.5 * sigma ** 2)
        L = self.base_latency_ms * jitter
        if multiplier is not None:
            L = L * np.asarray(multiplier, dtype=np.float64)
        return L
