"""D1-D4 disturbance injectors (paper §3.1) with cross-layer couplings.

Each disturbance drives (a) its *primary* host channels, (b) *leakage*
into neighbouring subsystems (a NIC burst costs CPU in ksoftirqd; heavy fio
raises iowait and runqueue), and (c) the all-reduce latency multiplier,
delayed by a short transfer lag (host cause leads device effect — this is
the lag the paper's +/-200 ms cross-correlation window exists to catch).

Amplitudes scale with a per-trial ``intensity`` so the evaluation sees a
range from marginal to blatant events, like the paper's 17-run spread
(Fig 2b box plots).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from repro.core.taxonomy import CauseClass


# ---------------------------------------------------------------------------
# envelopes
# ---------------------------------------------------------------------------

def _smoothstep(x: np.ndarray) -> np.ndarray:
    x = np.clip(x, 0.0, 1.0)
    return x * x * (3 - 2 * x)


def env_sustained(rng, T, rate, t_on, dur, rise_s=0.6):
    t = np.arange(T) / rate
    up = _smoothstep((t - t_on) / rise_s)
    down = _smoothstep((t_on + dur - t) / rise_s)
    return np.minimum(up, down)


def env_ramp(rng, T, rate, t_on, dur, ramp_s=4.5):
    t = np.arange(T) / rate
    up = _smoothstep((t - t_on) / ramp_s)
    down = _smoothstep((t_on + dur - t) / 0.8)
    return np.minimum(up, down)


def env_bursty(rng, T, rate, t_on, dur, period_s=None, duty=None):
    """On/off bursts inside the active window (tc-style traffic bursts).

    Period and duty vary per trial — real traffic generators are not
    metronomes, and the spread is what makes burst-shaped events land at
    different detection latencies across the 17 runs.
    """
    if period_s is None:
        period_s = float(rng.uniform(1.2, 2.6))
    if duty is None:
        duty = float(rng.uniform(0.32, 0.55))
    base = env_sustained(rng, T, rate, t_on, dur, rise_s=0.3)
    t = np.arange(T) / rate
    phase = rng.uniform(0, period_s)
    # jitter the period a little per cycle via phase noise
    cyc = ((t + phase) % period_s) / period_s
    gate = (cyc < duty).astype(np.float64)
    # smooth gate edges (~50 ms)
    k = max(1, int(0.05 * rate))
    kernel = np.ones(k) / k
    gate = np.convolve(gate, kernel, mode="same")
    return base * gate


ENVELOPES: Dict[str, Callable] = {
    "sustained": env_sustained,
    "ramp": env_ramp,
    "bursty": env_bursty,
}


# ---------------------------------------------------------------------------
# effect tables
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelEffect:
    channel: str
    amp: float            # additive, in channel units (at intensity 1)
    mode: str = "add"     # "add" | "set_drop" (drop toward amp) | "jitter"
    lag_s: float = 0.0    # channel-specific extra lag vs the envelope


@dataclasses.dataclass(frozen=True)
class Disturbance:
    kind: CauseClass
    name: str
    envelope: str
    effects: Tuple[ChannelEffect, ...]
    latency_amp: float          # L multiplier = 1 + amp * env (intensity 1)
    latency_lag_s: float        # host envelope leads latency by this much
    dur_s: Tuple[float, float]  # duration range
    intensity_sigma: float = 0.35   # lognormal sigma for per-trial intensity


DISTURBANCES: Dict[str, Disturbance] = {
    # D1 — fio high-throughput disk I/O -> PCIe/root-complex contention
    "io": Disturbance(
        kind=CauseClass.IO, name="D1-io-pressure", envelope="sustained",
        effects=(
            ChannelEffect("blkio_read_bytes", 1.1e9),
            ChannelEffect("blkio_write_bytes", 1.4e9),
            ChannelEffect("blkio_inflight", 48.0),
            ChannelEffect("iowait_frac", 0.35),
            # DMA contention: input feed throughput sags (two-sided channel)
            ChannelEffect("pcie_h2d_bytes", -2.5e9, lag_s=0.03),
            ChannelEffect("pcie_d2h_bytes", -2.0e8, lag_s=0.03),
            # leakage: completion storms cost some CPU
            ChannelEffect("sched_switch_rate", 2500.0),
            ChannelEffect("runqueue_len", 1.0),
            ChannelEffect("cpu_util_other", 0.06),
            ChannelEffect("dev_util", -0.08, lag_s=0.08),
        ),
        latency_amp=0.55, latency_lag_s=0.08, dur_s=(18.0, 30.0)),
    # D2 — CPU-bound process pinned to the workload's cores
    "cpu": Disturbance(
        kind=CauseClass.CPU, name="D2-cpu-contention", envelope="sustained",
        effects=(
            ChannelEffect("cpu_util_other", 0.72),
            ChannelEffect("runqueue_len", 9.0),
            ChannelEffect("involuntary_ctx", 1800.0),
            ChannelEffect("sched_switch_rate", 14000.0),
            # leakage: softirq processing squeezed -> small net effect
            ChannelEffect("net_rx_softirq", 500.0, lag_s=0.05),
            ChannelEffect("dev_util", -0.12, lag_s=0.06),
        ),
        latency_amp=0.65, latency_lag_s=0.05, dur_s=(18.0, 30.0)),
    # D3 — tc-generated NIC saturation bursts
    "nic": Disturbance(
        kind=CauseClass.NIC, name="D3-nic-burst", envelope="bursty",
        effects=(
            ChannelEffect("net_rx_softirq", 55000.0),
            ChannelEffect("net_tx_softirq", 9000.0),
            ChannelEffect("nic_rx_bytes", 1.15e9),
            ChannelEffect("nic_tx_bytes", 2.5e8),
            ChannelEffect("nic_rx_drops", 900.0, lag_s=0.04),
            # leakage: ksoftirqd burns CPU during bursts
            ChannelEffect("sched_switch_rate", 6000.0, lag_s=0.02),
            ChannelEffect("cpu_util_other", 0.12, lag_s=0.02),
            ChannelEffect("runqueue_len", 1.5, lag_s=0.02),
            ChannelEffect("dev_util", -0.07, lag_s=0.08),
        ),
        latency_amp=1.1, latency_lag_s=0.06, dur_s=(15.0, 25.0)),
    # D4 — power-cap-induced throttling
    "gpu": Disturbance(
        kind=CauseClass.GPU, name="D4-gpu-throttle", envelope="ramp",
        effects=(
            ChannelEffect("dev_power", -140.0, mode="add"),
            ChannelEffect("dev_clock", -430.0, mode="add"),
            ChannelEffect("dev_temp", -6.0, lag_s=2.0),
            ChannelEffect("dev_util", 0.04),   # busier at lower clock
        ),
        latency_amp=0.5, latency_lag_s=0.10, dur_s=(20.0, 32.0)),
}

CLASS_ORDER: Sequence[str] = ("io", "cpu", "nic", "gpu")


def make_disturbance(key: str) -> Disturbance:
    return DISTURBANCES[key]


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------

def _shift(env: np.ndarray, lag_s: float, rate: float) -> np.ndarray:
    """Delay the envelope by lag_s (cause first, effect later)."""
    k = int(round(lag_s * rate))
    if k == 0:
        return env
    out = np.zeros_like(env)
    if k > 0:
        out[k:] = env[:-k]
    else:
        out[:k] = env[-k:]
    return out


def apply_disturbance(rng: np.random.Generator, channels: List[str],
                      data: np.ndarray, dist: Disturbance, rate: float,
                      t_on: float, dur: float, intensity: float,
                      ) -> np.ndarray:
    """Mutates ``data`` in place; returns the latency multiplier series."""
    T = data.shape[1]
    env_fn = ENVELOPES[dist.envelope]
    env = env_fn(rng, T, rate, t_on, dur)
    # Precursor: injection tools have a setup phase (fio lays out files, tc
    # primes qdiscs, the cpu hog forks workers) that stirs the same channels
    # *before* the measured effect — contaminating the baseline window the
    # spike scores are normalised against.
    chan_env = env
    if rng.uniform() < 0.30:
        pre_t = t_on - float(rng.uniform(8.0, 16.0))
        pre_dur = float(rng.uniform(3.0, 6.0))
        pre = env_sustained(rng, T, rate, pre_t, pre_dur, rise_s=0.5)
        chan_env = np.maximum(env, float(rng.uniform(0.15, 0.30)) * pre)
    idx = {c: i for i, c in enumerate(channels)}
    for eff in dist.effects:
        i = idx.get(eff.channel)
        if i is None:
            continue
        e = _shift(chan_env, eff.lag_s + rng.normal(0.0, 0.01), rate)
        # per-channel amplitude wobble so channels aren't perfect copies
        wobble = float(rng.lognormal(0.0, 0.25))
        data[i] += eff.amp * intensity * wobble * e
        np.maximum(data[i], 0.0, out=data[i])
    # Latency response: the transfer from host cause to device latency is
    # not a clean fixed-lag copy — the lag drifts with queue depths and the
    # response amplitude fluctuates within the event.  Model as a two-lag
    # mixture with a slow multiplicative wobble; this caps the achievable
    # cross-correlation below 1 exactly like real traces do.
    lag = dist.latency_lag_s + rng.normal(0.0, 0.02)
    lag2 = lag + float(rng.uniform(0.25, 0.6))
    lenv = 0.65 * _shift(env, lag, rate) + 0.35 * _shift(env, lag2, rate)
    wob = np.convolve(rng.standard_normal(T), np.ones(int(rate)) / rate,
                      mode="same")
    sd = float(np.std(wob)) + 1e-12
    lenv = lenv * np.clip(1.0 + 0.25 * wob / sd, 0.3, 1.9)
    return 1.0 + dist.latency_amp * intensity * lenv


#: Channels considered "primary" evidence per class — used by the confuser
#: injector (it mimics the *footprint* of an unrelated tenant action).
PRIMARY_CHANNELS: Dict[str, Tuple[str, ...]] = {
    "io": ("blkio_read_bytes", "blkio_write_bytes", "blkio_inflight",
           "iowait_frac"),
    "cpu": ("cpu_util_other", "runqueue_len", "involuntary_ctx",
            "sched_switch_rate"),
    "nic": ("net_rx_softirq", "net_tx_softirq", "nic_rx_bytes",
            "nic_tx_bytes"),
    "gpu": ("dev_power", "dev_clock"),
}


def inject_confuser(rng: np.random.Generator, channels: List[str],
                    data: np.ndarray, cls: str, rate: float,
                    t_near: float, scale: float) -> None:
    """A temporally coincident, *causally unrelated* burst in class ``cls``.

    Multi-tenant hosts cluster activity in time (one tenant action touches
    disk and network together; cron fires on the minute), so real spike
    windows often contain innocent-bystander bursts in other subsystems.
    This is the principled generator of the confusion matrix's off-diagonal
    mass — the estimator must use lag structure and magnitude to beat it.
    """
    dist = DISTURBANCES[cls]
    T = data.shape[1]
    dur = float(rng.uniform(8.0, 18.0))
    t0 = t_near + float(rng.uniform(-1.0, 1.5))
    # half the time the bystander has the same temporal texture as the real
    # cause's latency response — the adversarial case for correlation
    env_fn = ENVELOPES["bursty"] if rng.uniform() < 0.35 else env_sustained
    env = env_fn(rng, T, rate, t0, dur)
    idx = {c: i for i, c in enumerate(channels)}
    primaries = PRIMARY_CHANNELS[cls]
    for eff in dist.effects:
        if eff.channel not in primaries:
            continue
        i = idx.get(eff.channel)
        if i is None:
            continue
        e = _shift(env, rng.normal(0.0, 0.03), rate)
        data[i] += eff.amp * scale * float(rng.lognormal(0.0, 0.3)) * e
        np.maximum(data[i], 0.0, out=data[i])
