"""Operational scoring: verdict streams vs per-event ground truth.

The paper's headline claims are operational — spikes detected within ~5 s,
root cause in 6-8 s — so a multi-fault evaluation must score *when* each
verdict landed, not only the end-of-trial class.  This module matches a
diagnoser's verdict stream (one :class:`VerdictEvent` per emitted
:class:`~repro.core.taxonomy.Diagnosis`) against a scenario's ground-truth
:class:`~repro.sim.scenarios.FaultEvent` timeline:

* **nearest-truth matching**: a verdict is a candidate for a truth event
  when its onset estimate falls inside the event's active span widened by
  ``tol_s`` on both sides; candidates are assigned greedily by smallest
  ``|verdict onset - truth onset|``, one-to-one, so under overlap each
  verdict explains at most one event and double-counting is impossible;
* **latency metrics** per matched pair: detection latency
  ``t_detect - truth.t_on`` (target: the paper's 5 s) and RCA latency
  ``t_ready - truth.t_on`` (target: the paper's 6-8 s).  ``t_ready`` is the
  deterministic virtual-time verdict stamp (evidence window closed), so
  scores are reproducible and identical across the per-event,
  event-batched and slab execution paths;
* **precision / recall / accuracy** under overlap: unmatched verdicts are
  false verdicts (the soak class must produce none), unmatched truth
  events are misses, and accuracy is judged on matched pairs only.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.engine import COOLDOWN_S
from repro.core.taxonomy import CauseClass, Diagnosis
from repro.sim.scenarios import FaultEvent

#: default matching tolerance: half the engine's cooldown — wide enough
#: for boundary-cadence detection (~5-9 s after onset) plus onset
#: estimation error, narrow enough that sequential events keep distinct
#: match windows.  Derived from the engine's single ``COOLDOWN_S``
#: definition so the scorer can never drift from the dedup machinery.
TOL_S = COOLDOWN_S / 2.0

#: the paper's operational targets (§1, Table 3)
DETECT_TARGET_S = 5.0
RCA_TARGET_S = 8.0


@dataclasses.dataclass(frozen=True)
class VerdictEvent:
    """One emitted verdict, reduced to what operational scoring needs."""

    t_onset: float           # engine's onset estimate
    t_detect: float          # when Layer 2 fired
    t_ready: float           # virtual time the verdict's evidence closed
    pred: CauseClass


def verdict_events(diags: Sequence[Diagnosis]) -> List[VerdictEvent]:
    """Reduce a diagnosis stream to scoreable verdict events."""
    return [VerdictEvent(t_onset=d.event.t_onset, t_detect=d.event.t_detect,
                         t_ready=(d.t_ready if d.t_ready is not None
                                  else d.t_rca),
                         pred=d.top_cause)
            for d in diags]


@dataclasses.dataclass
class MatchResult:
    pairs: List[Tuple[int, int]]     # (truth index, verdict index)
    missed: List[int]                # truth indices with no verdict
    spurious: List[int]              # verdict indices with no truth


def match_events(truth: Sequence[FaultEvent],
                 verdicts: Sequence[VerdictEvent],
                 tol_s: float = TOL_S) -> MatchResult:
    """Greedy one-to-one nearest-truth assignment.

    Candidate pairs are ``(t, v)`` with ``t.t_on - tol_s <= v.t_onset <=
    t.t_off + tol_s``; they are consumed in order of increasing
    ``|v.t_onset - t.t_on|``.  Cost ties are broken class-aware first — a
    verdict whose predicted cause equals the truth event's kind beats one
    that merely shares the onset — then by truth and verdict index, so
    fully-overlapping events match deterministically.  The class tiebreak
    matters exactly when a multi-hypothesis diagnoser emits several
    verdicts for one overlap window with the *same* onset estimate
    (co-verdicts anchored to the incident's first onset): any one-to-one
    assignment has the same cardinality, but attribution should pair each
    cause with its own event.  Greedy-by-cost remains exact in every case
    that matters: match windows only contend when events overlap, and
    then cardinality is tiebreak-invariant.
    """
    cands: List[Tuple[float, int, int, int]] = []
    for i, t in enumerate(truth):
        for j, v in enumerate(verdicts):
            if t.t_on - tol_s <= v.t_onset <= t.t_off + tol_s:
                cands.append((abs(v.t_onset - t.t_on),
                              int(v.pred != t.kind), i, j))
    cands.sort()
    used_t: set = set()
    used_v: set = set()
    pairs: List[Tuple[int, int]] = []
    for _, _, i, j in cands:
        if i in used_t or j in used_v:
            continue
        used_t.add(i)
        used_v.add(j)
        pairs.append((i, j))
    pairs.sort()
    return MatchResult(
        pairs=pairs,
        missed=[i for i in range(len(truth)) if i not in used_t],
        spurious=[j for j in range(len(verdicts)) if j not in used_v])


@dataclasses.dataclass
class TrialScore:
    """Per-trial tallies; aggregate with :func:`summarize`."""

    n_truth: int
    n_verdicts: int
    n_matched: int
    n_correct: int                       # matched pairs with the right class
    detect_latencies: List[float]        # t_detect - truth.t_on, matched
    rca_latencies: List[float]           # t_ready - truth.t_on, matched


def _effective_t(t: float,
                 restart_windows: Sequence[Tuple[float, float]]) -> float:
    """Latency stamp for a verdict time under monitor downtime.

    A verdict whose virtual timestamp falls inside a restart window
    ``[t0, t1)`` could not have been *delivered* before the monitor came
    back at ``t1`` — replay re-derives it at restore time.  Latency
    scoring therefore charges the downtime: the effective time is the
    window end.  Times outside every window are unchanged, and replay
    parity elsewhere still compares the raw virtual stamps.
    """
    for t0, t1 in restart_windows:
        if t0 <= t < t1:
            return float(t1)
    return float(t)


def score_trial(truth: Sequence[FaultEvent],
                verdicts: Sequence[VerdictEvent],
                tol_s: float = TOL_S,
                restart_windows: Sequence[Tuple[float, float]] = (),
                ) -> TrialScore:
    m = match_events(truth, verdicts, tol_s)
    det, rca, correct = [], [], 0
    for i, j in m.pairs:
        t, v = truth[i], verdicts[j]
        det.append(_effective_t(v.t_detect, restart_windows) - t.t_on)
        rca.append(_effective_t(v.t_ready, restart_windows) - t.t_on)
        if v.pred == t.kind:
            correct += 1
    return TrialScore(n_truth=len(truth), n_verdicts=len(verdicts),
                      n_matched=len(m.pairs), n_correct=correct,
                      detect_latencies=det, rca_latencies=rca)


def _pcts(xs: Sequence[float]) -> Optional[Dict[str, float]]:
    if not xs:
        return None
    a = np.asarray(xs, dtype=np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "max": float(a.max())}


def summarize(scores: Sequence[TrialScore], *,
              detect_target_s: float = DETECT_TARGET_S,
              rca_target_s: float = RCA_TARGET_S) -> Dict[str, object]:
    """Aggregate per-trial scores into one scorecard block.

    ``precision`` / ``recall`` / ``accuracy`` are ``None`` (JSON null)
    when their denominator is empty — a no-fault soak has no recall, a
    verdict-free class no precision — rather than a misleading 0 or 1.
    """
    n_truth = sum(s.n_truth for s in scores)
    n_verd = sum(s.n_verdicts for s in scores)
    n_match = sum(s.n_matched for s in scores)
    n_correct = sum(s.n_correct for s in scores)
    det = [x for s in scores for x in s.detect_latencies]
    rca = [x for s in scores for x in s.rca_latencies]
    return {
        "n_trials": len(scores),
        "n_truth_events": n_truth,
        "n_verdicts": n_verd,
        "n_matched": n_match,
        "false_verdicts": n_verd - n_match,
        "precision": (n_match / n_verd) if n_verd else None,
        "recall": (n_match / n_truth) if n_truth else None,
        "accuracy": (n_correct / n_match) if n_match else None,
        "detect_latency_s": _pcts(det),
        "rca_latency_s": _pcts(rca),
        "detect_within_target": (float(np.mean(
            np.asarray(det) <= detect_target_s)) if det else None),
        "rca_within_target": (float(np.mean(
            np.asarray(rca) <= rca_target_s)) if rca else None),
    }
