"""Multi-fault scenario DSL: timelines above :class:`~repro.sim.scenario.Trial`.

The paper's 68-trial protocol injects exactly one disturbance per trial and
scores end-of-trial classification.  Production diagnosis is judged on
*timelines* — faults overlap, recur, and hit several hosts of a fleet at
once — and on time-to-verdict, not only on the verdict itself.  This module
composes the D1-D4 injectors of :mod:`repro.sim.disturbances` into such
timelines; :mod:`repro.sim.scoring` scores a diagnoser's per-event verdict
stream against the per-event ground truth with nearest-truth matching.

Scenario classes (``SCENARIO_CLASSES``):

  ``single``        one fault — the paper-protocol control.
  ``overlap_pair``  two concurrent faults of different classes, the second
                    starting while the first is active (partial overlap).
  ``overlap_full``  two different-class faults injected at the same instant
                    (fully overlapping active windows).
  ``cascade``       three faults of distinct classes in sequence, spaced
                    past the engine's cooldown.
  ``flap``          one fault class recurring as short bursts — the
                    flapping-incident profile.
  ``soak``          no fault at all: the false-verdict control.
  ``fleet_nic``     the same NIC burst hitting several hosts of a fleet
                    slab (cross-host correlated incident); unaffected
                    hosts soak.

Chaos classes (telemetry corruption via :mod:`repro.sim.chaos`, appended
AFTER ``fleet_nic`` so existing class indices — and therefore every
committed trial's ``protocol_seed`` — stay byte-identical):

  ``chaos_soak``      no host fault; NaN burst + elevated freeze + dropped
                      ticks on the telemetry.  Zero-false-verdict control
                      for the chaos-hardened pipeline.
  ``chaos_overlap``   one real fault *while* the telemetry is corrupted
                      (baseline freeze + in-window NaN burst) — the fault
                      must still be detected within latency targets.
  ``frozen_channel``  latency channel stuck at an elevated value for tens
                      of seconds (plus a frozen evidence channel): the
                      canonical "broken probe imitates a persistent
                      incident" trap.  Zero verdicts expected.
  ``crash_restart``   agent crash/restart: every channel unreadable for a
                      multi-second gap mid-run.  Zero verdicts expected.

Monitor-survivability classes (appended after the chaos classes, same
append-only protocol-seed discipline).  Unlike chaos, monitor events do
not touch the telemetry — they schedule failures of the *diagnosis
process* itself, which the eval harness enacts:

  ``crash_during_incident``  one real fault; the monitor is killed shortly
                             after onset and warm-restored from its last
                             checkpoint — replayed verdicts must be
                             byte-identical to an uninterrupted run (zero
                             duplicates), latencies scored against the
                             restart window.

``compose_trial`` is the shared builder: ambient host signals generated
once, every :class:`FaultEvent` applied through the *same* envelope /
leakage machinery as ``make_trial`` (additive host-channel effects, lagged
latency response), latency multipliers composed multiplicatively —
concurrent contention compounds.  Every trial of a suite shares the grid
and channel layout, so the whole suite stacks into the columnar
:class:`~repro.sim.scenario.TrialStore` and runs through the
event-batched / slab Layer-3 paths unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.taxonomy import CauseClass
from repro.sim import chaos as chaos_mod
from repro.sim.chaos import ChaosEvent
from repro.sim.disturbances import (
    CLASS_ORDER, DISTURBANCES, apply_disturbance, inject_confuser,
)
from repro.telemetry.schema import LATENCY_METRIC
from repro.sim.hostmodel import HostSignalModel
from repro.sim.scenario import finalize_trial_channels, protocol_seed

#: scenario timelines are laid out for at least this much trial time —
#: cascade/flap event placement assumes the detector's 25 s warm-up plus
#: three cooldown-separated event slots.
MIN_DURATION_S = 115.0

#: default scenario-trial duration (the paper protocol's 90 s is too short
#: for three cooldown-separated events).
DURATION_S = 120.0


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One injected fault on a scenario timeline (exact ground truth)."""

    cls: str                 # disturbance key: "io" | "cpu" | "nic" | "gpu"
    t_on: float              # injection time, seconds on the trial grid
    dur_s: float
    intensity: float

    @property
    def t_off(self) -> float:
        return self.t_on + self.dur_s

    @property
    def kind(self) -> CauseClass:
        return DISTURBANCES[self.cls].kind

    def overlaps(self, other: "FaultEvent") -> bool:
        return self.t_on < other.t_off and other.t_on < self.t_off


@dataclasses.dataclass(frozen=True)
class MonitorEvent:
    """One scheduled failure of the monitor process itself.

    ``monitor_crash``: the diagnosis process dies at ``t`` and is
    warm-restored ``dur_s`` later from its last checkpoint; the trailing
    ring contents are replayed through the restored state.
    ``monitor_overload``: every diagnosis round in ``[t, t + dur_s)``
    carries ``cost_s`` of synthetic external load — the deadline-budget
    hysteresis must shed to detect-only instead of silently missing the
    5 s target.
    """

    kind: str                # "monitor_crash" | "monitor_overload"
    t: float                 # seconds on the trial grid
    dur_s: float = 0.0       # crash downtime / overload span
    cost_s: float = 0.0      # per-round synthetic cost (overload only)

    @property
    def t_end(self) -> float:
        return self.t + self.dur_s


@dataclasses.dataclass
class ScenarioTrial:
    """A composed timeline: telemetry matrix + per-event ground truth.

    Duck-type compatible with :class:`~repro.sim.scenario.Trial` where it
    matters (``ts`` / ``data`` / ``channels``), so
    ``TrialStore.from_trials`` stacks scenario suites unchanged.
    """

    ts: np.ndarray                  # (T,) seconds, uniform grid
    data: np.ndarray                # (C, T) float64
    channels: List[str]
    truth: List[FaultEvent]         # ground-truth events, time order
    scenario: str                   # scenario class name
    seed: int
    host: int = 0                   # slab row for fleet scenarios
    #: incident id: trials of one fleet scenario instance share it, so
    #: consumers can regroup a flat suite into (hosts, C, T) slabs without
    #: reverse-engineering per-host seed derivation
    group: int = 0
    #: telemetry-corruption ground truth (chaos classes); ``data`` already
    #: carries the corruption — this records what was injected where
    chaos: List[ChaosEvent] = dataclasses.field(default_factory=list)
    #: scheduled monitor-process failures (survivability classes); the
    #: telemetry is untouched — the eval harness enacts these against the
    #: diagnosis loop (crash + warm restore, synthetic overload)
    monitor: List[MonitorEvent] = dataclasses.field(default_factory=list)

    @property
    def rate_hz(self) -> float:
        return 1.0 / float(self.ts[1] - self.ts[0])


def compose_trial(seed: int, events: Sequence[FaultEvent], *,
                  duration_s: float = DURATION_S, rate_hz: float = 100.0,
                  confuser_prob: float = 0.3,
                  msg_bytes: Optional[int] = None,
                  scenario: str = "", host: int = 0,
                  host_model: Optional[HostSignalModel] = None,
                  ) -> ScenarioTrial:
    """Build one scenario trial from an explicit event list.

    Same machinery as ``make_trial``: same ambient model and injector, and
    the identical assembly tail (``finalize_trial_channels`` — device
    zero-order hold, workload model, step channel), so the two builders
    cannot drift.  With several events the host-channel effects add (each
    injector already writes additively) and the latency multipliers
    *multiply* — two concurrent contention sources compound the
    collective's slowdown.
    """
    rng = np.random.default_rng(seed)
    T = int(duration_s * rate_hz)
    ts = np.arange(T) / rate_hz

    hm = host_model or HostSignalModel(rate_hz=rate_hz)
    channels, data = hm.generate(rng, T)

    mult = np.ones(T, dtype=np.float64)
    for ev in events:
        dist = DISTURBANCES[ev.cls]
        mult *= apply_disturbance(rng, channels, data, dist, rate_hz,
                                  ev.t_on, ev.dur_s, ev.intensity)
    # innocent-bystander burst near the first event, as in make_trial
    if events and rng.uniform() < confuser_prob:
        present = {ev.cls for ev in events}
        others = [c for c in CLASS_ORDER if c not in present]
        if others:
            cls = others[int(rng.integers(0, len(others)))]
            inject_confuser(rng, channels, data, cls, rate_hz,
                            events[0].t_on,
                            scale=float(rng.uniform(0.6, 1.4)))

    channels, data, _ = finalize_trial_channels(rng, channels, data, mult,
                                                rate_hz, msg_bytes)
    truth = sorted(events, key=lambda e: e.t_on)
    return ScenarioTrial(ts=ts, data=data, channels=channels,
                         truth=list(truth), scenario=scenario, seed=seed,
                         host=host)


# ---------------------------------------------------------------------------
# event samplers, one per scenario class
# ---------------------------------------------------------------------------

def _strong(rng: np.random.Generator) -> float:
    """Clearly-injected intensity: the multi-fault classes measure *timeline*
    behaviour (overlap, recurrence), not marginal-event sensitivity — that
    spread stays with the ``single`` control."""
    return float(np.clip(rng.lognormal(0.35, 0.30), 0.9, 3.0))


def _paper_spread(rng: np.random.Generator) -> float:
    """make_trial's marginal-to-blatant per-trial intensity spread."""
    return float(np.clip(rng.lognormal(-0.1, 0.5), 0.33, 3.0))


def _distinct(rng: np.random.Generator, n: int) -> List[str]:
    picks = rng.choice(len(CLASS_ORDER), size=n, replace=False)
    return [CLASS_ORDER[int(i)] for i in picks]


def _sample_single(rng: np.random.Generator) -> List[FaultEvent]:
    cls = CLASS_ORDER[int(rng.integers(len(CLASS_ORDER)))]
    dist = DISTURBANCES[cls]
    return [FaultEvent(cls, float(rng.uniform(32.0, 56.0)),
                       float(rng.uniform(*dist.dur_s)), _paper_spread(rng))]


def _sample_overlap_pair(rng: np.random.Generator) -> List[FaultEvent]:
    c1, c2 = _distinct(rng, 2)
    t1 = float(rng.uniform(32.0, 42.0))
    e1 = FaultEvent(c1, t1, float(rng.uniform(14.0, 20.0)), _strong(rng))
    e2 = FaultEvent(c2, t1 + float(rng.uniform(3.0, 7.0)),
                    float(rng.uniform(12.0, 18.0)), _strong(rng))
    return [e1, e2]


def _sample_overlap_full(rng: np.random.Generator) -> List[FaultEvent]:
    c1, c2 = _distinct(rng, 2)
    t1 = float(rng.uniform(32.0, 42.0))
    dur = float(rng.uniform(12.0, 18.0))
    return [FaultEvent(c1, t1, dur, _strong(rng)),
            FaultEvent(c2, t1 + float(rng.uniform(-0.3, 0.3)),
                       dur * float(rng.uniform(0.9, 1.1)), _strong(rng))]


def _sample_cascade(rng: np.random.Generator) -> List[FaultEvent]:
    classes = _distinct(rng, 3)
    onsets = (float(rng.uniform(28.0, 34.0)), float(rng.uniform(58.0, 64.0)),
              float(rng.uniform(88.0, 94.0)))
    return [FaultEvent(c, t, float(rng.uniform(9.0, 14.0)), _strong(rng))
            for c, t in zip(classes, onsets)]


def _sample_flap(rng: np.random.Generator) -> List[FaultEvent]:
    cls = CLASS_ORDER[int(rng.integers(len(CLASS_ORDER)))]
    t = float(rng.uniform(28.0, 32.0))
    out = []
    for _ in range(3):
        out.append(FaultEvent(cls, t, float(rng.uniform(5.5, 8.5)),
                              _strong(rng)))
        # spacing > cooldown AND > baseline window + burst duration, so the
        # previous burst has left the trailing baseline by the time the
        # next one must clear 3 sigma (a contaminated baseline inflates
        # sigma and genuinely masks recurring same-class bursts)
        t += 27.0 + float(rng.uniform(0.0, 3.0))
    return out


def _sample_soak(rng: np.random.Generator) -> List[FaultEvent]:
    del rng
    return []


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    name: str
    sampler: Callable[[np.random.Generator], List[FaultEvent]]
    description: str
    multi_fault: bool = False
    #: innocent-bystander probability.  The single-fault control keeps
    #: ``make_trial``'s 0.6; multi-fault classes already carry intrinsic
    #: cross-fault confusion (the other event IS the bystander), so they
    #: add only a small extra rate.
    confuser_prob: float = 0.6


# ---------------------------------------------------------------------------
# chaos classes: telemetry corruption, composable with fault timelines
# ---------------------------------------------------------------------------

def _sample_chaos_overlap_fault(rng: np.random.Generator) -> List[FaultEvent]:
    """One strong fault, onset phase-pinned against the 5 s eval cadence.

    Onset in [30.6, 31.4] puts first detection at the 35 s boundary tick
    with 3.6-4.4 s detection latency — inside the 5 s target with margin
    left for the injector's ramp/lag AND the in-window NaN burst the
    chaos sampler adds (>= 175 valid hot samples must survive the
    persistence gate even with both eating into the window)."""
    cls = CLASS_ORDER[int(rng.integers(len(CLASS_ORDER)))]
    intensity = float(np.clip(rng.lognormal(0.5, 0.25), 1.2, 3.0))
    return [FaultEvent(cls, float(rng.uniform(30.6, 31.4)),
                       float(rng.uniform(12.0, 16.0)), intensity)]


def _chaos_soak_sampler(rng: np.random.Generator,
                        events: List[FaultEvent]) -> List[ChaosEvent]:
    del events
    return [
        ChaosEvent("nan", float(rng.uniform(30.0, 50.0)),
                   float(rng.uniform(2.0, 4.0)), channel=LATENCY_METRIC),
        ChaosEvent("freeze", float(rng.uniform(60.0, 75.0)),
                   float(rng.uniform(8.0, 12.0)), channel=LATENCY_METRIC,
                   magnitude=float(rng.uniform(0.5, 1.5))),
        ChaosEvent("drop", float(rng.uniform(90.0, 105.0)),
                   float(rng.uniform(1.0, 2.0))),
    ]


def _chaos_overlap_sampler(rng: np.random.Generator,
                           events: List[FaultEvent]) -> List[ChaosEvent]:
    t_on = events[0].t_on
    return [
        # ambient-value freeze in the pre-onset baseline: retroactive run
        # invalidation must drop it without starving the >= 32-valid gate
        ChaosEvent("freeze", float(rng.uniform(8.0, 16.0)),
                   float(rng.uniform(3.0, 5.0)), channel=LATENCY_METRIC),
        # NaN burst *inside* the detection window, short enough that the
        # fault's hot run still clears the persistence count
        ChaosEvent("nan", t_on + float(rng.uniform(0.2, 0.6)),
                   float(rng.uniform(0.3, 0.6)), channel=LATENCY_METRIC),
    ]


def _frozen_channel_sampler(rng: np.random.Generator,
                            events: List[FaultEvent]) -> List[ChaosEvent]:
    del events
    return [
        ChaosEvent("freeze", float(rng.uniform(40.0, 70.0)),
                   float(rng.uniform(15.0, 25.0)), channel=LATENCY_METRIC,
                   magnitude=float(rng.uniform(0.5, 1.5))),
        ChaosEvent("freeze", float(rng.uniform(40.0, 70.0)),
                   float(rng.uniform(10.0, 20.0)),
                   channel="cpu_util_other"),
    ]


def _crash_restart_sampler(rng: np.random.Generator,
                           events: List[FaultEvent]) -> List[ChaosEvent]:
    del events
    return [ChaosEvent("drop", float(rng.uniform(40.0, 80.0)),
                       float(rng.uniform(8.0, 14.0)))]


SCENARIOS: Dict[str, ScenarioSpec] = {
    s.name: s for s in (
        ScenarioSpec("single", _sample_single,
                     "one fault, paper-protocol control"),
        ScenarioSpec("overlap_pair", _sample_overlap_pair,
                     "two concurrent faults, partial overlap",
                     multi_fault=True, confuser_prob=0.15),
        ScenarioSpec("overlap_full", _sample_overlap_full,
                     "two different-class faults at the same instant",
                     multi_fault=True, confuser_prob=0.15),
        ScenarioSpec("cascade", _sample_cascade,
                     "three distinct faults in sequence", multi_fault=True,
                     confuser_prob=0.15),
        ScenarioSpec("flap", _sample_flap,
                     "one fault class recurring in short bursts",
                     multi_fault=True, confuser_prob=0.15),
        ScenarioSpec("soak", _sample_soak,
                     "no fault: false-verdict control"),
    )
}


@dataclasses.dataclass(frozen=True)
class ChaosScenarioSpec(ScenarioSpec):
    """A scenario class whose trials also carry telemetry corruption."""

    chaos_sampler: Optional[Callable[
        [np.random.Generator, List[FaultEvent]], List[ChaosEvent]]] = None


CHAOS_SCENARIOS: Dict[str, ChaosScenarioSpec] = {
    s.name: s for s in (
        ChaosScenarioSpec("chaos_soak", _sample_soak,
                          "no fault; NaN/freeze/drop telemetry corruption",
                          chaos_sampler=_chaos_soak_sampler),
        ChaosScenarioSpec("chaos_overlap", _sample_chaos_overlap_fault,
                          "one real fault under telemetry corruption",
                          confuser_prob=0.15,
                          chaos_sampler=_chaos_overlap_sampler),
        ChaosScenarioSpec("frozen_channel", _sample_soak,
                          "latency channel stuck at an elevated value",
                          chaos_sampler=_frozen_channel_sampler),
        ChaosScenarioSpec("crash_restart", _sample_soak,
                          "agent crash: all channels dark for a gap",
                          chaos_sampler=_crash_restart_sampler),
    )
}

# ---------------------------------------------------------------------------
# monitor-survivability classes: the diagnosis process itself fails
# ---------------------------------------------------------------------------

def _sample_crash_incident_fault(rng: np.random.Generator,
                                 ) -> List[FaultEvent]:
    """One strong fault, onset phase-pinned like ``chaos_overlap`` — the
    crash must land while the incident is in flight, and the detection
    boundary at 35 s keeps the latency arithmetic explicit."""
    cls = CLASS_ORDER[int(rng.integers(len(CLASS_ORDER)))]
    intensity = float(np.clip(rng.lognormal(0.5, 0.25), 1.2, 3.0))
    return [FaultEvent(cls, float(rng.uniform(30.6, 31.4)),
                       float(rng.uniform(12.0, 16.0)), intensity)]


def _crash_during_incident_sampler(rng: np.random.Generator,
                                   events: List[FaultEvent],
                                   ) -> List[MonitorEvent]:
    """Kill the monitor 1.5-3.5 s after fault onset — before the 35 s
    detection boundary, so the incident is mid-flight (often with a
    pending event) — with 4-8 s of downtime before the warm restore."""
    t_on = events[0].t_on
    return [MonitorEvent("monitor_crash",
                         t_on + float(rng.uniform(1.5, 3.5)),
                         dur_s=float(rng.uniform(4.0, 8.0)))]


@dataclasses.dataclass(frozen=True)
class MonitorScenarioSpec(ScenarioSpec):
    """A scenario class whose trials schedule monitor-process failures."""

    monitor_sampler: Optional[Callable[
        [np.random.Generator, List[FaultEvent]],
        List[MonitorEvent]]] = None


MONITOR_SCENARIOS: Dict[str, MonitorScenarioSpec] = {
    s.name: s for s in (
        MonitorScenarioSpec("crash_during_incident",
                            _sample_crash_incident_fault,
                            "monitor killed mid-incident, warm-restored "
                            "from checkpoint with ring replay",
                            confuser_prob=0.15,
                            monitor_sampler=_crash_during_incident_sampler),
    )
}

#: every scenario class: registry samplers first, the fleet class next,
#: chaos classes after, monitor-survivability classes LAST — append-only,
#: so every pre-existing class index (and therefore every committed
#: trial's protocol seed) stays byte-identical
SCENARIO_CLASSES: Tuple[str, ...] = (tuple(SCENARIOS) + ("fleet_nic",)
                                     + tuple(CHAOS_SCENARIOS)
                                     + tuple(MONITOR_SCENARIOS))


def scenario_spec(name: str) -> ScenarioSpec:
    """Spec lookup across the fault, fleet, chaos and monitor registries."""
    if name in SCENARIOS:
        return SCENARIOS[name]
    if name in CHAOS_SCENARIOS:
        return CHAOS_SCENARIOS[name]
    if name in MONITOR_SCENARIOS:
        return MONITOR_SCENARIOS[name]
    if name == "fleet_nic":
        return ScenarioSpec(
            "fleet_nic", _sample_soak,
            "correlated NIC burst across a fleet slab", confuser_prob=0.15)
    raise KeyError(f"unknown scenario class {name!r}")


def make_scenario(seed: int, name: str, *,
                  duration_s: float = DURATION_S, rate_hz: float = 100.0,
                  confuser_prob: Optional[float] = None, n_hosts: int = 6,
                  n_affected: int = 2) -> List[ScenarioTrial]:
    """One scenario instance: a list of trials (one per host).

    Registry classes return a single trial; ``fleet_nic`` returns
    ``n_hosts`` trials sharing the grid/channel layout, with the *same*
    NIC burst (identical timing and intensity) injected on ``n_affected``
    of them — the cross-host correlated incident a fleet monitor must
    attribute to every affected host at once.
    """
    if duration_s < MIN_DURATION_S:
        raise ValueError(
            f"scenario timelines need duration_s >= {MIN_DURATION_S}")
    if name == "fleet_nic":
        rng = np.random.default_rng(seed * 7919 + 13)
        burst = FaultEvent("nic", float(rng.uniform(32.0, 48.0)),
                           float(rng.uniform(10.0, 16.0)), _strong(rng))
        affected = {int(h) for h in
                    rng.choice(n_hosts, size=n_affected, replace=False)}
        cp = 0.15 if confuser_prob is None else confuser_prob
        trials = [compose_trial(seed * 131 + h,
                                [burst] if h in affected else [],
                                duration_s=duration_s, rate_hz=rate_hz,
                                confuser_prob=cp, scenario=name, host=h)
                  for h in range(n_hosts)]
        for t in trials:
            t.group = seed
        return trials
    spec = (SCENARIOS.get(name) or CHAOS_SCENARIOS.get(name)
            or MONITOR_SCENARIOS.get(name))
    if spec is None:
        raise KeyError(f"unknown scenario class {name!r}")
    rng = np.random.default_rng(seed * 7919 + 13)
    events = spec.sampler(rng)
    cp = spec.confuser_prob if confuser_prob is None else confuser_prob
    trial = compose_trial(seed, events, duration_s=duration_s,
                          rate_hz=rate_hz, confuser_prob=cp, scenario=name)
    chaos_sampler = getattr(spec, "chaos_sampler", None)
    if chaos_sampler is not None:
        # chaos gets its own stream: corruption layout never perturbs the
        # fault/ambient draw, so a chaos class stays comparable with its
        # fault-only counterpart at the same seed
        crng = np.random.default_rng(seed * 104729 + 7)
        chaos = chaos_sampler(crng, events)
        chaos_mod.apply_chaos(trial.data, trial.channels, rate_hz, chaos)
        trial.chaos = list(chaos)
    monitor_sampler = getattr(spec, "monitor_sampler", None)
    if monitor_sampler is not None:
        # monitor failures also get a dedicated stream, and they never
        # touch trial.data at all: the telemetry on disk is what the
        # hosts emitted whether or not anyone was watching
        mrng = np.random.default_rng(seed * 15485863 + 11)
        trial.monitor = list(monitor_sampler(mrng, events))
    return [trial]


def build_suite(n_per_class: int = 4, seed: int = 0, *,
                duration_s: float = DURATION_S, rate_hz: float = 100.0,
                classes: Sequence[str] = SCENARIO_CLASSES,
                n_hosts: int = 6, n_affected: int = 2,
                ) -> List[ScenarioTrial]:
    """``n_per_class`` instances of every scenario class, one flat list.

    Seeding goes through ``run_eval``'s own ``protocol_seed`` helper, so
    suites are reproducible per (seed, class index, instance) under the
    same formula as the eval.  All trials share one grid and channel
    layout — the suite stacks directly into a
    :class:`~repro.sim.scenario.TrialStore`.
    """
    out: List[ScenarioTrial] = []
    for ci, cls in enumerate(classes):
        for k in range(n_per_class):
            out.extend(make_scenario(protocol_seed(seed, ci, k), cls,
                                     duration_s=duration_s, rate_hz=rate_hz,
                                     n_hosts=n_hosts,
                                     n_affected=n_affected))
    return out
