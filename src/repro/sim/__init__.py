"""Controlled-injection evaluation substrate (paper §3).

The paper evaluates on a real 4xA100 node by injecting fio / cpu-pin / tc /
power-cap disturbances.  This container has neither GPUs nor a disposable
NIC, so injection happens one layer down: a calibrated host-signal model
generates the same telemetry channels with the same cross-layer couplings,
and the *estimators* (our engine + baselines B1-B3) are identical to what
would run against real probes.  Ground truth is exact by construction.
"""
from repro.sim.workload import AllReduceWorkload, MESSAGE_SIZES
from repro.sim.hostmodel import HostSignalModel, ChannelModel
from repro.sim.disturbances import (
    Disturbance, DISTURBANCES, make_disturbance, apply_disturbance,
)
from repro.sim.scenario import (
    Trial, TrialStore, make_trial, run_eval, EvalRecord,
    N_PER_CLASS, PROTOCOL_CLASSES,
)
from repro.sim.scenarios import (
    FaultEvent, ScenarioTrial, SCENARIO_CLASSES, SCENARIOS,
    build_suite, compose_trial, make_scenario,
)
from repro.sim.scoring import (
    VerdictEvent, match_events, score_trial, summarize, verdict_events,
)

__all__ = [
    "AllReduceWorkload", "MESSAGE_SIZES",
    "HostSignalModel", "ChannelModel",
    "Disturbance", "DISTURBANCES", "make_disturbance", "apply_disturbance",
    "Trial", "TrialStore", "make_trial", "run_eval", "EvalRecord",
    "N_PER_CLASS", "PROTOCOL_CLASSES",
    "FaultEvent", "ScenarioTrial", "SCENARIO_CLASSES", "SCENARIOS",
    "build_suite", "compose_trial", "make_scenario",
    "VerdictEvent", "match_events", "score_trial", "summarize",
    "verdict_events",
]
