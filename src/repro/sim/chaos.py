"""Chaos injection: telemetry-fault timelines for robustness hardening.

The eval's D1-D4 disturbances corrupt the *host* (contention the monitor
must diagnose); chaos events corrupt the *telemetry itself* (faults the
monitor must survive without lying).  The paper's premise — host-side
telemetry as diagnostic ground — holds only if a broken probe cannot
masquerade as a broken host, so this module injects the probe failures a
fleet actually sees and the rest of the stack is hardened against:

  ``nan`` / ``inf``     burst of non-finite readings on one channel
  ``freeze``            stuck-at channel: one value repeats for the span
                        (optionally elevated — the nastiest case, a frozen
                        spike that *looks* persistent)
  ``drop``              dropped ticks: every channel unreadable (NaN) for
                        the span — also models an agent crash/restart gap
  ``counter_reset``     cumulative counter restarts from zero mid-run
                        (negative delta at the seam)
  ``clock_jump``        sampling clock steps forward/backward mid-run
  ``exception``         collector raises instead of returning a sample
  ``slow``              collector blocks past the sampling deadline

The first four corrupt telemetry *values* and apply directly to a trial
matrix (:func:`apply_chaos`) — composable with any D1-D4 fault timeline.
The last four are *behavioral* and only make sense at the collector/agent
boundary: :class:`ChaosCollector` wraps any :class:`Collector` and acts
them out, and :func:`apply_clock_jumps` warps a timestamp grid for the
rate-conversion guards.  Everything is seeded through the caller's
``numpy`` generator — a chaos timeline is exactly reproducible from
``(seed, scenario)`` like every fault timeline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.telemetry.collectors import Collector

#: chaos kinds that rewrite trial-matrix values (handled by apply_chaos)
VALUE_KINDS = ("nan", "inf", "freeze", "drop")
#: chaos kinds acted out at the collector/agent boundary
BEHAVIOR_KINDS = ("counter_reset", "clock_jump", "exception", "slow")
CHAOS_KINDS = VALUE_KINDS + BEHAVIOR_KINDS


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One telemetry fault on a chaos timeline (exact ground truth).

    ``channel`` None targets every channel (mandatory for ``drop``);
    ``magnitude`` is kind-specific: freeze elevation factor (value held at
    ``x * (1 + magnitude)``), inf sign (negative -> -inf), clock-jump
    seconds (negative -> backward), slow-collector stall seconds.
    """

    kind: str
    t_on: float
    dur_s: float
    channel: Optional[str] = None
    magnitude: float = 0.0

    def __post_init__(self):
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"unknown chaos kind {self.kind!r}")

    @property
    def t_off(self) -> float:
        return self.t_on + self.dur_s

    def active(self, t: float) -> bool:
        return self.t_on <= t < self.t_off


@dataclasses.dataclass(frozen=True)
class ChaosPolicy:
    """A composable, immutable set of chaos events.

    ``compose`` merges two policies (time-sorted), so scenario builders
    can layer e.g. a freeze policy over a drop policy the same way fault
    timelines compose out of FaultEvents.
    """

    events: Tuple[ChaosEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(
            sorted(self.events, key=lambda e: e.t_on)))

    def compose(self, other: "ChaosPolicy") -> "ChaosPolicy":
        return ChaosPolicy(self.events + other.events)

    def active(self, t: float,
               kinds: Optional[Sequence[str]] = None) -> List[ChaosEvent]:
        return [e for e in self.events if e.active(t)
                and (kinds is None or e.kind in kinds)]

    def overlaps(self, t0: float, t1: float) -> bool:
        return any(e.t_on < t1 and t0 < e.t_off for e in self.events)


def _span(ts_or_rate, T: int, ev: ChaosEvent) -> Tuple[int, int]:
    rate = float(ts_or_rate)
    i0 = max(0, int(round(ev.t_on * rate)))
    i1 = min(T, int(round(ev.t_off * rate)))
    return i0, i1


def apply_chaos(data: np.ndarray, channels: Sequence[str], rate_hz: float,
                events: Sequence[ChaosEvent]) -> np.ndarray:
    """Corrupt a (C, T) trial matrix in place with every value-kind event.

    Behavioral kinds are ignored here (they have no matrix encoding).
    Returns the (C, T) bool mask of corrupted cells — the ground truth a
    test can hand to the masked detection paths, and exactly the cells
    ``sanitize.validity_mask`` must refuse (nan/inf/drop) or retroactively
    invalidate (freeze runs).
    """
    C, T = data.shape
    index = {c: i for i, c in enumerate(channels)}
    hit = np.zeros((C, T), bool)
    for ev in events:
        if ev.kind not in VALUE_KINDS:
            continue
        i0, i1 = _span(rate_hz, T, ev)
        if i1 <= i0:
            continue
        rows = (range(C) if ev.channel is None or ev.kind == "drop"
                else [index[ev.channel]])
        for ci in rows:
            if ev.kind == "nan" or ev.kind == "drop":
                data[ci, i0:i1] = np.nan
            elif ev.kind == "inf":
                data[ci, i0:i1] = -np.inf if ev.magnitude < 0 else np.inf
            else:  # freeze: stuck at (optionally elevated) first value
                data[ci, i0:i1] = data[ci, i0] * (1.0 + ev.magnitude)
            hit[ci, i0:i1] = True
    return hit


def apply_clock_jumps(ts: np.ndarray,
                      events: Sequence[ChaosEvent]) -> np.ndarray:
    """Warp a timestamp grid with every ``clock_jump`` event.

    Samples at or after ``t_on`` shift by ``magnitude`` seconds (negative
    = backward step, producing the non-monotonic dt <= 0 sequences the
    rate-conversion guards must survive).  Returns a new array.
    """
    out = np.asarray(ts, np.float64).copy()
    for ev in events:
        if ev.kind != "clock_jump":
            continue
        out[np.asarray(ts) >= ev.t_on] += ev.magnitude
    return out


class ChaosCollector(Collector):
    """Wrap any collector and act out a chaos policy at its boundary.

    Value kinds corrupt the inner sample's readings (named channel, or
    all); ``exception`` raises instead of returning (exercising the
    agent's crash isolation + backoff), ``slow`` stalls past the sampling
    deadline (exercising the watchdog), ``counter_reset`` re-bases the
    named channel to zero at ``t_on`` so the agent sees a negative delta.
    ``sample_block`` refuses any grid a chaos event overlaps — the agent
    falls back to the per-tick path, where chaos actually applies.
    """

    def __init__(self, inner: Collector, policy: ChaosPolicy):
        self.inner = inner
        self.policy = policy
        self.metrics = inner.metrics
        self._frozen: Dict[Tuple[int, Optional[str]], float] = {}
        self._reset_base: Dict[str, float] = {}
        #: chaos bookkeeping (ground truth for tests)
        self.exceptions_raised = 0
        self.stalls = 0

    def sample(self, now: float) -> Dict[str, float]:
        active = self.policy.active(now)
        for ev in active:
            if ev.kind == "exception":
                self.exceptions_raised += 1
                raise RuntimeError(
                    f"chaos: collector exception at t={now:.3f}")
        for ev in active:
            if ev.kind == "slow":
                self.stalls += 1
                time.sleep(max(float(ev.magnitude), 0.0))
        out = self.inner.sample(now)
        for ev in active:
            targets = (list(out) if ev.channel is None
                       else ([ev.channel] if ev.channel in out else []))
            if ev.kind == "nan" or ev.kind == "drop":
                for c in targets:
                    out[c] = float("nan")
            elif ev.kind == "inf":
                v = float("-inf") if ev.magnitude < 0 else float("inf")
                for c in targets:
                    out[c] = v
            elif ev.kind == "freeze":
                key = (id(ev), ev.channel)
                for c in targets:
                    k = (id(ev), c)
                    if k not in self._frozen:
                        self._frozen[k] = out[c] * (1.0 + ev.magnitude)
                    out[c] = self._frozen[k]
                del key
        # counter resets persist past the event window: a restarted
        # counter stays re-based, it does not un-reset at t_off
        for ev in self.policy.events:
            if ev.kind != "counter_reset" or now < ev.t_on:
                continue
            for c in ([ev.channel] if ev.channel else list(out)):
                if c not in out:
                    continue
                if c not in self._reset_base:
                    self._reset_base[c] = out[c]
                out[c] = out[c] - self._reset_base[c]
        return out

    def sample_block(self, grid: np.ndarray,
                     ) -> Optional[Dict[str, np.ndarray]]:
        g = np.asarray(grid, np.float64)
        if g.size and self.policy.overlaps(float(g[0]), float(g[-1])):
            return None
        if g.size and any(e.kind == "counter_reset" and float(g[-1]) >= e.t_on
                          for e in self.policy.events):
            return None
        return self.inner.sample_block(grid)

    def close(self) -> None:
        self.inner.close()
