"""Trial construction and the 68-trial evaluation harness (paper §3-4).

``make_trial`` builds one injected-disturbance trial: ambient host signals,
the W1 all-reduce latency series, and exact ground truth.  ``run_eval``
replays the paper's protocol — 17 trials per disturbance class — through any
set of diagnosers and aggregates accuracy / confusion / Time-to-RCA.

``TrialStore`` is the columnar counterpart of the trial list: the whole
eval laid out as ONE contiguous f32 (trials, C, T) slab, so the
event-batched Layer 3 gathers every event's evidence by slab indexing (a
constant number of fancy-index ops) instead of re-slicing each trial's
numpy matrix per event.  ``run_eval(batch_events=True)`` feeds it to every
store-capable diagnoser.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.baselines import Diagnoser, DiagnoserResult, make_baseline
from repro.core.taxonomy import CauseClass
from repro.sim.disturbances import (
    CLASS_ORDER, DISTURBANCES, Disturbance, apply_disturbance,
    inject_confuser,
)
from repro.sim.hostmodel import HostSignalModel
from repro.sim.workload import MESSAGE_SIZES, AllReduceWorkload

LATENCY_CH = "coll_allreduce_ms"
STEP_CH = "step_latency_ms"

#: the paper's §3 protocol: 17 trials per disturbance class, replayed in
#: CLASS_ORDER (68 trials total).  THE definition — ``run_eval`` and every
#: benchmark that reconstructs protocol trials import it from here, so the
#: scenario suite and the eval cannot drift apart.
N_PER_CLASS = 17
PROTOCOL_CLASSES: Sequence[str] = CLASS_ORDER


def protocol_seed(seed: int, class_index: int, k: int) -> int:
    """Per-trial seed of the eval protocol — one definition, used by
    ``run_eval`` and the scenario suite so instance (seed, ci, k) is
    reproducible across both."""
    return seed * 100003 + class_index * 1009 + k


def finalize_trial_channels(rng: np.random.Generator, channels: List[str],
                            data: np.ndarray, mult: np.ndarray,
                            rate_hz: float,
                            msg_bytes: Optional[int] = None,
                            ) -> Tuple[List[str], np.ndarray, int]:
    """Shared trial-assembly tail for every trial builder.

    Device channels dropped to the 10 Hz NVML cadence (zero-order hold),
    the W1 all-reduce latency series under the disturbance multiplier, the
    end-to-end step channel, and the final (C, T) stack.  ``make_trial``
    and the scenario composer both finish through here, so this half of
    trial construction cannot drift between the paper protocol and the
    scenario DSL.  (Same-seed outputs of the two builders still differ:
    their rng streams diverge earlier — make_trial draws t_on/dur/
    intensity from the trial rng, the composer takes explicit events.)
    Returns ``(channels, data, msg_bytes)``.
    """
    T = data.shape[1]
    for i, name in enumerate(channels):
        if name.startswith("dev_"):
            k = int(rate_hz // 10)
            data[i] = np.repeat(data[i][::k], k)[: data.shape[1]]
    msg = int(msg_bytes if msg_bytes is not None
              else MESSAGE_SIZES[rng.integers(8, len(MESSAGE_SIZES))])
    wl = AllReduceWorkload(msg_bytes=msg)
    L = wl.latency_series(rng, T, multiplier=mult)
    # end-to-end step latency = collective + compute segment w/ its own noise
    compute_ms = 18.0 * (1.0 + 0.03 * rng.standard_normal(T))
    step = L + np.maximum(compute_ms, 0.0)
    channels = channels + [LATENCY_CH, STEP_CH]
    data = np.vstack([data, L[None, :], step[None, :]]).astype(np.float64)
    return channels, data, msg


@dataclasses.dataclass
class Trial:
    ts: np.ndarray                  # (T,) seconds, uniform grid
    data: np.ndarray                # (C, T)
    channels: List[str]
    truth: CauseClass
    t_on: float                     # injection time
    dur_s: float
    intensity: float
    msg_bytes: int

    @property
    def rate_hz(self) -> float:
        return 1.0 / float(self.ts[1] - self.ts[0])


def make_trial(seed: int, disturbance: str, *, duration_s: float = 90.0,
               rate_hz: float = 100.0, t_on: Optional[float] = None,
               intensity: Optional[float] = None,
               msg_bytes: Optional[int] = None,
               confuser_prob: float = 0.6,
               host_model: Optional[HostSignalModel] = None) -> Trial:
    rng = np.random.default_rng(seed)
    dist: Disturbance = DISTURBANCES[disturbance]
    T = int(duration_s * rate_hz)
    ts = np.arange(T) / rate_hz

    hm = host_model or HostSignalModel(rate_hz=rate_hz)
    channels, data = hm.generate(rng, T)

    if t_on is None:
        t_on = float(rng.uniform(32.0, 48.0))
    dur = float(rng.uniform(*dist.dur_s))
    if intensity is None:
        intensity = float(np.clip(rng.lognormal(-0.1, 0.5), 0.33, 3.0))
    mult = apply_disturbance(rng, channels, data, dist, rate_hz,
                             t_on, dur, intensity)
    # temporally coincident innocent-bystander activity in other subsystems
    if rng.uniform() < confuser_prob:
        others = [c for c in CLASS_ORDER if c != disturbance]
        cls = others[int(rng.integers(0, len(others)))]
        inject_confuser(rng, channels, data, cls, rate_hz, t_on,
                        scale=float(rng.uniform(0.6, 1.4)))

    channels, data, msg = finalize_trial_channels(rng, channels, data, mult,
                                                  rate_hz, msg_bytes)
    return Trial(ts=ts, data=data, channels=channels, truth=dist.kind,
                 t_on=t_on, dur_s=dur, intensity=intensity, msg_bytes=msg)


# ---------------------------------------------------------------------------
# columnar trial store
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TrialStore:
    """An entire eval's trials as ONE contiguous f32 (trials, C, T) slab.

    All trials of the protocol share the sampling grid and channel layout,
    so stacking them columnar lets the event-batched Layer 3
    (:meth:`CorrelationEngine.diagnose_events_slab`) gather every event's
    evidence by slab indexing — a constant number of fancy-index ops —
    instead of one python-level numpy reslice per event.  ``slab[i]`` is a
    zero-copy (C, T) row view for the per-trial detection sweep.
    """

    ts: np.ndarray                  # (T,) shared uniform grid
    slab: np.ndarray                # (trials, C, T) f32, C-contiguous
    channels: List[str]

    def __len__(self) -> int:
        return self.slab.shape[0]

    @classmethod
    def from_trials(cls, trials: Sequence[Trial]) -> "TrialStore":
        t0 = trials[0]
        for t in trials[1:]:
            if t.channels != t0.channels or t.ts.shape != t0.ts.shape:
                raise ValueError("trials disagree on channel/grid layout")
        slab = np.empty((len(trials), t0.data.shape[0], t0.ts.shape[0]),
                        np.float32)
        for i, t in enumerate(trials):
            slab[i] = t.data
        return cls(ts=t0.ts, slab=slab, channels=list(t0.channels))

    def rows(self) -> List[Tuple[np.ndarray, np.ndarray, List[str]]]:
        """Per-trial (ts, data, channels) views — the legacy interface."""
        return [(self.ts, self.slab[i], self.channels)
                for i in range(len(self))]


# ---------------------------------------------------------------------------
# evaluation protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class EvalRecord:
    trial_seed: int
    truth: CauseClass
    t_on: float
    intensity: float
    diagnoser: str
    pred: CauseClass
    time_to_rca: Optional[float]    # vs true injection time
    wall_seconds: float


def run_eval(diagnosers: Sequence[Diagnoser], n_per_class: int = N_PER_CLASS,
             seed: int = 0, duration_s: float = 90.0,
             rate_hz: float = 100.0,
             classes: Sequence[str] = PROTOCOL_CLASSES,
             batch_events: bool = True) -> List[EvalRecord]:
    """Replay the paper's protocol through every diagnoser.

    ``batch_events=True`` (default) hands each *engine-backed* diagnoser
    all trials at once: Layer-2 detection still sweeps trial by trial, but
    every trial's pending event is stacked as a row into ONE fused Layer-3
    dispatch — the 68-trial eval runs Layer 3 once per diagnoser instead
    of 68 times.  Store-capable diagnosers (``diagnose_store`` override)
    additionally consume the whole eval as a columnar :class:`TrialStore`
    — one contiguous f32 (trials, C, T) slab whose evidence gather is slab
    indexing, not per-event python reslicing.  ``False`` replays the
    per-trial sequential path (the parity oracle).  Per-record
    ``wall_seconds`` is amortized (batch wall / n_trials) in batched mode.
    """
    trial_seeds: List[int] = []
    trials: List[Trial] = []
    for ci, cls in enumerate(classes):
        for k in range(n_per_class):
            trial_seed = protocol_seed(seed, ci, k)
            trial_seeds.append(trial_seed)
            trials.append(make_trial(trial_seed, cls, duration_s=duration_s,
                                     rate_hz=rate_hz))
    records: List[EvalRecord] = []
    store: Optional[TrialStore] = None
    for dg in diagnosers:
        batched = (batch_events and
                   type(dg).diagnose_trials is not Diagnoser.diagnose_trials)
        store_capable = (batch_events and
                         type(dg).diagnose_store is not Diagnoser.diagnose_store)
        if store_capable:
            if store is None:       # built once, shared by all diagnosers
                store = TrialStore.from_trials(trials)
            w0 = time.perf_counter()
            results = dg.diagnose_store(store)
            per = (time.perf_counter() - w0) / max(len(trials), 1)
            walls = [per] * len(trials)
        elif batched:
            # no per-trial defensive copies here: the batched diagnosers
            # never mutate trial data (B3 eventizes on an internal copy),
            # and duplicating every trial would double the eval's peak
            # memory (all trials are held at once for the event stacking)
            w0 = time.perf_counter()
            results = dg.diagnose_trials(
                [(t.ts, t.data, t.channels) for t in trials])
            per = (time.perf_counter() - w0) / max(len(trials), 1)
            walls = [per] * len(trials)
        else:
            results, walls = [], []
            for trial in trials:
                w0 = time.perf_counter()
                results.append(dg.diagnose_trial(
                    trial.ts, trial.data.copy(), trial.channels))
                walls.append(time.perf_counter() - w0)
        for trial, trial_seed, res, wall in zip(trials, trial_seeds,
                                                results, walls):
            ttr = (res.t_rca - trial.t_on) if res.t_rca is not None else None
            records.append(EvalRecord(
                trial_seed=trial_seed, truth=trial.truth, t_on=trial.t_on,
                intensity=trial.intensity, diagnoser=dg.name,
                pred=res.pred, time_to_rca=ttr, wall_seconds=wall))
    return records


# ---------------------------------------------------------------------------
# aggregation (Tables 2/3/4)
# ---------------------------------------------------------------------------

def accuracy_by_class(records: Sequence[EvalRecord], diagnoser: str,
                      ) -> Dict[CauseClass, float]:
    out: Dict[CauseClass, float] = {}
    for cls in (CauseClass.IO, CauseClass.CPU, CauseClass.NIC, CauseClass.GPU):
        rs = [r for r in records if r.diagnoser == diagnoser and r.truth == cls]
        if rs:
            out[cls] = sum(r.pred == r.truth for r in rs) / len(rs)
    return out


def mean_accuracy(records: Sequence[EvalRecord], diagnoser: str) -> float:
    acc = accuracy_by_class(records, diagnoser)
    return float(np.mean(list(acc.values()))) if acc else 0.0


def confusion_matrix(records: Sequence[EvalRecord], diagnoser: str,
                     ) -> Tuple[List[CauseClass], np.ndarray]:
    classes = [CauseClass.IO, CauseClass.CPU, CauseClass.NIC, CauseClass.GPU]
    cm = np.zeros((4, 5))
    cols = classes + [CauseClass.UNKNOWN]
    for r in records:
        if r.diagnoser != diagnoser:
            continue
        i = classes.index(r.truth)
        j = cols.index(r.pred) if r.pred in cols else 4
        cm[i, j] += 1
    row = cm.sum(axis=1, keepdims=True)
    row[row == 0] = 1
    return classes, cm / row


def rca_time_by_class(records: Sequence[EvalRecord], diagnoser: str,
                      correct_only: bool = True) -> Dict[CauseClass, float]:
    out: Dict[CauseClass, float] = {}
    for cls in (CauseClass.IO, CauseClass.CPU, CauseClass.NIC, CauseClass.GPU):
        vals = [r.time_to_rca for r in records
                if r.diagnoser == diagnoser and r.truth == cls
                and r.time_to_rca is not None
                and (not correct_only or r.pred == r.truth)]
        if vals:
            out[cls] = float(np.mean(vals))
    return out
