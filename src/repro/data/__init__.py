"""Data pipeline."""
from repro.data.pipeline import SyntheticLMPipeline, PipelineConfig

__all__ = ["SyntheticLMPipeline", "PipelineConfig"]
