"""Deterministic, shardable synthetic LM data pipeline with prefetch.

Production posture without a dataset dependency: batches are generated from
a counter-keyed PRNG (so any host can regenerate any step's shard — exactly
the property a multi-host input pipeline needs for restart), staged through
a background prefetch thread, and sharded along the batch dim.

The token stream is a mixture of Zipf-distributed unigrams and short
repeated motifs, giving a learnable (loss-decreasing) signal for the
end-to-end training example rather than uniform noise.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_len: int = 16
    motif_prob: float = 0.5
    prefetch: int = 2
    frames_dim: int = 0      # encdec: emit frame embeddings of this width
    img_tokens: int = 0      # vlm: emit stub patch embeddings
    img_dim: int = 0


class SyntheticLMPipeline:
    def __init__(self, cfg: PipelineConfig):
        self.cfg = cfg
        # precompute a zipf-ish unigram table once (vocab-sized)
        v = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = v ** (-cfg.zipf_a)
        self._probs = p / p.sum()
        self._q: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ----------------------------------------------------------- generation
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Regenerable batch for a given global step (restart-stable)."""
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed << 20) ^ step)
        B, S = cfg.batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(B, S + 1), p=self._probs)
        # inject repeated motifs: predictable structure => learnable
        n_motifs = int(cfg.motif_prob * B)
        for i in range(n_motifs):
            m = rng.choice(cfg.vocab, size=cfg.motif_len, p=self._probs)
            reps = (S + 1) // cfg.motif_len + 1
            row = np.tile(m, reps)[: S + 1]
            toks[i] = row
        batch = {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((B, S), np.float32),
        }
        if cfg.frames_dim:
            batch["frames"] = (rng.standard_normal(
                (B, S, cfg.frames_dim)) * 0.02).astype(np.float32)
        if cfg.img_tokens:
            batch["img"] = (rng.standard_normal(
                (B, cfg.img_tokens, cfg.img_dim)) * 0.02).astype(np.float32)
        return batch

    # -------------------------------------------------------------- prefetch
    def start(self, start_step: int = 0) -> None:
        self._q = queue.Queue(maxsize=self.cfg.prefetch)
        self._stop.clear()

        def worker():
            step = start_step
            while not self._stop.is_set():
                b = self.batch_at(step)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, b), timeout=0.2)
                        break
                    except queue.Full:
                        continue
                step += 1

        self._thread = threading.Thread(target=worker, daemon=True,
                                        name="data-prefetch")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        assert self._q is not None, "call start() first"
        while True:
            step, b = self._q.get()
            yield b
