"""Checkpointing + failure handling."""
from repro.checkpoint.checkpointer import Checkpointer
from repro.checkpoint.fault import FailureInjector, resume_or_init

__all__ = ["Checkpointer", "FailureInjector", "resume_or_init"]
