"""Atomic, retention-managed checkpointing for pytree train states.

Layout: ``<dir>/step_<n>/`` holding one ``.npy`` per leaf (keyed by the
flattened tree path) plus ``manifest.json`` (treedef + dtypes + step).
Writes go to ``step_<n>.tmp`` and are renamed only after fsync — a killed
process can never leave a half-written checkpoint that ``latest_step``
would pick up (restart safety is tested by killing a training run
mid-write).

Multi-host posture: each host writes only the leaves it owns (the
process-local shards); here (single process) that is the whole tree.  The
read path reassembles from the manifest, so adding hosts changes the
writer, not the format.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------ save
    def save(self, step: int, state: Any) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        leaves = _flatten_with_paths(state)
        manifest = {"step": int(step), "leaves": []}
        for key, leaf in leaves:
            arr = np.asarray(leaf)
            orig_dtype = str(arr.dtype)
            if arr.dtype not in (np.float64, np.float32, np.float16,
                                 np.int64, np.int32, np.int16, np.int8,
                                 np.uint8, np.bool_):
                # ml_dtypes (bfloat16, fp8) do not round-trip through
                # np.save/np.load — store widened, restore re-narrows
                arr = arr.astype(np.float32)
            fname = key.replace("/", "__") + ".npy"
            np.save(tmp / fname, arr)
            manifest["leaves"].append(
                {"key": key, "file": fname, "dtype": orig_dtype,
                 "shape": list(arr.shape)})
        mpath = tmp / "manifest.json"
        mpath.write_text(json.dumps(manifest))
        # fsync the manifest then atomically publish the directory
        with open(mpath, "r") as f:
            os.fsync(f.fileno())
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "manifest.json").exists():
                continue
            try:
                steps.append(int(p.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
        return max(steps) if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Any:
        """Restore into the structure (and dtypes) of ``like``."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        by_key = {e["key"]: e for e in manifest["leaves"]}
        flat = _flatten_with_paths(like)
        treedef = jax.tree_util.tree_structure(like)
        new_leaves = []
        for key, leaf in flat:
            e = by_key.get(key)
            if e is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(d / e["file"])
            new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype)
                              if hasattr(leaf, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, new_leaves)

    # -------------------------------------------------------------------- gc
    def _gc(self) -> None:
        steps = sorted(p for p in self.dir.glob("step_*")
                       if p.suffix != ".tmp")
        while len(steps) > self.keep:
            shutil.rmtree(steps.pop(0))
