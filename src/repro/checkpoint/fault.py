"""Failure injection + restart logic (fault-tolerance drill machinery)."""
from __future__ import annotations

import os
from typing import Any, Callable, Optional, Tuple

from repro.checkpoint.checkpointer import Checkpointer


class FailureInjector:
    """Deterministically injects a simulated process death at a given step.

    Raising ``SystemExit``-like failure mid-training (after the step, before
    or during the checkpoint write, per ``phase``) exercises the restart
    path the way a preempted TPU host would.
    """

    def __init__(self, fail_at_step: Optional[int] = None,
                 phase: str = "after_step"):
        assert phase in ("after_step", "mid_checkpoint")
        self.fail_at_step = fail_at_step
        self.phase = phase
        self.fired = False

    def maybe_fail(self, step: int, phase: str) -> None:
        if (self.fail_at_step is not None and step == self.fail_at_step
                and phase == self.phase and not self.fired):
            self.fired = True
            raise RuntimeError(
                f"[injected] simulated host failure at step {step} ({phase})")


def resume_or_init(ckpt: Checkpointer, init_fn: Callable[[], Any],
                   ) -> Tuple[Any, int]:
    """Restore the latest checkpoint if one exists, else initialize.

    Returns (state, start_step).  The training loop calls this on every
    (re)start — the whole restart story is: run the same command again.
    """
    latest = ckpt.latest_step()
    state = init_fn()
    if latest is None:
        return state, 0
    restored = ckpt.restore(state, latest)
    return restored, latest + 1
