"""Batched serving engine: prefill -> greedy/temperature decode loop.

Two jit programs (the standard split): ``prefill`` is compute-bound over
the prompt, ``decode_step`` is memory-bound per token with a donated cache.
Telemetry hooks stamp per-token latency into the device channel, so the
paper's engine monitors serving exactly like training.

Archs without a fused prefill (pure-SSM / hybrid) prefill by stepping the
decode function over prompt tokens — correct, if slower; EXPERIMENTS.md
notes it as the fallback path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model
from repro.monitor.hooks import StepTelemetry


@dataclasses.dataclass
class GenerateResult:
    tokens: np.ndarray              # (B, n_new)
    prefill_ms: float
    per_token_ms: List[float]


class ServeEngine:
    def __init__(self, model: Model, params, max_len: int = 2048,
                 telemetry: Optional[StepTelemetry] = None):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.tele = telemetry
        self._decode = jax.jit(model.decode, donate_argnums=(2,))
        self._prefill = (jax.jit(lambda p, b: model.prefill(p, b, max_len))
                         if model.prefill is not None else None)

    def _prefill_by_stepping(self, prompts: jax.Array):
        B, S = prompts.shape
        cache = self.model.init_cache(B, self.max_len)
        logits = None
        for i in range(S):
            logits, cache = self._decode(self.params, prompts[:, i:i + 1],
                                         cache)
        return logits, cache

    def generate(self, prompts: np.ndarray, n_new: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 extra_batch: Optional[Dict[str, jax.Array]] = None,
                 ) -> GenerateResult:
        """prompts: (B, S) int32 -> greedy (or sampled) continuation."""
        prompts = jnp.asarray(prompts, jnp.int32)
        B = prompts.shape[0]
        t0 = time.perf_counter()
        if self._prefill is not None:
            batch = {"tokens": prompts}
            if extra_batch:
                batch.update(extra_batch)
            logits, cache = self._prefill(self.params, batch)
        else:
            logits, cache = self._prefill_by_stepping(prompts)
        prefill_ms = (time.perf_counter() - t0) * 1e3

        rng = jax.random.key(seed)
        out: List[np.ndarray] = []
        per_token: List[float] = []
        last = logits[:, -1, : self.model.cfg.vocab]
        for i in range(n_new):
            if temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(sub, last / temperature, axis=-1)
            else:
                tok = jnp.argmax(last, axis=-1)
            tok = tok.astype(jnp.int32).reshape(B, 1)
            out.append(np.asarray(tok))
            t1 = time.perf_counter()
            if self.tele:
                self.tele.step_begin()
            logits, cache = self._decode(self.params, tok, cache)
            logits.block_until_ready()
            ms = (time.perf_counter() - t1) * 1e3
            if self.tele:
                self.tele.step_end()
            per_token.append(ms)
            last = logits[:, -1, : self.model.cfg.vocab]
        return GenerateResult(tokens=np.concatenate(out, axis=1),
                              prefill_ms=prefill_ms,
                              per_token_ms=per_token)
