"""Serving stack: batched generation over prefill/decode."""
from repro.serve.engine import ServeEngine, GenerateResult

__all__ = ["ServeEngine", "GenerateResult"]
