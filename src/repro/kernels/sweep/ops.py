"""Jit'd public wrapper for the batched Layer-2 sweep.

This is the suite-scale eval hot path: ONE dispatch over the f32
(rows, T) latency slab yields per-tick ``(fire, score, onset)`` decisions
for every row — the per-trial loop ran ``spike.detect_sweep`` row by row
with per-row f64 conversion and a fully materialized (#ticks, wn)
z-matrix.

Exactness contract.  The f32 sweep is built to agree with the f64 per-row
oracle *decision for decision*:

  * rolling baseline moments are computed here, host-side, in exact f64
    with the same prefix-sum pass as the oracle
    (:func:`rolling_moments` — ``spike.sliding_baseline_stats`` per row
    tile, bitwise-identical) and only then downcast to f32 for the
    kernel's z,
  * the persistence gate compares an integer sample count
    (:func:`persistence_count`, decided once in exact f64),
  * every tick whose window holds a z within ``SWEEP_GUARD_EPS`` of the
    threshold — the only ticks f32 rounding could flip — is flagged
    ``marginal``; callers (``CorrelationEngine.detect_events_store``)
    re-decide exactly those ticks through the f64 oracle.

Typical slabs flag well under a few percent of ticks, so the guard costs
~nothing while making the slab path byte-exact by construction instead of
byte-exact by luck.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spike as spike_mod
from repro.kernels import tuning
from repro.kernels.sweep.ref import sweep_rows_ref
from repro.kernels.sweep.sweep import sweep_rows_pallas

#: f32-vs-f64 decision guard band on |z - threshold| (see module docstring).
#: Generous: the observed f32 error with exact-f64 moments is ~1e-5 on the
#: hottest mean/sigma ratios, so 5e-3 leaves two orders of margin and still
#: flags only the rare genuinely-marginal tick.
SWEEP_GUARD_EPS = 5e-3


def persistence_count(n: int, persistence: float) -> int:
    """Smallest integer c with ``c / n >= persistence`` in f64.

    The scalar rule (:func:`repro.core.spike.detect`) gates on
    ``hot.mean() >= persistence`` computed in f64; comparing an f32
    fraction against the f64 threshold can flip exactly at the boundary
    count, so the kernels gate on the integer count instead — decided
    here, once, in exact f64.
    """
    n = int(n)
    if n <= 0 or persistence <= 0.0:
        return 0
    c = min(int(np.ceil(persistence * n)), n)
    while c > 0 and (c - 1) / n >= persistence:
        c -= 1
    while c <= n and c / n < persistence:
        c += 1
    return c


@functools.partial(jax.jit, static_argnames=(
    "wn", "threshold", "min_hot", "eps", "argmax_fallback", "use_kernel",
    "interpret", "block_t"))
def _sweep_jit(x, mu, sd, ticks, valid_n, wn, threshold, min_hot, eps,
               argmax_fallback, use_kernel, interpret, block_t):
    if use_kernel:
        return sweep_rows_pallas(x, mu, sd, ticks, valid_n, wn, threshold,
                                 min_hot, eps, argmax_fallback,
                                 block_t=block_t, interpret=interpret)
    return sweep_rows_ref(x, mu, sd, ticks, valid_n, wn, threshold,
                          min_hot, eps, argmax_fallback, block_t)


def rolling_moments(lat64: np.ndarray, ticks: np.ndarray, wn: int, bn: int,
                    valid_n: Optional[np.ndarray] = None,
                    ) -> Tuple[np.ndarray, np.ndarray]:
    """Exact-f64 rolling baseline moments for every (row, tick).

    Bitwise-identical to what ``spike.detect_sweep`` computes — it IS the
    same prefix-sum pass (``spike.sliding_baseline_stats``), run per row
    tile so the O(T) rolling arrays stay cache-resident (a flat batched
    pass over the whole slab is measurably slower than 48 L2-sized row
    passes); same shift, same sigma floor, and the bn=0 empty-baseline
    convention of ``baseline_stats`` — mu 0, sigma at the absolute floor.
    Ragged rows (``valid_n``) use their own truncated series, exactly as
    the oracle sweeping ``x[:valid]`` would; their out-of-range ticks get
    placeholder (0, 1) moments that the sweep masks anyway.
    """
    lat64 = np.asarray(lat64, np.float64)
    R = lat64.shape[0]
    nt = ticks.size
    if bn <= 0:
        return (np.zeros((R, nt)),
                np.full((R, nt), spike_mod.SIGMA_FLOOR_ABS))
    starts = ticks - wn - bn
    mu = np.zeros((R, nt))
    sd = np.ones((R, nt))
    for r in range(R):
        nv = lat64.shape[1] if valid_n is None else int(valid_n[r])
        k = int(np.searchsorted(ticks, nv, side="right"))
        if k == 0:
            continue
        mu[r, :k], sd[r, :k] = spike_mod.sliding_baseline_stats(
            lat64[r, :nv], starts[:k], bn)
    return mu, sd


def rolling_moments_masked(lat64: np.ndarray, valid: np.ndarray,
                           ticks: np.ndarray, wn: int, bn: int,
                           valid_n: Optional[np.ndarray] = None,
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Validity-masked :func:`rolling_moments`: ``(mu, sd, n_valid)``.

    Same per-row prefix-sum pass as the masked oracle
    (``spike.masked_sliding_baseline_stats`` — bitwise identical), so a
    kernel dispatch staged on these moments agrees with
    ``spike.detect_sweep_masked`` decision for decision.  ``n_valid`` is
    the per-(row, tick) valid baseline sample count the caller gates on.
    """
    lat64 = np.asarray(lat64, np.float64)
    v = np.asarray(valid, bool)
    R = lat64.shape[0]
    nt = ticks.size
    if bn <= 0:
        return (np.zeros((R, nt)),
                np.full((R, nt), spike_mod.SIGMA_FLOOR_ABS),
                np.full((R, nt), np.iinfo(np.intp).max, np.intp))
    starts = ticks - wn - bn
    mu = np.zeros((R, nt))
    sd = np.ones((R, nt))
    cnt = np.zeros((R, nt), np.intp)
    for r in range(R):
        nv = lat64.shape[1] if valid_n is None else int(valid_n[r])
        k = int(np.searchsorted(ticks, nv, side="right"))
        if k == 0:
            continue
        mu[r, :k], sd[r, :k], cnt[r, :k] = \
            spike_mod.masked_sliding_baseline_stats(
                lat64[r, :nv], v[r, :nv], starts[:k], bn)
    return mu, sd, cnt


def sweep_rows_exact(lat, wn: int, bn: int, ticks: np.ndarray,
                     threshold: float = 3.0, persistence: float = 0.0,
                     valid_n: Optional[np.ndarray] = None,
                     moments: Optional[Tuple[np.ndarray, np.ndarray]] = None,
                     chunk: int = 4096,
                     valid: Optional[np.ndarray] = None,
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """The batched sweep's exact-f64 CPU path: score-screened, no guard.

    Bitwise-identical FIRE decisions — and, at every fired tick, bitwise
    scores and onsets — vs running :func:`repro.core.spike.detect_sweep`
    row by row, but the (rows, #ticks, wn) z-tensor is never formed for
    ticks that provably cannot fire.  The screen is a *sound upper bound*
    on the hot-sample count from fixed 64-sample block maxima: rounding
    is monotone, so a block whose max-z stays at or below the threshold
    holds no hot sample, and ``64 * (#hot blocks overlapping the
    window)`` bounds the count.  Ambient windows — whose max-z routinely
    pokes over 3 sigma (the expected max of ~500 correlated samples sits
    right there) but whose hot count is a handful — are rejected without
    ever gathering the window; only the surviving (row, tick) pairs get
    the oracle's exact rule evaluated, in one fancy-index batch chunked
    at ``chunk`` pairs so peak memory stays bounded.

    Returns ``(fire, score, onset)`` of shape (rows, #ticks).  ``fire``
    is exact everywhere.  ``score`` and ``onset`` are exact wherever the
    screen let the tick through — in particular at every fired tick,
    which is all the event resolve ever reads (the oracle's
    ``detect_events`` consumes score/onset only for fired ticks);
    screened-out ticks report score 0 / onset -1, as do masked ragged
    ticks (``valid_n``).

    ``valid`` (rows, T) bool adds per-tick validity (chaos hardening):
    invalid cells enter neither moments nor the screen (they are staged
    -inf, so no block containing only poison can look hot), survivors are
    re-decided through ``spike.detect_sweep_at_masked``, and ticks with
    under ``MIN_VALID_BASELINE_N`` valid baseline samples are refused —
    the exact path then matches ``spike.detect_sweep_masked`` fire for
    fire.  An all-true mask is dropped, keeping the clean path
    byte-identical.
    """
    lat64 = np.asarray(lat, np.float64)
    R, T = lat64.shape
    wn, bn = int(wn), int(bn)
    ticks = np.asarray(ticks, dtype=np.int64)
    nt = ticks.size
    if nt == 0:
        e = np.empty((R, 0))
        return e.astype(bool), e, e.astype(np.intp)
    if ticks.min() < wn + bn or ticks.max() > T:
        raise ValueError(f"ticks must lie in [{wn + bn}, {T}]")
    vn = (np.full(R, T, np.int64) if valid_n is None
          else np.asarray(valid_n, np.int64))
    vmask = None
    if valid is not None:
        vmask = np.asarray(valid, bool)
        if vmask.shape != (R, T):
            raise ValueError(f"valid {vmask.shape} vs lat {lat64.shape}")
        if vmask.all():
            vmask = None
    bcnt = None
    if moments is None:
        if vmask is None:
            moments = rolling_moments(lat64, ticks, wn, bn,
                                      None if valid_n is None else vn)
        else:
            mm, ss, bcnt = rolling_moments_masked(
                lat64, vmask, ticks, wn, bn,
                None if valid_n is None else vn)
            moments = (mm, ss)
    mu, sd = moments
    tick_ok = ticks[None, :] <= vn[:, None]
    score = np.zeros((R, nt))
    fire = np.zeros((R, nt), bool)
    onset = np.full((R, nt), -1, np.intp)
    # block-max screen (see docstring): a tick survives only if enough
    # g-sample blocks overlapping its window contain a hot sample
    g = 64
    nB = -(-T // g)
    Bpad = np.full((R, nB * g), -np.inf)
    Bpad[:, :T] = lat64 if vmask is None else np.where(vmask, lat64, -np.inf)
    Bmax = Bpad.reshape(R, nB, g).max(axis=2)              # (R, nB)
    m = wn // g + 2
    k0 = (ticks - wn) // g
    cols = k0[:, None] + np.arange(m)[None, :]              # (nt, m)
    inwin = cols <= ((ticks - 1) // g)[:, None]
    zb = (Bmax[:, np.clip(cols, 0, nB - 1)]
          - mu[..., None]) / sd[..., None]                  # (R, nt, m)
    bound = g * ((zb > threshold) & inwin[None, :, :]).sum(axis=2)
    min_hot = persistence_count(wn, persistence)
    cand_mask = (bound >= max(min_hot, 1)) & tick_ok
    if bcnt is not None:
        cand_mask &= bcnt >= spike_mod.MIN_VALID_BASELINE_N
    # surviving ticks: the oracle's exact rule, per row so the window
    # gather is a strided view of an L2-resident series
    for r in np.flatnonzero(cand_mask.any(axis=1)):
        ci = np.flatnonzero(cand_mask[r])
        row = lat64[r, :int(vn[r])] if valid_n is not None else lat64[r]
        for lo in range(0, ci.size, chunk):
            sl = ci[lo:lo + chunk]
            if vmask is None:
                f, s, o = spike_mod.detect_sweep_at(
                    row, wn, ticks[sl], mu[r, sl], sd[r, sl],
                    threshold, persistence)
            else:
                vrow = vmask[r, :int(vn[r])] if valid_n is not None \
                    else vmask[r]
                f, s, o = spike_mod.detect_sweep_at_masked(
                    row, vrow, wn, ticks[sl], mu[r, sl], sd[r, sl],
                    threshold, persistence,
                    baseline_count=None if bcnt is None else bcnt[r, sl])
            fire[r, sl], score[r, sl], onset[r, sl] = f, s, o
    return fire, score, onset


def sweep_rows(lat: np.ndarray, wn: int, bn: int, ticks: np.ndarray,
               threshold: float = 3.0, persistence: float = 0.0,
               valid_n: Optional[np.ndarray] = None,
               moments: Optional[Tuple[np.ndarray, np.ndarray]] = None,
               argmax_fallback: bool = False, eps: float = SWEEP_GUARD_EPS,
               use_kernel: bool = False, interpret: bool = True,
               block_t: Optional[int] = None,
               valid: Optional[np.ndarray] = None,
               device=None,
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Batched :func:`repro.core.spike.detect_sweep` over a latency slab.

    ``lat`` (rows, T) — any dtype, staged to f32 for the dispatch; every
    row is evaluated at the shared ``ticks`` (each in ``[wn + bn, T]``)
    against its own rolling baseline, in ONE jit dispatch (masked-XLA ref
    by default; ``use_kernel=True`` for the Pallas kernel, interpret mode
    on CPU).  Returns ``(fire, score, onset, marginal)`` numpy arrays of
    shape (rows, #ticks):

      fire      bool, the full scalar detect rule per (row, tick);
      score     f32 max-z (0 where the tick is masked);
      onset     first above-threshold window index; -1 when nothing
                crosses, or the arg-max-z sample with
                ``argmax_fallback=True`` (the ``detect_rows`` fleet
                convention — see core.spike);
      marginal  bool, some window z within ``eps`` of the threshold —
                or, under ``argmax_fallback``, a no-hot-sample tick whose
                top two z values near-tie (the f32 arg-max could swap) —
                the ticks an exactness-seeking caller re-decides in f64.

    ``valid_n`` gives ragged per-row valid lengths (rows are only
    evaluated at ticks ``<= valid_n[row]``; masked ticks report fire
    False / onset -1).  ``moments`` overrides the exact-f64 rolling
    (mu, sd) prep — the fleet detect path passes ``detect_rows``-style
    direct moments so the single-tick decision matches its oracle.

    ``valid`` (rows, T) bool adds per-tick validity (chaos hardening):
    invalid cells are staged as ``MASK_NEG`` — the same sentinel the
    kernels already use for padded lanes — so their z is astronomically
    negative and they can neither look hot nor win the max/argmax;
    rolling moments come from the masked prefix pass, and ticks whose
    baseline holds fewer than ``MIN_VALID_BASELINE_N`` valid samples (or
    whose window holds no valid cell) are forced quiet host-side after
    the dispatch.  An all-true mask is dropped before staging, so the
    clean path is byte-identical to ``valid=None``.

    ``device`` pins the jit dispatch to one ``jax.Device`` (sharded fleet
    monitoring places each shard's sweep on its own mesh device); None
    keeps JAX's default placement.  Placement never changes the decision
    — moments are exact f64 host-side and marginal ticks re-decide
    through the f64 oracle regardless of where the f32 sweep ran.
    """
    lat = np.asarray(lat)
    if lat.ndim != 2:
        raise ValueError(f"lat must be (rows, T), got {lat.shape}")
    R, T = lat.shape
    wn, bn = int(wn), int(bn)
    ticks = np.asarray(ticks, dtype=np.int64)
    nt = ticks.size
    if nt == 0:
        e = np.empty((R, 0))
        return (e.astype(bool), e.astype(np.float64),
                e.astype(np.intp), e.astype(bool))
    if ticks.min() < wn + bn or ticks.max() > T:
        raise ValueError(f"ticks must lie in [{wn + bn}, {T}]")
    if valid_n is None:
        vn = np.full(R, T, np.int64)
    else:
        vn = np.asarray(valid_n, np.int64)
        if vn.shape != (R,):
            raise ValueError(f"valid_n {vn.shape} vs rows {R}")
    vmask = None
    if valid is not None:
        vmask = np.asarray(valid, bool)
        if vmask.shape != (R, T):
            raise ValueError(f"valid {vmask.shape} vs lat {lat.shape}")
        if vmask.all():
            vmask = None
    bcnt = None
    if moments is None:
        if vmask is None:
            moments = rolling_moments(np.asarray(lat, np.float64), ticks,
                                      wn, bn,
                                      None if valid_n is None else vn)
        else:
            mm, ss, bcnt = rolling_moments_masked(
                np.asarray(lat, np.float64), vmask, ticks, wn, bn,
                None if valid_n is None else vn)
            moments = (mm, ss)
    mu, sd = moments
    min_hot = persistence_count(wn, persistence)
    lat32 = np.ascontiguousarray(lat, np.float32)
    if vmask is not None:
        lat32 = np.where(vmask, lat32, np.float32(spike_mod.MASK_NEG))
    def _dispatch():
        return _sweep_jit(
            jnp.asarray(lat32),
            jnp.asarray(np.asarray(mu, np.float32)),
            jnp.asarray(np.asarray(sd, np.float32)),
            jnp.asarray(ticks, jnp.int32), jnp.asarray(vn, jnp.int32),
            wn, float(threshold), int(min_hot), float(eps),
            bool(argmax_fallback), bool(use_kernel), bool(interpret),
            tuning.sweep_block_t(block_t))
    if device is None:
        fire, score, onset, marg = _dispatch()
    else:
        with jax.default_device(device):
            fire, score, onset, marg = _dispatch()
    fire = np.asarray(fire).astype(bool)
    score = np.array(score, np.float64)
    onset = np.asarray(onset).astype(np.intp)
    marg = np.asarray(marg).astype(bool)
    if vmask is not None:
        # host-side validity gate: a baseline you cannot estimate (or a
        # window with zero valid cells) may never fire, whatever the
        # staged sentinel z came out to
        cv = np.concatenate([np.zeros((R, 1)), np.cumsum(vmask, axis=1)],
                            axis=1)
        wcnt = cv[:, ticks] - cv[:, ticks - wn]
        ok = wcnt > 0
        if bcnt is not None:
            ok &= bcnt >= spike_mod.MIN_VALID_BASELINE_N
        fire &= ok
        score = np.where(ok, score, 0.0)
        onset = np.where(ok, onset, -1)
    return fire, score, onset, marg
