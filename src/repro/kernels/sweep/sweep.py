"""Pallas TPU kernel: batched Layer-2 detection sweep — per-tick spike
score + persistence gate + onset for EVERY row of the (rows, T) latency
slab in one dispatch.

The per-trial eval loop ran :func:`repro.core.spike.detect_sweep` once per
latency row: per-row f64 conversion, per-row prefix sums and a fully
materialized (#ticks, wn) z-matrix.  Here one grid cell handles
(``block_r`` rows x ``block_t`` ticks): the cell keeps its rows' full f32
latency series VMEM-resident, gathers the cell's tick windows from them
(``W[r, i, k] = x[r, tick_i - wn + k]`` — one gather, the same trick as
the fused kernel's lag matrix), and computes against *precomputed* rolling
baseline moments:

  * the window max-z spike score per (row, tick),
  * the above-threshold sample count (integer persistence gate, decided
    host-side in exact f64 by ``ops.persistence_count``),
  * the onset index — first above-threshold sample, with the fleet
    monitor's arg-max-z fallback behind a flag (``detect_rows`` vs
    ``detect`` convention, see core.spike),
  * an epsilon-marginality bit: whether any window z sits within ``eps``
    of the threshold, i.e. whether f32 rounding could flip this tick's
    decision against the f64 oracle (the ops layer re-checks flagged
    ticks exactly).

Baseline moments (mu, sd) arrive as (rows, #ticks) inputs — the rolling
prefix-sum pass is O(rows * T) scalar work the host does once in exact
f64 (``ops.rolling_moments``); the kernel spends its
bandwidth on the O(rows * #ticks * wn) window reductions, tick-blocked so
the z working set stays bounded at (block_r, block_t, wn) instead of the
full (rows, #ticks, wn) tensor.  ``MASK_NEG`` lane masking covers padded
lanes, padded ticks AND ragged per-row valid lengths, so FleetAggregator
slabs with masked/young hosts feed it directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spike import MASK_NEG as NEG
from repro.kernels import tuning


def _sweep_kernel(wn: int, n_ticks: int, threshold: float, min_hot: int,
                  eps: float, argmax_fallback: bool,
                  ticks_ref, valid_ref, x_ref, mu_ref, sd_ref,
                  fire_ref, score_ref, onset_ref, marg_ref):
    """ticks_ref (1, bt) i32; valid_ref (br, 1) i32; x_ref (br, Tp) f32;
    mu_ref/sd_ref (br, bt) f32; outputs (br, bt)."""
    br, bt = mu_ref.shape
    j = pl.program_id(1)

    t = ticks_ref[0, :]                                        # (bt,) i32
    nv = valid_ref[:, 0]                                       # (br,) i32
    # padding mask (ticks beyond the true grid) + ragged row mask
    ok = (j * bt + jax.lax.iota(jnp.int32, bt) < n_ticks)
    tick_ok = ok[None, :] & (t[None, :] <= nv[:, None])        # (br, bt)

    # one gather builds the cell's window tile from the resident rows
    idx = jax.lax.broadcasted_iota(jnp.int32, (bt, wn), 1)
    cols = t[:, None] - wn + idx                               # (bt, wn)
    W = jnp.take(x_ref[...], cols, axis=1)                     # (br, bt, wn)

    z = (W - mu_ref[...][..., None]) / sd_ref[...][..., None]
    zm = jnp.where(tick_ok[..., None], z, NEG)
    score = jnp.max(zm, axis=-1)                               # (br, bt)
    hot = zm > threshold
    cnt = jnp.sum(hot.astype(jnp.int32), axis=-1)
    lane = jax.lax.broadcasted_iota(jnp.int32, zm.shape, 2)
    first_hot = jnp.min(jnp.where(hot, lane, wn), axis=-1)
    if argmax_fallback:
        # arg-max via first index attaining the max (np.argmax tie rule)
        none = jnp.min(jnp.where(zm == score[..., None], lane, wn), axis=-1)
    else:
        none = jnp.full(cnt.shape, -1, jnp.int32)
    onset = jnp.where(cnt > 0, first_hot, none)

    fire_ref[...] = ((score > threshold) & (cnt >= min_hot)
                     & tick_ok).astype(jnp.int32)
    score_ref[...] = jnp.where(tick_ok, score, 0.0)
    onset_ref[...] = jnp.where(tick_ok, onset, -1)
    marg = jnp.any((jnp.abs(zm - threshold) < eps) & tick_ok[..., None],
                   axis=-1)
    if argmax_fallback:
        # arg-max fallback onsets can swap under f32 rounding when two
        # samples near-tie for the row max — flag those ticks marginal
        tie = jnp.sum((zm >= score[..., None] - eps) & tick_ok[..., None],
                      axis=-1) >= 2
        marg = marg | (tie & (cnt == 0) & tick_ok)
    marg_ref[...] = marg.astype(jnp.int32)


def sweep_rows_pallas(x: jax.Array, mu: jax.Array, sd: jax.Array,
                      ticks: jax.Array, valid_n: jax.Array, wn: int,
                      threshold: float, min_hot: int, eps: float,
                      argmax_fallback: bool, block_r: int | None = None,
                      block_t: int | None = None, interpret: bool = True,
                      ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x (R, T) f32, mu/sd (R, nt) f32, ticks (nt,) i32, valid_n (R,) i32
    -> (fire i32, score f32, onset i32, marginal i32), each (R, nt).

    One dispatch for the whole slab; grid (rows / block_r, ticks /
    block_t).  ``interpret`` runs the body on CPU (the bit-accurate
    validation path); on TPU pass interpret=False.  Tile sizes default to
    the env-overridable config (kernels.tuning).
    """
    R, T = x.shape
    nt = int(ticks.shape[0])
    br = tuning.sweep_block_r(block_r)
    bt = max(1, min(tuning.sweep_block_t(block_t), nt))
    pad_r = (-R) % br
    pad_t = (-nt) % bt
    if T % 128:
        # lane-align the resident series; ticks never index the pad (every
        # real window ends at t <= T, pad ticks gather the [0, wn) head)
        x = jnp.pad(x, ((0, 0), (0, (-T) % 128)))
    if pad_r:
        x = jnp.pad(x, ((0, pad_r), (0, 0)))
        valid_n = jnp.pad(valid_n, (0, pad_r))        # 0 => every tick masked
    if pad_t:
        # padded ticks gather a safe in-range window; masked via n_ticks
        ticks = jnp.pad(ticks, (0, pad_t), constant_values=int(wn))
    if pad_r or pad_t:
        mu = jnp.pad(mu, ((0, pad_r), (0, pad_t)))
        sd = jnp.pad(sd, ((0, pad_r), (0, pad_t)), constant_values=1.0)
    Rp, ntp = R + pad_r, nt + pad_t
    Tp = x.shape[1]

    fire, score, onset, marg = pl.pallas_call(
        functools.partial(_sweep_kernel, int(wn), nt, float(threshold),
                          int(min_hot), float(eps), bool(argmax_fallback)),
        grid=(Rp // br, ntp // bt),
        in_specs=[
            pl.BlockSpec((1, bt), lambda i, j: (0, j)),
            pl.BlockSpec((br, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((br, Tp), lambda i, j: (i, 0)),
            pl.BlockSpec((br, bt), lambda i, j: (i, j)),
            pl.BlockSpec((br, bt), lambda i, j: (i, j)),
        ],
        out_specs=[pl.BlockSpec((br, bt), lambda i, j: (i, j))] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((Rp, ntp), jnp.int32),
            jax.ShapeDtypeStruct((Rp, ntp), jnp.float32),
            jax.ShapeDtypeStruct((Rp, ntp), jnp.int32),
            jax.ShapeDtypeStruct((Rp, ntp), jnp.int32),
        ],
        interpret=interpret,
    )(ticks.astype(jnp.int32)[None], valid_n.astype(jnp.int32)[:, None],
      x.astype(jnp.float32), mu.astype(jnp.float32), sd.astype(jnp.float32))
    return (fire[:R, :nt], score[:R, :nt], onset[:R, :nt], marg[:R, :nt])
