"""Pure-jnp oracle for the batched Layer-2 sweep kernel.

Same per-tick math as :func:`repro.core.spike.detect_sweep` — z against
precomputed rolling baseline moments, max-z score, integer persistence
count, first-hot onset — in f32 over ALL rows of the latency slab at once.
This is the XLA path the CPU eval times, and the AD-friendly path.

Peak memory is bounded: the (rows, #ticks, wn) z-block never exists — a
``lax.map`` over tick blocks materializes at most (rows, block_t, wn) per
step, the tick-blocked structure the Pallas kernel mirrors as its grid.

Baseline moments arrive as *inputs* (``mu``/``sd``, (rows, #ticks)): the
ops layer computes them host-side in f64 with the prefix-sum trick
(``ops.rolling_moments`` — the oracle's own
:func:`repro.core.spike.sliding_baseline_stats` per row tile) and
downcasts.  Keeping the O(rows * T) rolling pass exact and off-kernel is
what makes the f32 sweep's decisions agree with the f64 oracle to within
the epsilon guard (see ops).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spike import MASK_NEG as NEG


def _tick_block(x, mu_b, sd_b, t_b, ok_b, valid_n, wn: int, threshold: float,
                min_hot: int, eps: float, argmax_fallback: bool):
    """Decisions for one tick block.

    x (R, T) f32; mu_b/sd_b (R, bt); t_b (bt,) i32 tick sample indices;
    ok_b (bt,) bool padding mask; valid_n (R,) i32 per-row valid lengths.
    Returns (fire bool, score f32, onset i32, marginal bool), each (R, bt).
    """
    idx = jnp.arange(wn, dtype=jnp.int32)
    cols = t_b[:, None] - wn + idx[None, :]                    # (bt, wn)
    W = jnp.take(x, cols, axis=1)                              # (R, bt, wn)
    tick_ok = ok_b[None, :] & (t_b[None, :] <= valid_n[:, None])
    z = (W - mu_b[..., None]) / sd_b[..., None]
    zm = jnp.where(tick_ok[..., None], z, NEG)
    score = jnp.max(zm, axis=-1)
    hot = zm > threshold
    cnt = jnp.sum(hot.astype(jnp.int32), axis=-1)
    fire = (score > threshold) & (cnt >= min_hot) & tick_ok
    first_hot = jnp.min(jnp.where(hot, idx[None, None, :], wn), axis=-1)
    if argmax_fallback:
        none = jnp.argmax(zm, axis=-1).astype(jnp.int32)
    else:
        none = jnp.full(cnt.shape, -1, jnp.int32)
    onset = jnp.where(cnt > 0, first_hot.astype(jnp.int32), none)
    onset = jnp.where(tick_ok, onset, -1)
    score = jnp.where(tick_ok, score, 0.0)
    marginal = jnp.any((jnp.abs(zm - threshold) < eps) & tick_ok[..., None],
                       axis=-1)
    if argmax_fallback:
        # the fallback onset is an arg-max over z: two samples within eps
        # of the row max can swap order under f32 rounding even far from
        # the threshold, so near-ties on quiet ticks are marginal too
        tie = jnp.sum((zm >= score[..., None] - eps) & tick_ok[..., None],
                      axis=-1) >= 2
        marginal = marginal | (tie & (cnt == 0) & tick_ok)
    return fire, score, onset, marginal


def sweep_rows_ref(x: jax.Array, mu: jax.Array, sd: jax.Array,
                   ticks: jax.Array, valid_n: jax.Array, wn: int,
                   threshold: float, min_hot: int, eps: float,
                   argmax_fallback: bool, block_t: int,
                   ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """x (R, T), mu/sd (R, nt), ticks (nt,), valid_n (R,) ->
    (fire bool, score f32, onset i32, marginal bool), each (R, nt)."""
    R, _ = x.shape
    nt = ticks.shape[0]
    bt = max(1, min(int(block_t), nt))
    pad = (-nt) % bt
    nb = (nt + pad) // bt
    # padded ticks point at a safe in-range window; masked out via ok
    ticks_p = jnp.concatenate(
        [ticks.astype(jnp.int32), jnp.full(pad, int(wn), jnp.int32)])
    ok_p = jnp.arange(nt + pad) < nt
    mu_p = jnp.concatenate([mu, jnp.zeros((R, pad), mu.dtype)], axis=1)
    sd_p = jnp.concatenate([sd, jnp.ones((R, pad), sd.dtype)], axis=1)

    def step(args):
        t_b, ok_b, mu_b, sd_b = args
        return _tick_block(x, mu_b, sd_b, t_b, ok_b, valid_n, wn,
                           threshold, min_hot, eps, argmax_fallback)

    fire, score, onset, marg = jax.lax.map(step, (
        ticks_p.reshape(nb, bt), ok_p.reshape(nb, bt),
        mu_p.reshape(R, nb, bt).transpose(1, 0, 2),
        sd_p.reshape(R, nb, bt).transpose(1, 0, 2)))
    out = []
    for a in (fire, score, onset, marg):               # (nb, R, bt) -> (R, nt)
        out.append(a.transpose(1, 0, 2).reshape(R, nt + pad)[:, :nt])
    return tuple(out)
