"""Suite-scale Layer-2 sweep: per-tick (fire, score, onset) for every row
of an f32 (rows, T) latency slab in ONE dispatch."""
from repro.kernels.sweep.ops import (
    SWEEP_GUARD_EPS, persistence_count, sweep_rows,
)

__all__ = ["SWEEP_GUARD_EPS", "persistence_count", "sweep_rows"]
