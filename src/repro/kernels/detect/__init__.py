"""Streaming fleet-detect: spike score + persistence gate + onset in one
pass over the (hosts, window) latency slab — since PR 5 a single-tick view
of the shared batched sweep core (:mod:`repro.kernels.sweep`)."""
from repro.kernels.detect.ops import (
    detect_hosts, detect_hosts_slab, persistence_count,
)

__all__ = ["detect_hosts", "detect_hosts_slab", "persistence_count"]
