"""Streaming fleet-detect kernel: spike score + persistence gate + onset
in one pass over the (hosts, window) latency slab."""
from repro.kernels.detect.ops import detect_hosts, persistence_count

__all__ = ["detect_hosts", "persistence_count"]
