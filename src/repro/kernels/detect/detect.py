"""Pallas TPU kernel: streaming fleet detect — spike score + persistence
gate + onset estimate in one pass over the (hosts, window) latency slab.

The seed fleet path made two trips over the latency slab per
``diagnose_fleet`` call: a spike-kernel dispatch for the (hosts,) max-z
scores, then an f64 re-slice + scalar-rule ``detect_rows`` replay over the
candidate hosts for the persistence gate and onset estimates.  One grid
cell here handles ``block_h`` hosts and computes, from a single
VMEM-resident read of the (block_h, Nw) window tile and its (block_h, Nb)
baseline tile:

  * baseline mean/std with the sigma floor (VPU row reductions),
  * the window max-z spike score S_h,
  * the above-threshold sample count (the persistence gate, compared
    against a precomputed integer count so the decision is bit-identical
    to the f64 ``hot.mean() >= persistence`` rule),
  * the onset index: first above-threshold sample, arg-max z fallback.

Everything downstream (flag ordering, Layer-3 gather) consumes the three
small (hosts,) outputs — the slab is read exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.spike import (
    MASK_NEG as NEG, SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL,
)
from repro.kernels import tuning


def _detect_kernel(nw_valid: int, nb_valid: int, threshold: float,
                   min_hot: int, win_ref, base_ref,
                   score_ref, fire_ref, onset_ref):
    """win_ref (1, bh, Nw); base_ref (1, bh, Nb); outputs (1, bh)."""
    Nw = win_ref.shape[-1]
    Nb = base_ref.shape[-1]
    wmask = (jax.lax.iota(jnp.int32, Nw) < nw_valid)
    bmask = (jax.lax.iota(jnp.int32, Nb) < nb_valid).astype(jnp.float32)
    nb = jnp.float32(nb_valid)

    # ---- baseline moments + sigma floor (same policy as core.spike)
    b = base_ref[0] * bmask[None, :]
    mu = jnp.sum(b, axis=1) / nb                                   # (bh,)
    d = (b - mu[:, None]) * bmask[None, :]
    sd = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=1) / nb, 0.0))
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)

    # ---- window z, max-z score, persistence count, onset — one tile read
    w = win_ref[0]                                                 # (bh, Nw)
    z = (w - mu[:, None]) / sd[:, None]
    z = jnp.where(wmask[None, :], z, NEG)
    score = jnp.max(z, axis=1)
    hot = (z > threshold) & wmask[None, :]
    cnt = jnp.sum(hot.astype(jnp.int32), axis=1)

    idx = jax.lax.broadcasted_iota(jnp.int32, z.shape, 1)
    first_hot = jnp.min(jnp.where(hot, idx, Nw), axis=1)
    # arg-max via first index attaining the max (np.argmax tie rule)
    amax = jnp.min(jnp.where(z == score[:, None], idx, Nw), axis=1)

    score_ref[0] = score
    fire_ref[0] = ((score > threshold) & (cnt >= min_hot)).astype(jnp.int32)
    onset_ref[0] = jnp.where(cnt > 0, first_hot, amax)


def detect_hosts_pallas(windows: jax.Array, baselines: jax.Array,
                        threshold: float, min_hot: int,
                        nw_valid: int | None = None,
                        nb_valid: int | None = None,
                        block_h: int | None = None, interpret: bool = True,
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """windows (H, Nw), baselines (H, Nb) -> (fire i32, score f32, onset i32)
    each (H,).  Nw and Nb must be lane-aligned (pad + pass valid counts);
    ``min_hot`` is the integer persistence gate (see ops.persistence_count).
    """
    H, Nw = windows.shape
    Nb = baselines.shape[-1]
    if Nw % 128 or Nb % 128:
        raise ValueError(f"Nw={Nw}, Nb={Nb} must be lane-aligned (x128)")
    nw_valid = Nw if nw_valid is None else int(nw_valid)
    nb_valid = Nb if nb_valid is None else int(nb_valid)
    bh = tuning.detect_block_h(block_h)
    pad_h = (-H) % bh
    if pad_h:
        windows = jnp.pad(windows, ((0, pad_h), (0, 0)))
        baselines = jnp.pad(baselines, ((0, pad_h), (0, 0)),
                            constant_values=1.0)
    Hp = H + pad_h

    score, fire, onset = pl.pallas_call(
        functools.partial(_detect_kernel, nw_valid, nb_valid,
                          float(threshold), int(min_hot)),
        grid=(1, Hp // bh),
        in_specs=[
            pl.BlockSpec((1, bh, Nw), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bh, Nb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bh), lambda b, j: (b, j)),
            pl.BlockSpec((1, bh), lambda b, j: (b, j)),
            pl.BlockSpec((1, bh), lambda b, j: (b, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, Hp), jnp.float32),
            jax.ShapeDtypeStruct((1, Hp), jnp.int32),
            jax.ShapeDtypeStruct((1, Hp), jnp.int32),
        ],
        interpret=interpret,
    )(windows.astype(jnp.float32)[None], baselines.astype(jnp.float32)[None])
    return fire[0, :H], score[0, :H], onset[0, :H]
