"""Jit'd public wrapper for the streaming fleet-detect kernel.

This is the ``diagnose_fleet`` Layer-2 hot path: ONE dispatch over the
(hosts, wn) latency slab yields, per host, the spike score, the
persistence-gated straggler decision, and the onset estimate — the seed
needed a spike-kernel dispatch plus an f64 re-slice + scalar-rule
``detect_rows`` replay over the candidates for the same three outputs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.detect.detect import detect_hosts_pallas
from repro.kernels.detect.ref import detect_hosts_ref


def persistence_count(n: int, persistence: float) -> int:
    """Smallest integer c with ``c / n >= persistence`` in f64.

    The scalar rule (:func:`repro.core.spike.detect_rows`) gates on
    ``hot.mean() >= persistence`` computed in f64; comparing an f32
    fraction against the f64 threshold can flip exactly at the boundary
    count, so the kernel gates on the integer count instead — decided
    here, once, in exact f64.
    """
    n = int(n)
    if n <= 0 or persistence <= 0.0:
        return 0
    c = min(int(np.ceil(persistence * n)), n)
    while c > 0 and (c - 1) / n >= persistence:
        c -= 1
    while c <= n and c / n < persistence:
        c += 1
    return c


def _pad128(x: jax.Array, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % 128
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=(
    "threshold", "min_hot", "use_kernel", "interpret"))
def _detect_hosts_jit(windows, baselines, threshold, min_hot,
                      use_kernel, interpret):
    if not use_kernel:
        return detect_hosts_ref(windows, baselines, threshold, min_hot)
    nw, nb = windows.shape[-1], baselines.shape[-1]
    w = _pad128(windows.astype(jnp.float32), 1)
    b = _pad128(baselines.astype(jnp.float32), 1)
    return detect_hosts_pallas(w, b, threshold, min_hot,
                               nw_valid=nw, nb_valid=nb, interpret=interpret)




def detect_hosts(windows, baselines, threshold: float = 3.0,
                 persistence: float = 0.0, use_kernel: bool = True,
                 interpret: bool = True,
                 ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched Layer-2 decision per host row, one dispatch.

    ``windows`` (H, Nw) vs ``baselines`` (H, Nb) -> ``(fire, score, onset)``
    numpy arrays of length H: fire is the full scalar :func:`spike.detect`
    rule (max-z above threshold AND >= ``persistence`` of the window hot),
    onset the first above-threshold sample with arg-max z fallback —
    exactly :func:`repro.core.spike.detect_rows`, f32, without the
    intermediate (H, Nw) z materialization in host memory.
    """
    windows = jnp.asarray(windows)
    baselines = jnp.asarray(baselines)
    if windows.ndim != 2 or baselines.ndim != 2 \
            or windows.shape[0] != baselines.shape[0]:
        raise ValueError(f"shape mismatch: windows {windows.shape} "
                         f"baselines {baselines.shape}")
    min_hot = persistence_count(windows.shape[-1], persistence)
    fire, score, onset = _detect_hosts_jit(
        windows, baselines, float(threshold), min_hot,
        bool(use_kernel), bool(interpret))
    return (np.asarray(fire).astype(bool), np.asarray(score),
            np.asarray(onset).astype(np.intp))


def detect_hosts_slab(tail, wn: int, bn: int, threshold: float = 3.0,
                      persistence: float = 0.0, use_kernel: bool = True,
                      interpret: bool = True,
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """:func:`detect_hosts` over a trailing latency slab.

    ``tail`` is the (H, bn + wn) slab — baseline columns then window
    columns, exactly the layout of a trailing ring snapshot.  The split
    is materialized host-side as two contiguous f32 blocks: jax aliases
    aligned contiguous f32 numpy on CPU (zero-copy), whereas handing it a
    strided slab view takes a slow elementwise transfer path, and
    slicing inside the jit re-materializes both halves on device.
    """
    tail = np.asarray(tail)
    if tail.ndim != 2 or tail.shape[-1] != wn + bn:
        raise ValueError(f"tail {tail.shape} vs bn+wn={bn + wn}")
    win = np.ascontiguousarray(tail[:, bn:], dtype=np.float32)
    base = np.ascontiguousarray(tail[:, :bn], dtype=np.float32)
    return detect_hosts(win, base, threshold, persistence,
                        use_kernel=use_kernel, interpret=interpret)
