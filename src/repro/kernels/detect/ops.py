"""Streaming fleet detect = the batched Layer-2 sweep at a single tick.

This is the ``diagnose_fleet`` Layer-2 hot path: ONE dispatch over the
(hosts, bn + wn) trailing latency slab yields, per host, the spike score,
the persistence-gated straggler decision, and the onset estimate.  Since
PR 5 the implementation IS :mod:`repro.kernels.sweep` — the fleet's
boundary evaluation is the suite sweep with one evaluation tick at the
slab edge and the ``detect_rows`` arg-max onset fallback — so the fleet
and the eval no longer maintain two sweep kernels.

Exactness: baseline moments are computed here in f64 exactly as
:func:`repro.core.spike.detect_rows` does (direct mean/std + sigma
floor), and any host whose window holds a z within the sweep's epsilon
guard of the threshold is re-decided through the f64 oracle — the
fast-path flagged set and onsets are byte-exact against ``detect_rows``
by construction, not merely on the tested slabs.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import spike as spike_mod
from repro.kernels.sweep import ops as sweep_ops
from repro.kernels.sweep.ops import persistence_count  # re-export (tests/API)

__all__ = ["detect_hosts", "detect_hosts_slab", "persistence_count"]


def _detect_tail(tail32: np.ndarray, patch_win: np.ndarray,
                 patch_base: np.ndarray, wn: int, bn: int,
                 threshold: float, persistence: float,
                 use_kernel: bool, interpret: bool, exact: bool,
                 device=None, moments=None,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Single-tick sweep over the (H, bn + wn) trailing slab.

    ``patch_win``/``patch_base`` are the caller's original (H, Nw)/(H, Nb)
    arrays, any dtype — only epsilon-marginal rows are ever upcast from
    them for the exact ``detect_rows`` re-decision.

    ``moments`` (mu, sd) — each (H,) f64, sd already sigma-floored —
    skips the O(H * bn) direct moment pass (the incremental streaming
    state supplies these at O(delta)); marginal rows are still re-decided
    through the f64 oracle from the raw patch, so epsilon-close moments
    cannot move a decision.
    """
    H, T = tail32.shape
    if moments is not None:
        mu, sd = (np.asarray(m, np.float64).reshape(H) for m in moments)
    else:
        # detect_rows' f64 moments, bit for bit: accumulating the f32 rows
        # in f64 (dtype=) adds each exactly-representable element in the
        # same pairwise order as upcasting first, without (H, Nb) f64 copies
        mu = patch_base.mean(axis=1, dtype=np.float64)
        sd = np.maximum(patch_base.std(axis=1, dtype=np.float64),
                        np.maximum(spike_mod.SIGMA_FLOOR_ABS,
                                   spike_mod.SIGMA_FLOOR_REL * np.abs(mu)))
    if moments is not None:
        # with moments supplied the sweep never touches the baseline
        # columns — dispatch on the window slice only, so the staged
        # copy and the kernel's slab stay O(wn) instead of O(wn + bn)
        # (onsets are window-relative either way; verified equivalent
        # for both kernel and reference dispatch)
        disp, bn_d = np.ascontiguousarray(tail32[:, bn:]), 0
        ticks = np.array([wn], np.int64)
    else:
        disp, bn_d = tail32, bn
        ticks = np.array([T], np.int64)
    fire, score, onset, marg = sweep_ops.sweep_rows(
        disp, wn, bn_d, ticks, threshold, persistence,
        moments=(mu[:, None], sd[:, None]), argmax_fallback=True,
        use_kernel=use_kernel, interpret=interpret, device=device)
    fire, score, onset, marg = (fire[:, 0], score[:, 0], onset[:, 0],
                                marg[:, 0])
    if exact and marg.any():
        # guard band hit: re-decide those hosts through the f64 oracle so
        # the fast path cannot split from detect_rows at the threshold
        rows = np.flatnonzero(marg)
        f2, s2, o2 = spike_mod.detect_rows(
            np.asarray(patch_win[rows], np.float64),
            np.asarray(patch_base[rows], np.float64),
            threshold, persistence)
        fire[rows], score[rows], onset[rows] = f2, s2, o2
    return fire, score, onset


def detect_hosts(windows, baselines, threshold: float = 3.0,
                 persistence: float = 0.0, use_kernel: bool = True,
                 interpret: bool = True, exact: bool = True,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched Layer-2 decision per host row, one dispatch.

    ``windows`` (H, Nw) vs ``baselines`` (H, Nb) -> ``(fire, score, onset)``
    numpy arrays of length H: fire is the full scalar :func:`spike.detect`
    rule (max-z above threshold AND >= ``persistence`` of the window hot),
    onset the first above-threshold sample with arg-max z fallback —
    exactly :func:`repro.core.spike.detect_rows` (``exact=True`` makes the
    agreement byte-exact via the marginality guard), without the
    intermediate (H, Nw) z materialization in host memory.
    """
    windows = np.asarray(windows)
    baselines = np.asarray(baselines)
    if windows.ndim != 2 or baselines.ndim != 2 \
            or windows.shape[0] != baselines.shape[0]:
        raise ValueError(f"shape mismatch: windows {windows.shape} "
                         f"baselines {baselines.shape}")
    wn, bn = windows.shape[1], baselines.shape[1]
    tail32 = np.concatenate([np.asarray(baselines, np.float32),
                             np.asarray(windows, np.float32)], axis=1)
    fire, score, onset = _detect_tail(
        tail32, windows, baselines, wn, bn, float(threshold),
        float(persistence), bool(use_kernel), bool(interpret), bool(exact))
    return fire.astype(bool), score, onset.astype(np.intp)


def detect_hosts_slab(tail, wn: int, bn: int, threshold: float = 3.0,
                      persistence: float = 0.0, use_kernel: bool = True,
                      interpret: bool = True, exact: bool = True,
                      valid: Optional[np.ndarray] = None,
                      force_oracle: bool = False, device=None,
                      moments=None,
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """:func:`detect_hosts` over a trailing latency slab.

    ``tail`` is the (H, bn + wn) slab — baseline columns then window
    columns, exactly the layout of a trailing ring snapshot — staged as
    ONE contiguous f32 block (jax aliases aligned contiguous f32 numpy on
    CPU zero-copy, whereas a strided slab view takes the slow elementwise
    transfer path).

    ``valid`` (H, bn + wn) bool adds per-tick validity (chaos
    hardening): masked decisions route through the f64 oracle
    ``spike.detect_rows_masked`` — poisoned cells enter neither the
    moments nor the max/argmax, and hosts whose baseline keeps fewer
    than ``MIN_VALID_BASELINE_N`` valid samples stay quiet.  Corruption
    is the exceptional path, so it takes the oracle, not the kernel: the
    two can then never disagree.  An all-true mask is dropped and the
    call is byte-identical to ``valid=None``.

    ``force_oracle=True`` routes through the masked f64 oracle even for
    a clean (or absent) mask, as if an all-true mask were corrupt.  The
    sharded fleet monitor needs this: a single-slab round with ANY
    invalid cell takes the oracle for EVERY host, so when one shard sees
    corruption the clean shards must take the oracle too — otherwise the
    oracle-vs-fast split would follow shard boundaries and the parity
    contract would depend on where a host happens to live.

    ``device`` pins the fast path's sweep dispatch to one ``jax.Device``
    (see :func:`repro.kernels.sweep.ops.sweep_rows`); None keeps the
    default placement.

    ``moments`` (mu, sd) f64 arrays of length H pre-empt the direct
    baseline moment pass on the clean fast path (see
    :class:`repro.core.rolling.IncrementalMoments`); ignored on the
    masked/forced oracle path, which always derives exact masked moments
    itself.
    """
    tail = np.asarray(tail)
    if tail.ndim != 2 or tail.shape[-1] != wn + bn:
        raise ValueError(f"tail {tail.shape} vs bn+wn={bn + wn}")
    v = None
    if valid is not None:
        v = np.asarray(valid, bool)
        if v.shape != tail.shape:
            raise ValueError(f"valid {v.shape} vs tail {tail.shape}")
        if v.all():
            v = None
    if v is not None or force_oracle:
        if v is None:
            v = np.ones(tail.shape, bool)
        t64 = np.asarray(tail, np.float64)
        fire, score, onset = spike_mod.detect_rows_masked(
            t64[:, bn:], t64[:, :bn], v[:, bn:], v[:, :bn],
            float(threshold), float(persistence))
        return fire.astype(bool), score, onset.astype(np.intp)
    tail32 = np.ascontiguousarray(tail, np.float32)
    # the exact re-decision must see the caller's values, not the f32
    # staging — only a genuinely-f32 tail may reuse the staged copy
    patch = tail32 if tail.dtype == np.float32 else tail
    fire, score, onset = _detect_tail(
        tail32, patch[:, bn:], patch[:, :bn], int(wn), int(bn),
        float(threshold), float(persistence), bool(use_kernel),
        bool(interpret), bool(exact), device=device, moments=moments)
    return fire.astype(bool), score, onset.astype(np.intp)
