"""Pure-jnp oracle for the streaming detect kernel.

Same math as :func:`repro.core.spike.detect_rows` (sigma floor, max-z,
persistence fraction, first-hot/arg-max onset), in f32 over the whole host
slab at once — the XLA path the CPU benchmark times, and the AD-friendly
path.  The persistence gate compares an integer sample count (precomputed
by ops.persistence_count) so the f32 path decides bit-identically to the
f64 scalar rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spike import (
    MASK_NEG as NEG, SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL,
)


def detect_hosts_ref(windows: jax.Array, baselines: jax.Array,
                     threshold: float, min_hot: int,
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """windows (H, Nw), baselines (H, Nb) -> (fire i32, score f32, onset i32)."""
    w = windows.astype(jnp.float32)
    b = baselines.astype(jnp.float32)
    mu = b.mean(axis=-1)
    sd = b.std(axis=-1)
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)
    z = (w - mu[:, None]) / sd[:, None]
    score = z.max(axis=-1)
    hot = z > threshold
    cnt = jnp.sum(hot.astype(jnp.int32), axis=-1)
    fire = ((score > threshold) & (cnt >= min_hot)).astype(jnp.int32)
    onset = jnp.where(cnt > 0, jnp.argmax(hot, axis=-1),
                      jnp.argmax(z, axis=-1)).astype(jnp.int32)
    return fire, score, onset
