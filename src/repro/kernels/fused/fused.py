"""Pallas TPU kernel: fused baseline stats + max-z spike score + lagged
cross-correlation.

The seed pipeline dispatched :mod:`repro.kernels.spike` and
:mod:`repro.kernels.xcorr` separately, so every (host, metric) telemetry
window crossed HBM twice.  Here one grid cell handles (1 host, block_m
metrics) and computes, from a single VMEM-resident read of the tile:

  * baseline mean/std (VPU row reductions, sigma floor as in core.spike),
  * the window max-z spike score S_i,
  * the full lag sweep rho_i(k), |k| <= K, as one MXU matmul.

The lag-shifted latency matrix is built with a single gather from the
zero-padded centered latency row — ``Lshift[j, t] = Lpad[t + j]`` — instead
of the seed xcorr kernel's 2K+1-iteration Python loop of ``dynamic_slice``
calls, which unrolled into 2K+1 separate VMEM copies at trace time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.spike import (
    MASK_NEG as NEG, SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL,
)
from repro.kernels import tuning
from repro.kernels.xcorr.xcorr import shifted_lag_matrix

_EPS = 1e-12
LAG_PAD = tuning.DEFAULT_LAG_PAD   # default lag padding (env-overridable)


def _fused_kernel(n_valid: int, nb_valid: int, max_lag: int,
                  lat_ref, met_ref, base_ref, score_ref, rho_ref):
    """lat_ref (1, N); met_ref (1, bm, N); base_ref (1, bm, Nb);
    score_ref (1, bm); rho_ref (1, bm, LAG_PAD)."""
    N = lat_ref.shape[-1]
    Nb = base_ref.shape[-1]
    K = int(max_lag)
    bm = met_ref.shape[1]
    valid = (jax.lax.iota(jnp.int32, N) < n_valid).astype(jnp.float32)
    bmask = (jax.lax.iota(jnp.int32, Nb) < nb_valid).astype(jnp.float32)
    nv = jnp.float32(n_valid)
    nb = jnp.float32(nb_valid)

    # ---- Layer 2: baseline stats + window max-z (reads the tile once)
    b = base_ref[0] * bmask[None, :]
    mu = jnp.sum(b, axis=1) / nb                                   # (bm,)
    d = (b - mu[:, None]) * bmask[None, :]
    sd = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=1) / nb, 0.0))
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)

    w = met_ref[0]                                                 # (bm, N)
    z = (w - mu[:, None]) / sd[:, None]
    z = jnp.where(valid[None, :] > 0, z, NEG)
    score_ref[0] = jnp.max(z, axis=1)

    # ---- Layer 3: centered/normalized series, shared with the same tile
    L = lat_ref[0, :] * valid
    Lmean = jnp.sum(L) / nv
    Lc = (L - Lmean) * valid
    Ln = jnp.sqrt(jnp.sum(Lc * Lc)) + _EPS

    Mw = w * valid[None, :]
    Mmean = jnp.sum(Mw, axis=1, keepdims=True) / nv
    Mc = (Mw - Mmean) * valid[None, :]
    Mn = jnp.sqrt(jnp.sum(Mc * Mc, axis=1)) + _EPS                 # (bm,)

    Lshift = shifted_lag_matrix(Lc, K)                             # (2K+1, N)
    rho = jax.lax.dot_general(
        Mc, Lshift, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                        # (bm, 2K+1)
    rho = rho / (Mn[:, None] * Ln)
    out = jnp.zeros((bm, rho_ref.shape[-1]), jnp.float32)
    out = jax.lax.dynamic_update_slice(out, rho, (0, 0))
    rho_ref[0] = out


def fused_rca_pallas(latency: jax.Array, metrics: jax.Array,
                     baselines: jax.Array, max_lag: int,
                     n_valid: int | None = None, nb_valid: int | None = None,
                     block_m: int | None = None, lag_pad: int | None = None,
                     interpret: bool = True,
                     ) -> tuple[jax.Array, jax.Array]:
    """latency (B, N), metrics (B, M, N), baselines (B, M, Nb) ->
    (scores (B, M), rho (B, M, 2K+1)), fp32.

    N and Nb must be lane-aligned (pad + pass n_valid/nb_valid).
    ``interpret`` runs the kernel body on CPU (the bit-accurate validation
    path); on TPU pass interpret=False.  ``block_m``/``lag_pad`` default to
    the env-overridable tile config (kernels.tuning).
    """
    B, Mm, N = metrics.shape
    Nb = baselines.shape[-1]
    if N % 128 != 0 or Nb % 128 != 0:
        raise ValueError(f"N={N}, Nb={Nb} must be lane-aligned (x128)")
    n_valid = N if n_valid is None else int(n_valid)
    nb_valid = Nb if nb_valid is None else int(nb_valid)
    K = int(max_lag)
    bm = tuning.block_m(block_m)
    lp = tuning.lag_pad(K, lag_pad)
    pad_m = (-Mm) % bm
    if pad_m:
        metrics = jnp.pad(metrics, ((0, 0), (0, pad_m), (0, 0)))
        baselines = jnp.pad(baselines, ((0, 0), (0, pad_m), (0, 0)),
                            constant_values=1.0)
    Mp = Mm + pad_m

    scores, rho = pl.pallas_call(
        functools.partial(_fused_kernel, n_valid, nb_valid, K),
        grid=(B, Mp // bm),
        in_specs=[
            pl.BlockSpec((1, N), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bm, N), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bm, Nb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda b, j: (b, j)),
            pl.BlockSpec((1, bm, lp), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, Mp, lp), jnp.float32),
        ],
        interpret=interpret,
    )(latency.astype(jnp.float32), metrics.astype(jnp.float32),
      baselines.astype(jnp.float32))
    return scores[:, :Mm], rho[:, :Mm, : 2 * K + 1]


# --------------------------------------------------------------- masked rows
def _fused_masked_kernel(max_lag: int, nv_ref, nb_ref,
                         lat_ref, met_ref, base_ref, score_ref, rho_ref):
    """Per-row ragged variant: valid lengths come from SMEM scalars.

    nv_ref/nb_ref (1, 1) int32 — this grid row's valid window/baseline
    lengths; everything else identical to :func:`_fused_kernel`.  Rows are
    events here, not hosts: the event-batched Layer-3 path stacks every
    pending event's (latency, metrics, baselines) windows left-aligned
    into one slab and explains them all in one dispatch.
    """
    N = lat_ref.shape[-1]
    Nb = base_ref.shape[-1]
    K = int(max_lag)
    bm = met_ref.shape[1]
    n_valid = nv_ref[0, 0]
    nb_valid = nb_ref[0, 0]
    valid = (jax.lax.iota(jnp.int32, N) < n_valid).astype(jnp.float32)
    bmask = (jax.lax.iota(jnp.int32, Nb) < nb_valid).astype(jnp.float32)
    nv = n_valid.astype(jnp.float32)
    nb = nb_valid.astype(jnp.float32)

    b = base_ref[0] * bmask[None, :]
    mu = jnp.sum(b, axis=1) / nb
    d = (b - mu[:, None]) * bmask[None, :]
    sd = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=1) / nb, 0.0))
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)

    w = met_ref[0] * valid[None, :]
    z = (w - mu[:, None]) / sd[:, None]
    z = jnp.where(valid[None, :] > 0, z, NEG)
    score_ref[0] = jnp.max(z, axis=1)

    L = lat_ref[0, :] * valid
    Lmean = jnp.sum(L) / nv
    Lc = (L - Lmean) * valid
    Ln = jnp.sqrt(jnp.sum(Lc * Lc)) + _EPS

    Mmean = jnp.sum(w, axis=1, keepdims=True) / nv
    Mc = (w - Mmean) * valid[None, :]
    Mn = jnp.sqrt(jnp.sum(Mc * Mc, axis=1)) + _EPS

    Lshift = shifted_lag_matrix(Lc, K)
    rho = jax.lax.dot_general(
        Mc, Lshift, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    rho = rho / (Mn[:, None] * Ln)
    out = jnp.zeros((bm, rho_ref.shape[-1]), jnp.float32)
    out = jax.lax.dynamic_update_slice(out, rho, (0, 0))
    rho_ref[0] = out


def fused_rca_masked_pallas(latency: jax.Array, metrics: jax.Array,
                            baselines: jax.Array, n_valid: jax.Array,
                            nb_valid: jax.Array, max_lag: int,
                            block_m: int | None = None,
                            lag_pad: int | None = None,
                            interpret: bool = True,
                            ) -> tuple[jax.Array, jax.Array]:
    """Ragged-row fused RCA: per-row valid lengths.

    latency (B, N), metrics (B, M, N), baselines (B, M, Nb) left-aligned
    with zero tails; n_valid/nb_valid (B,) int32 give each row's true
    window/baseline lengths.  Returns (scores (B, M), rho (B, M, 2K+1)).
    """
    B, Mm, N = metrics.shape
    Nb = baselines.shape[-1]
    if N % 128 != 0 or Nb % 128 != 0:
        raise ValueError(f"N={N}, Nb={Nb} must be lane-aligned (x128)")
    K = int(max_lag)
    bm = tuning.block_m(block_m)
    lp = tuning.lag_pad(K, lag_pad)
    pad_m = (-Mm) % bm
    if pad_m:
        metrics = jnp.pad(metrics, ((0, 0), (0, pad_m), (0, 0)))
        baselines = jnp.pad(baselines, ((0, 0), (0, pad_m), (0, 0)),
                            constant_values=1.0)
    Mp = Mm + pad_m
    nv = n_valid.astype(jnp.int32).reshape(B, 1)
    nb = nb_valid.astype(jnp.int32).reshape(B, 1)

    scores, rho = pl.pallas_call(
        functools.partial(_fused_masked_kernel, K),
        grid=(B, Mp // bm),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, N), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bm, N), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bm, Nb), lambda b, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bm), lambda b, j: (b, j)),
            pl.BlockSpec((1, bm, lp), lambda b, j: (b, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Mp), jnp.float32),
            jax.ShapeDtypeStruct((B, Mp, lp), jnp.float32),
        ],
        interpret=interpret,
    )(nv, nb, latency.astype(jnp.float32), metrics.astype(jnp.float32),
      baselines.astype(jnp.float32))
    return scores[:, :Mm], rho[:, :Mm, : 2 * K + 1]
