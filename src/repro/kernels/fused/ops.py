"""Jit'd public wrapper for the fused spike+xcorr kernel with CPU fallback.

This is the fleet-RCA hot path: one dispatch yields, for every (host,
metric), the spike score against its baseline AND the full lag sweep against
that host's latency window — the two quantities confidence fusion consumes.
Rows can be hosts (fleet path) or pending events (event-batched eval path,
via the ragged ``fused_rca_max_ragged``).

``DISPATCH_COUNT`` counts python-level fused Layer-3 dispatches (one per
``fused_rca_max``/``fused_rca_max_ragged`` call, jit cache hits included) —
the eval harness asserts the 68-trial run issues exactly one per diagnoser.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused.fused import fused_rca_masked_pallas, fused_rca_pallas
from repro.kernels.fused.ref import fused_rca_masked_ref, fused_rca_ref

#: python-level fused-dispatch counter (see module docstring)
DISPATCH_COUNT = 0


def _pad128(x: jax.Array, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % 128
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def fused_rca(latency: jax.Array, metrics: jax.Array, baselines: jax.Array,
              max_lag: int = 20, use_kernel: bool = True,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(scores (B, M), rho (B, M, 2K+1)) for latency (B, N), metrics
    (B, M, N), baselines (B, M, Nb).

    ``use_kernel=True`` dispatches the fused Pallas kernel (interpret mode
    executes the body on CPU for validation); False composes the pure-jnp
    references — also the AD-friendly path.
    """
    if latency.ndim != 2 or metrics.ndim != 3 or baselines.ndim != 3:
        raise ValueError(f"latency {latency.shape}, metrics {metrics.shape}, "
                         f"baselines {baselines.shape}")
    if not use_kernel:
        return fused_rca_ref(latency, metrics, baselines, max_lag)
    n, nb = metrics.shape[-1], baselines.shape[-1]
    lat = _pad128(latency.astype(jnp.float32), 1)
    met = _pad128(metrics.astype(jnp.float32), 2)
    base = _pad128(baselines.astype(jnp.float32), 2)
    return fused_rca_pallas(lat, met, base, max_lag, n_valid=n, nb_valid=nb,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def _fused_rca_max_jit(latency, metrics, baselines, max_lag,
                       use_kernel, interpret):
    scores, rho = fused_rca(latency, metrics, baselines, max_lag,
                            use_kernel, interpret)
    idx = jnp.argmax(jnp.abs(rho), axis=-1)
    c = jnp.take_along_axis(jnp.abs(rho), idx[..., None], axis=-1)[..., 0]
    return scores, c, idx - max_lag


def fused_rca_max(latency, metrics, baselines, max_lag: int = 20,
                  use_kernel: bool = True, interpret: bool = True):
    """(scores, c, lag) per (B, M): spike scores plus max |rho| over lags
    and its arg-max lag — the exact inputs of confidence.rank_causes."""
    global DISPATCH_COUNT
    DISPATCH_COUNT += 1
    return _fused_rca_max_jit(latency, metrics, baselines, int(max_lag),
                              bool(use_kernel), bool(interpret))


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def _fused_rca_max_ragged_jit(latency, metrics, baselines, n_valid, nb_valid,
                              max_lag, use_kernel, interpret):
    # zero the tails inside the jit (XLA fuses it) so the masked math sees
    # exact zeros regardless of caller padding garbage
    N, Nb = metrics.shape[-1], baselines.shape[-1]
    tmask = jnp.arange(N)[None, :] < n_valid[:, None]
    bmask = jnp.arange(Nb)[None, :] < nb_valid[:, None]
    latency = jnp.where(tmask, latency, 0.0)
    metrics = jnp.where(tmask[:, None, :], metrics, 0.0)
    baselines = jnp.where(bmask[:, None, :], baselines, 0.0)
    if use_kernel:
        lat = _pad128(latency.astype(jnp.float32), 1)
        met = _pad128(metrics.astype(jnp.float32), 2)
        base = _pad128(baselines.astype(jnp.float32), 2)
        scores, rho = fused_rca_masked_pallas(lat, met, base, n_valid,
                                              nb_valid, max_lag,
                                              interpret=interpret)
    else:
        scores, rho = fused_rca_masked_ref(latency, metrics, baselines,
                                           n_valid, nb_valid, max_lag)
    idx = jnp.argmax(jnp.abs(rho), axis=-1)
    c = jnp.take_along_axis(jnp.abs(rho), idx[..., None], axis=-1)[..., 0]
    return scores, c, idx - max_lag


def fused_rca_max_ragged(latency, metrics, baselines, n_valid, nb_valid,
                         max_lag: int = 20, use_kernel: bool = False,
                         interpret: bool = True):
    """Ragged-row :func:`fused_rca_max`: rows (events or hosts) carry their
    own valid window/baseline lengths.

    ``latency`` (B, N), ``metrics`` (B, M, N), ``baselines`` (B, M, Nb) are
    left-aligned with arbitrary (ignored) tails beyond ``n_valid[b]`` /
    ``nb_valid[b]``.  One dispatch for the whole stack — the event-batched
    Layer-3 path of ``run_eval``.  ``use_kernel=False`` (default) runs the
    masked XLA reference, the CPU timing path; True dispatches the masked
    Pallas kernel (interpret mode validates on CPU).
    """
    global DISPATCH_COUNT
    DISPATCH_COUNT += 1
    latency = jnp.asarray(latency)
    metrics = jnp.asarray(metrics)
    baselines = jnp.asarray(baselines)
    if latency.ndim != 2 or metrics.ndim != 3 or baselines.ndim != 3:
        raise ValueError(f"latency {latency.shape}, metrics {metrics.shape}, "
                         f"baselines {baselines.shape}")
    return _fused_rca_max_ragged_jit(latency, metrics, baselines,
                                     jnp.asarray(n_valid, jnp.int32),
                                     jnp.asarray(nb_valid, jnp.int32),
                                     int(max_lag), bool(use_kernel),
                                     bool(interpret))
