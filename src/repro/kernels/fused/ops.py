"""Jit'd public wrapper for the fused spike+xcorr kernel with CPU fallback.

This is the fleet-RCA hot path: one dispatch yields, for every (host,
metric), the spike score against its baseline AND the full lag sweep against
that host's latency window — the two quantities confidence fusion consumes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.fused.fused import fused_rca_pallas
from repro.kernels.fused.ref import fused_rca_ref


def _pad128(x: jax.Array, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % 128
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def fused_rca(latency: jax.Array, metrics: jax.Array, baselines: jax.Array,
              max_lag: int = 20, use_kernel: bool = True,
              interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(scores (B, M), rho (B, M, 2K+1)) for latency (B, N), metrics
    (B, M, N), baselines (B, M, Nb).

    ``use_kernel=True`` dispatches the fused Pallas kernel (interpret mode
    executes the body on CPU for validation); False composes the pure-jnp
    references — also the AD-friendly path.
    """
    if latency.ndim != 2 or metrics.ndim != 3 or baselines.ndim != 3:
        raise ValueError(f"latency {latency.shape}, metrics {metrics.shape}, "
                         f"baselines {baselines.shape}")
    if not use_kernel:
        return fused_rca_ref(latency, metrics, baselines, max_lag)
    n, nb = metrics.shape[-1], baselines.shape[-1]
    lat = _pad128(latency.astype(jnp.float32), 1)
    met = _pad128(metrics.astype(jnp.float32), 2)
    base = _pad128(baselines.astype(jnp.float32), 2)
    return fused_rca_pallas(lat, met, base, max_lag, n_valid=n, nb_valid=nb,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def fused_rca_max(latency, metrics, baselines, max_lag: int = 20,
                  use_kernel: bool = True, interpret: bool = True):
    """(scores, c, lag) per (B, M): spike scores plus max |rho| over lags
    and its arg-max lag — the exact inputs of confidence.rank_causes."""
    scores, rho = fused_rca(latency, metrics, baselines, max_lag,
                            use_kernel, interpret)
    idx = jnp.argmax(jnp.abs(rho), axis=-1)
    c = jnp.take_along_axis(jnp.abs(rho), idx[..., None], axis=-1)[..., 0]
    return scores, c, idx - max_lag
