"""Fused Layer-2+3 kernel: baseline stats + spike scores + lagged xcorr in
one pass over each (host, metric-block) tile."""
from repro.kernels.fused.ops import (
    fused_rca, fused_rca_max, fused_rca_max_ragged,
)

__all__ = ["fused_rca", "fused_rca_max", "fused_rca_max_ragged"]
