"""Pure-jnp oracle for the fused spike+xcorr kernel.

Composes the two single-purpose oracles — proving the fusion changes data
movement, not math.  ``fused_rca_masked_ref`` is the ragged-row variant
(per-row valid lengths) behind the event-batched Layer-3 path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.spike import (
    MASK_NEG as NEG, SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL,
)
from repro.kernels.spike.ref import spike_scores_ref
from repro.kernels.xcorr.ref import lagged_xcorr_ref

_EPS = 1e-12


def fused_rca_ref(latency: jax.Array, metrics: jax.Array,
                  baselines: jax.Array, max_lag: int,
                  ) -> tuple[jax.Array, jax.Array]:
    """latency (B, N), metrics (B, M, N), baselines (B, M, Nb) ->
    (scores (B, M), rho (B, M, 2K+1)) f32."""
    scores = spike_scores_ref(metrics, baselines)
    rho = lagged_xcorr_ref(latency, metrics, max_lag)
    return scores, rho


def fused_rca_masked_ref(latency: jax.Array, metrics: jax.Array,
                         baselines: jax.Array, n_valid: jax.Array,
                         nb_valid: jax.Array, max_lag: int,
                         ) -> tuple[jax.Array, jax.Array]:
    """Ragged-row oracle: rows are left-aligned with zero tails and
    ``n_valid``/``nb_valid`` (B,) int32 give true lengths per row.

    Same math as composing spike_scores_ref + lagged_xcorr_ref on each
    row's valid prefix: baseline moments over the valid baseline samples,
    max-z over the valid window, and overlap-only lag products normalized
    by full-(valid-)window energies.
    """
    B, Mm, N = metrics.shape
    Nb = baselines.shape[-1]
    K = int(max_lag)
    L = latency.astype(jnp.float32)
    Mx = metrics.astype(jnp.float32)
    Bs = baselines.astype(jnp.float32)
    nv = n_valid.astype(jnp.float32)[:, None]                   # (B, 1)
    nbv = nb_valid.astype(jnp.float32)[:, None]
    tmask = (jnp.arange(N)[None, :] < n_valid[:, None]
             ).astype(jnp.float32)                              # (B, N)
    bmask = (jnp.arange(Nb)[None, :] < nb_valid[:, None]
             ).astype(jnp.float32)                              # (B, Nb)

    # Layer 2: baseline stats + window max-z over the valid samples
    b = Bs * bmask[:, None, :]
    mu = jnp.sum(b, axis=-1) / nbv                              # (B, M)
    d = (b - mu[..., None]) * bmask[:, None, :]
    sd = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1) / nbv, 0.0))
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)
    w = Mx * tmask[:, None, :]
    z = (w - mu[..., None]) / sd[..., None]
    z = jnp.where(tmask[:, None, :] > 0, z, NEG)
    scores = jnp.max(z, axis=-1)                                # (B, M)

    # Layer 3: centered/normalized series, one gather-based lag sweep
    Lm = L * tmask
    Lc = (Lm - jnp.sum(Lm, axis=-1, keepdims=True) / nv) * tmask
    Ln = jnp.sqrt(jnp.sum(Lc * Lc, axis=-1)) + _EPS             # (B,)
    Mc = (w - jnp.sum(w, axis=-1, keepdims=True) / nv[..., None]
          ) * tmask[:, None, :]
    Mn = jnp.sqrt(jnp.sum(Mc * Mc, axis=-1)) + _EPS             # (B, M)
    Lpad = jnp.pad(Lc, ((0, 0), (K, K)))
    idx = (jnp.arange(2 * K + 1)[:, None]
           + jnp.arange(N)[None, :])                            # (2K+1, N)
    Lshift = Lpad[:, idx]                                       # (B, 2K+1, N)
    rho = jnp.einsum("bmt,bkt->bmk", Mc, Lshift)
    rho = rho / (Mn[..., None] * Ln[:, None, None])
    return scores, rho
