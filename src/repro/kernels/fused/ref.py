"""Pure-jnp oracle for the fused spike+xcorr kernel.

Composes the two single-purpose oracles — proving the fusion changes data
movement, not math.
"""
from __future__ import annotations

import jax

from repro.kernels.spike.ref import spike_scores_ref
from repro.kernels.xcorr.ref import lagged_xcorr_ref


def fused_rca_ref(latency: jax.Array, metrics: jax.Array,
                  baselines: jax.Array, max_lag: int,
                  ) -> tuple[jax.Array, jax.Array]:
    """latency (B, N), metrics (B, M, N), baselines (B, M, Nb) ->
    (scores (B, M), rho (B, M, 2K+1)) f32."""
    scores = spike_scores_ref(metrics, baselines)
    rho = lagged_xcorr_ref(latency, metrics, max_lag)
    return scores, rho
