"""Jit'd wrapper for the batched spike-score kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.spike.ref import spike_scores_ref
from repro.kernels.spike.spike import spike_scores_pallas


def _pad128(x: jax.Array, axis: int) -> jax.Array:
    pad = (-x.shape[axis]) % 128
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def spike_scores(windows: jax.Array, baselines: jax.Array,
                 use_kernel: bool = True, interpret: bool = True,
                 ) -> jax.Array:
    """Batched spike scores (B, M) for (B, M, Nw) windows vs (B, M, Nb)."""
    if not use_kernel:
        return spike_scores_ref(windows, baselines)
    nw, nb = windows.shape[-1], baselines.shape[-1]
    w = _pad128(windows.astype(jnp.float32), 2)
    b = _pad128(baselines.astype(jnp.float32), 2)
    return spike_scores_pallas(w, b, nw_valid=nw, nb_valid=nb,
                               interpret=interpret)
