"""Pallas TPU kernel: fused baseline stats + max-z spike score.

One grid cell handles (1 host, block_m metrics): baseline mean/std and the
window max-z are VPU row reductions over lane-aligned windows; the fusion
avoids materializing the (B, M, N) z-score tensor in HBM — the kernel reads
each telemetry row once and writes one score.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

SIGMA_FLOOR_REL = 1e-3
SIGMA_FLOOR_ABS = 1e-9
NEG = -3.4e38


def _spike_kernel(nw_valid: int, nb_valid: int, win_ref, base_ref, out_ref):
    """win_ref (1, bm, Nw), base_ref (1, bm, Nb), out_ref (1, bm)."""
    Nw = win_ref.shape[-1]
    Nb = base_ref.shape[-1]
    bm = win_ref.shape[1]
    wmask = (jax.lax.iota(jnp.int32, Nw) < nw_valid)
    bmask = (jax.lax.iota(jnp.int32, Nb) < nb_valid).astype(jnp.float32)
    nb = jnp.float32(nb_valid)

    b = base_ref[0] * bmask[None, :]
    mu = jnp.sum(b, axis=1) / nb                                  # (bm,)
    var = jnp.sum((b - mu[:, None]) * bmask[None, :] * (b - mu[:, None]),
                  axis=1) / nb
    sd = jnp.sqrt(jnp.maximum(var, 0.0))
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)

    w = win_ref[0]
    z = (w - mu[:, None]) / sd[:, None]
    z = jnp.where(wmask[None, :], z, NEG)
    out_ref[0] = jnp.max(z, axis=1)


def spike_scores_pallas(windows: jax.Array, baselines: jax.Array,
                        nw_valid: int | None = None,
                        nb_valid: int | None = None,
                        block_m: int | None = None, interpret: bool = True,
                        ) -> jax.Array:
    """windows (B, M, Nw), baselines (B, M, Nb) -> (B, M) f32.

    ``block_m`` defaults to the env-overridable tile config."""
    B, M, Nw = windows.shape
    Nb = baselines.shape[-1]
    if Nw % 128 or Nb % 128:
        raise ValueError("window dims must be lane-aligned")
    nw_valid = Nw if nw_valid is None else int(nw_valid)
    nb_valid = Nb if nb_valid is None else int(nb_valid)
    block_m = tuning.block_m(block_m)
    pad_m = (-M) % block_m
    if pad_m:
        windows = jnp.pad(windows, ((0, 0), (0, pad_m), (0, 0)))
        baselines = jnp.pad(baselines, ((0, 0), (0, pad_m), (0, 0)),
                            constant_values=1.0)
    Mp = M + pad_m
    out = pl.pallas_call(
        functools.partial(_spike_kernel, nw_valid, nb_valid),
        grid=(B, Mp // block_m),
        in_specs=[
            pl.BlockSpec((1, block_m, Nw), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_m, Nb), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((B, Mp), jnp.float32),
        interpret=interpret,
    )(windows.astype(jnp.float32), baselines.astype(jnp.float32))
    return out[:, :M]
