"""Pure-jnp oracle for batched spike scores (paper Layer 2, batched)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

SIGMA_FLOOR_REL = 1e-3
SIGMA_FLOOR_ABS = 1e-9


def spike_scores_ref(windows: jax.Array, baselines: jax.Array) -> jax.Array:
    """windows (B, M, N), baselines (B, M, Nb) -> scores (B, M) f32.

    S = max_t (w(t) - mu_b) / max(sigma_b, floor)   (one-sided rise).
    """
    w = windows.astype(jnp.float32)
    b = baselines.astype(jnp.float32)
    mu = b.mean(axis=-1)
    sd = b.std(axis=-1)
    floor = jnp.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * jnp.abs(mu))
    sd = jnp.maximum(sd, floor)
    return ((w - mu[..., None]) / sd[..., None]).max(axis=-1)
