"""Env/config-driven tile-size selection for the Pallas kernels.

The fused, xcorr and detect kernels tile their grids by ``block_m`` (metric
rows per grid cell — hosts, for the detect kernel) and pad the lag axis to
``LAG_PAD`` lanes.  The defaults below are the shapes the kernels were
written against (DESIGN.md §6: bm=8 keeps the (bm + 2K + 2) x N x 4-byte
working set far under VMEM); on real TPU hardware the sweet spot depends on
the generation, so both are overridable without code edits:

    REPRO_BLOCK_M=16 REPRO_LAG_PAD=128 python -m benchmarks.run --only kernel
    REPRO_DETECT_BLOCK_H=32 ...                      # detect kernel host tile

``benchmarks/kernelbench.py`` sweeps the ``block_m`` candidates in interpret
mode (`kernel/tile_sweep/*` rows) so a hardware run has a starting grid; the
ROADMAP's TPU-tuning item consumes those rows.
"""
from __future__ import annotations

import os

DEFAULT_BLOCK_M = 8      # metric rows per (host, metric-block) grid cell
DEFAULT_BLOCK_H = 8      # host rows per detect-kernel grid cell
DEFAULT_LAG_PAD = 64     # lag output lanes (>= 2K+1, lane-aligned)

#: candidates the interpret-mode microbench sweeps (hardware starting grid)
BLOCK_M_CANDIDATES = (4, 8, 16)


def _env_int(name: str, default: int, minimum: int = 1) -> int:
    raw = os.environ.get(name)
    if not raw:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer")
    if v < minimum:
        raise ValueError(f"{name}={v} must be >= {minimum}")
    return v


def block_m(override: int | None = None) -> int:
    """Metric-block rows for the fused/xcorr/spike kernels."""
    if override is not None:
        return int(override)
    return _env_int("REPRO_BLOCK_M", DEFAULT_BLOCK_M)


def detect_block_h(override: int | None = None) -> int:
    """Host-block rows for the streaming detect kernel."""
    if override is not None:
        return int(override)
    return _env_int("REPRO_DETECT_BLOCK_H", DEFAULT_BLOCK_H)


def lag_pad(max_lag: int, override: int | None = None) -> int:
    """Lag-axis padding: env/explicit override, floored at 2K+1."""
    pad = (int(override) if override is not None
           else _env_int("REPRO_LAG_PAD", DEFAULT_LAG_PAD))
    return max(pad, 2 * int(max_lag) + 1)
