"""Env/config-driven tile-size selection for the Pallas kernels.

The fused, xcorr and detect kernels tile their grids by ``block_m`` (metric
rows per grid cell — hosts, for the detect kernel) and pad the lag axis to
``LAG_PAD`` lanes.  The defaults below are the shapes the kernels were
written against (DESIGN.md §6: bm=8 keeps the (bm + 2K + 2) x N x 4-byte
working set far under VMEM); on real TPU hardware the sweet spot depends on
the generation, so both are overridable without code edits:

    REPRO_BLOCK_M=16 REPRO_LAG_PAD=128 python -m benchmarks.run --only kernel
    REPRO_SWEEP_BLOCK_T=64 REPRO_SWEEP_BLOCK_R=4 ... # sweep kernel tile

``benchmarks/kernelbench.py`` sweeps the ``block_m`` candidates in interpret
mode (`kernel/tile_sweep/*` rows) so a hardware run has a starting grid; the
ROADMAP's TPU-tuning item consumes those rows.
"""
from __future__ import annotations

import os

DEFAULT_BLOCK_M = 8      # metric rows per (host, metric-block) grid cell
DEFAULT_LAG_PAD = 64     # lag output lanes (>= 2K+1, lane-aligned)
DEFAULT_SWEEP_BLOCK_T = 128   # evaluation ticks per sweep tile / ref block
DEFAULT_SWEEP_BLOCK_R = 8     # latency rows per sweep-kernel grid cell

#: candidates the interpret-mode microbench sweeps (hardware starting grid)
BLOCK_M_CANDIDATES = (4, 8, 16)


def _env_int(name: str, default: int, minimum: int = 1) -> int:
    raw = os.environ.get(name)
    if not raw:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer")
    if v < minimum:
        raise ValueError(f"{name}={v} must be >= {minimum}")
    return v


def block_m(override: int | None = None) -> int:
    """Metric-block rows for the fused/xcorr/spike kernels."""
    if override is not None:
        return int(override)
    return _env_int("REPRO_BLOCK_M", DEFAULT_BLOCK_M)


def lag_pad(max_lag: int, override: int | None = None) -> int:
    """Lag-axis padding: env/explicit override, floored at 2K+1."""
    pad = (int(override) if override is not None
           else _env_int("REPRO_LAG_PAD", DEFAULT_LAG_PAD))
    return max(pad, 2 * int(max_lag) + 1)


def sweep_block_t(override: int | None = None) -> int:
    """Evaluation ticks per Layer-2 sweep tile (``REPRO_SWEEP_BLOCK_T``).

    Bounds peak memory of the batched detection sweep: the (rows, ticks,
    wn) z-block is only ever materialized ``block_t`` ticks at a time, both
    in the masked-XLA reference (a ``lax.map`` step) and as the tick axis
    of one Pallas grid cell.  Larger tiles amortize dispatch overhead;
    smaller ones cap the VMEM working set (~``block_r * block_t * wn * 4``
    bytes per live intermediate).
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SWEEP_BLOCK_T", DEFAULT_SWEEP_BLOCK_T)


def sweep_block_r(override: int | None = None) -> int:
    """Latency rows per sweep-kernel grid cell (``REPRO_SWEEP_BLOCK_R``).

    Each cell keeps its ``block_r`` full (row, T) latency series VMEM-
    resident and gathers the cell's tick windows from them, so the row
    tile bounds the resident-slab footprint (``block_r * T * 4`` bytes) on
    top of the tick-block working set above.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SWEEP_BLOCK_R", DEFAULT_SWEEP_BLOCK_R)
