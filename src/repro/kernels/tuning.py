"""Env/config-driven tile-size selection for the Pallas kernels.

The fused, xcorr and detect kernels tile their grids by ``block_m`` (metric
rows per grid cell — hosts, for the detect kernel) and pad the lag axis to
``LAG_PAD`` lanes.  The defaults below are the shapes the kernels were
written against (DESIGN.md §6: bm=8 keeps the (bm + 2K + 2) x N x 4-byte
working set far under VMEM); on real TPU hardware the sweet spot depends on
the generation, so both are overridable without code edits:

    REPRO_BLOCK_M=16 REPRO_LAG_PAD=128 python -m benchmarks.run --only kernel
    REPRO_SWEEP_BLOCK_T=64 REPRO_SWEEP_BLOCK_R=4 ... # sweep kernel tile

``benchmarks/kernelbench.py`` sweeps the ``block_m`` candidates in interpret
mode (`kernel/tile_sweep/*` rows) so a hardware run has a starting grid; the
ROADMAP's TPU-tuning item consumes those rows.
"""
from __future__ import annotations

import os

DEFAULT_BLOCK_M = 8      # metric rows per (host, metric-block) grid cell
DEFAULT_LAG_PAD = 64     # lag output lanes (>= 2K+1, lane-aligned)
DEFAULT_SWEEP_BLOCK_T = 128   # evaluation ticks per sweep tile / ref block
DEFAULT_SWEEP_BLOCK_R = 8     # latency rows per sweep-kernel grid cell
DEFAULT_SHARD_HOSTS = 1024    # hosts per fleet-monitor shard slab
DEFAULT_RACK_SHARDS = 8       # shards per rack in the two-level reduce
DEFAULT_SHARD_TOPK = 16       # evidence candidates shipped per shard/rack
DEFAULT_REANCHOR_ROUNDS = 32  # rounds between exact-f64 moment re-anchors
DEFAULT_MOMENT_BLOCK = 64     # ticks per cached incremental-moment block

#: candidates the interpret-mode microbench sweeps (hardware starting grid)
BLOCK_M_CANDIDATES = (4, 8, 16)


def _env_int(name: str, default: int, minimum: int = 1) -> int:
    raw = os.environ.get(name)
    if not raw:
        return default
    try:
        v = int(raw)
    except ValueError:
        raise ValueError(f"{name}={raw!r} is not an integer")
    if v < minimum:
        raise ValueError(f"{name}={v} must be >= {minimum}")
    return v


def block_m(override: int | None = None) -> int:
    """Metric-block rows for the fused/xcorr/spike kernels."""
    if override is not None:
        return int(override)
    return _env_int("REPRO_BLOCK_M", DEFAULT_BLOCK_M)


def lag_pad(max_lag: int, override: int | None = None) -> int:
    """Lag-axis padding: env/explicit override, floored at 2K+1."""
    pad = (int(override) if override is not None
           else _env_int("REPRO_LAG_PAD", DEFAULT_LAG_PAD))
    return max(pad, 2 * int(max_lag) + 1)


def sweep_block_t(override: int | None = None) -> int:
    """Evaluation ticks per Layer-2 sweep tile (``REPRO_SWEEP_BLOCK_T``).

    Bounds peak memory of the batched detection sweep: the (rows, ticks,
    wn) z-block is only ever materialized ``block_t`` ticks at a time, both
    in the masked-XLA reference (a ``lax.map`` step) and as the tick axis
    of one Pallas grid cell.  Larger tiles amortize dispatch overhead;
    smaller ones cap the VMEM working set (~``block_r * block_t * wn * 4``
    bytes per live intermediate).
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SWEEP_BLOCK_T", DEFAULT_SWEEP_BLOCK_T)


def sweep_block_r(override: int | None = None) -> int:
    """Latency rows per sweep-kernel grid cell (``REPRO_SWEEP_BLOCK_R``).

    Each cell keeps its ``block_r`` full (row, T) latency series VMEM-
    resident and gathers the cell's tick windows from them, so the row
    tile bounds the resident-slab footprint (``block_r * T * 4`` bytes) on
    top of the tick-block working set above.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SWEEP_BLOCK_R", DEFAULT_SWEEP_BLOCK_R)


def shard_hosts(override: int | None = None) -> int:
    """Hosts per fleet-monitor shard slab (``REPRO_SHARD_HOSTS``).

    The sharded fleet monitor (monitor/shard.py) cuts the (hosts, C, T)
    fleet into contiguous slabs of at most this many hosts; each slab is
    one detect dispatch (one device placement on the mesh) and one
    evidence gather.  Bounds per-shard resident memory at
    ``shard_hosts * C * T * 4`` bytes — the knob that keeps 64k-host
    fleets feasible on a box that could never hold the full slab.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SHARD_HOSTS", DEFAULT_SHARD_HOSTS)


def rack_shards(override: int | None = None) -> int:
    """Shards per rack in the two-level reduce (``REPRO_RACK_SHARDS``).

    Shard candidate lists are merged rack-first, then rack winners merge
    at fleet level — the fan-in at each tree level stays at most
    ``rack_shards`` (resp. ``ceil(n_shards / rack_shards)``) instead of
    ``n_shards``.  Shapes the reduce topology only; verdicts are
    invariant to it (the merge order is deterministic and the candidate
    order is a total order).
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_RACK_SHARDS", DEFAULT_RACK_SHARDS)


def reanchor_rounds(override: int | None = None) -> int:
    """Rounds between exact-f64 moment re-anchors (``REPRO_REANCHOR_ROUNDS``).

    The incremental streaming-moment state (core/rolling.py) is rebuilt
    from scratch and bitwise-compared against the incrementally-maintained
    cache every this-many monitor rounds — the drift guard that turns
    "incremental must equal from-scratch" from a hope into a continuously
    re-proven invariant (``fleet/incremental_parity``).  Lower values
    re-prove more often at O(rows * bn) per re-anchor; the block-anchored
    design makes equality exact by construction, so the default re-checks
    sparsely.  Forced re-anchors (chaos rounds, agent restarts, checkpoint
    restores) ignore this cadence.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_REANCHOR_ROUNDS", DEFAULT_REANCHOR_ROUNDS)


def moment_block(override: int | None = None) -> int:
    """Ticks per cached incremental-moment block (``REPRO_MOMENT_BLOCK``).

    The incremental moments partition the absolute tick axis into fixed
    blocks of this many ticks and cache one f64 (sum, sum-of-squares)
    pair per (host, block).  Each block entry is a pure function of that
    block's values at fixed absolute positions — which is what makes the
    incremental state bitwise-identical to a from-scratch rebuild.  A
    monitor round pays O(delta) new-block work plus O(bn / block)
    combine; smaller blocks shrink the per-round head/tail partial
    reductions (<= 2 * block ticks) while growing the combine fan-in.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_MOMENT_BLOCK", DEFAULT_MOMENT_BLOCK)


def shard_topk(override: int | None = None) -> int:
    """Evidence candidates shipped per shard/rack (``REPRO_SHARD_TOPK``).

    The deployment default for the ``rca_top_k`` cap a sharded fleet
    passes to its monitor (the bench's storm rows and the operations
    runbook use it): each shard then ships evidence blocks for at most
    this many of its worst flagged hosts, and each rack forwards at most
    this many of its shards' union — the bound that keeps cross-shard
    traffic at candidates, never raw telemetry, during an incident
    storm.  Not applied implicitly: a ``ShardedFleetMonitor`` built
    without ``rca_top_k`` explains every flagged host, exactly like the
    single-slab monitor it must stay byte-exact against.
    """
    if override is not None:
        return int(override)
    return _env_int("REPRO_SHARD_TOPK", DEFAULT_SHARD_TOPK)
