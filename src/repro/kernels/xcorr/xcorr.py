"""Pallas TPU kernel: batched lagged cross-correlation.

Fleet-scale Layer 3 (DESIGN.md §6): one correlation engine ingests windows
from B hosts x M metrics and correlates each against that host's latency
window over lags |k| <= K.

TPU mapping: for one (host, metric-block) grid cell we materialize the
lag-shifted latency matrix Lshift (2K+1, N) in VMEM once (a single gather
from a zero-padded row — :func:`shifted_lag_matrix`), then the
whole lag sweep is a single MXU matmul:

    rho_block = Mc (bm, N) @ Lshift^T (N, 2K+1)

with fp32 accumulation; means/norms are VPU row reductions.  Block shapes
keep the working set ((bm + 2K + 2) * N * 4 bytes ~ 0.3 MB for bm=8,
N=512, K=20) far under VMEM, and N is lane-aligned (multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import tuning

_EPS = 1e-12
LAG_PAD = tuning.DEFAULT_LAG_PAD   # default lag padding (env-overridable)


def shifted_lag_matrix(lc: jax.Array, max_lag: int) -> jax.Array:
    """(2K+1, N) matrix with row j pairing L(t) with M(t - (j - K)).

    One gather from the zero-padded row: Lshift[j, t] = Lpad[t + j], with
    Lpad[K:K+N] = lc.  Positive lag = metric leads, matching core.xcorr.
    Shared by this kernel and kernels.fused.
    """
    N = lc.shape[-1]
    K = int(max_lag)
    lpad = jnp.zeros((N + 2 * K,), jnp.float32)
    lpad = jax.lax.dynamic_update_slice(lpad, lc, (K,))
    j = jax.lax.broadcasted_iota(jnp.int32, (2 * K + 1, N), 0)
    t = jax.lax.broadcasted_iota(jnp.int32, (2 * K + 1, N), 1)
    return jnp.take(lpad, j + t, axis=0)


def _xcorr_kernel(n_valid: int, max_lag: int,
                  lat_ref, met_ref, out_ref):
    """lat_ref: (1, N); met_ref: (1, bm, N); out_ref: (1, bm, LAG_PAD)."""
    N = lat_ref.shape[-1]
    K = max_lag
    bm = met_ref.shape[1]

    valid = (jax.lax.iota(jnp.int32, N) < n_valid).astype(jnp.float32)
    nv = jnp.float32(n_valid)

    L = lat_ref[0, :] * valid
    Lmean = jnp.sum(L) / nv
    Lc = (L - Lmean) * valid
    Ln = jnp.sqrt(jnp.sum(Lc * Lc)) + _EPS

    M = met_ref[0] * valid[None, :]                    # (bm, N)
    Mmean = jnp.sum(M, axis=1, keepdims=True) / nv
    Mc = (M - Mmean) * valid[None, :]
    Mn = jnp.sqrt(jnp.sum(Mc * Mc, axis=1)) + _EPS     # (bm,)

    # lag-shifted latency matrix in one gather from the zero-padded row:
    # row j pairs L(t) with M(t - (j - K)):  Lshift[j, t] = Lc[t + (j - K)]
    # (positive lag = metric leads, matching core.xcorr and ref.py)
    Lshift = shifted_lag_matrix(Lc, K)                 # (2K+1, N)

    rho = jax.lax.dot_general(
        Mc, Lshift, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)            # (bm, 2K+1)
    rho = rho / (Mn[:, None] * Ln)
    out = jnp.zeros((bm, out_ref.shape[-1]), jnp.float32)
    out = jax.lax.dynamic_update_slice(out, rho, (0, 0))
    out_ref[0] = out


def lagged_xcorr_pallas(latency: jax.Array, metrics: jax.Array,
                        max_lag: int, n_valid: int | None = None,
                        block_m: int | None = None,
                        lag_pad: int | None = None,
                        interpret: bool = True) -> jax.Array:
    """latency (B, N), metrics (B, M, N) -> rho (B, M, 2K+1), fp32.

    N must be a multiple of 128 (pad + pass ``n_valid``).  ``interpret``
    runs the kernel body on CPU (bit-accurate validation path); on TPU pass
    interpret=False.  ``block_m``/``lag_pad`` default to the
    env-overridable tile config (kernels.tuning).
    """
    B, Mm, N = metrics.shape
    if N % 128 != 0:
        raise ValueError(f"N={N} must be lane-aligned (multiple of 128)")
    n_valid = N if n_valid is None else int(n_valid)
    K = int(max_lag)
    bm = tuning.block_m(block_m)
    lp = tuning.lag_pad(K, lag_pad)
    pad_m = (-Mm) % bm
    if pad_m:
        metrics = jnp.pad(metrics, ((0, 0), (0, pad_m), (0, 0)))
    Mp = Mm + pad_m

    out = pl.pallas_call(
        functools.partial(_xcorr_kernel, n_valid, K),
        grid=(B, Mp // bm),
        in_specs=[
            pl.BlockSpec((1, N), lambda b, j: (b, 0)),
            pl.BlockSpec((1, bm, N), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bm, lp), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Mp, lp), jnp.float32),
        interpret=interpret,
    )(latency.astype(jnp.float32), metrics.astype(jnp.float32))
    return out[:, :Mm, : 2 * K + 1]
