"""Jit'd public wrapper for the lagged-xcorr kernel with CPU fallback."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.xcorr.ref import lagged_xcorr_ref
from repro.kernels.xcorr.xcorr import lagged_xcorr_pallas


def _pad_to(x: jax.Array, mult: int, axis: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def lagged_xcorr(latency: jax.Array, metrics: jax.Array, max_lag: int = 20,
                 use_kernel: bool = True, interpret: bool = True,
                 ) -> jax.Array:
    """Batched rho (B, M, 2K+1).  latency (B, N), metrics (B, M, N).

    ``use_kernel=True`` dispatches to the Pallas TPU kernel (interpret mode
    executes the kernel body on CPU for validation); False uses the
    pure-jnp reference — also the AD-friendly path.
    """
    if latency.ndim != 2 or metrics.ndim != 3:
        raise ValueError(f"latency {latency.shape}, metrics {metrics.shape}")
    if not use_kernel:
        return lagged_xcorr_ref(latency, metrics, max_lag)
    n = latency.shape[-1]
    lat = _pad_to(latency.astype(jnp.float32), 128, 1)
    met = _pad_to(metrics.astype(jnp.float32), 128, 2)
    return lagged_xcorr_pallas(lat, met, max_lag, n_valid=n,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("max_lag", "use_kernel",
                                             "interpret"))
def max_abs_xcorr(latency, metrics, max_lag: int = 20,
                  use_kernel: bool = True, interpret: bool = True):
    """(c, lag) per (B, M): max |rho| over lags and its arg-max lag."""
    rho = lagged_xcorr(latency, metrics, max_lag, use_kernel, interpret)
    idx = jnp.argmax(jnp.abs(rho), axis=-1)
    c = jnp.take_along_axis(jnp.abs(rho), idx[..., None], axis=-1)[..., 0]
    return c, idx - max_lag
