"""Pure-jnp oracle for batched lagged cross-correlation.

rho[b, m, K+k] = sum_t Lc[b,t] Mc[b,m,t-k] / (||Lc[b]|| * ||Mc[b,m]||)
for k in [-K, K] (positive k: metric leads), overlap-only numerator,
full-window norms (paper §2.2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


def lagged_xcorr_ref(latency: jax.Array, metrics: jax.Array,
                     max_lag: int) -> jax.Array:
    """latency: (B, N) f32; metrics: (B, M, N) f32 -> (B, M, 2K+1) f32."""
    L = latency.astype(jnp.float32)
    Mx = metrics.astype(jnp.float32)
    B, N = L.shape
    K = int(max_lag)
    Lc = L - L.mean(axis=-1, keepdims=True)
    Mc = Mx - Mx.mean(axis=-1, keepdims=True)
    Ln = jnp.sqrt(jnp.sum(Lc * Lc, axis=-1)) + _EPS          # (B,)
    Mn = jnp.sqrt(jnp.sum(Mc * Mc, axis=-1)) + _EPS          # (B, M)

    def one_lag(k):
        # pair L(t) with M(t-k): positive k = metric leads
        def pos():
            return jnp.einsum("bt,bmt->bm", Lc[:, k:], Mc[:, :, :N - k])
        def neg():
            return jnp.einsum("bt,bmt->bm", Lc[:, :N + k], Mc[:, :, -k:])
        return pos() if k >= 0 else neg()

    cols = [one_lag(k) for k in range(-K, K + 1)]
    rho = jnp.stack(cols, axis=-1)                            # (B, M, 2K+1)
    return rho / (Mn[..., None] * Ln[:, None, None])


def max_abs_xcorr_ref(latency, metrics, max_lag):
    rho = lagged_xcorr_ref(latency, metrics, max_lag)
    idx = jnp.argmax(jnp.abs(rho), axis=-1)
    c = jnp.take_along_axis(jnp.abs(rho), idx[..., None], axis=-1)[..., 0]
    return c, idx - max_lag
