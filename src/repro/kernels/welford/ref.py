"""Pure-jnp oracle for chunked Welford statistics."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def welford_ref(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x (B, M, N) -> (mean (B, M), var (B, M)), population variance, f64-
    free but numerically careful reference (two-pass)."""
    x = x.astype(jnp.float32)
    mu = x.mean(axis=-1)
    var = jnp.mean((x - mu[..., None]) ** 2, axis=-1)
    return mu, var
