"""Jit'd wrapper for the Welford kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.welford.ref import welford_ref
from repro.kernels.welford.welford import welford_pallas


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def welford(x: jax.Array, use_kernel: bool = True, interpret: bool = True):
    """(mean, var) over the last axis of (B, M, N)."""
    if not use_kernel:
        return welford_ref(x)
    n = x.shape[-1]
    pad = (-n) % 128
    if pad:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad)))
    return welford_pallas(x.astype(jnp.float32), n_valid=n,
                          interpret=interpret)
