"""Pallas TPU kernel: chunk-parallel Welford merge for streaming baselines.

Maintaining per-channel baseline (mean, var) over long horizons needs a
single-pass, numerically stable reduction (naive sum-of-squares cancels
catastrophically in fp32 when mean >> std, which is routine for byte
counters).  The kernel walks lane-aligned chunks of the window with a
``fori_loop``, carrying (count, mean, M2) in VMEM scratch and merging each
chunk with Chan's parallel-Welford update:

  delta = mean_c - mean;  mean += delta * n_c / n;  M2 += M2_c + delta^2 *
  n * n_c / (n + n_c)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 128


def _welford_kernel(n_valid: int, x_ref, mean_ref, var_ref):
    """x_ref (1, bm, N); mean/var (1, bm)."""
    N = x_ref.shape[-1]
    bm = x_ref.shape[1]
    n_chunks = N // CHUNK

    def body(c, carry):
        cnt, mean, m2 = carry                         # (bm,) each
        lo = c * CHUNK
        idx = lo + jax.lax.iota(jnp.int32, CHUNK)
        valid = (idx < n_valid).astype(jnp.float32)   # (CHUNK,)
        xc = jax.lax.dynamic_slice(x_ref[0], (0, lo), (bm, CHUNK))
        n_c = jnp.sum(valid)
        # chunk stats (masked)
        safe = jnp.maximum(n_c, 1.0)
        mean_c = jnp.sum(xc * valid[None, :], axis=1) / safe
        d = (xc - mean_c[:, None]) * valid[None, :]
        m2_c = jnp.sum(d * d, axis=1)
        # Chan merge
        tot = cnt + n_c
        tot_safe = jnp.maximum(tot, 1.0)
        delta = mean_c - mean
        mean_new = mean + delta * n_c / tot_safe
        m2_new = m2 + m2_c + delta * delta * cnt * n_c / tot_safe
        # skip empty chunks
        mean_new = jnp.where(n_c > 0, mean_new, mean)
        m2_new = jnp.where(n_c > 0, m2_new, m2)
        cnt_new = jnp.where(n_c > 0, tot, cnt)
        return cnt_new, mean_new, m2_new

    cnt0 = jnp.zeros((bm,), jnp.float32)
    init = (cnt0, jnp.zeros((bm,), jnp.float32), jnp.zeros((bm,), jnp.float32))
    cnt, mean, m2 = jax.lax.fori_loop(0, n_chunks, body, init)
    mean_ref[0] = mean
    var_ref[0] = m2 / jnp.maximum(cnt, 1.0)


def welford_pallas(x: jax.Array, n_valid: int | None = None,
                   block_m: int = 8, interpret: bool = True):
    """x (B, M, N) -> (mean, var) each (B, M) f32.  N % 128 == 0."""
    B, M, N = x.shape
    if N % 128 != 0:
        raise ValueError("N must be lane-aligned")
    n_valid = N if n_valid is None else int(n_valid)
    pad_m = (-M) % block_m
    if pad_m:
        x = jnp.pad(x, ((0, 0), (0, pad_m), (0, 0)))
    Mp = M + pad_m
    mean, var = pl.pallas_call(
        functools.partial(_welford_kernel, n_valid),
        grid=(B, Mp // block_m),
        in_specs=[pl.BlockSpec((1, block_m, N), lambda b, j: (b, j, 0))],
        out_specs=[pl.BlockSpec((1, block_m), lambda b, j: (b, j)),
                   pl.BlockSpec((1, block_m), lambda b, j: (b, j))],
        out_shape=[jax.ShapeDtypeStruct((B, Mp), jnp.float32),
                   jax.ShapeDtypeStruct((B, Mp), jnp.float32)],
        interpret=interpret,
    )(x.astype(jnp.float32))
    return mean[:, :M], var[:, :M]
