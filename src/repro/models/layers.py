"""Core layers: norms, RoPE, attention (train/prefill/decode), MLPs.

Attention is *chunked* (online-softmax over KV blocks, scanned over Q
blocks) — the pure-JAX equivalent of flash attention.  Nothing ever
materializes an (S, S) score matrix, which is what makes the 32k-prefill
and 4k-train dry-runs fit in HBM without a custom kernel.  Masks (causal /
sliding-window / prefix-LM) are evaluated per block pair from iota, never
as a full matrix.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, ParamSpec
from repro.parallel.ctx import shard_act

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 *statistics* but no full-size fp32 tensor.

    The variance is accumulated in fp32 via einsum; only the (B, S, 1)
    scale is fp32, cast to bf16 before the product.  Keeping every
    (B, S, E) tensor bf16 matters beyond precision: XLA places resharding
    collectives on whichever tensor in the elementwise chain it likes, and
    a materialized fp32 x32 doubles the all-gather/all-reduce wire bytes
    of the sequence-parallel residual stream (measured 2x on yi-9b train;
    EXPERIMENTS.md §Perf iteration A3).
    """
    var = jnp.einsum("...d,...d->...", x, x,
                     preferred_element_type=jnp.float32) / x.shape[-1]
    scale = jax.lax.rsqrt(var + eps)[..., None].astype(x.dtype)
    return x * scale * (1.0 + w)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w + b


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D), positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq      # (..., S, half)
    ang = ang[..., None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention parameter specs
# ---------------------------------------------------------------------------

def attn_specs(cfg: ArchConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    E, H, KV, D = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    s: Dict[str, ParamSpec] = {
        "wq": ParamSpec((E, H, D), ("embed", "heads", None)),
        "wk": ParamSpec((E, KV, D), ("embed", "kv_heads", None)),
        "wv": ParamSpec((E, KV, D), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, D, E), ("heads", None, "embed")),
    }
    if cfg.qk_norm:
        s["q_norm"] = ParamSpec((D,), (None,), init="zeros")
        s["k_norm"] = ParamSpec((D,), (None,), init="zeros")
    return s


# ---------------------------------------------------------------------------
# chunked attention core (online softmax)
# ---------------------------------------------------------------------------

NEG_INF = -1e30

#: sequences at least this long use the sequence-parallel residual layout
SEQ_PARALLEL_MIN = 1024


def res_seq_axis(S: int) -> str:
    """Logical axis for the residual stream's sequence dim."""
    return "act_seq_res" if S >= SEQ_PARALLEL_MIN else "act_seq"


def _block_mask(q_idx: jax.Array, k_idx: jax.Array, mask_mode: str,
                window: int, prefix_len: int) -> jax.Array:
    """(Qb, Kb) bool mask from absolute indices; True = attend."""
    q = q_idx[:, None]
    k = k_idx[None, :]
    if mask_mode == "none":           # bidirectional (encoder / cross)
        return jnp.ones((q_idx.shape[0], k_idx.shape[0]), dtype=bool)
    allowed = k <= q                  # causal
    if mask_mode == "window" and window > 0:
        allowed &= (q - k) < window
    if mask_mode == "prefix" and prefix_len > 0:
        allowed |= (q < prefix_len) & (k < prefix_len)
    return allowed


#: perf knob (see EXPERIMENTS.md §Perf): static triangular schedule for
#: causal attention — each Q chunk only scans its own prefix of KV chunks,
#: halving attention FLOPs vs the rectangular schedule.
CAUSAL_TRIANGLE = False


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      mask_mode: str = "causal", window: int = 0,
                      prefix_len: int = 0, q_chunk: int = 1024,
                      k_chunk: int = 1024,
                      q_offset: int = 0) -> jax.Array:
    """q: (B, Sq, H, D), k/v: (B, Sk, KV, D) with H a multiple of KV.

    Online-softmax over KV chunks inside a scan over Q chunks; fp32
    accumulators.  ``q_offset`` is the absolute position of q[0] (used at
    decode/prefill-continuation time).  With ``CAUSAL_TRIANGLE`` the causal
    path unrolls Q chunks and gives each a statically-shorter KV scan.
    """
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    rep = H // KV
    scale = 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, Sq)
    k_chunk = min(k_chunk, Sk)
    nq = (Sq + q_chunk - 1) // q_chunk
    nk = (Sk + k_chunk - 1) // k_chunk
    # pad to multiples
    pad_q = nq * q_chunk - Sq
    pad_k = nk * k_chunk - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    # (B, nq, qc, H, D) -> scan over nq
    qs = q.reshape(B, nq, q_chunk, H, D)
    ks = k.reshape(B, nk, k_chunk, KV, D)
    vs = v.reshape(B, nk, k_chunk, KV, D)

    k_valid = jnp.arange(nk * k_chunk) < Sk

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def q_body(carry, qi):
        # rematerialized on backward: probability blocks are recomputed,
        # never stored across chunks — the flash-attention memory contract
        del carry
        qb, q_index = qi           # (B, qc, H, D), scalar chunk id
        q_abs = q_offset + q_index * q_chunk + jnp.arange(q_chunk)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def kv_body(acc, ki):
            m, l, o = acc          # (B,H,qc), (B,H,qc), (B,H,qc,D) fp32
            kb, vb, k_index = ki
            k_abs = k_index * k_chunk + jnp.arange(k_chunk)
            mask = _block_mask(q_abs, k_abs, mask_mode, window, prefix_len)
            mask &= k_valid[k_index * k_chunk + jnp.arange(k_chunk)][None, :]
            # scores: (B, H, qc, kc)
            kb_r = jnp.repeat(kb, rep, axis=2) if rep > 1 else kb
            vb_r = jnp.repeat(vb, rep, axis=2) if rep > 1 else vb
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb_r,
                           preferred_element_type=jnp.float32) * scale
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(vb_r.dtype), vb_r,
                            preferred_element_type=jnp.float32)
            o_new = o * corr[..., None] + pv
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_body, (m0, l0, o0),
            (ks.swapaxes(0, 1), vs.swapaxes(0, 1), jnp.arange(nk)))
        l = jnp.maximum(l, 1e-20)
        out = (o / l[..., None]).swapaxes(1, 2)      # (B, qc, H, D)
        return None, out.astype(qb.dtype)

    if CAUSAL_TRIANGLE and mask_mode == "causal" and q_offset == 0 \
            and Sq == Sk and window == 0:
        # static triangular schedule: q chunk i attends to kv chunks 0..i,
        # so total score-block work is nq(nq+1)/2 instead of nq*nk.
        chunks = []
        for i in range(nq):
            def tri_body(carry, qi, _hi=i + 1):
                qb, q_index = qi

                @functools.partial(jax.checkpoint, prevent_cse=False)
                def kv_body(acc, ki):
                    m, l, o = acc
                    kb, vb, k_index = ki
                    k_abs = k_index * k_chunk + jnp.arange(k_chunk)
                    q_abs = q_offset + q_index * q_chunk + jnp.arange(q_chunk)
                    mask = _block_mask(q_abs, k_abs, "causal", 0, 0)
                    mask &= k_valid[k_index * k_chunk
                                    + jnp.arange(k_chunk)][None, :]
                    kb_r = jnp.repeat(kb, rep, axis=2) if rep > 1 else kb
                    vb_r = jnp.repeat(vb, rep, axis=2) if rep > 1 else vb
                    s = jnp.einsum("bqhd,bkhd->bhqk", qb, kb_r,
                                   preferred_element_type=jnp.float32) * scale
                    s = jnp.where(mask[None, None], s, NEG_INF)
                    m_new = jnp.maximum(m, s.max(axis=-1))
                    p = jnp.exp(s - m_new[..., None])
                    corr = jnp.exp(m - m_new)
                    l_new = l * corr + p.sum(axis=-1)
                    pv = jnp.einsum("bhqk,bkhd->bhqd",
                                    p.astype(vb_r.dtype), vb_r,
                                    preferred_element_type=jnp.float32)
                    return (m_new, l_new, o * corr[..., None] + pv), None

                m0 = jnp.full((B, H, q_chunk), NEG_INF, jnp.float32)
                l0 = jnp.zeros((B, H, q_chunk), jnp.float32)
                o0 = jnp.zeros((B, H, q_chunk, D), jnp.float32)
                (m, l, o), _ = jax.lax.scan(
                    kv_body, (m0, l0, o0),
                    (ks.swapaxes(0, 1)[:_hi], vs.swapaxes(0, 1)[:_hi],
                     jnp.arange(_hi)))
                l = jnp.maximum(l, 1e-20)
                return None, (o / l[..., None]).swapaxes(1, 2).astype(qb.dtype)

            _, oc = tri_body(None, (qs[:, i], jnp.asarray(i)))
            chunks.append(oc)
        out = jnp.concatenate(chunks, axis=1)
        return out[:, :Sq]

    _, outs = jax.lax.scan(q_body, None,
                           (qs.swapaxes(0, 1), jnp.arange(nq)))
    out = outs.swapaxes(0, 1).reshape(B, nq * q_chunk, H, D)
    return out[:, :Sq]


def plain_attention(q, k, v, mask_mode="causal", window=0, prefix_len=0,
                    q_offset=0, kv_valid_len=None):
    """Unchunked reference path (small seq / decode).  kv_valid_len masks
    cache slots beyond the write frontier (scalar or (B,))."""
    B, Sq, H, D = q.shape
    _, Sk, KV, _ = k.shape
    rep = H // KV
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                   preferred_element_type=jnp.float32) / math.sqrt(D)
    q_abs = q_offset + jnp.arange(Sq)
    k_abs = jnp.arange(Sk)
    mask = _block_mask(q_abs, k_abs, mask_mode, window, prefix_len)
    if kv_valid_len is not None:
        valid = k_abs[None, :] < jnp.reshape(kv_valid_len, (-1, 1))
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


# ---------------------------------------------------------------------------
# full attention layer (projections + rope + core)
# ---------------------------------------------------------------------------

def attn_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
               mask_mode: str = "causal", prefix_len: int = 0,
               positions: Optional[jax.Array] = None,
               kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
               use_rope: bool = True) -> jax.Array:
    """Self (or cross, via kv_override=(xk_src)) attention over a full
    sequence — the training / prefill path."""
    B, S, E = x.shape
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"])
    src = x if kv_override is None else kv_override[0]
    k = jnp.einsum("bse,ehd->bshd", src, p["wk"])
    v = jnp.einsum("bse,ehd->bshd", src, p["wv"])
    q = shard_act(q, "act_batch", "act_seq", "act_heads", "act_head_dim")
    k = shard_act(k, "act_batch", "act_seq", "act_kv_heads", "act_head_dim")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(S)
        q = rope(q, jnp.broadcast_to(pos, (B, q.shape[1])), cfg.rope_theta)
        kpos = jnp.arange(k.shape[1]) if kv_override is not None else pos
        k = rope(k, jnp.broadcast_to(kpos, (B, k.shape[1])), cfg.rope_theta)
    if S > 1024 or k.shape[1] > 1024:
        o = chunked_attention(q, k, v, mask_mode, cfg.window, prefix_len)
    else:
        o = plain_attention(q, k, v, mask_mode, cfg.window, prefix_len)
    o = shard_act(o, "act_batch", "act_seq", "act_heads", "act_head_dim")
    return jnp.einsum("bshd,hde->bse", o, p["wo"])


# -- decode with cache -------------------------------------------------------

def attn_decode(p: Dict[str, jax.Array], x: jax.Array, cache_k: jax.Array,
                cache_v: jax.Array, pos: jax.Array, cfg: ArchConfig,
                use_rope: bool = True,
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode.  x: (B, 1, E); caches (B, Smax, KV, D); ``pos`` is
    the absolute position (scalar).  Sliding-window archs use a ring buffer
    (Smax == window) — keys are stored post-RoPE so ring order is
    irrelevant to the attention math.
    """
    B, _, E = x.shape
    Smax = cache_k.shape[1]
    q = jnp.einsum("bse,ehd->bshd", x, p["wq"])
    k = jnp.einsum("bse,ehd->bshd", x, p["wk"])
    v = jnp.einsum("bse,ehd->bshd", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope:
        posb = jnp.broadcast_to(pos[None], (B, 1))
        q = rope(q, posb, cfg.rope_theta)
        k = rope(k, posb, cfg.rope_theta)
    slot = (pos % Smax) if cfg.window > 0 else jnp.minimum(pos, Smax - 1)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k.astype(cache_k.dtype),
                                           (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v.astype(cache_v.dtype),
                                           (0, slot, 0, 0))
    cache_k = shard_act(cache_k, "act_batch", "act_seq_mp", "act_kv_heads",
                        "act_head_dim")
    cache_v = shard_act(cache_v, "act_batch", "act_seq_mp", "act_kv_heads",
                        "act_head_dim")
    valid = jnp.minimum(pos + 1, Smax)
    o = plain_attention(q, cache_k, cache_v, mask_mode="none",
                        kv_valid_len=valid)
    y = jnp.einsum("bshd,hde->bse", o, p["wo"])
    return y, cache_k, cache_v


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    E, F = cfg.d_model, cfg.d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "wi0": ParamSpec((E, F), ("embed", "mlp")),
            "wi1": ParamSpec((E, F), ("embed", "mlp")),
            "wo": ParamSpec((F, E), ("mlp", "embed")),
        }
    return {
        "wi": ParamSpec((E, F), ("embed", "mlp")),
        "wo": ParamSpec((F, E), ("mlp", "embed")),
    }


def mlp_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
              ) -> jax.Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wi0"]) * (x @ p["wi1"])
    elif cfg.act == "geglu":
        h = jax.nn.gelu(x @ p["wi0"]) * (x @ p["wi1"])
    elif cfg.act == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:  # gelu
        h = jax.nn.gelu(x @ p["wi"])
    h = shard_act(h, "act_batch", "act_seq", "act_ff")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def embed_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    return {
        "embedding": ParamSpec((cfg.vocab_padded, cfg.d_model),
                               ("vocab", "embed"), scale=1.0),
        "unembed": ParamSpec((cfg.d_model, cfg.vocab_padded),
                             ("embed", "vocab")),
    }


def embed_lookup(p: Dict[str, jax.Array], tokens: jax.Array) -> jax.Array:
    x = jnp.take(p["embedding"], tokens, axis=0)
    return shard_act(x, "act_batch", "act_seq", "act_embed")


def unembed(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    # bf16 matmul output: keeping the einsum in bf16 keeps the *cotangent*
    # chain bf16 (a preferred_element_type=f32 here makes every upstream
    # activation gradient f32 — 2x memory and collective bytes).  The loss
    # upcasts elementwise, whose backward casts back down.
    logits = jnp.einsum("bse,ev->bsv", x, p["unembed"].astype(x.dtype))
    return shard_act(logits, "act_batch", "act_seq", "act_vocab")


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def softmax_xent(logits: jax.Array, labels: jax.Array,
                 mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean cross-entropy over valid positions; logits fp32 (B,S,V)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
