"""Whisper-style encoder-decoder backbone.

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
supplies precomputed frame embeddings (B, T_frames, d_model).  The backbone
is faithful: learned positional embeddings, bidirectional encoder,
causal decoder with cross-attention, GELU MLPs.  (We use bias-free
projections and RMSNorm uniformly across the zoo — noted in DESIGN.md as a
deviation from Whisper's LayerNorm+bias; it does not change shapes or
sharding.)
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import ArchConfig, ParamSpec, stack_specs
from repro.parallel.ctx import shard_act

# Learned-pos table sizes: cover the largest assigned shape (32k decode /
# prefill).  Whisper itself caps at 1500 frames/448 tokens — the assignment
# exercises the BACKBONE at these shapes, so the tables are sized to match.
MAX_FRAMES = 32768
MAX_TOKENS = 32768


def enc_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "attn": L.attn_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def dec_layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln3": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "attn": L.attn_specs(cfg),
        "xattn": L.attn_specs(cfg),
        "mlp": L.mlp_specs(cfg),
    }


def encdec_specs(cfg: ArchConfig) -> Dict[str, Any]:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    return {
        "embed": L.embed_specs(cfg),
        "enc_pos": ParamSpec((MAX_FRAMES, cfg.d_model), ("pos", "embed"),
                             scale=0.02),
        "dec_pos": ParamSpec((MAX_TOKENS, cfg.d_model), ("pos", "embed"),
                             scale=0.02),
        "enc_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "enc_layers": stack_specs(enc_layer_specs(cfg), n_enc),
        "dec_layers": stack_specs(dec_layer_specs(cfg), cfg.n_layers),
    }


def encode(params, cfg: ArchConfig, frames: jax.Array) -> jax.Array:
    """frames: (B, T, E) stub-frontend embeddings."""
    T = frames.shape[1]
    x = frames + params["enc_pos"][:T][None].astype(frames.dtype)
    sax = L.res_seq_axis(x.shape[1])
    x = shard_act(x, "act_batch", sax, "act_embed")

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + L.attn_apply(lp["attn"], h, cfg, mask_mode="none",
                             use_rope=False)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg)
        return shard_act(x, "act_batch", sax, "act_embed"), None

    from repro.train.remat import maybe_remat
    x, _ = jax.lax.scan(maybe_remat(body), x, params["enc_layers"])
    return L.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg: ArchConfig, tokens: jax.Array,
                 enc_out: jax.Array) -> jax.Array:
    S = tokens.shape[1]
    x = L.embed_lookup(params["embed"], tokens)
    x = x + params["dec_pos"][:S][None].astype(x.dtype)
    sax = L.res_seq_axis(S)
    x = shard_act(x, "act_batch", sax, "act_embed")

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + L.attn_apply(lp["attn"], h, cfg, mask_mode="causal",
                             use_rope=False)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + L.attn_apply(lp["xattn"], h, cfg, mask_mode="none",
                             kv_override=(enc_out,), use_rope=False)
        h = L.rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg)
        return shard_act(x, "act_batch", sax, "act_embed"), None

    from repro.train.remat import maybe_remat
    x, _ = jax.lax.scan(maybe_remat(body), x, params["dec_layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], x)


def encdec_loss(params, cfg: ArchConfig, batch):
    enc = encode(params, cfg, batch["frames"])
    logits = decode_train(params, cfg, batch["tokens"], enc)
    loss = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return loss, {"xent": loss}


# ---------------------------------------------------------------------------
# serving: prefill computes encoder output + cross-KV; decode streams tokens
# ---------------------------------------------------------------------------

def encdec_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                      enc_len: int, dtype=jnp.bfloat16):
    Ld = cfg.n_layers
    return {
        "k": jnp.zeros((Ld, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((Ld, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "xk": jnp.zeros((Ld, batch, enc_len, cfg.n_kv, cfg.head_dim), dtype),
        "xv": jnp.zeros((Ld, batch, enc_len, cfg.n_kv, cfg.head_dim), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def encdec_cache_logical():
    kv = (None, "act_batch", "act_seq_mp", "act_kv_heads", "act_head_dim")
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": ()}


def encdec_prefill(params, cfg: ArchConfig, frames: jax.Array,
                   batch: int, max_len: int):
    """Encode audio; fill cross-KV; empty self cache."""
    enc = encode(params, cfg, frames)

    def xkv(lp):
        k = jnp.einsum("bse,ehd->bshd", enc, lp["xattn"]["wk"])
        v = jnp.einsum("bse,ehd->bshd", enc, lp["xattn"]["wv"])
        return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)

    xks, xvs = jax.vmap(xkv)(params["dec_layers"])
    cache = encdec_init_cache(cfg, batch, max_len, enc.shape[1])
    cache["xk"], cache["xv"] = xks, xvs
    return enc, cache


def encdec_decode_step(params, cfg: ArchConfig, token: jax.Array, cache):
    x = L.embed_lookup(params["embed"], token)
    pos = cache["pos"]
    x = x + jax.lax.dynamic_slice_in_dim(
        params["dec_pos"], jnp.minimum(pos, MAX_TOKENS - 1), 1, 0
    )[None].astype(x.dtype)[:, 0][:, None]

    def body(x, xs):
        lp, ck, cv, xk, xv = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, ck, cv = L.attn_decode(lp["attn"], h, ck, cv, pos, cfg,
                                  use_rope=False)
        x = x + y
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        q = jnp.einsum("bse,ehd->bshd", h, lp["xattn"]["wq"])
        o = L.plain_attention(q, xk, xv, mask_mode="none")
        x = x + jnp.einsum("bshd,hde->bse", o, lp["xattn"]["wo"])
        h = L.rms_norm(x, lp["ln3"], cfg.norm_eps)
        x = x + L.mlp_apply(lp["mlp"], h, cfg)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache = dict(cache, k=ks, v=vs, pos=pos + 1)
    return logits, new_cache
