"""Architecture registry: one `Model` facade per family.

`build_model(cfg)` returns a `Model` whose members are plain functions
(closures over the frozen config) — ready for `jax.jit`, `jax.eval_shape`,
and the dry-run's abstract lowering.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm as SM
from repro.models import transformer as TF
from repro.models.common import (
    ArchConfig, init_tree, spec_tree_logical,
)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    specs: Dict[str, Any]
    # init(rng) -> params
    init: Callable[[jax.Array], Dict[str, Any]]
    # loss(params, batch) -> (scalar, metrics)
    loss: Callable[[Dict[str, Any], Dict[str, jax.Array]], Tuple]
    # decode(params, token, cache) -> (logits, cache)
    decode: Optional[Callable] = None
    # init_cache(batch, max_len) -> cache pytree
    init_cache: Optional[Callable] = None
    cache_logical: Optional[Callable] = None
    # prefill(params, batch, max_len) -> (logits, cache)
    prefill: Optional[Callable] = None

    @property
    def param_logical(self) -> Dict[str, Any]:
        return spec_tree_logical(self.specs)

    def abstract_params(self) -> Dict[str, Any]:
        """Shape/dtype tree without allocation (dry-run path)."""
        return jax.eval_shape(self.init, jax.random.key(0))

    def param_count(self, active_only: bool = False) -> int:
        """Total (or MoE-active) parameter count from specs."""
        import numpy as np
        total = 0
        def walk(tree, in_expert):
            nonlocal total
            for k, v in tree.items():
                if isinstance(v, dict):
                    walk(v, in_expert or k == "moe")
                else:
                    n = int(np.prod(v.shape))
                    if active_only and in_expert and v.shape and \
                            self.cfg.n_experts > 1 and \
                            v.shape[-1] != self.cfg.n_experts and \
                            self.cfg.n_experts in v.shape:
                        # expert-stacked weight: count top_k/n_experts share
                        n = n * self.cfg.top_k // self.cfg.n_experts
                    total += n
        walk(self.specs, False)
        return total


def build_model(cfg: ArchConfig) -> Model:
    dt = cfg.compute_dtype
    if cfg.family in ("dense", "moe", "vlm"):
        specs = TF.decoder_specs(cfg)
        return Model(
            cfg=cfg, specs=specs,
            init=lambda rng: init_tree(rng, specs, dt),
            loss=functools.partial(_tf_loss, cfg),
            decode=functools.partial(_tf_decode, cfg),
            init_cache=functools.partial(_tf_init_cache, cfg),
            cache_logical=TF.cache_logical,
            prefill=functools.partial(_tf_prefill, cfg),
        )
    if cfg.family == "ssm":
        specs = SM.mamba_specs(cfg)
        return Model(
            cfg=cfg, specs=specs,
            init=lambda rng: init_tree(rng, specs, dt),
            loss=functools.partial(_ssm_loss, cfg),
            decode=functools.partial(_ssm_decode, cfg),
            init_cache=functools.partial(_ssm_init_cache, cfg),
            cache_logical=SM.mamba_cache_logical,
            prefill=functools.partial(_ssm_prefill, cfg),
        )
    if cfg.family == "hybrid":
        specs = HY.hybrid_specs(cfg)
        return Model(
            cfg=cfg, specs=specs,
            init=lambda rng: init_tree(rng, specs, dt),
            loss=functools.partial(_hy_loss, cfg),
            decode=functools.partial(_hy_decode, cfg),
            init_cache=functools.partial(_hy_init_cache, cfg),
            cache_logical=HY.hybrid_cache_logical,
            prefill=functools.partial(_hy_prefill, cfg),
        )
    if cfg.family == "encdec":
        specs = ED.encdec_specs(cfg)
        return Model(
            cfg=cfg, specs=specs,
            init=lambda rng: init_tree(rng, specs, dt),
            loss=functools.partial(_ed_loss, cfg),
            decode=functools.partial(_ed_decode, cfg),
            init_cache=functools.partial(_ed_init_cache, cfg),
            cache_logical=ED.encdec_cache_logical,
            prefill=functools.partial(_ed_prefill, cfg),
        )
    raise ValueError(f"unknown family {cfg.family!r}")


# --- partial targets (named functions pickle/jit better than lambdas) ------

def _tf_loss(cfg, params, batch):
    return TF.lm_loss(params, cfg, batch)


def _tf_decode(cfg, params, token, cache):
    return TF.decode_step(params, cfg, token, cache)


def _tf_init_cache(cfg, batch, max_len):
    return TF.init_cache(cfg, batch, max_len)


def _tf_prefill(cfg, params, batch, max_len):
    return TF.prefill(params, cfg, batch["tokens"], max_len,
                      prefix_embeds=batch.get("img"))


def _ssm_loss(cfg, params, batch):
    return SM.mamba_loss(params, cfg, batch)


def _ssm_decode(cfg, params, token, cache):
    return SM.mamba_decode_step(params, cfg, token, cache)


def _ssm_init_cache(cfg, batch, max_len):
    return SM.mamba_init_cache(cfg, batch, max_len)


def _ssm_prefill(cfg, params, batch, max_len):
    return SM.mamba_prefill(params, cfg, batch["tokens"], max_len)


def _hy_loss(cfg, params, batch):
    return HY.hybrid_loss(params, cfg, batch)


def _hy_decode(cfg, params, token, cache):
    return HY.hybrid_decode_step(params, cfg, token, cache)


def _hy_init_cache(cfg, batch, max_len):
    return HY.hybrid_init_cache(cfg, batch, max_len)


def _hy_prefill(cfg, params, batch, max_len):
    return HY.hybrid_prefill(params, cfg, batch["tokens"], max_len)


def _ed_loss(cfg, params, batch):
    return ED.encdec_loss(params, cfg, batch)


def _ed_decode(cfg, params, token, cache):
    return ED.encdec_decode_step(params, cfg, token, cache)


def _ed_init_cache(cfg, batch, max_len):
    # encoder length for the shape set: frames = seq_len (stub embeddings)
    return ED.encdec_init_cache(cfg, batch, max_len, enc_len=max_len)


def _ed_prefill(cfg, params, batch, max_len):
    return ED.encdec_prefill(params, cfg, batch["frames"],
                             batch["frames"].shape[0], max_len)


def list_architectures():
    from repro.configs import ALL_CONFIGS
    return sorted(ALL_CONFIGS)
