"""Jamba-style hybrid: Mamba + attention interleaved 1:7, MoE every 2nd layer.

Layout (per Jamba paper): period-8 blocks, attention at in-block index 4,
MoE replacing the dense MLP on odd in-block indices.  32 layers = 4 blocks;
the 4 blocks are scanned (each block's 8 heterogeneous layers are unrolled
inside the scan body — HLO grows with the block pattern, not with depth).
No explicit positional embedding: the Mamba layers carry position.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import ssm as S_mod
from repro.models.common import ArchConfig, ParamSpec, stack_specs
from repro.parallel.ctx import shard_act

PERIOD = 8
ATTN_POS = 4


def _is_attn(i: int, cfg: ArchConfig) -> bool:
    return i % PERIOD == ATTN_POS


def _is_moe(i: int, cfg: ArchConfig) -> bool:
    return cfg.n_experts > 0 and (i % 2 == 1)


def block_specs(cfg: ArchConfig) -> Dict[str, Any]:
    """One period-8 block; stacked n_layers//8 times."""
    out: Dict[str, Any] = {}
    for i in range(PERIOD):
        s: Dict[str, Any] = {
            "ln1": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
            "ln2": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        }
        s["attn" if _is_attn(i, cfg) else "ssm"] = (
            L.attn_specs(cfg) if _is_attn(i, cfg) else S.ssm_specs(cfg))
        s["moe" if _is_moe(i, cfg) else "mlp"] = (
            M.moe_specs(cfg) if _is_moe(i, cfg) else L.mlp_specs(cfg))
        out[f"l{i}"] = s
    return out


def hybrid_specs(cfg: ArchConfig) -> Dict[str, Any]:
    assert cfg.n_layers % PERIOD == 0, "hybrid depth must be a multiple of 8"
    return {
        "embed": L.embed_specs(cfg),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "blocks": stack_specs(block_specs(cfg), cfg.n_layers // PERIOD),
    }


def _ffn(lp: Dict[str, Any], h: jax.Array, cfg: ArchConfig):
    if "moe" in lp:
        return M.moe_apply(lp["moe"], h, cfg)
    return L.mlp_apply(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)


def hybrid_forward(params: Dict[str, Any], cfg: ArchConfig,
                   tokens: jax.Array) -> Tuple[jax.Array, jax.Array]:
    x = L.embed_lookup(params["embed"], tokens)
    sax = L.res_seq_axis(x.shape[1])
    x = shard_act(x, "act_batch", sax, "act_embed")

    from repro.train.remat import maybe_remat

    def one_layer(x, lp):
        # nested remat: each of the 8 unrolled block layers recomputes
        # independently on backward (MoE dispatch buffers are large)
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        if "attn" in lp:
            x = x + L.attn_apply(lp["attn"], h, cfg, mask_mode="causal",
                                 use_rope=False)
        else:
            x = x + S.ssm_apply(lp["ssm"], h, cfg)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        y, a = _ffn(lp, h, cfg)
        x = shard_act(x + y, "act_batch", sax, "act_embed")
        return x, a

    def body(carry, bp):
        x, aux = carry
        for i in range(PERIOD):
            x, a = maybe_remat(one_layer)(x, bp[f"l{i}"])
            aux = aux + a
        return (x, aux), None

    from repro.train.remat import maybe_remat
    (x, aux), _ = jax.lax.scan(maybe_remat(body),
                               (x, jnp.zeros((), jnp.float32)),
                               params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, aux / max(cfg.n_layers, 1)


def hybrid_loss(params, cfg: ArchConfig, batch):
    logits, aux = hybrid_forward(params, cfg, batch["tokens"])
    loss = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return loss + 0.01 * aux, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def hybrid_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    nb = cfg.n_layers // PERIOD
    n_ssm = PERIOD - 1
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "k": jnp.zeros((nb, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "v": jnp.zeros((nb, batch, max_len, cfg.n_kv, cfg.head_dim), dtype),
        "conv": jnp.zeros((nb, n_ssm, batch, cfg.ssm_conv - 1, conv_dim),
                          dtype),
        "ssm": jnp.zeros((nb, n_ssm, batch, cfg.ssm_nheads, cfg.ssm_headdim,
                          cfg.ssm_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def hybrid_cache_logical() -> Dict[str, Tuple]:
    kv = (None, "act_batch", "act_seq_mp", "act_kv_heads", "act_head_dim")
    return {
        "k": kv, "v": kv,
        "conv": (None, None, "act_batch", None, "act_ff"),
        "ssm": (None, None, "act_batch", "act_ssm_heads", None, "act_state"),
        "pos": (),
    }


def hybrid_prefill(params, cfg: ArchConfig, tokens: jax.Array,
                   max_len: int):
    """Prompt -> last logits + decode cache (attn KV capture + SSM states)."""
    B, S = tokens.shape
    x = L.embed_lookup(params["embed"], tokens)
    sax = L.res_seq_axis(S)
    x = shard_act(x, "act_batch", sax, "act_embed")
    cache_len = max(max_len, S)

    def body(x, bp):
        convs, hs = [], []
        kc = vc = None
        for i in range(PERIOD):
            lp = bp[f"l{i}"]
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if "attn" in lp:
                k = jnp.einsum("bse,ehd->bshd", h, lp["attn"]["wk"])
                v = jnp.einsum("bse,ehd->bshd", h, lp["attn"]["wv"])
                pad = cache_len - S
                kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(jnp.bfloat16)
                vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))
                             ).astype(jnp.bfloat16)
                x = x + L.attn_apply(lp["attn"], h, cfg, mask_mode="causal",
                                     use_rope=False)
            else:
                y, (conv, hstate) = S_mod.ssm_apply(lp["ssm"], h, cfg,
                                                    return_state=True)
                convs.append(conv.astype(jnp.bfloat16))
                hs.append(hstate)
                x = x + y
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            y, _ = _ffn(lp, h, cfg)
            x = shard_act(x + y, "act_batch", sax, "act_embed")
        return x, (kc, vc, jnp.stack(convs), jnp.stack(hs))

    x, (ks, vs, convs, hs) = jax.lax.scan(body, x, params["blocks"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:])
    cache = {"k": ks, "v": vs, "conv": convs, "ssm": hs,
             "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def hybrid_decode_step(params, cfg: ArchConfig, token: jax.Array,
                       cache: Dict[str, jax.Array]):
    x = L.embed_lookup(params["embed"], token)
    pos = cache["pos"]

    def body(x, xs):
        bp, ck, cv, conv, ssm_st = xs
        new_conv, new_ssm = [], []
        si = 0
        for i in range(PERIOD):
            lp = bp[f"l{i}"]
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            if "attn" in lp:
                y, ck, cv = L.attn_decode(lp["attn"], h, ck, cv, pos, cfg,
                                          use_rope=False)
            else:
                y, c_new, s_new = S.ssm_decode(lp["ssm"], h, conv[si],
                                               ssm_st[si], cfg)
                new_conv.append(c_new)
                new_ssm.append(s_new)
                si += 1
            x = x + y
            h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            y, _ = _ffn(lp, h, cfg)
            x = x + y
        return x, (ck, cv, jnp.stack(new_conv), jnp.stack(new_ssm))

    x, (ks, vs, convs, ssms) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"], cache["conv"],
                  cache["ssm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, {"k": ks, "v": vs, "conv": convs, "ssm": ssms,
                    "pos": pos + 1}
