"""Mamba-2 (SSD, state-space duality) block — chunked matmul formulation.

TPU adaptation (DESIGN.md §2): the SSD chunked algorithm is already the
MXU-friendly form — intra-chunk terms are dense (Q x Q) einsums, inter-chunk
terms a short ``lax.scan`` over chunk states.  No selective-scan CUDA kernel
to port; the dual form IS the TPU algorithm (chunk size tuned for the MXU
instead of SM shared memory).

Shapes: x (B, S, H, P) heads x headdim; B/C (B, S, G, N) groups x state;
dt (B, S, H); A (H,) negative reals (stored as log magnitude).
State: (B, H, P, N).
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, ParamSpec
from repro.parallel.ctx import shard_act


def ssm_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    E = cfg.d_model
    DI = cfg.d_inner
    H = cfg.ssm_nheads
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    conv_dim = DI + 2 * G * N
    return {
        # order in proj: [z (DI), x (DI), B (G*N), C (G*N), dt (H)]
        "in_proj": ParamSpec((E, 2 * DI + 2 * G * N + H), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_dim), ("conv", "mlp")),
        "conv_b": ParamSpec((conv_dim,), ("mlp",), init="zeros"),
        "a_log": ParamSpec((H,), ("dt",), init="ones"),
        "dt_bias": ParamSpec((H,), ("dt",), init="zeros"),
        "d_skip": ParamSpec((H,), ("dt",), init="ones"),
        "norm_w": ParamSpec((DI,), ("mlp",), init="zeros"),
        "out_proj": ParamSpec((DI, E), ("mlp", "embed")),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    DI, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :DI]
    xc = zxbcdt[..., DI:2 * DI]
    Bm = zxbcdt[..., 2 * DI:2 * DI + G * N]
    Cm = zxbcdt[..., 2 * DI + G * N:2 * DI + 2 * G * N]
    dt = zxbcdt[..., 2 * DI + 2 * G * N:]
    return z, xc, Bm, Cm, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along S.  xbc: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(K):  # K is 4 — unrolled taps fuse into one kernel
        out = out + pad[:, i:i + xbc.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int = 128,
                h0: jax.Array | None = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x: (B,S,H,P), dt: (B,S,H) (post-softplus), A: (H,) negative,
    Bm/Cm: (B,S,G,N).  Returns y (B,S,H,P) and final state (B,H,P,N).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = (S + chunk - 1) // chunk
    padn = nc * chunk - S
    if padn:
        x = jnp.pad(x, ((0, 0), (0, padn), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, padn), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, padn), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, padn), (0, 0), (0, 0)))
    Sp = nc * chunk

    xw = (x * dt[..., None].astype(x.dtype)
          ).reshape(Bsz, nc, chunk, H, P)                    # dt-weighted input
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = jnp.repeat(Bm, rep, axis=2).reshape(Bsz, nc, chunk, H, N)
    Cc = jnp.repeat(Cm, rep, axis=2).reshape(Bsz, nc, chunk, H, N)

    # log decay per step: log a_t = dt_t * A  (A negative)
    la = dtc * A[None, None, None, :]                 # (B,nc,Q,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))

    # One scan over chunks computes intra-chunk (quadratic, chunk-local),
    # the inter-chunk contribution against the carried state, and the state
    # update — so only ONE chunk's (Q,Q,H) tensors are ever live, and the
    # checkpoint keeps the backward at the same footprint.
    @jax.checkpoint
    def chunk_body(h, inp):
        xw_c, la_c, B_c, C_c = inp        # (B,Q,H,P), (B,Q,H), (B,Q,H,N) x2
        lcum = jnp.cumsum(la_c, axis=1)                   # (B,Q,H)
        ltot = lcum[:, -1]                                # (B,H)
        # intra: decay(t,s) = exp(lcum[t]-lcum[s]), s <= t.  The mask goes
        # INSIDE the exp: exp(diff) at masked (t<s) positions overflows to
        # +inf, and 0*inf in the where-VJP poisons the whole backward.
        diff = lcum[:, :, None, :] - lcum[:, None, :, :]  # (B,Q,Q,H)
        decay = jnp.exp(jnp.where(mask[None, :, :, None], diff, -1e30))
        scores = jnp.einsum("bthn,bshn->btsh", C_c, B_c) * decay
        y_intra = jnp.einsum("btsh,bshp->bthp", scores.astype(xw_c.dtype),
                             xw_c)
        # inter: C_t . (exp(lcum[t]) * h_prev)
        y_inter = jnp.einsum("bthn,bhpn->bthp", C_c, h) \
            * jnp.exp(lcum)[..., None].astype(xw_c.dtype)
        # state update: h' = exp(ltot) h + sum_s exp(ltot - lcum[s]) B_s xw_s
        sdec = jnp.exp(ltot[:, None, :] - lcum)           # (B,Q,H)
        st = jnp.einsum("bshn,bshp,bsh->bhpn", B_c, xw_c,
                        sdec.astype(xw_c.dtype))
        h_new = h * jnp.exp(ltot)[:, :, None, None].astype(h.dtype) \
            + st.astype(h.dtype)
        return h_new, y_intra + y_inter

    h_init = (jnp.zeros((Bsz, H, P, N), x.dtype) if h0 is None
              else h0.astype(x.dtype))
    h_last, ys = jax.lax.scan(
        chunk_body, h_init,
        (xw.swapaxes(0, 1), la.swapaxes(0, 1),
         Bc.swapaxes(0, 1), Cc.swapaxes(0, 1)))
    y = ys.swapaxes(0, 1).reshape(Bsz, Sp, H, P)[:, :S]
    return y, h_last


def ssm_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
              return_state: bool = False):
    """Full mamba2 mixer over a sequence (train / prefill).

    ``return_state=True`` additionally returns (conv_tail, h_last) — the
    decode-cache state after consuming the sequence (prefill path).
    """
    B, S, E = x.shape
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = x @ p["in_proj"]
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xbc_raw = jnp.concatenate([xc, Bm, Cm], axis=-1)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xc, Bm, Cm = (xbc[..., :cfg.d_inner],
                  xbc[..., cfg.d_inner:cfg.d_inner + G * N],
                  xbc[..., cfg.d_inner + G * N:])
    xh = xc.reshape(B, S, H, P)
    xh = shard_act(xh, "act_batch", "act_seq", "act_ssm_heads", "act_head_dim")
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, h_last = ssd_chunked(xh, dt, A, Bm.reshape(B, S, G, N),
                            Cm.reshape(B, S, G, N))
    y = y + xh * p["d_skip"][None, None, :, None]
    y = y.reshape(B, S, cfg.d_inner)
    y = rms_norm_gated(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        K = cfg.ssm_conv
        tail = xbc_raw[:, -(K - 1):]                 # pre-conv window
        if S < K - 1:
            tail = jnp.pad(xbc_raw, ((0, 0), (K - 1 - S, 0), (0, 0)))
        return out, (tail, h_last.astype(jnp.float32))
    return out


def rms_norm_gated(y: jax.Array, z: jax.Array, w: jax.Array,
                   eps: float) -> jax.Array:
    # same bf16-tensor / fp32-stats discipline as layers.rms_norm
    y = y * jax.nn.silu(z)
    var = jnp.einsum("...d,...d->...", y, y,
                     preferred_element_type=jnp.float32) / y.shape[-1]
    scale = jax.lax.rsqrt(var + eps)[..., None].astype(y.dtype)
    return y * scale * (1.0 + w)


# ---------------------------------------------------------------------------
# decode: single-token state update
# ---------------------------------------------------------------------------

def ssm_decode(p: Dict[str, jax.Array], x: jax.Array,
               conv_state: jax.Array, ssm_state: jax.Array,
               cfg: ArchConfig,
               ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, 1, E); conv_state: (B, K-1, conv_dim); ssm_state (B,H,P,N)."""
    B = x.shape[0]
    H, P = cfg.ssm_nheads, cfg.ssm_headdim
    G, N = cfg.ssm_ngroups, cfg.ssm_state
    zxbcdt = x @ p["in_proj"]                          # (B,1,*)
    z, xc, Bm, Cm, dt = _split_proj(cfg, zxbcdt)
    xbc_new = jnp.concatenate([xc, Bm, Cm], axis=-1)   # (B,1,conv_dim)
    window = jnp.concatenate([conv_state, xbc_new], axis=1)  # (B,K,conv)
    out = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    xbc = jax.nn.silu(out)[:, None, :]                 # (B,1,conv)
    conv_state_new = window[:, 1:]
    xc = xbc[..., :cfg.d_inner]
    Bm = xbc[..., cfg.d_inner:cfg.d_inner + G * N].reshape(B, G, N)
    Cm = xbc[..., cfg.d_inner + G * N:].reshape(B, G, N)
    xh = xc.reshape(B, H, P)
    dt1 = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    a = jnp.exp(dt1 * A)                               # (B,H)
    rep = H // G
    Bh = jnp.repeat(Bm, rep, axis=1)                   # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    upd = jnp.einsum("bhp,bhn->bhpn", xh * dt1[..., None].astype(xh.dtype), Bh)
    h_new = ssm_state * a[:, :, None, None].astype(ssm_state.dtype) + upd
    y = (jnp.einsum("bhpn,bhn->bhp", h_new, Ch).astype(xh.dtype)
         + xh * p["d_skip"][None, :, None].astype(xh.dtype))
    y = y.reshape(B, 1, cfg.d_inner)
    y = rms_norm_gated(y, z, p["norm_w"], cfg.norm_eps)
    return (y @ p["out_proj"]).astype(x.dtype), conv_state_new, h_new


# ---------------------------------------------------------------------------
# pure-SSM LM assembly (mamba2-*): x += ssm(norm(x)) per layer, no MLP
# ---------------------------------------------------------------------------

def mamba_specs(cfg: ArchConfig) -> Dict[str, "ParamSpec"]:
    from repro.models import layers as L
    from repro.models.common import stack_specs
    layer = {
        "ln": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ssm": ssm_specs(cfg),
    }
    return {
        "embed": L.embed_specs(cfg),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "layers": stack_specs(layer, cfg.n_layers),
    }


def mamba_forward(params, cfg: ArchConfig, tokens):
    from repro.models import layers as L
    x = L.embed_lookup(params["embed"], tokens)
    sax = L.res_seq_axis(x.shape[1])
    x = shard_act(x, "act_batch", sax, "act_embed")

    def body(x, lp):
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        x = x + ssm_apply(lp["ssm"], h, cfg)
        return shard_act(x, "act_batch", sax, "act_embed"), None

    from repro.train.remat import maybe_remat
    x, _ = jax.lax.scan(maybe_remat(body), x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return L.unembed(params["embed"], x), jnp.zeros((), jnp.float32)


def mamba_loss(params, cfg: ArchConfig, batch):
    from repro.models import layers as L
    logits, _ = mamba_forward(params, cfg, batch["tokens"])
    loss = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    return loss, {"xent": loss}


def mamba_init_cache(cfg: ArchConfig, batch: int, max_len: int,
                     dtype=jnp.bfloat16):
    del max_len  # constant-size state: the whole point of an SSM
    conv_dim = cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_dim),
                          dtype),
        "ssm": jnp.zeros((cfg.n_layers, batch, cfg.ssm_nheads,
                          cfg.ssm_headdim, cfg.ssm_state), jnp.float32),
        "pos": jnp.zeros((), jnp.int32),
    }


def mamba_cache_logical():
    return {
        "conv": (None, "act_batch", None, "act_ff"),
        "ssm": (None, "act_batch", "act_ssm_heads", None, "act_state"),
        "pos": (),
    }


def mamba_prefill(params, cfg: ArchConfig, tokens, max_len: int):
    """Consume a prompt, returning last-position logits + decode cache.

    The SSD chunked scan already carries the running state; prefill is the
    forward pass with per-layer state capture — O(S) work, O(1) cache (the
    whole point of an SSM serving stack).
    """
    from repro.models import layers as L
    del max_len  # state size is constant
    x = L.embed_lookup(params["embed"], tokens)
    sax = L.res_seq_axis(x.shape[1])
    x = shard_act(x, "act_batch", sax, "act_embed")

    def body(x, lp):
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, (conv, hstate) = ssm_apply(lp["ssm"], h, cfg, return_state=True)
        x = shard_act(x + y, "act_batch", sax, "act_embed")
        return x, (conv.astype(jnp.bfloat16), hstate)

    x, (convs, hs) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:])
    cache = {"conv": convs, "ssm": hs,
             "pos": jnp.asarray(tokens.shape[1], jnp.int32)}
    return logits, cache


def mamba_decode_step(params, cfg: ArchConfig, token, cache):
    from repro.models import layers as L
    x = L.embed_lookup(params["embed"], token)

    def body(x, xs):
        lp, conv, sst = xs
        h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
        y, conv, sst = ssm_decode(lp["ssm"], h, conv, sst, cfg)
        return x + y, (conv, sst)

    x, (convs, ssts) = jax.lax.scan(
        body, x, (params["layers"], cache["conv"], cache["ssm"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    return logits, {"conv": convs, "ssm": ssts, "pos": cache["pos"] + 1}
