"""Shared config dataclass, parameter-spec machinery, init helpers."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# architecture config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int                # query heads (0 for attn-free)
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    act: str = "swiglu"         # swiglu | geglu | gelu | squared_relu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1          # MoE replaces MLP on layers where i % every == 0
    capacity_factor: float = 1.25
    # --- attention extras ---
    window: int = 0             # sliding-window size; 0 = full attention
    qk_norm: bool = False
    # --- SSM (mamba2) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    # --- hybrid (jamba): attention on layers where i % attn_every == offset
    attn_every: int = 0
    attn_offset: int = 0
    # --- enc-dec (whisper) ---
    n_enc_layers: int = 0
    # --- vlm (paligemma) ---
    n_img_tokens: int = 0
    # --- dtype policy ---
    dtype: str = "bfloat16"     # activations/weights compute dtype
    # --- training memory policy: grad-accumulation microbatches (0 = off).
    # Big models need it to fit v5e HBM: it divides every activation term
    # (remat carry stacks, MoE dispatch buffers) by the microbatch count at
    # the cost of an fp32 grad accumulator (params-sized, ZeRO-sharded).
    train_microbatch: int = 0
    # --- bookkeeping ---
    source: str = ""            # citation tag
    sub_quadratic: bool = False  # eligible for long_500k

    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a multiple of 256 so it shards on any mesh
        axis we use (documented in DESIGN.md; pad rows are never targets)."""
        return int(math.ceil(self.vocab / 256) * 256)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def compute_dtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def family_of(cfg: ArchConfig) -> str:
    return cfg.family


# ---------------------------------------------------------------------------
# parameter specs: shape + logical axes, used for init AND sharding
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]   # logical axis name per dim
    init: str = "normal"                 # normal | zeros | ones | small_normal
    scale: float = 1.0                   # stddev multiplier for normal init

    def __post_init__(self):
        if len(self.shape) != len(self.logical):
            raise ValueError(f"shape {self.shape} vs logical {self.logical}")


def init_param(key: jax.Array, spec: ParamSpec, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    # fan-in scaled normal
    fan_in = spec.shape[0] if len(spec.shape) >= 2 else max(spec.shape[-1], 1)
    if len(spec.shape) >= 3:
        fan_in = int(np.prod(spec.shape[:-1]))
    std = spec.scale / math.sqrt(max(fan_in, 1))
    return (std * jax.random.normal(key, spec.shape)).astype(dtype)


def init_tree(key: jax.Array, specs: Dict[str, Any], dtype) -> Dict[str, Any]:
    """Initialize a nested dict of ParamSpec into a pytree of arrays.

    Keys get independent fold_in streams, so adding a parameter never
    perturbs the initialization of existing ones (checkpoint stability).
    """
    out: Dict[str, Any] = {}
    for name in sorted(specs):
        sub = specs[name]
        sub_key = jax.random.fold_in(key, hash(name) % (2 ** 31))
        if isinstance(sub, dict):
            out[name] = init_tree(sub_key, sub, dtype)
        else:
            out[name] = init_param(sub_key, sub, dtype)
    return out


def spec_tree_logical(specs: Dict[str, Any]) -> Dict[str, Any]:
    """Parallel pytree of logical-axis tuples (for sharding rules)."""
    out: Dict[str, Any] = {}
    for name, sub in specs.items():
        if isinstance(sub, dict):
            out[name] = spec_tree_logical(sub)
        else:
            out[name] = sub.logical
    return out


def stacked(spec: ParamSpec, n: int, axis_name: str = "layer") -> ParamSpec:
    """Stack a per-layer spec along a leading scan axis."""
    return ParamSpec((n,) + spec.shape, (axis_name,) + spec.logical,
                     spec.init, spec.scale)


def stack_specs(specs: Dict[str, Any], n: int) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for name, sub in specs.items():
        if isinstance(sub, dict):
            out[name] = stack_specs(sub, n)
        else:
            out[name] = stacked(sub, n)
    return out
