"""Decoder-only LM assembly (dense + MoE + prefix-LM variants).

Layers are stacked along a leading axis and applied with ``jax.lax.scan``
so compile time and HLO size are depth-independent (96-layer nemotron
compiles as fast as 2-layer smoke configs).  Remat policy is applied to the
scan body by the training stack.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models.common import (
    ArchConfig, ParamSpec, init_tree, spec_tree_logical, stack_specs,
)
from repro.parallel.ctx import shard_act


def layer_specs(cfg: ArchConfig) -> Dict[str, Any]:
    s: Dict[str, Any] = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "attn": L.attn_specs(cfg),
    }
    if cfg.n_experts > 0 and cfg.moe_every == 1:
        s["moe"] = M.moe_specs(cfg)
    else:
        s["mlp"] = L.mlp_specs(cfg)
    return s


def decoder_specs(cfg: ArchConfig) -> Dict[str, Any]:
    return {
        "embed": L.embed_specs(cfg),
        "final_norm": ParamSpec((cfg.d_model,), ("embed",), init="zeros"),
        "layers": stack_specs(layer_specs(cfg), cfg.n_layers),
    }


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ArchConfig, x: jax.Array, lp: Dict[str, Any],
               mask_mode: str, prefix_len: int) -> Tuple[jax.Array, jax.Array]:
    sax = L.res_seq_axis(x.shape[1])
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    y = L.attn_apply(lp["attn"], h, cfg, mask_mode=mask_mode,
                     prefix_len=prefix_len)
    # constrain the sublayer OUTPUT (the TP partial sum) to the seq-sharded
    # layout: XLA lowers a partial-sum einsum with sharded output as a
    # reduce-scatter instead of all-reduce (Megatron-SP collective shape)
    y = shard_act(y, "act_batch", sax, "act_embed")
    x = x + y
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        y, aux = M.moe_apply(lp["moe"], h, cfg)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h, cfg), jnp.zeros((), jnp.float32)
    y = shard_act(y, "act_batch", sax, "act_embed")
    x = x + y
    return x, aux


def decoder_forward(params: Dict[str, Any], cfg: ArchConfig,
                    tokens: jax.Array,
                    prefix_embeds: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward -> (logits, aux_loss).

    ``prefix_embeds`` (VLM): (B, P, E) stub-frontend embeddings prepended;
    attention is bidirectional within the prefix (prefix-LM mask).
    """
    x = L.embed_lookup(params["embed"], tokens)
    mask_mode = "causal" if cfg.window == 0 else "window"
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
        mask_mode = "prefix"
    x = shard_act(x, "act_batch", L.res_seq_axis(x.shape[1]), "act_embed")

    def body(carry, lp):
        x, aux = carry
        x, a = _layer_fwd(cfg, x, lp, mask_mode, prefix_len)
        return (x, aux + a), None

    from repro.train.remat import maybe_remat
    (x, aux), _ = jax.lax.scan(maybe_remat(body),
                               (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if prefix_embeds is not None:
        x = x[:, prefix_len:]
    logits = L.unembed(params["embed"], x)
    return logits, aux / max(cfg.n_layers, 1)


def lm_loss(params: Dict[str, Any], cfg: ArchConfig,
            batch: Dict[str, jax.Array]) -> Tuple[jax.Array, Dict[str, Any]]:
    logits, aux = decoder_forward(params, cfg, batch["tokens"],
                                  prefix_embeds=batch.get("img"))
    loss = L.softmax_xent(logits, batch["labels"], batch.get("mask"))
    total = loss + 0.01 * aux
    return total, {"xent": loss, "aux": aux}


# ---------------------------------------------------------------------------
# prefill / decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               ) -> Dict[str, jax.Array]:
    """Stacked (L, B, S, KV, D) KV cache; sliding-window archs bound S at
    the window size (ring buffer)."""
    s = min(max_len, cfg.window) if cfg.window > 0 else max_len
    shape = (cfg.n_layers, batch, s, cfg.n_kv, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_logical() -> Dict[str, Tuple]:
    ax = (None, "act_batch", "act_seq_mp", "act_kv_heads", "act_head_dim")
    return {"k": ax, "v": ax, "pos": ()}


def decode_step(params: Dict[str, Any], cfg: ArchConfig,
                token: jax.Array, cache: Dict[str, jax.Array],
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One new token against the cache.  token: (B, 1) int32."""
    x = L.embed_lookup(params["embed"], token)
    pos = cache["pos"]

    def body(x, xs):
        lp, ck, cv = xs
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        y, ck, cv = L.attn_decode(lp["attn"], h, ck, cv, pos, cfg)
        x = x + y
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = M.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.mlp_apply(lp["mlp"], h, cfg)
        return x + y, (ck, cv)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], cache["k"],
                                         cache["v"]))
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x)
    new_cache = {"k": ks, "v": vs, "pos": pos + 1}
    return logits, new_cache


def prefill(params: Dict[str, Any], cfg: ArchConfig, tokens: jax.Array,
            max_len: int, prefix_embeds: Optional[jax.Array] = None,
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Process a prompt, returning last-position logits + a filled cache.

    Implemented as the training forward plus per-layer K/V capture (the
    standard two-program serving split: prefill is compute-bound and uses
    the chunked-attention path; decode is memory-bound).
    """
    B, S = tokens.shape[0], tokens.shape[1]
    x = L.embed_lookup(params["embed"], tokens)
    mask_mode = "causal" if cfg.window == 0 else "window"
    prefix_len = 0
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
        prefix_len = prefix_embeds.shape[1]
        mask_mode = "prefix"
    S_tot = x.shape[1]
    # the cache must hold the whole prompt (incl. any VLM prefix tokens)
    cache_len = (min(max_len, cfg.window) if cfg.window > 0
                 else max(max_len, S_tot))

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        # capture K/V (post-rope) for the cache while computing attention
        k = jnp.einsum("bse,ehd->bshd", h, lp["attn"]["wk"])
        v = jnp.einsum("bse,ehd->bshd", h, lp["attn"]["wv"])
        if cfg.qk_norm:
            k = L.rms_norm(k, lp["attn"]["k_norm"], cfg.norm_eps)
        k = L.rope(k, jnp.broadcast_to(jnp.arange(S_tot), (B, S_tot)),
                   cfg.rope_theta)
        if cfg.window > 0 and S_tot >= cache_len:
            # ring layout: slot = pos % window; for S_tot >= window the
            # last `window` positions occupy slots (pos % window)
            keep = S_tot - cache_len
            kc = jnp.roll(k[:, keep:], shift=S_tot % cache_len, axis=1)
            vc = jnp.roll(v[:, keep:], shift=S_tot % cache_len, axis=1)
        else:
            pad = cache_len - S_tot
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        x = x + L.attn_apply(lp["attn"], h, cfg, mask_mode=mask_mode,
                             prefix_len=prefix_len)
        h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = M.moe_apply(lp["moe"], h, cfg)
        else:
            y = L.mlp_apply(lp["mlp"], h, cfg)
        return x + y, (kc.astype(jnp.bfloat16), vc.astype(jnp.bfloat16))

    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x[:, -1:])
    cache = {"k": ks, "v": vs,
             "pos": jnp.asarray(S_tot, jnp.int32)}
    return logits, cache
