"""Model zoo: the 10 assigned architectures as pure-JAX pytree models.

Design rules (MaxText-style, framework-grade):
  * parameters are nested dicts of jnp arrays; no framework objects;
  * repeated layers are STACKED along a leading axis and applied with
    ``jax.lax.scan`` so compile time is depth-independent;
  * every parameter carries a *logical axis* spec (a tuple of names like
    ("embed", "mlp")); :mod:`repro.parallel.sharding` maps logical names to
    mesh axes, so the same model code runs on any mesh;
  * abstract instantiation (``jax.eval_shape`` over init) powers the
    multi-pod dry-run without allocating a single real weight.
"""
from repro.models.common import ArchConfig, ParamSpec, family_of
from repro.models.registry import (
    build_model, Model, list_architectures,
)

__all__ = ["ArchConfig", "ParamSpec", "family_of", "build_model", "Model",
           "list_architectures"]
