"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

TPU adaptation notes (DESIGN.md §2): GPU MoE kernels use ragged grouped
GEMMs; the TPU-native formulation keeps everything dense and static-shaped.
We use *grouped sort dispatch*: tokens are routed within their batch group
(which is data-sharded), so dispatch gathers never cross shards:

  router logits -> top-k -> flat (token,slot) list -> stable argsort by
  expert -> rank-within-expert via running offsets -> capacity drop ->
  gather into (E, C, d) -> per-expert GEMMs -> weighted segment-sum combine.

This avoids the (T, E, C) one-hot of classic GShard dispatch (O(T*E*C)
memory) at the cost of an argsort — O(T k log(Tk)) on the VPU, negligible
against the expert GEMMs.  Aux load-balancing loss follows Switch/GShard.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ArchConfig, ParamSpec
from repro.parallel.ctx import shard_act


def moe_specs(cfg: ArchConfig) -> Dict[str, ParamSpec]:
    E, F, X = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((E, X), ("embed", None)),
        "wi0": ParamSpec((X, E, F), ("expert", "embed", "mlp")),
        "wi1": ParamSpec((X, E, F), ("expert", "embed", "mlp")),
        "wo": ParamSpec((X, F, E), ("expert", "mlp", "embed")),
    }


def _capacity(tokens_per_group: int, cfg: ArchConfig) -> int:
    c = int(tokens_per_group * cfg.top_k * cfg.capacity_factor
            / max(cfg.n_experts, 1))
    return max(c, cfg.top_k)


#: perf knob (EXPERIMENTS.md §Perf, grok iteration C): constrain expert
#: weights to their compute layout (gathered over the FSDP axis) before the
#: expert GEMMs, so the contraction over d_model has no data-axis partial
#: sums — one weight all-gather replaces per-token activation all-reduces.
FORCE_WEIGHT_GATHER = False


def moe_apply(p: Dict[str, jax.Array], x: jax.Array, cfg: ArchConfig,
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, E) -> (y, aux_loss).  Groups = batch rows (data-sharded)."""
    from repro.parallel.ctx import current_ctx
    if FORCE_WEIGHT_GATHER and current_ctx() is not None:
        import jax.numpy as _jnp
        from jax.sharding import NamedSharding, PartitionSpec as _P
        ctx = current_ctx()
        gat = lambda w, spec: jax.lax.with_sharding_constraint(
            w, NamedSharding(ctx.mesh, spec))
        p = dict(p,
                 wi0=gat(p["wi0"], _P(None, None, "model")),
                 wi1=gat(p["wi1"], _P(None, None, "model")),
                 wo=gat(p["wo"], _P(None, "model", None)))
    B, S, E = x.shape
    X, K = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)

    logits = jnp.einsum("bse,ex->bsx", x, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)              # (B, S, X)
    top_w, top_e = jax.lax.top_k(probs, K)               # (B, S, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- aux load-balance loss (Switch eq. 4-6) over the whole batch
    me = probs.mean(axis=(0, 1))                          # (X,)
    ce = jnp.zeros((X,), jnp.float32).at[top_e.reshape(-1)].add(
        1.0 / (B * S * K))
    aux = X * jnp.sum(me * ce)

    # --- per-group sort dispatch
    Tk = S * K
    flat_e = top_e.reshape(B, Tk)                         # expert ids
    flat_w = top_w.reshape(B, Tk).astype(x.dtype)
    flat_tok = jnp.tile(jnp.repeat(jnp.arange(S), K)[None], (B, 1))

    order = jnp.argsort(flat_e, axis=-1, stable=True)     # (B, Tk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    sorted_tok = jnp.take_along_axis(flat_tok, order, axis=-1)
    sorted_w = jnp.take_along_axis(flat_w, order, axis=-1)

    # rank within expert: position in sorted list minus expert start offset
    counts = jax.vmap(lambda e: jnp.bincount(e, length=X))(flat_e)  # (B, X)
    starts = jnp.cumsum(counts, axis=-1) - counts                   # (B, X)
    rank = jnp.arange(Tk)[None] - jnp.take_along_axis(starts, sorted_e, -1)
    keep = rank < C
    slot = jnp.where(keep, sorted_e * C + rank, X * C)    # overflow slot

    # token index per (E*C) slot; dropped slots point at a zero row
    inv = jnp.full((B, X * C + 1), S, jnp.int32)
    inv = jax.vmap(lambda iv, sl, tk: iv.at[sl].set(tk, mode="drop"))(
        inv, slot, sorted_tok)
    slot_tok = inv[:, : X * C]                            # (B, X*C)
    slot_w = jnp.zeros((B, X * C + 1), x.dtype)
    slot_w = jax.vmap(lambda sv, sl, w: sv.at[sl].set(w, mode="drop"))(
        slot_w, slot, sorted_w)[:, : X * C]

    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, E), x.dtype)], axis=1)
    disp = jnp.take_along_axis(
        x_pad, slot_tok[..., None], axis=1)               # (B, X*C, E)
    disp = disp.reshape(B, X, C, E)
    disp = shard_act(disp, "act_batch", "act_expert", None, "act_embed")

    # --- expert FFN (SwiGLU), batched over experts
    h = jax.nn.silu(jnp.einsum("bxce,xef->bxcf", disp, p["wi0"])) \
        * jnp.einsum("bxce,xef->bxcf", disp, p["wi1"])
    h = shard_act(h, "act_batch", "act_expert", None, "act_ff")
    out = jnp.einsum("bxcf,xfe->bxce", h, p["wo"])        # (B, X, C, E)

    # --- weighted combine back to tokens
    out_flat = out.reshape(B, X * C, E) * slot_w[..., None]
    y = jax.vmap(
        lambda o, t: jnp.zeros((S, E), o.dtype).at[t].add(o, mode="drop"))(
        out_flat, slot_tok)
    y = shard_act(y, "act_batch", "act_seq", "act_embed")
    return y, aux.astype(jnp.float32)
