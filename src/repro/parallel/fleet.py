"""Device placement for the sharded fleet monitor.

The monitor side of the distribution layer: where :mod:`sharding`/
:mod:`ctx` map *model* tensors onto the mesh, this module maps *telemetry
shards* — each shard of the (hosts, C, T) fleet slab runs its detect
dispatch on one device of a 1-D ``"shard"`` mesh.  On a single-device box
(CI, the CPU bench) every shard lands on the same device and the layout
degenerates to the single-slab path's placement; on a real multi-device
mesh the shards' sweeps dispatch onto distinct accelerators with no code
change in the monitor.

Placement never changes verdicts: the sweep's decision contract
(exact-f64 moments host-side, marginal ticks re-decided through the f64
oracle — see ``kernels/sweep/ops.py``) holds on every backend, so device
assignment here is purely a throughput/locality decision.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import jax
from jax.sharding import Mesh


def monitor_devices(backend: Optional[str] = None) -> List[jax.Device]:
    """The device pool the sharded monitor schedules over.

    Defaults to every device of the default backend — the same pool the
    model mesh is built from.  A deployment that dedicates devices to
    monitoring passes a backend name.
    """
    return list(jax.devices(backend))


def fleet_mesh(devices: Optional[Sequence[jax.Device]] = None) -> Mesh:
    """A 1-D ``"shard"`` mesh over the monitor's device pool.

    One axis is all the monitor needs: shards are independent through
    detection (the rack→fleet reduce is a host-side candidate merge, not
    a collective), so there is no model/data axis split to express.
    """
    devs = list(devices) if devices is not None else monitor_devices()
    if not devs:
        raise ValueError("no devices available for the fleet mesh")
    import numpy as np
    return Mesh(np.array(devs), axis_names=("shard",))


def shard_devices(n_shards: int,
                  devices: Optional[Sequence[jax.Device]] = None,
                  ) -> List[jax.Device]:
    """Round-robin shard→device assignment over the pool.

    Returns a list of length ``n_shards``: shard ``i`` dispatches on
    ``devices[i % len(devices)]``.  Deterministic (no load balancing) so
    a round's placement — and therefore its performance profile — is
    reproducible run to run.
    """
    devs = list(devices) if devices is not None else monitor_devices()
    if not devs:
        raise ValueError("no devices available for shard placement")
    return [devs[i % len(devs)] for i in range(int(n_shards))]
