"""Mesh context: how model code applies activation sharding constraints
without threading mesh/rules through every function signature.

Inside ``mesh_context(mesh, rules)``, ``shard_act(x, "act_batch",
"act_seq", "act_embed")`` lowers to ``jax.lax.with_sharding_constraint``;
outside any context it is the identity, so models run unmodified on a
single device and in unit tests.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import Rules, logical_to_pspec


@dataclasses.dataclass
class MeshCtx:
    mesh: Mesh
    rules: Rules


class _State(threading.local):
    def __init__(self):
        self.stack: list = []


_STATE = _State()


@contextlib.contextmanager
def mesh_context(mesh: Mesh, rules: Rules):
    _STATE.stack.append(MeshCtx(mesh, rules))
    try:
        with mesh:
            yield _STATE.stack[-1]
    finally:
        _STATE.stack.pop()


def current_ctx() -> Optional[MeshCtx]:
    return _STATE.stack[-1] if _STATE.stack else None


def shard_act(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op
    without an active mesh context)."""
    ctx = current_ctx()
    if ctx is None:
        return x
    if len(logical) != x.ndim:
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    spec = logical_to_pspec(logical, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def with_logical(pspec_logical: Sequence[Optional[str]]) -> P:
    """Resolve a logical tuple to a PartitionSpec under the active context
    (P() everywhere when no context)."""
    ctx = current_ctx()
    if ctx is None:
        return P()
    return logical_to_pspec(pspec_logical, ctx.rules)
