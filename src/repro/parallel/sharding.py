"""Logical axis -> mesh axis rules and PartitionSpec construction.

Weights and activations use DISJOINT logical vocabularies (a weight "embed"
is FSDP-sharded over the data axis; an activation's embed dim is unsharded)
— mixing them is the classic source of accidental all-gathers.

Weight axes:
  embed   -> data      (FSDP / ZeRO shard; gathered per layer by XLA)
  mlp     -> model     (tensor parallel)
  heads   -> model     (tensor parallel, only when divisible)
  vocab   -> model     (output projection TP)
  layer/expert/kv_heads/conv/state/... -> unsharded

Activation axes:
  act_batch    -> (pod, data)
  act_heads    -> model   (when heads divide the axis; else None)
  act_seq_mp   -> model   (sequence sharding - the fallback attention
                           strategy for archs whose head count does not
                           divide the model axis, and the KV-cache layout
                           for long-context decode = flash-decoding split)
  act_ff/act_vocab -> model
  everything else -> unsharded
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from typing import TYPE_CHECKING
if TYPE_CHECKING:  # avoid circular import (models import parallel.ctx)
    from repro.models.common import ArchConfig

Rules = Dict[str, Any]   # logical name -> mesh axis | tuple | None

#: static defaults; make_rules() specializes per (config, mesh)
LOGICAL_RULES: Rules = {
    # weights
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv_heads": None,
    "vocab": "model",
    "layer": None,
    "expert": None,
    "conv": None,
    "state": None,
    "dt": None,
    "pos": None,
    # activations
    "act_batch": ("pod", "data"),
    "act_seq": None,
    "act_seq_mp": "model",
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": None,
    "act_head_dim": None,
    "act_ff": "model",
    "act_vocab": "model",
    "act_expert": None,
    "act_cap": "data",
    "act_state": None,
    "act_ssm_heads": "model",
    # Megatron-style sequence parallelism for the residual stream: the
    # between-layer carry (and hence the remat-saved activation stack) is
    # sharded over the model axis on the sequence dim; XLA inserts the
    # all-gather at QKV/FFN entry and reduce-scatter at exit.
    "act_seq_res": "model",
}


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        s = 1
        for n in name:
            s *= _axis_size(mesh, n)
        return s
    return mesh.shape[name] if name in mesh.axis_names else 0


def make_rules(cfg: Optional["ArchConfig"], mesh: Mesh) -> Rules:
    """Specialize the rule table for a config + mesh.

    - drops mesh axes that don't exist (single-pod mesh has no "pod");
    - if the arch's head count does not divide the model axis, attention
      falls back to sequence sharding: heads unshard, act_seq_mp stays.
    """
    rules = dict(LOGICAL_RULES)

    def filter_axes(v):
        if v is None:
            return None
        if isinstance(v, (tuple, list)):
            keep = tuple(a for a in v if a in mesh.axis_names)
            return keep if keep else None
        return v if v in mesh.axis_names else None

    rules = {k: filter_axes(v) for k, v in rules.items()}

    tp = _axis_size(mesh, "model")
    if cfg is not None and tp > 1:
        if cfg.n_heads == 0 or cfg.n_heads % tp != 0:
            rules["heads"] = None
            rules["act_heads"] = None
        # kv heads shard only if they divide (they rarely do; grouped KV is
        # replicated on the model axis and that is cheap - it is small)
        if cfg.n_kv and cfg.n_kv % tp == 0:
            rules["kv_heads"] = "model"
            rules["act_kv_heads"] = "model"
        if cfg.n_experts and cfg.n_experts % tp == 0:
            # expert-parallel layout is available; default keeps mlp TP
            pass
        if cfg.vocab_padded % tp != 0:
            rules["vocab"] = None
            rules["act_vocab"] = None
        if cfg.ssm_state == 0 or (cfg.ssm_nheads % tp != 0):
            rules["act_ssm_heads"] = None
        if cfg.d_ff and cfg.d_ff % tp != 0:
            rules["mlp"] = None
            rules["act_ff"] = None
    dp = _axis_size(mesh, "data")
    if cfg is not None and dp > 1 and cfg.d_model % dp != 0:
        rules["embed"] = None
    return rules


def logical_to_pspec(logical: Sequence[Optional[str]], rules: Rules) -> P:
    axes = []
    used: set = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        # a mesh axis may appear at most once per spec
        if ax is not None:
            flat = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
            if any(a in used for a in flat):
                ax = None
            else:
                used.update(flat)
        axes.append(ax)
    return P(*axes)


def param_pspecs(logical_tree: Dict[str, Any], rules: Rules) -> Dict[str, Any]:
    """Map a pytree of logical tuples to a pytree of PartitionSpec."""
    out: Dict[str, Any] = {}
    for k, v in logical_tree.items():
        if isinstance(v, dict):
            out[k] = param_pspecs(v, rules)
        else:
            out[k] = logical_to_pspec(v, rules)
    return out


def named_shardings(pspec_tree: Dict[str, Any], mesh: Mesh) -> Dict[str, Any]:
    return jax.tree.map(
        lambda p: NamedSharding(mesh, p), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))
