"""Distribution layer: mesh construction, logical-axis sharding rules,
activation constraints.

The model code annotates tensors with *logical* axis names; this package
owns the mapping to physical mesh axes, so the same model runs on a single
CPU device (everything maps to None), one pod (16x16 "data" x "model"), or
multi-pod (2 x 16 x 16 "pod" x "data" x "model").
"""
from repro.parallel.sharding import (
    LOGICAL_RULES, make_rules, logical_to_pspec, param_pspecs,
)
from repro.parallel.ctx import (
    MeshCtx, mesh_context, current_ctx, shard_act, with_logical,
)

__all__ = [
    "LOGICAL_RULES", "make_rules", "logical_to_pspec", "param_pspecs",
    "MeshCtx", "mesh_context", "current_ctx", "shard_act", "with_logical",
]
