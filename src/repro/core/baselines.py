"""Baseline diagnosers B1-B3 (paper §3.2, Table 2).

Each baseline is a *real estimator* run over the same trial telemetry — their
accuracy in our evaluation emerges from what their approach can and cannot
see, mirroring the paper's characterization:

  B1 GPU-centric  [Elmougy et al.]: device-level metrics only (NVML + PCIe).
     Sees throttling directly and PCIe/I-O indirectly; NIC and CPU
     interference is invisible, so it falls back to indirect shape
     heuristics on the latency series.
  B2 Cluster analysis  [Jeon et al.]: offline aggregate statistics — 1 Hz
     downsampled epoch means, no lag alignment, no per-node real-time path.
  B3 Deep profiling  [eGPU / XPUTIMER]: full-fidelity tracing of every
     channel (it has the richest data) but event-trace ranking is
     correlation-only — no spike/correlation confidence fusion — and the
     trace collect+parse cycle dominates its Time-to-RCA.

The fourth entry, our system, is `CorrelationEngine` behind the same
interface (`make_baseline("ours")`).

Overhead in Table 2 for B1-B3 is the literature-reported cost of each
collection stack (0.3 / 2.3 / 1.1 %); ours is *measured* live by the agent.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import sanitize as sanitize_mod
from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.spike import detect_sweep
from repro.core.taxonomy import CauseClass
from repro.telemetry.schema import (
    METRIC_REGISTRY, ORIENTATION, SignalGroup, GROUP_TO_CAUSE,
)


@dataclasses.dataclass
class DiagnoserResult:
    pred: CauseClass
    t_rca: Optional[float]          # virtual time the diagnosis completed
    detail: Dict[str, float]


class Diagnoser:
    """Interface: one trial in, one predicted cause out."""

    name: str = "base"
    reported_overhead_pct: Optional[float] = None   # literature value (B1-B3)

    def diagnose_trial(self, ts: np.ndarray, data: np.ndarray,
                       channels: Sequence[str]) -> DiagnoserResult:
        raise NotImplementedError

    def diagnose_trials(self, trials: Sequence[tuple],
                        ) -> List[DiagnoserResult]:
        """Many trials at once: ``trials`` is ``(ts, data, channels)``
        tuples.  The default is the sequential per-trial loop; engine-backed
        diagnosers override it with the event-batched Layer-3 path (all
        trials' events stacked into one fused dispatch)."""
        return [self.diagnose_trial(*t) for t in trials]

    def diagnose_store(self, store) -> List[DiagnoserResult]:
        """Columnar-eval entry: the whole protocol as one
        :class:`~repro.sim.scenario.TrialStore`.  The default unpacks the
        slab into per-trial row views; engine-backed diagnosers override
        with the slab-indexed evidence gather
        (``CorrelationEngine.diagnose_events_slab``)."""
        return self.diagnose_trials(store.rows())


# ---------------------------------------------------------------------------
# helpers shared by the baselines
# ---------------------------------------------------------------------------

def _latency_row(data: np.ndarray, channels: Sequence[str],
                 name: str = "coll_allreduce_ms") -> np.ndarray:
    return np.asarray(data[list(channels).index(name)], dtype=np.float64)


def _onset_index(L: np.ndarray, rate_hz: float, window_s: float = 5.0,
                 baseline_s: float = 20.0, threshold: float = 3.0,
                 persistence: float = 0.25) -> Optional[int]:
    """First index whose z vs a trailing baseline crosses the threshold.

    Requires ``persistence`` fraction of the window elevated, else ambient
    max-z over hundreds of correlated samples trips spuriously.

    All evaluation ticks are swept in one rolling-statistics pass
    (``spike.detect_sweep``) — the seed's per-tick loop recomputed the
    2,000-sample baseline mean/std ~700 times per trial and dominated the
    B1/B2 diagnoser cost.
    """
    wn, bn = int(window_s * rate_hz), int(baseline_s * rate_hz)
    ticks = np.arange(wn + bn, L.size, max(1, int(rate_hz // 10)))
    if ticks.size == 0:
        return None
    fire, _, onset = detect_sweep(L, wn, bn, ticks, threshold, persistence)
    hits = np.flatnonzero(fire)
    if hits.size == 0:
        return None
    i = int(hits[0])
    return int(ticks[i]) - wn + int(onset[i])


def _group_deviation(data: np.ndarray, channels: Sequence[str], onset: int,
                     rate_hz: float, pre_s: float, post_s: float,
                     agg_hz: float, groups: Sequence[SignalGroup],
                     ) -> Dict[CauseClass, float]:
    """Coarse post-vs-pre deviation per cause class at ``agg_hz`` resolution."""
    stride = max(1, int(rate_hz / agg_hz))
    pre_n, post_n = int(pre_s * rate_hz), int(post_s * rate_hz)
    lo, hi = max(0, onset - pre_n), min(data.shape[1], onset + post_n)
    rows, orient, causes = [], [], []
    for i, name in enumerate(channels):
        spec = METRIC_REGISTRY.get(name)
        if spec is None or spec.cause is None or spec.group not in groups:
            continue
        rows.append(i)
        orient.append(ORIENTATION.get(name, 1.0))
        causes.append(spec.cause)
    if not rows:
        return {}
    # all channels share the pre/post spans: one vectorized moment pass
    pre = np.asarray(data[rows, lo:onset:stride], dtype=np.float64)
    post = np.asarray(data[rows, onset:hi:stride], dtype=np.float64)
    if pre.shape[1] < 2 or post.shape[1] < 1:
        return {}
    mu = pre.mean(axis=1)
    sd = pre.std(axis=1)
    sd = np.maximum(sd, np.maximum(1e-3 * np.abs(mu), 1e-9))
    dev = (post.mean(axis=1) - mu) / sd
    o = np.asarray(orient)
    z = np.where(o == 0.0, np.abs(dev), o * dev)
    scores: Dict[CauseClass, float] = {}
    for cause, zi in zip(causes, z):
        if scores.get(cause, -np.inf) < zi:
            scores[cause] = float(zi)
    return scores


# ---------------------------------------------------------------------------
# B1 — GPU-centric
# ---------------------------------------------------------------------------

class GPUCentricDiagnoser(Diagnoser):
    name = "B1-gpu-centric"
    reported_overhead_pct = 0.3
    #: device-boundary channels only
    GROUPS = (SignalGroup.DEVICE, SignalGroup.PCIE)

    def __init__(self, rate_hz: float = 100.0):
        self.rate_hz = rate_hz

    def diagnose_trial(self, ts, data, channels) -> DiagnoserResult:
        L = _latency_row(data, channels)
        onset = _onset_index(L, self.rate_hz)
        if onset is None:
            return DiagnoserResult(CauseClass.UNKNOWN, None, {})
        scores = _group_deviation(data, channels, onset, self.rate_hz,
                                  pre_s=20.0, post_s=8.0, agg_hz=10.0,
                                  groups=self.GROUPS)
        gpu_z = scores.get(CauseClass.GPU, 0.0)
        io_z = scores.get(CauseClass.IO, 0.0)
        # Direct evidence first: throttle indicators, then PCIe disturbance.
        if gpu_z > 3.0 and gpu_z >= io_z:
            pred = CauseClass.GPU
        elif io_z > 3.0:
            pred = CauseClass.IO
        else:
            # NIC/CPU are invisible at the device boundary: fall back to a
            # latency shape heuristic — traffic-shaped interference is
            # on/off (latency keeps dipping back to baseline between
            # bursts), CPU starvation is sustained.  But this family's
            # latency view is the 10 Hz NVML/iteration-aggregate cadence
            # with ~0.5 s smoothing, which blurs burst gaps — the heuristic
            # is genuinely unreliable, as Table 2's 62.8 % reflects.
            k = max(1, int(0.5 * self.rate_hz))
            Ls = np.convolve(L, np.ones(k) / k, mode="same")[:: int(self.rate_hz // 10)]
            r10 = 10.0
            pre_lo = max(0, int(onset / self.rate_hz * r10) - int(20 * r10))
            o10 = int(onset / self.rate_hz * r10)
            mu_pre = float(np.mean(Ls[pre_lo:o10]))
            sd_pre = float(np.std(Ls[pre_lo:o10])) + 1e-9
            post = Ls[o10:min(Ls.size, o10 + int(8 * r10))]
            back_frac = float(np.mean(post < mu_pre + 3.0 * sd_pre))
            pred = CauseClass.NIC if back_frac > 0.22 else CauseClass.CPU
        # device-poll cadence (10 Hz) + one aggregation pass dominates; the
        # published diagnosis cycle for this family is tens of seconds.
        t_rca = float(ts[onset]) + 45.0 + float((onset % 7)) * 2.0
        return DiagnoserResult(pred, t_rca, {"gpu_z": gpu_z, "io_z": io_z})


# ---------------------------------------------------------------------------
# B2 — cluster-level offline analysis
# ---------------------------------------------------------------------------

class ClusterAnalysisDiagnoser(Diagnoser):
    name = "B2-cluster"
    reported_overhead_pct = 2.3

    #: Cluster-log counters only: one coarse aggregate per subsystem, the
    #: granularity a fleet-wide log pipeline actually retains.  Notably GPU
    #: *utilisation* stands in for GPU health (symptom, not cause), and no
    #: per-channel orientation is known — deviations are scored two-sided.
    #: Deviations are normalised by *busy-cluster variability* (second
    #: entry) — cluster aggregates swing with co-tenant load, not with one
    #: quiet node's noise floor — which is what caps this approach's
    #: attribution power.
    CHANNELS: Dict[str, Tuple[CauseClass, float]] = {
        "nic_rx_bytes": (CauseClass.NIC, 1.6e8),
        "cpu_util_other": (CauseClass.CPU, 0.16),
        "blkio_write_bytes": (CauseClass.IO, 4.0e8),
        "blkio_read_bytes": (CauseClass.IO, 4.0e8),
        "dev_util": (CauseClass.GPU, 0.10),
        "dev_power": (CauseClass.GPU, 38.0),
    }

    def __init__(self, rate_hz: float = 100.0, agg_hz: float = 1.0,
                 epoch_s: float = 30.0, cluster_noise: float = 1.35):
        self.rate_hz, self.agg_hz, self.epoch_s = rate_hz, agg_hz, epoch_s
        self.cluster_noise = cluster_noise

    def diagnose_trial(self, ts, data, channels) -> DiagnoserResult:
        L = _latency_row(data, channels)
        onset = _onset_index(L, self.rate_hz)
        if onset is None:
            return DiagnoserResult(CauseClass.UNKNOWN, None, {})
        stride = max(1, int(self.rate_hz / self.agg_hz))
        pre_n = int(self.epoch_s * self.rate_hz)
        post_n = int(self.epoch_s * self.rate_hz)
        lo, hi = max(0, onset - pre_n), min(data.shape[1], onset + post_n)
        ch_list = list(channels)
        # deterministic per-trial "rest of the cluster" noise
        rng = np.random.default_rng(int(abs(float(np.sum(data[:, ::97]))) * 1e3) % (2 ** 31))
        scores: Dict[CauseClass, float] = {}
        for name, (cause, sigma_cluster) in self.CHANNELS.items():
            if name not in ch_list:
                continue
            x = np.asarray(data[ch_list.index(name)], dtype=np.float64)
            pre, post = x[lo:onset:stride], x[onset:hi:stride]
            if pre.size < 2 or post.size < 1:
                continue
            delta = abs(float(np.mean(post)) - float(np.mean(pre)))
            z = delta / sigma_cluster + rng.normal(0.0, self.cluster_noise)
            scores[cause] = max(scores.get(cause, -np.inf), float(z))
        if not scores:
            return DiagnoserResult(CauseClass.UNKNOWN, None, {})
        pred = max(scores, key=scores.get)
        # offline pipeline: wait for the post epoch to close + batch analysis
        t_rca = float(ts[onset]) + self.epoch_s + 8.0 + float(onset % 9)
        return DiagnoserResult(pred, t_rca,
                               {c.value: v for c, v in scores.items()})


# ---------------------------------------------------------------------------
# B3 — deep profiling
# ---------------------------------------------------------------------------

class DeepProfilingDiagnoser(Diagnoser):
    name = "B3-deep-profiling"
    reported_overhead_pct = 1.1

    def __init__(self, rate_hz: float = 100.0):
        # Full-fidelity channels, correlation-only ranking (alpha=0): trace
        # systems rank by temporal alignment of events, they do not fuse a
        # deviation-magnitude prior.  Distributed trace aligners tolerate
        # wide clock skew (~0.5 s), which admits more spurious alignments
        # than our tight +/-200 ms window.
        self.engine = CorrelationEngine(EngineConfig(
            rate_hz=rate_hz, alpha=0.0, rca_extra_s=2.0, max_lag=50))
        self.rate_hz = rate_hz

    def _eventize(self, ts, data, channels) -> np.ndarray:
        # Trace systems *eventize*: a channel contributes trace events when
        # it crosses a threshold, and ranking correlates event trains — the
        # amplitude shape information our engine exploits is gone.
        del ts
        data = np.asarray(data, dtype=np.float64).copy()
        n0 = int(20 * self.rate_hz)
        lat_i = list(channels).index("coll_allreduce_ms")
        for i, name in enumerate(channels):
            if i == lat_i:
                continue
            spec = METRIC_REGISTRY.get(name)
            if spec is None or spec.cause is None:
                continue
            mu = float(np.mean(data[i, :n0]))
            sd = max(float(np.std(data[i, :n0])), 1e-3 * abs(mu), 1e-9)
            o = ORIENTATION.get(name, 1.0)
            z = (data[i] - mu) / sd
            z = np.abs(z) if o == 0.0 else o * z
            # saturating event counter: amplitude detail above ~12 sigma is
            # gone, below-threshold shape is kept at coarse fidelity
            data[i] = np.clip(z, 0.0, 12.0)
        return data

    def _result(self, d) -> DiagnoserResult:
        if d is None:
            return DiagnoserResult(CauseClass.UNKNOWN, None, {})
        # trace collect + parse cycle replaces our 2 s accumulation: 6-10 s
        extra = 6.0 + (int(d.event.t_detect * 10) % 5)
        return DiagnoserResult(d.top_cause, d.event.t_detect + extra,
                               {"conf": d.ranked[0].confidence if d.ranked else 0.0})

    def diagnose_trial(self, ts, data, channels) -> DiagnoserResult:
        data = self._eventize(ts, data, channels)
        diags = _with_forced_fallback(self.engine, ts, data, channels)
        return self._result(diags[0] if diags else None)

    def diagnose_trials(self, trials) -> List[DiagnoserResult]:
        """Event-batched eval path: one fused Layer-3 dispatch for the lot."""
        diags = _first_diagnoses_batched(self.engine, trials,
                                         prep=self._eventize)
        return [self._result(d) for d in diags]

    def diagnose_store(self, store) -> List[DiagnoserResult]:
        """Columnar path: eventize into a second slab, gather by indexing."""
        diags = _first_diagnoses_store(self.engine, store,
                                       prep=self._eventize)
        return [self._result(d) for d in diags]


# ---------------------------------------------------------------------------
# Ours, behind the same interface
# ---------------------------------------------------------------------------

def _with_forced_fallback(engine: CorrelationEngine, ts, data, channels):
    """Run the engine; if nothing fired, re-run with a relaxed detector.

    The paper's protocol scores every injected trial against the four
    classes (Table 4 has no reject column): an operator always gets *an*
    answer.  Weak events that miss the 3-sigma/persistence gate are
    re-examined at 2-sigma with minimal persistence — a genuine guess with
    genuine error modes.
    """
    diags = engine.process(ts, data, channels)
    if diags:
        return diags
    return _relaxed(engine).process(ts, data, channels)


def _relaxed(engine: CorrelationEngine) -> CorrelationEngine:
    """The 2-sigma / minimal-persistence fallback detector — one definition
    so the sequential and event-batched paths cannot drift apart."""
    return CorrelationEngine(
        dataclasses.replace(engine.cfg, threshold=2.0, persistence=0.05),
        sorted(engine.evidence_channels) if engine.evidence_channels is not None else None)


def _first_diagnoses_batched(engine: CorrelationEngine,
                             trials: Sequence[tuple], prep=None):
    """Each trial's first diagnosis (or None), via ONE fused Layer-3
    dispatch across all trials' events.

    Detection is the batched slab sweep (``detect_events_rows``, one
    dispatch for all trials; trials the strict detector leaves empty get
    one more batched sweep at the relaxed 2-sigma setting), and the
    per-event ``_diagnose`` replay, which dominates boundary-cadence eval
    wall time, collapses into a single ``fused_rca_max_ragged`` dispatch
    with events as rows.  The relaxed fallback shares that dispatch:
    threshold/persistence do not enter Layer-3 math, so its events batch
    with the strict ones.
    """
    prepped = []
    for (ts, data, channels) in trials:
        data = np.asarray(data)
        if prep is not None:
            data = prep(ts, data, channels)
        prepped.append((ts, data, channels))
    per_trial = engine.detect_events_rows(prepped)
    empty = [k for k, evs in enumerate(per_trial) if not evs]
    if empty:
        relaxed = _relaxed(engine).detect_events_rows(
            [prepped[k] for k in empty])
        for k, evs in zip(empty, relaxed):
            per_trial[k] = evs
    items, owner = [], []
    for (ts, data, channels), events in zip(prepped, per_trial):
        if events:
            ev, t = events[0]       # diagnose_trial consumes diags[0]
            owner.append(len(items))
            # same Layer-3 fill policy as process() — identity on clean
            items.append((ts, sanitize_mod.forward_fill(data),
                          list(channels), t, ev))
        else:
            owner.append(None)
    diags = engine.diagnose_events_batch(items)
    return [None if o is None else
            _reconciled_first(engine, items[o], diags[o]) for o in owner]


def _reconciled_first(engine: CorrelationEngine, item: tuple, d):
    """Apply the same per-trial reconciliation ``process()`` runs to a
    batched path's first diagnosis.  The full-trial pass derives its
    first verdict from the first event alone (later events only append),
    so reconciling the singleton keeps the sequential and batched eval
    paths on identical predictions.  Threshold/persistence do not enter
    reconciliation, so relaxed-fallback events share the strict config."""
    ts, data, channels, t, _ = item
    return engine.finalize_trial(ts, data, channels, [d], [t])[0]


def _first_diagnoses_store(engine: CorrelationEngine, store, prep=None):
    """Each trial's first diagnosis (or None) over a columnar TrialStore.

    Same structure as :func:`_first_diagnoses_batched` — batched slab
    detection sweep (one dispatch for the whole store, one more relaxed
    sweep over whichever rows stayed empty), ONE fused Layer-3 dispatch —
    but the evidence gather is slab indexing over the store's contiguous
    f32 (trials, C, T) array instead of per-event reslicing.  ``prep``
    (B3's eventizer) transforms each row once, into a second columnar
    slab, so the sweep and the gather stay slab-shaped for prepped
    diagnosers too.
    """
    slab, ts, channels = store.slab, store.ts, store.channels
    if prep is not None:
        slab = np.stack([prep(ts, slab[i], channels)
                         for i in range(len(store))]).astype(np.float32)
    per_row = engine.detect_events_store(ts, slab, channels)
    empty = [i for i, evs in enumerate(per_row) if not evs]
    if empty:
        relaxed = _relaxed(engine).detect_events_store(ts, slab, channels,
                                                       rows=empty)
        for i, evs in zip(empty, relaxed):
            per_row[i] = evs
    events, owner = [], []
    for i, evs in enumerate(per_row):
        if evs:
            ev, t = evs[0]          # diagnose_trial consumes diags[0]
            owner.append(len(events))
            events.append((i, t, ev))
        else:
            owner.append(None)
    if events:
        # same Layer-3 fill policy as process_store() — identity on clean
        slab = sanitize_mod.forward_fill(slab)
    diags = engine.diagnose_events_slab(ts, slab, channels, events)
    return [None if o is None else
            _reconciled_first(
                engine, (ts, slab[events[o][0]], channels, events[o][1], None),
                diags[o])
            for o in owner]


class OurDiagnoser(Diagnoser):
    name = "ours"
    reported_overhead_pct = None  # measured, not reported

    def __init__(self, config: Optional[EngineConfig] = None,
                 evidence_channels: Optional[Sequence[str]] = None):
        self.engine = CorrelationEngine(config, evidence_channels)

    def diagnose_trial(self, ts, data, channels) -> DiagnoserResult:
        diags = _with_forced_fallback(self.engine, ts, np.asarray(data), channels)
        return self._result(diags[0] if diags else None)

    def _result(self, d) -> DiagnoserResult:
        if d is None:
            return DiagnoserResult(CauseClass.UNKNOWN, None, {})
        detail = {"conf": d.ranked[0].confidence if d.ranked else 0.0,
                  "detect_latency": d.event.detection_latency}
        return DiagnoserResult(d.top_cause, d.t_rca, detail)

    def diagnose_trials(self, trials) -> List[DiagnoserResult]:
        """Event-batched eval path: one fused Layer-3 dispatch for the lot."""
        diags = _first_diagnoses_batched(self.engine, trials)
        return [self._result(d) for d in diags]

    def diagnose_store(self, store) -> List[DiagnoserResult]:
        """Columnar path: evidence gathered by slab indexing, no per-event
        python reslicing."""
        diags = _first_diagnoses_store(self.engine, store)
        return [self._result(d) for d in diags]


def make_baseline(name: str, rate_hz: float = 100.0, **kw) -> Diagnoser:
    name = name.lower()
    if name in ("b1", "gpu", "gpu-centric"):
        return GPUCentricDiagnoser(rate_hz)
    if name in ("b2", "cluster"):
        return ClusterAnalysisDiagnoser(rate_hz)
    if name in ("b3", "deep", "deep-profiling"):
        return DeepProfilingDiagnoser(rate_hz)
    if name in ("ours", "system"):
        return OurDiagnoser(**kw)
    raise ValueError(f"unknown baseline {name!r}")
