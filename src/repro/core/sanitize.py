"""Telemetry validity screening (chaos hardening, Layer 1.5).

Production telemetry lies in two ways the detection math must survive:
non-numeric corruption (NaN/Inf bursts from crashed probes, dropped ticks
surfacing as gaps) and *plausible-looking* corruption — a stuck collector
repeating its last value forever.  The first is cheap to find
(``isfinite``); the second needs run-length analysis: a real 100 Hz
latency series is continuous noise and never repeats the same f32 value
64 times in a row, while a frozen channel does nothing else.

This module derives per-tick validity masks from raw series and provides
the Layer-3 counterpart (``forward_fill``) that replaces non-finite
evidence cells with the last valid value so correlation windows stay
finite.  Contract shared with the masked detectors
(:mod:`repro.core.spike`): **a clean input is returned untouched** —
``validity_mask`` returns ``None`` and ``forward_fill`` returns the very
same array object — so the sanitized pipeline is byte-exact with the
pre-chaos pipeline whenever nothing is wrong, and the scan itself is the
only overhead (benchmarked in ``benchmarks/fleetbench.chaos_rows``).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

#: a run of at least this many *identical consecutive* finite values is a
#: frozen (stuck-at) channel.  Longer than any legitimate zero-order hold
#: in the pipeline (device channels repeat 10 samples at 100 Hz), shorter
#: than the detector's persistence requirement (0.35 * 500 = 175 hot
#: samples), so a frozen-at-elevated channel is masked long before it
#: could fire a spike.
FREEZE_RUN_N = 64


def freeze_runs(x: np.ndarray, run_n: int = FREEZE_RUN_N) -> np.ndarray:
    """Bool mask of cells inside a frozen run (1D or 2D, time last axis).

    A maximal run of ``>= run_n`` identical consecutive values is flagged
    *in full* — including its head.  Retroactive flagging matters: the
    run's head samples carry the stuck value too, and leaving them valid
    would let a frozen-at-elevated channel poison baselines (sigma
    collapses to the floor and every later ambient sample looks like a
    3-sigma spike).  NaN breaks runs (NaN != NaN) and is handled by the
    finiteness check instead.
    """
    x = np.asarray(x)
    one_d = x.ndim == 1
    if one_d:
        x = x[None, :]
    R, T = x.shape
    out = np.zeros((R, T), bool)
    if T >= run_n > 0:
        same = x[:, 1:] == x[:, :-1]
        for r in range(R):
            # run ids via boundary cumsum, then per-run lengths
            boundary = np.empty(T, bool)
            boundary[0] = True
            boundary[1:] = ~same[r]
            run_id = np.cumsum(boundary) - 1
            run_len = np.bincount(run_id)
            out[r] = run_len[run_id] >= run_n
    return out[0] if one_d else out


def validity_mask(x: np.ndarray, run_n: int = FREEZE_RUN_N,
                  check_freeze: bool = True) -> Optional[np.ndarray]:
    """Per-tick validity of a series (1D) or row-batch (2D).

    ``None`` means *every* cell is valid — the caller keeps its original
    unmasked code path, which is what makes clean inputs byte-exact.
    Otherwise a bool mask of the input's shape: finite AND (when
    ``check_freeze``) outside any frozen run.
    """
    x = np.asarray(x)
    finite = np.isfinite(x)
    clean = bool(finite.all())
    if clean and not check_freeze:
        return None
    if check_freeze:
        frozen = freeze_runs(x, run_n)
        if clean and not frozen.any():
            return None
        valid = finite & ~frozen
    else:
        valid = finite
    return valid


def forward_fill(x: np.ndarray) -> np.ndarray:
    """Replace non-finite cells with the last finite value (time axis last).

    Returns ``x`` itself (no copy) when everything is finite.  Leading
    invalid cells take the first finite value (backfill); a fully invalid
    row becomes zeros.  Frozen-but-finite cells are left alone — flat
    evidence scores ~zero spike and ~zero correlation, so it cannot
    manufacture a cause.
    """
    x = np.asarray(x)
    finite = np.isfinite(x)
    if finite.all():
        return x
    shape = x.shape
    T = shape[-1]
    x2 = x.reshape(-1, T)
    f2 = finite.reshape(-1, T)
    idx = np.where(f2, np.arange(T)[None, :], 0)
    np.maximum.accumulate(idx, axis=1, out=idx)
    rows = np.arange(x2.shape[0])[:, None]
    y = x2[rows, idx]
    # leading cells before the first finite sample: backfill from the right
    still = ~np.isfinite(y)
    if still.any():
        ridx = np.where(f2[:, ::-1], np.arange(T)[None, :], 0)
        np.maximum.accumulate(ridx, axis=1, out=ridx)
        yb = x2[:, ::-1][rows, ridx][:, ::-1]
        y = np.where(still, yb, y)
        y[~np.isfinite(y)] = 0.0    # fully invalid row
    return np.ascontiguousarray(y.reshape(shape), dtype=x.dtype)
