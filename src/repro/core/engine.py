"""The correlation engine (paper Fig 1, Layers 2-4, streaming).

Operates on a sliding 5 s observation window over the synchronized 100 Hz
telemetry matrix.  When the latency channel's spike score exceeds 3 sigma,
the engine (a) stamps the detection, (b) lets ``rca_extra_s`` more data
accumulate so lagged correlation sees the spike flanks, then (c) runs
Layer 3 (per-metric spike scores + lagged cross-correlation + confidence
fusion) and emits a ranked :class:`Diagnosis`.

Time accounting matches the paper's metrics:
  detection latency  ~ window mechanics (≈5 s after onset),
  Time-to-RCA        = onset -> diagnosis complete (detection + accumulation
                       + analysis compute), the paper's 6-8 s.

The full-trial replay (``process``) evaluates every cadence tick from one
vectorized prefix-sum pass (``spike.detect_sweep``) instead of re-slicing
the 2,500-sample baseline at every tick; ``fast=False`` keeps the original
scalar per-tick path as the parity oracle.  At suite scale the per-trial
sweep itself batches: ``detect_events_slab`` / ``detect_events_store`` /
``detect_events_rows`` run Layer 2 for ALL rows of a (trials, C, T) slab
in one batched sweep (kernels/sweep) and replay the concurrent-hypothesis
state machine over the precomputed decisions — byte-exact against the
per-row path, which remains the oracle.  Layer 2 carries up to
``max_hypotheses`` incident hypotheses at once (each with its own
maturation deadline and cooldown); ``core.reconcile`` post-processes the
matured stream into one verdict per distinct cause.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import confidence as conf_mod
from repro.core import sanitize as sanitize_mod
from repro.core import spike as spike_mod
from repro.core import xcorr as xcorr_mod
from repro.core.taxonomy import CauseClass, Diagnosis, SpikeEvent
from repro.telemetry.schema import METRIC_REGISTRY, ORIENTATION

#: below this many samples a pre-onset slice is too short to be a baseline
MIN_BASELINE_N = 32

#: the engine's duplicate-suppression window, in seconds.  THE definition —
#: :class:`EngineConfig` defaults to it, the fleet monitor's session dedup
#: inherits it through ``cfg.cooldown_s``, and the scorer's matching
#: tolerance is derived from it (``sim.scoring.TOL_S``), so the three layers
#: cannot silently drift apart.
COOLDOWN_S = 15.0

#: python-level evidence-gather operations (numpy slice/fancy-index calls on
#: trial data) — the observable the columnar trial store exists to shrink:
#: ``diagnose_events_batch`` pays O(events) of them, the slab path O(1) per
#: layout group.  Counted, not timed, so tests can assert the reduction.
SLICE_OPS = 0


@dataclasses.dataclass
class EngineConfig:
    rate_hz: float = 100.0
    window_s: float = 5.0        # observation window W (paper Table 1)
    baseline_s: float = 20.0     # baseline window W_b preceding W
    threshold: float = 3.0       # 3-sigma (paper Table 1)
    persistence: float = 0.35    # fraction of W that must exceed 3-sigma
    pre_onset_s: float = 2.5     # correlation window reaches back this far
                                 # before the estimated onset (the rise is
                                 # where lagged correlation has its signal)
    max_lag: int = 20            # K samples = 200 ms @ 100 Hz (paper)
    alpha: float = 0.5           # confidence mixing weight (paper)
    rca_extra_s: float = 2.0     # post-detection accumulation before Layer 3
    eval_every: int = 0          # detection cadence in samples; 0 = window_n
                                 # (boundary evaluation — gives the paper's
                                 # ~5 s detection latency with a 5 s window)
    cooldown_s: float = COOLDOWN_S   # suppress duplicate events
    latency_metric: str = "coll_allreduce_ms"
    max_hypotheses: int = 3      # concurrent Layer-2 incident hypotheses
    step_sigma: float = 2.0      # a fired tick during an active incident
                                 # opens a new hypothesis only when the
                                 # window's hot level steps this many of the
                                 # newest hypothesis's sigmas above its anchor
    swap_margin: float = 0.05    # reconciliation: an uncorroborated primary
                                 # yields to a corroborated runner within
                                 # this confidence margin

    @property
    def window_n(self) -> int:
        return int(self.window_s * self.rate_hz)

    @property
    def baseline_n(self) -> int:
        return int(self.baseline_s * self.rate_hz)


@dataclasses.dataclass
class Hypothesis:
    """One concurrent Layer-2 incident hypothesis.

    ``rca_at`` is an absolute sample index on the trial grid (the tick at
    which the hypothesis matures into a diagnosable event); ``mu``/``sd``
    anchor the hot-level statistics of the window that opened it, against
    which a later fired tick's step is measured.  A hypothesis stays in
    the set after maturing until its cooldown expires, so it keeps
    suppressing re-detections of the same regime.
    """

    event: SpikeEvent
    rca_at: int
    matured: bool = False
    mu: float = 0.0          # hot-level anchor: mean of the opening
    sd: float = 0.0          # window's post-onset samples, and its sigma

    def to_dict(self) -> Dict[str, object]:
        e = self.event
        return {"event": {"t_onset": e.t_onset, "t_detect": e.t_detect,
                          "score": e.score, "metric": e.metric},
                "rca_at": int(self.rca_at), "matured": bool(self.matured),
                "mu": float(self.mu), "sd": float(self.sd)}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "Hypothesis":
        e = d["event"]
        return cls(event=SpikeEvent(
                       t_onset=float(e["t_onset"]),
                       t_detect=float(e["t_detect"]),
                       score=float(e["score"]), metric=str(e["metric"])),
                   rca_at=int(d["rca_at"]), matured=bool(d["matured"]),
                   mu=float(d["mu"]), sd=float(d["sd"]))


@dataclasses.dataclass
class StreamState:
    """The mutable machine of :meth:`CorrelationEngine.detect_events`,
    externalized so a monitor can checkpoint it and resume after a crash.

    The machine is a bounded set of concurrent :class:`Hypothesis` records
    (``cfg.max_hypotheses`` at most), each with its own maturation deadline
    and cooldown anchor.  ``rca_at`` indices are absolute sample positions
    on the trial grid, so resuming is only valid over growing prefixes of
    the *same* grid (which is exactly what a ring replay presents).
    ``t_seen`` marks the newest cadence tick already evaluated: on resume,
    older ticks are skipped, so an event emitted before the crash can
    never be emitted again — the duplicate-verdict suppression is the
    restored hypothesis set itself.
    """

    hypotheses: List[Hypothesis] = dataclasses.field(default_factory=list)
    t_seen: float = -np.inf          # newest tick time already evaluated

    def flush(self, T: int) -> List[Tuple[SpikeEvent, int]]:
        """End-of-stream flush: every not-yet-matured hypothesis with
        whatever data exists, in maturation (``rca_at``) order — exactly
        the stateless path's trial-end flush."""
        due = sorted((h for h in self.hypotheses if not h.matured),
                     key=lambda h: h.rca_at)
        out = [(h.event, int(T) - 1) for h in due]
        for h in due:
            h.matured = True
        return out

    def to_dict(self) -> Dict[str, object]:
        return {"t_seen": float(self.t_seen),
                "hypotheses": [h.to_dict() for h in self.hypotheses]}

    @classmethod
    def from_dict(cls, d: Dict[str, object]) -> "StreamState":
        # no fallback for the retired single-pending shape: a payload
        # without the hypothesis set is from a different machine and must
        # fail loudly (the caller cold-starts), never half-restore
        hyps = d["hypotheses"]
        return cls(hypotheses=[Hypothesis.from_dict(h) for h in hyps],
                   t_seen=float(d["t_seen"]))


#: (channels, latency_metric, evidence_restriction) -> (names, row idx,
#: orientation vector).  Evaluating the registry per channel is pure, so the
#: layout is shared process-wide across engines and the fleet monitor.
_LAYOUT_CACHE: Dict[tuple, Tuple[List[str], np.ndarray, np.ndarray]] = {}


def evidence_layout(channels: Sequence[str], latency_metric: str,
                    evidence_channels: Optional[frozenset] = None,
                    ) -> Tuple[List[str], np.ndarray, np.ndarray]:
    """Names, row indices and orientation signs of the evidence channels."""
    key = (tuple(channels), latency_metric, evidence_channels)
    hit = _LAYOUT_CACHE.get(key)
    if hit is not None:
        return hit
    names: List[str] = []
    idx: List[int] = []
    orient: List[float] = []
    for i, name in enumerate(channels):
        if name == latency_metric:
            continue
        spec = METRIC_REGISTRY.get(name)
        if spec is None or spec.cause is None:
            continue
        if evidence_channels is not None and name not in evidence_channels:
            continue
        names.append(name)
        idx.append(i)
        orient.append(ORIENTATION.get(name, 1.0))
    out = (names, np.asarray(idx, np.intp), np.asarray(orient, np.float64))
    _LAYOUT_CACHE[key] = out
    return out


def pick_baseline_slice(nb: int, onset_head: int, n_total: int) -> slice:
    """Baseline columns for Layer-3 scoring, shared by the scalar engine
    and the batched fleet path.

    Trailing history when present (``nb`` columns precede the window);
    otherwise the window's pre-onset head — a genuine quiet stretch — and
    only the full (spiky) window as a last resort.  (The seed's np.resize
    hack silently used the spiky window itself as baseline.)
    """
    if nb > 0:
        return slice(0, nb)
    if onset_head >= MIN_BASELINE_N:
        return slice(0, onset_head)
    return slice(0, n_total)


def orient_about_baseline(X: np.ndarray, orient: np.ndarray,
                          b_sl: slice) -> np.ndarray:
    """Apply per-metric anomaly orientation about the baseline-region mean.

    ``X`` is (..., M, N) with metrics on the second-to-last axis and
    ``orient`` (M,) in {+1, -1, 0}: +1 a rise is anomalous, -1 a drop,
    0 two-sided (|deviation|).
    """
    mu = X[..., b_sl].mean(axis=-1, keepdims=True)       # (..., M, 1)
    # match X's dtype so the f32 columnar path stays f32 (no silent upcast)
    o = orient.reshape(-1, 1).astype(X.dtype, copy=False)
    dev = X - mu
    return mu + np.where(o == 0.0, np.abs(dev), o * dev)


class CorrelationEngine:
    """Streaming engine over an aligned (C, T) telemetry matrix."""

    def __init__(self, config: Optional[EngineConfig] = None,
                 evidence_channels: Optional[Sequence[str]] = None):
        self.cfg = config or EngineConfig()
        #: restrict evidence to these channels (None = registry default);
        #: used for probe-ablation experiments.
        self.evidence_channels = (set(evidence_channels)
                                  if evidence_channels is not None else None)

    # ------------------------------------------------------------------ util
    def _oriented(self, name: str, x: np.ndarray, mu: float) -> np.ndarray:
        """Apply anomaly orientation: +1 rise, -1 drop, 0 two-sided (|dev|)."""
        o = ORIENTATION.get(name, 1.0)
        if o == 0.0:
            return mu + np.abs(x - mu)
        return mu + o * (x - mu)

    def _is_evidence(self, name: str) -> bool:
        spec = METRIC_REGISTRY.get(name)
        if spec is None or spec.cause is None:
            return False
        if self.evidence_channels is not None and name not in self.evidence_channels:
            return False
        return True

    def _layout(self, channels: Sequence[str]):
        restrict = (frozenset(self.evidence_channels)
                    if self.evidence_channels is not None else None)
        return evidence_layout(channels, self.cfg.latency_metric, restrict)

    # ------------------------------------------------------- batch processing
    def detect_events(self, ts: np.ndarray, data: np.ndarray,
                      channels: Sequence[str], fast: bool = True,
                      state: Optional[StreamState] = None,
                      ) -> List[Tuple[SpikeEvent, int]]:
        """Layer-2 sweep only: every event the streaming replay would
        diagnose, as ``(event, rca_index)`` pairs in time order.

        The detection sequence (cooldown, pending-accumulation windows) is
        independent of Layer-3 *results*, so the sweep can be split off and
        the diagnoses batched — ``process`` composes the two, and the
        event-batched eval path stacks the events of many trials into one
        fused dispatch (``diagnose_events_batch``).  ``rca_index`` is the
        exact sample index Layer 3 runs at (detection + accumulation,
        clamped to trial end).

        The machine carries up to ``cfg.max_hypotheses`` concurrent
        hypotheses.  The first detection of a quiet stream always opens
        one; while any hypothesis is active (pending maturation or inside
        its cooldown), a further fired tick opens a *new* hypothesis only
        when the window's hot level steps at least ``cfg.step_sigma`` of
        the newest hypothesis's sigmas above its anchor — a genuinely new
        regime on top of the incident, not the same elevated plateau
        re-firing.  Each hypothesis matures at its own accumulation index
        and keeps suppressing re-detections until its own cooldown
        expires.  With ``max_hypotheses=1`` the machine degenerates to the
        original single-pending/global-cooldown behaviour, event for
        event.

        With ``state`` the machine resumes from (and persists back to) a
        :class:`StreamState`: ticks at or before ``state.t_seen`` are
        skipped and unmatured hypotheses survive the call instead of being
        flushed at the array end — running the detector over growing
        prefixes of one grid yields byte-for-byte the one-shot event
        stream (the warm-restart replay contract; the caller ends the
        stream with ``state.flush``).  Stateful calls always decide through
        the scalar per-tick oracle: the sweep's prefix-sum moments are
        shifted by the *global* series mean, so a tick's score would drift
        in the last bits with the prefix length — slice-exact scalar stats
        are the only decisions identical no matter where the stream was
        cut.
        """
        cfg = self.cfg
        channels = list(channels)
        if data.shape != (len(channels), ts.shape[0]):
            raise ValueError(f"data {data.shape} vs channels {len(channels)} x T {ts.shape[0]}")
        if cfg.latency_metric not in channels:
            raise ValueError(f"latency channel {cfg.latency_metric!r} not present")
        li = channels.index(cfg.latency_metric)
        L = np.asarray(data[li], dtype=np.float64)
        # chaos hardening: a corrupted latency row (non-finite cells,
        # frozen runs) flips detection to the validity-masked oracle —
        # poisoned cells enter neither baselines nor decisions.  Clean
        # rows get None back and keep the original path bit for bit.
        Lv = sanitize_mod.validity_mask(L)
        T = ts.shape[0]
        wn, bn = cfg.window_n, cfg.baseline_n
        rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
        out: List[Tuple[SpikeEvent, int]] = []
        hyps: List[Hypothesis] = []
        seen_t = -np.inf
        if state is not None:
            hyps = state.hypotheses
            seen_t = state.t_seen
            fast = False     # slice-exact decisions, prefix-independent

        cadence = cfg.eval_every if cfg.eval_every > 0 else wn
        t0 = wn + bn
        ticks = np.arange(t0, T, cadence)
        if fast and ticks.size:
            # Layer-2 decisions for the whole sweep in one rolling pass; the
            # stateful cooldown/pending machinery below merely consults them.
            if Lv is None:
                fire_v, score_v, onset_v = spike_mod.detect_sweep(
                    L, wn, bn, ticks, cfg.threshold, cfg.persistence)
            else:
                fire_v, score_v, onset_v = spike_mod.detect_sweep_masked(
                    L, Lv, wn, bn, ticks, cfg.threshold, cfg.persistence)
        for i, t in enumerate(ticks):
            t = int(t)
            now = float(ts[t])
            # resume: ticks already evaluated before a checkpoint were
            # decided on the identical data prefix — re-walking them could
            # only re-emit, so they are skipped wholesale
            if now <= seen_t:
                continue
            # -- hypotheses pending accumulation mature at the exact
            # accumulation index, not the next boundary, in rca_at order
            for h in sorted((h for h in hyps
                             if not h.matured and t >= h.rca_at),
                            key=lambda h: h.rca_at):
                out.append((h.event, min(h.rca_at, T - 1)))
                h.matured = True
            # -- a matured hypothesis retires once its own cooldown lapses;
            # until then it keeps suppressing re-detections of its regime
            hyps = [h for h in hyps
                    if not (h.matured
                            and now - h.event.t_detect >= cfg.cooldown_s)]
            # -- Layer 2 detection on the latency channel
            if fast:
                is_spike = bool(fire_v[i])
                score = float(score_v[i])
                onset_idx = int(onset_v[i]) if is_spike else None
            else:
                obs = L[t - wn:t]
                base = L[t - wn - bn:t - wn]
                if Lv is None:
                    is_spike, score, onset_idx = spike_mod.detect(
                        obs, base, cfg.threshold, cfg.persistence)
                else:
                    is_spike, score, onset_idx = spike_mod.detect_masked(
                        obs, base, Lv[t - wn:t], Lv[t - wn - bn:t - wn],
                        cfg.threshold, cfg.persistence)
            if not is_spike:
                continue
            # hot-level anchor from the raw f64 latency row — the same
            # slice in every execution path, so the step-gate decision is
            # bitwise identical no matter which sweep produced the tick
            hot = L[t - wn + int(onset_idx):t]
            onset_t = float(ts[t - wn + int(onset_idx)])
            rec = Hypothesis(
                event=SpikeEvent(t_onset=onset_t, t_detect=now, score=score,
                                 metric=cfg.latency_metric),
                rca_at=t + rca_n, matured=False,
                mu=float(hot.mean()), sd=float(hot.std()))
            if not hyps:
                hyps.append(rec)
            elif len(hyps) < cfg.max_hypotheses:
                ref = hyps[-1]
                z = (rec.mu - ref.mu) / max(ref.sd, 1e-9)
                if z >= cfg.step_sigma:
                    hyps.append(rec)
        if state is not None:
            # persist the machine instead of flushing: the stream may
            # continue (next round, or a post-restart replay)
            state.hypotheses = hyps
            if ticks.size:
                state.t_seen = max(seen_t, float(ts[int(ticks[-1])]))
            return out
        # trial end: flush unmatured hypotheses using whatever data exists
        for h in sorted((h for h in hyps if not h.matured),
                        key=lambda h: h.rca_at):
            out.append((h.event, T - 1))
        return out

    # ------------------------------------------------- suite-scale Layer 2
    @staticmethod
    def _resolve_row(ts: np.ndarray, ticks: np.ndarray, fire_row: np.ndarray,
                     onset_row: np.ndarray, L_row: np.ndarray,
                     nt_r: int, T_r: int, wn: int, rca_n: int,
                     cooldown_s: float, max_hyp: int, step_sigma: float,
                     ) -> List[Tuple[int, int]]:
        """Replay :meth:`detect_events`' hypothesis-set state machine over
        one row's precomputed tick decisions — visiting fired ticks only
        instead of walking every tick.

        The set's evolution between fired ticks is fully determined: a
        hypothesis matures at the first tick reaching its accumulation
        index (an emission the caller can stamp without visiting the
        tick), and whether it has retired by a later fired tick is a pure
        predicate of that tick's clock — so recomputing the active set at
        each fired tick reproduces the per-tick walk exactly.  The
        step-sigma gate re-derives each fired window's hot statistics from
        the row's own f64 latency samples (``L_row``), the identical slice
        the scalar oracle reads, so gate decisions are bitwise the same.

        Returns ``(tick_index, rca_sample_index)`` pairs.  Hypotheses
        mature in ``rca_at`` order and ``rca_at`` grows with the opening
        tick, so detection order *is* maturation order — exactly the
        per-row loop's output order.  A hypothesis whose accumulation
        index lies past the last tick flushes at row end with whatever
        data exists.
        """
        hits = np.flatnonzero(fire_row[:nt_r])
        out: List[Tuple[int, int]] = []
        # open hypotheses: (tick_index, now, mature_tick_index, mu, sd);
        # mature_tick_index = first tick at/after rca_at (nt_r = never)
        hyps: List[Tuple[int, float, int, float, float]] = []
        for k in range(hits.size):
            i = int(hits[k])
            t = int(ticks[i])
            now = float(ts[t])
            # active = not (matured by this tick AND cooldown lapsed);
            # maturation at tick i itself precedes detection at i
            hyps = [h for h in hyps
                    if not (h[2] <= i and now - h[1] >= cooldown_s)]
            hot = L_row[t - wn + int(onset_row[i]):t]
            mu, sd = float(hot.mean()), float(hot.std())
            if hyps:
                if len(hyps) >= max_hyp:
                    continue
                ref = hyps[-1]
                z = (mu - ref[3]) / max(ref[4], 1e-9)
                if not z >= step_sigma:     # NaN-safe: NaN never opens
                    continue
            rca_at = t + rca_n
            # maturation happens at the top of a LATER tick's iteration
            # (the hypothesis is appended after its own tick's maturation
            # phase), so the first eligible tick is strictly after i even
            # when rca_n is 0
            j = max(int(np.searchsorted(ticks[:nt_r], rca_at)), i + 1)
            out.append((i, min(rca_at, T_r - 1) if j < nt_r else T_r - 1))
            hyps.append((i, now, j, mu, sd))
        return out

    def _sweep_events(self, ts: np.ndarray, lat64: np.ndarray,
                      valid_n: Optional[np.ndarray] = None,
                      use_kernel: bool = False,
                      ) -> List[List[Tuple[SpikeEvent, int]]]:
        """Shared slab-sweep core: ONE batched Layer-2 sweep over the
        (rows, T) latency slab + a numpy resolve per row.

        The rolling baseline moments are computed once for the whole slab
        in exact f64 — bitwise the per-row oracle's — and the default CPU
        path is the score-screened exact sweep
        (``sweep_ops.sweep_rows_exact``): decisions, onsets and scores are
        byte-identical to the per-row ``detect_events`` oracle *by
        construction*.  ``use_kernel=True`` dispatches the f32 Pallas
        sweep instead and re-decides its epsilon-marginal ticks / resolved
        detection scores through the same f64 moments
        (``spike.detect_sweep_at``), so the kernel path is byte-exact
        too: decisions provably agree off the guard band, and on it the
        oracle itself decides.
        """
        from repro.kernels.sweep import ops as sweep_ops

        cfg = self.cfg
        lat64 = np.asarray(lat64, dtype=np.float64)
        R, T = lat64.shape
        wn, bn = cfg.window_n, cfg.baseline_n
        rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
        cadence = cfg.eval_every if cfg.eval_every > 0 else wn
        ticks = np.arange(wn + bn, T, cadence)
        if ticks.size == 0:
            return [[] for _ in range(R)]
        nt = ticks.size

        def row64(r: int) -> np.ndarray:
            return (lat64[r] if valid_n is None
                    else lat64[r, :int(valid_n[r])])

        # chaos hardening: rows with corrupted cells (non-finite, frozen
        # runs) are carved out of the batched sweep and decided by the
        # masked oracle — the same function the per-trial path uses, so
        # all eval paths stay bitwise identical under chaos.  The mask is
        # derived per truncated row, exactly as detect_events sees it.
        row_mask: List[Optional[np.ndarray]] = [None] * R
        for r in range(R):
            row_mask[r] = sanitize_mod.validity_mask(row64(r))
        dirty = np.asarray([m is not None for m in row_mask])
        clean_idx = np.flatnonzero(~dirty)

        fire = np.zeros((R, nt), bool)
        score = np.zeros((R, nt))
        onset = np.full((R, nt), -1, np.intp)
        mu64 = np.zeros((R, nt))
        sd64 = np.ones((R, nt))
        if clean_idx.size:
            latC = lat64[clean_idx]
            vnC = (None if valid_n is None
                   else np.asarray(valid_n)[clean_idx])
            muC, sdC = sweep_ops.rolling_moments(latC, ticks, wn, bn, vnC)
            mu64[clean_idx], sd64[clean_idx] = muC, sdC
            if use_kernel:
                # the f32 dispatch slab is only staged on the kernel path —
                # an f32 source round-trips f64->f32 bit-identically
                fC, sC, oC, margC = sweep_ops.sweep_rows(
                    np.ascontiguousarray(latC, np.float32), wn, bn, ticks,
                    cfg.threshold, cfg.persistence, valid_n=vnC,
                    moments=(muC, sdC), use_kernel=True)
                for j in np.flatnonzero(margC.any(axis=1)):
                    m = margC[j]
                    r = int(clean_idx[j])
                    f2, s2, o2 = spike_mod.detect_sweep_at(
                        row64(r), wn, ticks[m], muC[j, m], sdC[j, m],
                        cfg.threshold, cfg.persistence)
                    fC[j, m], sC[j, m], oC[j, m] = f2, s2, o2
            else:
                fC, sC, oC = sweep_ops.sweep_rows_exact(
                    latC, wn, bn, ticks, cfg.threshold, cfg.persistence,
                    valid_n=vnC, moments=(muC, sdC))
            fire[clean_idx], score[clean_idx], onset[clean_idx] = fC, sC, oC
        for r in np.flatnonzero(dirty):
            x = row64(r)
            k = int(np.searchsorted(ticks, x.size, side="right"))
            if k == 0:
                continue
            fire[r, :k], score[r, :k], onset[r, :k] = \
                spike_mod.detect_sweep_masked(
                    x, row_mask[r], wn, bn, ticks[:k],
                    cfg.threshold, cfg.persistence)

        out: List[List[Tuple[SpikeEvent, int]]] = []
        for r in range(R):
            T_r = T if valid_n is None else int(valid_n[r])
            # the oracle's tick grid for a row ending at T_r is
            # arange(t0, T_r, cadence) — strictly below T_r, so a ragged
            # row must not be evaluated at a tick landing exactly on its
            # valid length (the sweep's <= masking is the detect_sweep
            # range convention, wider than the event grid)
            nt_r = int(np.searchsorted(ticks, T_r, side="left"))
            resolved = self._resolve_row(ts, ticks, fire[r], onset[r],
                                         row64(r), nt_r, T_r, wn, rca_n,
                                         cfg.cooldown_s, cfg.max_hypotheses,
                                         cfg.step_sigma)
            if not resolved:
                out.append([])
                continue
            if use_kernel and not dirty[r]:
                # stamp the oracle's f64 scores at the detection ticks
                # (the decisions there are already exact; the f32 max-z
                # value itself still carries rounding unless recomputed)
                det = np.asarray([i for i, _ in resolved], np.intp)
                _, s64, _ = spike_mod.detect_sweep_at(
                    row64(r), wn, ticks[det], mu64[r, det], sd64[r, det],
                    cfg.threshold, cfg.persistence)
                scores = [float(s) for s in s64]
            else:
                scores = [float(score[r, i]) for i, _ in resolved]
            evs: List[Tuple[SpikeEvent, int]] = []
            for k, (i, rca) in enumerate(resolved):
                t = int(ticks[i])
                evs.append((SpikeEvent(
                    t_onset=float(ts[t - wn + int(onset[r, i])]),
                    t_detect=float(ts[t]), score=scores[k],
                    metric=cfg.latency_metric), rca))
            out.append(evs)
        return out

    def detect_events_store(self, ts: np.ndarray, slab: np.ndarray,
                            channels: Sequence[str],
                            rows: Optional[Sequence[int]] = None,
                            valid_n: Optional[np.ndarray] = None,
                            use_kernel: bool = False,
                            ) -> List[List[Tuple[SpikeEvent, int]]]:
        """Per-row :meth:`detect_events` over a columnar (trials, C, T)
        slab — ONE batched sweep dispatch instead of a python loop of
        per-row sweeps.

        Returns one ``(event, rca_index)`` list per selected row (all rows
        when ``rows`` is None), byte-exact against calling
        :meth:`detect_events` on each row view: same events, same
        ``t_onset`` / ``t_detect`` stamps, same scores, same rca indices.
        ``valid_n`` marks ragged per-row valid lengths (a row is evaluated
        as if it ended there); ``use_kernel`` dispatches the Pallas sweep
        kernel instead of the masked-XLA reference.
        """
        cfg = self.cfg
        channels = list(channels)
        if cfg.latency_metric not in channels:
            raise ValueError(f"latency channel {cfg.latency_metric!r} not present")
        if slab.ndim != 3 or slab.shape[1] != len(channels) \
                or slab.shape[2] != ts.shape[0]:
            raise ValueError(f"slab {slab.shape} vs channels {len(channels)}"
                             f" x T {ts.shape[0]}")
        li = channels.index(cfg.latency_metric)
        if rows is None:
            lat = slab[:, li, :]
        else:
            lat = slab[np.asarray(list(rows), np.intp), li, :]
        return self._sweep_events(ts, lat, valid_n=valid_n,
                                  use_kernel=use_kernel)

    def detect_events_slab(self, ts: np.ndarray, slab: np.ndarray,
                           channels: Sequence[str], use_kernel: bool = False,
                           ) -> List[Tuple[int, SpikeEvent, int]]:
        """Every event of every slab row from one sweep dispatch + one
        resolve pass, as ``(row, event, rca_index)`` triples in row-major
        time order — the suite-scale counterpart of per-trial
        :meth:`detect_events`, byte-exact against it (same stamps, same
        scores; the per-row path is kept as the parity oracle)."""
        per_row = self.detect_events_store(ts, slab, channels,
                                           use_kernel=use_kernel)
        return [(r, ev, t) for r, evs in enumerate(per_row)
                for (ev, t) in evs]

    def detect_events_rows(self, trials: Sequence[tuple],
                           use_kernel: bool = False,
                           ) -> List[List[Tuple[SpikeEvent, int]]]:
        """:meth:`detect_events` over many ``(ts, data, channels)`` trials,
        batched through the slab sweep.

        Trials sharing a (channels, grid) layout are stacked — latency
        rows only — into one f32 slab per group and swept in one dispatch;
        a layout singleton costs the same one dispatch.  Byte-exact
        against the per-trial loop (the f64 guard re-decides against each
        trial's own series, so the f32 staging cannot shift a decision).
        """
        out: List[Optional[list]] = [None] * len(trials)
        groups: Dict[tuple, List[int]] = {}
        for k, (ts, data, channels) in enumerate(trials):
            # the whole grid is part of the key — trials sharing endpoints
            # but not interior timestamps must not inherit another
            # trial's clock for event stamps and cooldown math
            key = (tuple(channels), ts.shape[0],
                   hash(np.ascontiguousarray(ts).tobytes()))
            groups.setdefault(key, []).append(k)
        for (chans, _, _), idxs in groups.items():
            ts = trials[idxs[0]][0]
            li = list(chans).index(self.cfg.latency_metric)
            lat64 = np.stack([np.asarray(trials[k][1][li], np.float64)
                              for k in idxs])
            evs = self._sweep_events(ts, lat64, use_kernel=use_kernel)
            for k, e in zip(idxs, evs):
                out[k] = e
        return out

    def finalize_trial(self, ts: np.ndarray, data: np.ndarray,
                       channels: Sequence[str], diags: List[Diagnosis],
                       rca_idx: Sequence[int]) -> List[Diagnosis]:
        """Layer-3 reconciliation post-pass over one trial's time-ordered
        diagnoses (see ``core.reconcile``): corroboration-gated primary
        swap, secondary-hypothesis attribution, incident-close co-verdict.
        Identity when ``max_hypotheses <= 1`` — the single-pending
        machine's verdicts pass through untouched.  ``data`` must be the
        same (forward-filled) matrix Layer 3 diagnosed against."""
        if self.cfg.max_hypotheses <= 1 or not diags:
            return diags
        from repro.core import reconcile as reconcile_mod
        return reconcile_mod.reconcile_trial(self, ts, data, channels,
                                             diags, rca_idx)

    def process(self, ts: np.ndarray, data: np.ndarray,
                channels: Sequence[str], fast: bool = True) -> List[Diagnosis]:
        """Run the engine over a full trial; returns diagnoses in time order.

        ``ts``: (T,) uniform 100 Hz grid; ``data``: (C, T); ``channels``
        names the rows.  This replays exactly what the streaming deployment
        does tick by tick, with virtual time taken from ``ts``.

        ``fast=True`` precomputes every tick's detection decision in one
        vectorized rolling-statistics pass; ``fast=False`` is the original
        scalar per-tick path, kept as the parity oracle for tests and the
        before/after benchmark.
        """
        channels = list(channels)
        events = self.detect_events(ts, data, channels, fast=fast)
        li = channels.index(self.cfg.latency_metric)
        if events:
            # Layer 3 must not correlate against NaN/Inf evidence cells:
            # forward-fill non-finite cells row-wise (identity — same
            # array object — on clean data, so the clean path is
            # untouched).  Detection above already ran on the RAW data
            # with validity masks; only the explanation windows are
            # smoothed.
            data = sanitize_mod.forward_fill(np.asarray(data))
        diags = [self._diagnose(ts, data, channels, li, t, ev)
                 for ev, t in events]
        return self.finalize_trial(ts, data, channels, diags,
                                   [t for _, t in events])

    def process_batch(self, trials: Sequence[tuple], fast: bool = True,
                      use_kernel: bool = False) -> List[List[Diagnosis]]:
        """:meth:`process` over many trials, Layer 3 batched across ALL
        their events.

        ``trials`` is ``(ts, data, channels)`` tuples.  The Layer-2 sweep
        runs as ONE batched slab dispatch over all trials' latency rows
        (:meth:`detect_events_rows` — byte-exact vs the per-trial loop:
        same cooldown / pending machinery consulting the same decisions,
        so every event's ``t_onset`` / ``t_detect`` / ``t_ready`` stamps
        are identical), then every pending event of every trial is
        stacked as a row into ONE fused Layer-3 dispatch
        (:meth:`diagnose_events_batch`).  ``fast=False`` replays the
        scalar per-tick sweep per trial (the parity oracle).  Returns one
        time-ordered diagnosis list per trial — the multi-fault scenario
        scorer consumes this to check batched-vs-per-event verdict parity.
        """
        items, owner = [], []
        if fast:
            per_trial = self.detect_events_rows(trials)
        else:
            per_trial = [self.detect_events(ts, data, channels, fast=False)
                         for (ts, data, channels) in trials]
        filled: Dict[int, np.ndarray] = {}
        for k, (ts, data, channels) in enumerate(trials):
            if per_trial[k]:
                # same Layer-3 fill policy as process() — identity on
                # clean trials, so per-event/batched parity holds
                data = sanitize_mod.forward_fill(np.asarray(data))
                filled[k] = data
            for ev, t in per_trial[k]:
                owner.append(k)
                items.append((ts, data, list(channels), t, ev))
        diags = self.diagnose_events_batch(items, use_kernel=use_kernel)
        out: List[List[Diagnosis]] = [[] for _ in range(len(trials))]
        for k, d in zip(owner, diags):
            out[k].append(d)
        for k, (ts, _, channels) in enumerate(trials):
            if out[k]:
                out[k] = self.finalize_trial(
                    ts, filled[k], channels, out[k],
                    [t for _, t in per_trial[k]])
        return out

    def process_store(self, ts: np.ndarray, slab: np.ndarray,
                      channels: Sequence[str], fast: bool = True,
                      use_kernel: bool = False) -> List[List[Diagnosis]]:
        """:meth:`process_batch` over a columnar trial slab.

        ``slab`` is the (trials, C, T) f32 store layout (see
        ``sim.scenario.TrialStore``); detection is ONE batched sweep over
        the latency rows + one resolve pass
        (:meth:`detect_events_slab` — ``fast=False`` keeps the per-row
        scalar replay as the parity oracle), the Layer-3 evidence gather
        is slab indexing (:meth:`diagnose_events_slab`).  Returns one
        time-ordered diagnosis list per slab row.
        """
        events = []
        if fast:
            for i, ev, t in self.detect_events_slab(ts, slab, channels):
                events.append((i, t, ev))
        else:
            for i in range(slab.shape[0]):
                for ev, t in self.detect_events(ts, slab[i], channels,
                                                fast=False):
                    events.append((i, t, ev))
        if events:
            # Layer-3 fill over the whole store — per-row independent, so
            # gathered windows match the per-trial fill bit for bit;
            # identity (no copy) when the slab is clean
            slab = sanitize_mod.forward_fill(slab)
        diags = self.diagnose_events_slab(ts, slab, channels, events,
                                          use_kernel=use_kernel)
        out: List[List[Diagnosis]] = [[] for _ in range(slab.shape[0])]
        rcas: List[List[int]] = [[] for _ in range(slab.shape[0])]
        for (i, t, _), d in zip(events, diags):
            out[i].append(d)
            rcas[i].append(int(t))
        for i in range(slab.shape[0]):
            if out[i]:
                out[i] = self.finalize_trial(ts, slab[i], channels,
                                             out[i], rcas[i])
        return out

    # ------------------------------------------------------------- Layer 3+4
    def _diagnose(self, ts: np.ndarray, data: np.ndarray,
                  channels: List[str], li: int, t: int,
                  event: SpikeEvent) -> Diagnosis:
        cfg = self.cfg
        wall0 = time.perf_counter()
        wn, bn = cfg.window_n, cfg.baseline_n
        # RCA window: from shortly before the estimated onset (so the spike
        # *rise* — where lagged correlation carries signal — is inside the
        # window) through the post-detection accumulation.
        onset_idx = int(np.searchsorted(ts, event.t_onset))
        lo = max(0, min(t - wn - int(cfg.rca_extra_s * cfg.rate_hz),
                        onset_idx - int(cfg.pre_onset_s * cfg.rate_hz)))
        blo = max(0, lo - bn)
        L_win = np.asarray(data[li, lo:t], dtype=np.float64)

        names, idx, orient = self._layout(channels)
        if not names:
            return Diagnosis(event=event, ranked=[], per_metric={},
                             t_rca=float(ts[t]), analysis_seconds=0.0,
                             t_ready=float(ts[t]))
        # one vectorized slice over all evidence rows: [blo:t] covers both
        # the baseline region and the RCA window
        X = np.asarray(data[idx, blo:t], dtype=np.float64)
        wstart = lo - blo                 # window columns start here within X
        b_sl = pick_baseline_slice(wstart, max(0, onset_idx - lo), X.shape[1])
        XO = orient_about_baseline(X, orient, b_sl)
        W = XO[:, wstart:]                    # (M, rn)
        B = XO[:, b_sl]                       # (M, nb) common-length baseline
        scores = spike_mod.spike_scores_matrix(W, B)
        corr, lags = xcorr_mod.max_abs_xcorr(L_win, W, cfg.max_lag)
        ranked, per_metric = conf_mod.rank_causes(
            names, scores, corr, lags / cfg.rate_hz, cfg.alpha)
        analysis = time.perf_counter() - wall0
        return Diagnosis(event=event, ranked=ranked, per_metric=per_metric,
                         t_rca=float(ts[t]) + analysis,
                         analysis_seconds=analysis, t_ready=float(ts[t]))

    # ------------------------------------------------- event-batched Layer 3+4
    def diagnose_events_batch(self, items: Sequence[tuple],
                              use_kernel: bool = False) -> List[Diagnosis]:
        """Explain many pending events — possibly from different trials —
        in ONE fused Layer-3 dispatch per evidence layout.

        ``items``: ``(ts, data, channels, rca_index, event)`` tuples, e.g.
        the cross product of ``detect_events`` over an eval's trials.  Each
        event's RCA window geometry is *exactly* :meth:`_diagnose`'s (same
        slices, same orientation-about-baseline policy); windows of
        different lengths are stacked left-aligned and the per-row valid
        lengths ride along into ``fused_rca_max_ragged`` — events are just
        rows to the fused kernel.  For the homogeneous eval (one channel
        layout) that is a single dispatch for all 68 trials, vs one
        ``_diagnose`` per event.

        Returns one :class:`Diagnosis` per item, in item order.  The shared
        batch analysis wall time stamps every diagnosis in a group (the
        paper's Time-to-RCA includes analysis compute; the whole batch
        completes together).
        """
        from repro.kernels.fused import ops as fused_ops

        global SLICE_OPS
        cfg = self.cfg
        wn, bn = cfg.window_n, cfg.baseline_n
        rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
        pre_n = int(cfg.pre_onset_s * cfg.rate_hz)
        results: List[Optional[Diagnosis]] = [None] * len(items)
        groups: Dict[tuple, list] = {}
        for i, (ts, data, channels, t, event) in enumerate(items):
            channels = list(channels)
            li = channels.index(cfg.latency_metric)
            names, idx, orient = self._layout(channels)
            if not names:
                results[i] = Diagnosis(event=event, ranked=[], per_metric={},
                                       t_rca=float(ts[t]),
                                       analysis_seconds=0.0,
                                       t_ready=float(ts[t]))
                continue
            t = int(t)
            onset_idx = int(np.searchsorted(ts, event.t_onset))
            lo = max(0, min(t - wn - rca_n, onset_idx - pre_n))
            blo = max(0, lo - bn)
            L_win = np.asarray(data[li, lo:t], dtype=np.float64)
            X = np.asarray(data[idx, blo:t], dtype=np.float64)
            SLICE_OPS += 2                  # per-event reslice: L row + X
            wstart = lo - blo
            b_sl = pick_baseline_slice(wstart, max(0, onset_idx - lo),
                                       X.shape[1])
            XO = orient_about_baseline(X, orient, b_sl)
            groups.setdefault(tuple(names), []).append(
                (i, ts, t, event, L_win, XO[:, wstart:], XO[:, b_sl]))

        for names_key, rows in groups.items():
            w0 = time.perf_counter()
            names = list(names_key)
            E = len(rows)
            M = rows[0][5].shape[0]
            n_v = np.array([r[5].shape[1] for r in rows], np.int32)
            nb_v = np.array([r[6].shape[1] for r in rows], np.int32)
            # bucket the slab shape (rows to the next power of two, sample
            # axes to x256) so repeated calls with drifting event counts /
            # window lengths reuse one jit cache entry instead of
            # recompiling the ragged dispatch every time; padded rows carry
            # a tiny valid span of zeros and are dropped before ranking
            Ep = max(4, 1 << (E - 1).bit_length())
            N = -(-int(n_v.max()) // 256) * 256
            Nb = -(-int(nb_v.max()) // 256) * 256
            n_vp = np.full(Ep, 8, np.int32)
            nb_vp = np.full(Ep, 8, np.int32)
            n_vp[:E], nb_vp[:E] = n_v, nb_v
            L = np.zeros((Ep, N), np.float32)
            W = np.zeros((Ep, M, N), np.float32)
            B = np.zeros((Ep, M, Nb), np.float32)
            for e, (_, _, _, _, lw, w, b) in enumerate(rows):
                L[e, :lw.size] = lw
                W[e, :, :w.shape[1]] = w
                B[e, :, :b.shape[1]] = b
            s, c, lags = fused_ops.fused_rca_max_ragged(
                L, W, B, n_vp, nb_vp, max_lag=cfg.max_lag,
                use_kernel=use_kernel)
            s = np.asarray(s)[:E]
            c = np.asarray(c)[:E]
            lags = np.asarray(lags)[:E]
            ranked_all = conf_mod.rank_causes_batch(
                names, s, c, lags / cfg.rate_hz, cfg.alpha, details=True)
            analysis = time.perf_counter() - w0
            for e, (i, ts, t, event, _, _, _) in enumerate(rows):
                ranked, per_metric = ranked_all[e]
                results[i] = Diagnosis(event=event, ranked=ranked,
                                       per_metric=per_metric,
                                       t_rca=float(ts[t]) + analysis,
                                       analysis_seconds=analysis,
                                       t_ready=float(ts[t]))
        return results

    # -------------------------------------------------- columnar trial store
    def diagnose_events_slab(self, ts: np.ndarray, slab: np.ndarray,
                             channels: Sequence[str],
                             events: Sequence[tuple],
                             use_kernel: bool = False) -> List[Diagnosis]:
        """Event-batched Layer 3 over a columnar trial store.

        ``slab`` is one contiguous f32 (trials, C, T) array — every trial
        of an eval on the shared grid ``ts`` with the shared ``channels``
        layout — and ``events`` are ``(trial_row, rca_index, event)``
        triples.  Exactly :meth:`diagnose_events_batch`'s RCA geometry and
        kernel dispatch (same shape bucketing, so both paths share one jit
        cache entry), but the evidence gather is *slab indexing*: the
        latency windows, evidence windows and baselines of ALL events land
        in a constant number of fancy-index ops over the store, instead of
        one python-level reslice pair per event (``SLICE_OPS`` counts the
        difference).  Returns one :class:`Diagnosis` per event, in order.
        """
        from repro.kernels.fused import ops as fused_ops

        global SLICE_OPS
        cfg = self.cfg
        channels = list(channels)
        if not len(events):
            return []
        names, idx, orient = self._layout(channels)
        if not names:
            return [Diagnosis(event=ev, ranked=[], per_metric={},
                              t_rca=float(ts[int(t)]), analysis_seconds=0.0,
                              t_ready=float(ts[int(t)]))
                    for _, t, ev in events]
        w0 = time.perf_counter()
        li = channels.index(cfg.latency_metric)
        wn, bn = cfg.window_n, cfg.baseline_n
        rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
        pre_n = int(cfg.pre_onset_s * cfg.rate_hz)
        E, M = len(events), len(names)

        # per-event window geometry — scalar arithmetic, no data touched
        rows_tr = np.asarray([r for r, _, _ in events], np.intp)
        t_idx = np.asarray([int(t) for _, t, _ in events], np.intp)
        onset_idx = np.searchsorted(
            ts, np.asarray([ev.t_onset for _, _, ev in events]))
        lo = np.maximum(0, np.minimum(t_idx - wn - rca_n,
                                      onset_idx - pre_n))
        blo = np.maximum(0, lo - bn)
        n_v = (t_idx - lo).astype(np.int32)
        nb_v = np.asarray(
            [pick_baseline_slice(int(lo[e] - blo[e]),
                                 max(0, int(onset_idx[e] - lo[e])),
                                 int(t_idx[e] - blo[e])).stop
             for e in range(E)], np.int32)    # all baseline slices start at 0

        # the slab gathers: every event's L window / evidence window /
        # baseline in three fancy-index ops, padded rows clamped in-range
        jN = np.arange(int(n_v.max()))
        maskW = jN[None, :] < n_v[:, None]                       # (E, N)
        colW = np.where(maskW, lo[:, None] + jN[None, :], lo[:, None])
        jB = np.arange(int(nb_v.max()))
        maskB = jB[None, :] < nb_v[:, None]                      # (E, Nb)
        colB = np.where(maskB, blo[:, None] + jB[None, :], blo[:, None])
        # f64 like the per-event gather, so orientation numerics match
        L = slab[rows_tr[:, None], li, colW].astype(np.float64)
        Wm = slab[rows_tr[:, None, None], idx[None, :, None],
                  colW[:, None, :]].astype(np.float64)           # (E, M, N)
        Bm = slab[rows_tr[:, None, None], idx[None, :, None],
                  colB[:, None, :]].astype(np.float64)           # (E, M, Nb)
        SLICE_OPS += 3
        L[~maskW] = 0.0

        # orientation about the baseline-region mean, batched over events
        # (same policy as orient_about_baseline; mu from valid cols only)
        mu = ((Bm * maskB[:, None, :]).sum(-1, keepdims=True)
              / nb_v[:, None, None])                             # (E, M, 1)
        o = orient.reshape(1, -1, 1)
        WO = mu + np.where(o == 0.0, np.abs(Wm - mu), o * (Wm - mu))
        BO = mu + np.where(o == 0.0, np.abs(Bm - mu), o * (Bm - mu))
        WO *= maskW[:, None, :]
        BO *= maskB[:, None, :]

        # shape bucketing — identical to diagnose_events_batch so the two
        # paths reuse one jit cache entry
        Ep = max(4, 1 << (E - 1).bit_length())
        N = -(-int(n_v.max()) // 256) * 256
        Nb = -(-int(nb_v.max()) // 256) * 256
        n_vp = np.full(Ep, 8, np.int32)
        nb_vp = np.full(Ep, 8, np.int32)
        n_vp[:E], nb_vp[:E] = n_v, nb_v
        Lp = np.zeros((Ep, N), np.float32)
        Wp = np.zeros((Ep, M, N), np.float32)
        Bp = np.zeros((Ep, M, Nb), np.float32)
        Lp[:E, :L.shape[1]] = L
        Wp[:E, :, :WO.shape[2]] = WO
        Bp[:E, :, :BO.shape[2]] = BO
        s, c, lags = fused_ops.fused_rca_max_ragged(
            Lp, Wp, Bp, n_vp, nb_vp, max_lag=cfg.max_lag,
            use_kernel=use_kernel)
        s = np.asarray(s)[:E]
        c = np.asarray(c)[:E]
        lags = np.asarray(lags)[:E]
        ranked_all = conf_mod.rank_causes_batch(
            names, s, c, lags / cfg.rate_hz, cfg.alpha, details=True)
        analysis = time.perf_counter() - w0
        return [Diagnosis(event=event, ranked=ranked_all[e][0],
                          per_metric=ranked_all[e][1],
                          t_rca=float(ts[int(t)]) + analysis,
                          analysis_seconds=analysis,
                          t_ready=float(ts[int(t)]))
                for e, (_, t, event) in enumerate(events)]
