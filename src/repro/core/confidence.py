"""Confidence scoring and cause ranking (paper §2.2, Layer 3->4).

    conf_i = alpha * S_{M_i} + (1 - alpha) * c_i ,  alpha = 0.5

S_{M_i} is the metric's own spike score (unbounded, in sigmas) and c_i its
max-|lagged-correlation| (in [0,1]).  Following the paper we combine them
linearly; to keep the two addends commensurate the spike score is squashed
through a saturating map S -> S/(S+3) (3 = the detection threshold: a
metric spiking exactly at threshold contributes 0.5).  The squash is
monotone, so *rankings* match the raw formula whenever correlations agree;
it only matters when trading S against c — which is exactly where an
unbounded S would otherwise drown the correlation term.

Cause-level ranking takes, for each cause class, the best-confidence
metric among the channels that are evidence for it (taxonomy mapping).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.taxonomy import CauseClass, RankedCause
from repro.telemetry.schema import METRIC_REGISTRY

DEFAULT_ALPHA = 0.5
_SQUASH_SCALE = 3.0  # = detection threshold


def squash_spike(s: np.ndarray | float) -> np.ndarray | float:
    """Monotone map sigmas -> [0,1): s/(s+3), clamped at 0 below baseline."""
    s = np.maximum(s, 0.0)
    return s / (s + _SQUASH_SCALE)


def combine_confidence(spike_scores: np.ndarray, correlations: np.ndarray,
                       alpha: float = DEFAULT_ALPHA) -> np.ndarray:
    """conf_i = alpha * squash(S_i) + (1-alpha) * c_i, elementwise."""
    s = squash_spike(np.asarray(spike_scores, dtype=np.float64))
    c = np.clip(np.asarray(correlations, dtype=np.float64), 0.0, 1.0)
    return alpha * s + (1.0 - alpha) * c


def rank_causes(metric_names: Sequence[str], spike_scores: np.ndarray,
                correlations: np.ndarray, lags_s: np.ndarray,
                alpha: float = DEFAULT_ALPHA,
                ) -> Tuple[List[RankedCause], Dict[str, Dict[str, float]]]:
    """Aggregate metric-level evidence into ranked cause classes.

    Returns (ranked causes desc by confidence, per-metric detail dict).
    Metrics without a cause mapping (the latency channel itself) are skipped.
    """
    conf = combine_confidence(spike_scores, correlations, alpha)
    per_metric: Dict[str, Dict[str, float]] = {}
    best: Dict[CauseClass, RankedCause] = {}
    for i, name in enumerate(metric_names):
        spec = METRIC_REGISTRY.get(name)
        cause = spec.cause if spec is not None else None
        per_metric[name] = {
            "spike": float(spike_scores[i]),
            "corr": float(correlations[i]),
            "conf": float(conf[i]),
            "lag_s": float(lags_s[i]),
        }
        if cause is None:
            continue
        cur = best.get(cause)
        if cur is None or conf[i] > cur.confidence:
            best[cause] = RankedCause(
                cause=cause, confidence=float(conf[i]), top_metric=name,
                spike_score=float(spike_scores[i]),
                correlation=float(correlations[i]), lag_s=float(lags_s[i]))
    ranked = sorted(best.values(), key=lambda rc: -rc.confidence)
    return ranked, per_metric


#: metric-name tuple -> [(cause, column indices)] for the batched ranker
_CAUSE_COLS: Dict[tuple, List[Tuple[CauseClass, np.ndarray]]] = {}


def _cause_columns(metric_names: Sequence[str]):
    key = tuple(metric_names)
    hit = _CAUSE_COLS.get(key)
    if hit is None:
        by_cause: Dict[CauseClass, List[int]] = {}
        for i, name in enumerate(metric_names):
            spec = METRIC_REGISTRY.get(name)
            cause = spec.cause if spec is not None else None
            if cause is not None:
                by_cause.setdefault(cause, []).append(i)
        hit = [(c, np.asarray(cols, np.intp)) for c, cols in by_cause.items()]
        _CAUSE_COLS[key] = hit
    return hit


def rank_causes_batch(metric_names: Sequence[str], spike_scores: np.ndarray,
                      correlations: np.ndarray, lags_s: np.ndarray,
                      alpha: float = DEFAULT_ALPHA, details: bool = False,
                      ) -> List[Tuple[List[RankedCause],
                                      Dict[str, Dict[str, float]]]]:
    """Vectorized :func:`rank_causes` over a leading host axis.

    All inputs are (H, M); returns one ``(ranked, per_metric)`` pair per
    host.  The confidence fusion and per-cause arg-max run as whole-matrix
    reductions; only the final RankedCause assembly (H x #causes objects)
    stays in Python.  ``details=False`` skips building the H x M per-metric
    dicts — the fleet path requests them only for the straggler.
    """
    S = np.asarray(spike_scores, dtype=np.float64)
    C = np.asarray(correlations, dtype=np.float64)
    G = np.asarray(lags_s, dtype=np.float64)
    if S.ndim != 2 or S.shape != C.shape or S.shape != G.shape:
        raise ValueError(f"shape mismatch: {S.shape} {C.shape} {G.shape}")
    H = S.shape[0]
    conf = combine_confidence(S, C, alpha)                      # (H, M)
    names = list(metric_names)
    out: List[Tuple[List[RankedCause], Dict[str, Dict[str, float]]]] = []
    picks = []  # (cause, best_col (H,), best_conf (H,))
    for cause, cols in _cause_columns(names):
        sub = conf[:, cols]
        loc = np.argmax(sub, axis=1)
        picks.append((cause, cols[loc], sub[np.arange(H), loc]))
    for h in range(H):
        ranked = sorted(
            (RankedCause(cause=cause, confidence=float(bc[h]),
                         top_metric=names[int(col[h])],
                         spike_score=float(S[h, col[h]]),
                         correlation=float(C[h, col[h]]),
                         lag_s=float(G[h, col[h]]))
             for cause, col, bc in picks),
            key=lambda rc: -rc.confidence)
        per_metric: Dict[str, Dict[str, float]] = {}
        if details:
            per_metric = {name: {"spike": float(S[h, i]),
                                 "corr": float(C[h, i]),
                                 "conf": float(conf[h, i]),
                                 "lag_s": float(G[h, i])}
                          for i, name in enumerate(names)}
        out.append((ranked, per_metric))
    return out
