"""The paper's primary contribution: host-side spike detection + lagged
cross-correlation root-cause analysis, as a composable library.

Four-layer pipeline (paper Fig 1):
  L1 collection      -> repro.telemetry
  L2 sync + 3-sigma  -> repro.core.spike (+ telemetry.sync)
  L3 lagged xcorr    -> repro.core.xcorr, repro.core.confidence
  L4 ranked causes   -> repro.core.engine
"""
from repro.core.taxonomy import CauseClass, Diagnosis, SpikeEvent, RankedCause
from repro.core.spike import (
    baseline_stats, spike_score, spike_scores_matrix, detect, detect_rows,
    detect_sweep, detect_sweep_at, sliding_baseline_stats,
)
from repro.core.xcorr import lagged_xcorr, max_abs_xcorr, lagged_xcorr_batch
from repro.core.confidence import combine_confidence, rank_causes, rank_causes_batch
from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.baselines import (
    Diagnoser, GPUCentricDiagnoser, ClusterAnalysisDiagnoser,
    DeepProfilingDiagnoser, make_baseline,
)

__all__ = [
    "CauseClass", "Diagnosis", "SpikeEvent", "RankedCause",
    "baseline_stats", "spike_score", "spike_scores_matrix", "detect",
    "detect_rows", "detect_sweep", "detect_sweep_at",
    "sliding_baseline_stats",
    "lagged_xcorr", "max_abs_xcorr", "lagged_xcorr_batch",
    "combine_confidence", "rank_causes", "rank_causes_batch",
    "CorrelationEngine", "EngineConfig",
    "Diagnoser", "GPUCentricDiagnoser", "ClusterAnalysisDiagnoser",
    "DeepProfilingDiagnoser", "make_baseline",
]
