"""Layer-3 verdict reconciliation for concurrent incident hypotheses.

With ``max_hypotheses > 1`` the Layer-2 machine deliberately over-triggers:
a step above an active incident's level opens a second hypothesis whether it
is a genuinely new fault or the same fault still ramping.  This module is
the deterministic post-pass that turns the matured hypothesis stream of ONE
trial into the final verdict stream:

* **corroboration** — a cause is corroborated when one of its symptom
  channels (``telemetry.schema.SYMPTOM_FLOORS``) shows a two-sided raw-z
  deviation at or above its floor on the event's evidence geometry (the
  exact ``_diagnose`` window/baseline slices).
* **primary swap** — if the first event's top-ranked cause is not
  corroborated but a corroborated runner sits within ``cfg.swap_margin``
  of its confidence, the runner becomes the primary verdict.
* **secondary hypotheses** — a later hypothesis inside the incident emits
  its best not-yet-assigned corroborated cause, else is suppressed as a
  continuation phantom.
* **incident-close co-verdict** — when an incident closes with fewer than
  two verdicts, the evidence is re-scanned one cooldown past the last
  maturation: a not-yet-assigned cause that is corroborated, whose symptom
  crossed inside the incident's span, and whose confidence sits within its
  per-cause gap of the top cause earns exactly one co-verdict (the
  fully-overlapping-faults case, where Layer 2 sees a single step).

Everything here is pure post-processing over already-detected events; the
Layer-2 sweep, its parity contracts and the (fire, score, onset) slab are
untouched.  With ``max_hypotheses == 1`` the engine never calls this module
and verdicts are byte-identical to the single-pending machine's.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.taxonomy import CauseClass, Diagnosis, SpikeEvent
from repro.telemetry.schema import (GROUP_TO_CAUSE, METRIC_REGISTRY,
                                    SYMPTOM_FLOORS)

#: confidence gap (top cause minus candidate) within which an unassigned
#: corroborated cause earns the incident-close co-verdict.  Per cause: DMA
#: evidence is two-sided and diffuse so I/O runs a wide gap; CPU confusers
#: rank close to genuine contention so CPU runs the tightest.
CO_GAP: Dict[CauseClass, float] = {
    CauseClass.IO: 0.30,
    CauseClass.NIC: 0.15,
    CauseClass.GPU: 0.12,
    CauseClass.CPU: 0.08,
}

#: a co-verdict's symptom must have crossed its floor no earlier than this
#: long before the incident's first onset ...
CROSS_EARLY_S = 2.5
#: ... and no later than this long after the incident's last detection —
#: later crossings belong to a separate fault the machine will catch.
CROSS_LATE_S = 12.0

#: symptom crossing time is resolved to 1 s box means over the window
BOX_S = 1.0


def symptom_table() -> Dict[CauseClass, Tuple[Tuple[str, float], ...]]:
    """``SYMPTOM_FLOORS`` grouped by the cause each channel is evidence
    for, in registry declaration order."""
    out: Dict[CauseClass, List[Tuple[str, float]]] = {}
    for name, floor in SYMPTOM_FLOORS.items():
        cause = GROUP_TO_CAUSE[METRIC_REGISTRY[name].group]
        out.setdefault(cause, []).append((name, floor))
    return {c: tuple(v) for c, v in out.items()}


def _symptom_info(cfg, data: np.ndarray, channels: Sequence[str],
                  t_onset: float, t: int, ts: np.ndarray,
                  ) -> Dict[CauseClass, Tuple[bool, Optional[float]]]:
    """Per cause: (corroborated, first floor-crossing time or None), on
    the exact evidence geometry ``_diagnose`` uses for an event with this
    onset diagnosed at sample ``t``."""
    from repro.core.engine import pick_baseline_slice

    wn, bn = cfg.window_n, cfg.baseline_n
    rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
    pre_n = int(cfg.pre_onset_s * cfg.rate_hz)
    box_n = int(BOX_S * cfg.rate_hz)
    onset_idx = int(np.searchsorted(ts, t_onset))
    lo = max(0, min(t - wn - rca_n, onset_idx - pre_n))
    blo = max(0, lo - bn)
    nb = lo - blo
    b_sl = pick_baseline_slice(nb, max(0, onset_idx - lo), t - blo)
    idx = {c: i for i, c in enumerate(channels)}
    out: Dict[CauseClass, Tuple[bool, Optional[float]]] = {}
    for cause, chans in symptom_table().items():
        ok, t_cross = False, None
        for name, floor in chans:
            i = idx.get(name)
            if i is None:
                continue
            seg = np.asarray(data[i, blo:t], np.float64)
            B = seg[b_sl]
            W = seg[nb:]
            if W.size == 0 or B.size == 0:
                continue
            mb = float(B.mean())
            sd = max(float(B.std()), 1e-3 * abs(mb), 1e-9)
            if abs(float(W.mean()) - mb) / sd < floor:
                continue
            ok = True
            nbox = W.size // box_n
            if nbox > 0:
                bm = W[:nbox * box_n].reshape(nbox, box_n).mean(axis=1)
                hits = np.flatnonzero(np.abs(bm - mb) / sd >= floor)
                if hits.size:
                    tc = (lo + int(hits[0]) * box_n) / cfg.rate_hz
                    if t_cross is None or tc < t_cross:
                        t_cross = tc
        out[cause] = (ok, t_cross)
    return out


def _lead_with(d: Diagnosis, cause: CauseClass) -> Diagnosis:
    """The same diagnosis with ``cause``'s ranked entry moved to the front
    (``top_cause`` and downstream scoring follow ``ranked[0]``)."""
    if not d.ranked or d.ranked[0].cause == cause:
        return d
    lead = [rc for rc in d.ranked if rc.cause == cause]
    rest = [rc for rc in d.ranked if rc.cause != cause]
    return dataclasses.replace(d, ranked=lead + rest)


def reconcile_trial(engine, ts: np.ndarray, data: np.ndarray,
                    channels: Sequence[str], diags: Sequence[Diagnosis],
                    rca_idx: Sequence[int]) -> List[Diagnosis]:
    """Reconcile one trial's time-ordered diagnoses (with their RCA sample
    indices) into the final verdict stream."""
    cfg = engine.cfg
    if not diags:
        return []
    channels = list(channels)
    li = channels.index(cfg.latency_metric)
    rca_n = int(cfg.rca_extra_s * cfg.rate_hz)
    cool_n = int(cfg.cooldown_s * cfg.rate_hz)
    T = ts.shape[0]
    out: List[Diagnosis] = []
    incident: Optional[dict] = None

    def close_incident() -> None:
        nonlocal incident
        if incident is None:
            return
        inc, incident = incident, None
        if inc["n_emitted"] >= 2:
            return
        # re-scan one cooldown past the incident's last maturation: a
        # fully-overlapped co-fault's symptom has its full span by then
        t = min(T - 1, inc["last_idx"] + cool_n)
        e1: SpikeEvent = inc["e1"]
        d = engine._diagnose(ts, data, channels, li, t, e1)
        sym = _symptom_info(cfg, data, channels, e1.t_onset, t, ts)
        if not d.ranked:
            return
        top = d.ranked[0].confidence
        for rc in d.ranked:
            c = rc.cause
            ok, t_cross = sym.get(c, (False, None))
            if c in inc["assigned"] or not ok:
                continue
            if t_cross is None or not (e1.t_onset - CROSS_EARLY_S <= t_cross
                                       <= inc["t_last"] + CROSS_LATE_S):
                continue
            if top - rc.confidence > CO_GAP.get(c, 0.0):
                continue
            ev = dataclasses.replace(e1, t_onset=max(e1.t_onset, t_cross))
            out.append(dataclasses.replace(_lead_with(d, c), event=ev))
            break

    for d, t in zip(diags, rca_idx):
        t = int(t)
        ev = d.event
        if incident is not None and \
                ev.t_detect - incident["t_last"] >= cfg.cooldown_s:
            close_incident()
        if not d.ranked:
            out.append(d)
            continue
        conf = {rc.cause: rc.confidence for rc in d.ranked}
        order = [rc.cause for rc in d.ranked]
        sym = _symptom_info(cfg, data, channels, ev.t_onset, t, ts)
        if incident is None:
            primary = order[0]
            if not sym.get(primary, (False, None))[0]:
                for c in order[1:]:
                    if sym.get(c, (False, None))[0] and \
                            conf[c] >= conf[primary] - cfg.swap_margin:
                        primary = c
                        break
            out.append(_lead_with(d, primary))
            incident = dict(t_last=ev.t_detect, last_idx=t - rca_n,
                            assigned={primary}, n_emitted=1, e1=ev)
        else:
            cand = None
            for c in order:
                if c not in incident["assigned"] and \
                        sym.get(c, (False, None))[0]:
                    cand = c
                    break
            if cand is not None:
                out.append(_lead_with(d, cand))
                incident["assigned"].add(cand)
                incident["n_emitted"] += 1
            incident["t_last"] = ev.t_detect
            incident["last_idx"] = t - rca_n
    close_incident()
    return out
