"""3-sigma spike detection (paper §2.2, Layer 2).

    S_L = max_{t in W} (L(t) - mu_L) / sigma_L ,   spike iff S_L > 3

where (mu_L, sigma_L) come from a baseline window W_b preceding the
observation window W.  All functions are numpy (the per-host engine runs on
the host CPU, exactly as the paper's agent does); the batched fleet-scale
versions live in :mod:`repro.kernels.spike`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

DEFAULT_THRESHOLD = 3.0
#: floor on sigma, relative to |mu| — a perfectly flat baseline must not turn
#: numerical dust into spikes (sigma=0 would make any deviation infinite).
SIGMA_FLOOR_REL = 1e-3
SIGMA_FLOOR_ABS = 1e-9


def baseline_stats(baseline: np.ndarray) -> Tuple[float, float]:
    """(mu, sigma) over the baseline window, with a sigma floor."""
    x = np.asarray(baseline, dtype=np.float64)
    if x.size == 0:
        return 0.0, SIGMA_FLOOR_ABS
    mu = float(np.mean(x))
    sigma = float(np.std(x))
    floor = max(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * abs(mu))
    return mu, max(sigma, floor)


def spike_score(window: np.ndarray, mu: float, sigma: float) -> float:
    """S = max_t (x(t) - mu)/sigma.  One-sided: spikes are increases.

    (For metrics where the anomaly is a *drop* — e.g. dev_clock under
    power-cap throttling — callers pass the negated series; see
    `engine._oriented`.)"""
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return 0.0
    return float(np.max((x - mu) / sigma))


def detect(window: np.ndarray, baseline: np.ndarray,
           threshold: float = DEFAULT_THRESHOLD,
           persistence: float = 0.0,
           ) -> Tuple[bool, float, Optional[int]]:
    """Full Layer-2 check.

    ``persistence`` is the fraction of window samples that must exceed the
    threshold before a spike is declared.  0 reproduces the bare max-score
    rule; the production default (engine) uses 0.4 so a single noise sample
    cannot fire the detector — this is also what gives the paper's ~5 s
    detection latency with a 5 s window: the anomaly must *fill* a good part
    of the window before the boundary evaluation trips.

    Returns ``(is_spike, score, onset_index)`` where ``onset_index`` is the
    first sample in ``window`` whose z-score exceeds the threshold (the
    engine converts it to an onset timestamp).
    """
    mu, sigma = baseline_stats(baseline)
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return False, 0.0, None
    z = (x - mu) / sigma
    score = float(np.max(z))
    hot = z > threshold
    frac = float(np.mean(hot))
    if score > threshold and frac >= persistence:
        onset = int(np.argmax(hot))
        return True, score, onset
    return False, score, None


def spike_scores_matrix(windows: np.ndarray, baselines: np.ndarray) -> np.ndarray:
    """Per-row spike scores for a (M, N) window matrix vs (M, Nb) baselines.

    Used by Layer 3 to score every host metric M_i alongside the latency
    channel.  Vectorized numpy; the Pallas kernel in kernels/spike mirrors
    this for (hosts x metrics) batches.
    """
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    mu = b.mean(axis=1)
    sigma = b.std(axis=1)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    sigma = np.maximum(sigma, floor)
    return ((w - mu[:, None]) / sigma[:, None]).max(axis=1)
