"""3-sigma spike detection (paper §2.2, Layer 2).

    S_L = max_{t in W} (L(t) - mu_L) / sigma_L ,   spike iff S_L > 3

where (mu_L, sigma_L) come from a baseline window W_b preceding the
observation window W.  All functions are numpy (the per-host engine runs on
the host CPU, exactly as the paper's agent does); the batched fleet-scale
versions live in :mod:`repro.kernels.spike`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

DEFAULT_THRESHOLD = 3.0
#: floor on sigma, relative to |mu| — a perfectly flat baseline must not turn
#: numerical dust into spikes (sigma=0 would make any deviation infinite).
SIGMA_FLOOR_REL = 1e-3
SIGMA_FLOOR_ABS = 1e-9
#: f32 -inf surrogate the kernels use to mask padded lanes out of max/argmax
#: reductions — one definition so every kernel/ref pair stays in sync.
MASK_NEG = -3.4e38
#: evaluation ticks per ``detect_sweep`` chunk — bounds the (#ticks, wn)
#: z materialization at streaming cadence (a 10-sample-tick sweep over a
#: long trial would otherwise allocate the full matrix at once); chunking
#: is bitwise-invisible because every tick's decision is independent.
SWEEP_TICK_CHUNK = 1024


def baseline_stats(baseline: np.ndarray) -> Tuple[float, float]:
    """(mu, sigma) over the baseline window, with a sigma floor."""
    x = np.asarray(baseline, dtype=np.float64)
    if x.size == 0:
        return 0.0, SIGMA_FLOOR_ABS
    mu = float(np.mean(x))
    sigma = float(np.std(x))
    floor = max(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * abs(mu))
    return mu, max(sigma, floor)


def spike_score(window: np.ndarray, mu: float, sigma: float) -> float:
    """S = max_t (x(t) - mu)/sigma.  One-sided: spikes are increases.

    (For metrics where the anomaly is a *drop* — e.g. dev_clock under
    power-cap throttling — callers pass the negated series; see
    `engine._oriented`.)"""
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return 0.0
    return float(np.max((x - mu) / sigma))


def detect(window: np.ndarray, baseline: np.ndarray,
           threshold: float = DEFAULT_THRESHOLD,
           persistence: float = 0.0,
           ) -> Tuple[bool, float, Optional[int]]:
    """Full Layer-2 check.

    ``persistence`` is the fraction of window samples that must exceed the
    threshold before a spike is declared.  0 reproduces the bare max-score
    rule; the production default (engine) uses 0.4 so a single noise sample
    cannot fire the detector — this is also what gives the paper's ~5 s
    detection latency with a 5 s window: the anomaly must *fill* a good part
    of the window before the boundary evaluation trips.

    Returns ``(is_spike, score, onset_index)`` where ``onset_index`` is the
    first sample in ``window`` whose z-score exceeds the threshold (the
    engine converts it to an onset timestamp).  When no sample crosses,
    ``onset_index`` is ``None`` — the streaming engine has nothing to
    timestamp.  This deliberately differs from :func:`detect_rows`, whose
    fleet-monitor convention falls back to the arg-max-z sample so marginal
    hosts still carry a timestamp estimate; the batched sweep kernels
    (:mod:`repro.kernels.sweep`) expose both conventions behind an explicit
    flag so neither caller can drift.
    """
    mu, sigma = baseline_stats(baseline)
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return False, 0.0, None
    z = (x - mu) / sigma
    score = float(np.max(z))
    hot = z > threshold
    frac = float(np.mean(hot))
    if score > threshold and frac >= persistence:
        onset = int(np.argmax(hot))
        return True, score, onset
    return False, score, None


def sliding_baseline_stats(x: np.ndarray, starts: np.ndarray, n: int,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """(mu, sigma) of ``x[s:s+n]`` for every start in ``starts`` — O(T + #starts).

    One prefix-sum pass replaces per-tick ``np.mean``/``np.std`` recomputation.
    The series is shifted by its global mean before the squared pass so the
    sum-of-squares difference does not cancel catastrophically for large-mean
    channels (byte counters); this is the rolling-moment analogue of the
    Welford kernel's chunk merge.  Applies the same sigma floor as
    :func:`baseline_stats`.
    """
    x = np.asarray(x, dtype=np.float64)
    starts = np.asarray(starts, dtype=np.intp)
    n = int(n)
    if n <= 0 or (starts.size and (starts.min() < 0 or starts.max() + n > x.size)):
        raise ValueError(f"invalid baseline spans: n={n}, x.size={x.size}")
    shift = float(x.mean()) if x.size else 0.0
    y = x - shift
    c1 = np.concatenate(([0.0], np.cumsum(y)))
    c2 = np.concatenate(([0.0], np.cumsum(y * y)))
    m = (c1[starts + n] - c1[starts]) / n
    var = np.maximum((c2[starts + n] - c2[starts]) / n - m * m, 0.0)
    mu = m + shift
    sigma = np.sqrt(var)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    return mu, np.maximum(sigma, floor)


def detect_sweep(x: np.ndarray, window_n: int, baseline_n: int,
                 ticks: np.ndarray, threshold: float = DEFAULT_THRESHOLD,
                 persistence: float = 0.0,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`detect` over many evaluation ticks at once.

    For every tick ``t`` the decision is over ``window = x[t-wn:t]`` against
    ``baseline = x[t-wn-bn:t-wn]`` — exactly the scalar rule, but baseline
    moments come from one prefix-sum pass and the window reductions from a
    strided view, so a full-trial sweep costs O(T + #ticks * wn) instead of
    re-slicing the baseline at every tick.

    Returns ``(is_spike, score, onset)`` arrays over ticks; ``onset`` is the
    first window index whose z exceeds the threshold (-1 where none does).
    """
    x = np.asarray(x, dtype=np.float64)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn, bn = int(window_n), int(baseline_n)
    nt = ticks.size
    if nt == 0:
        e = np.empty(0)
        return e.astype(bool), e, e.astype(np.intp)
    if ticks.min() < wn + bn or ticks.max() > x.size:
        raise ValueError(f"ticks must lie in [{wn + bn}, {x.size}]")
    if bn > 0:
        mu, sigma = sliding_baseline_stats(x, ticks - wn - bn, bn)
    else:  # empty baseline: scalar baseline_stats() convention
        mu = np.zeros(nt)
        sigma = np.full(nt, SIGMA_FLOOR_ABS)
    # strided view: row i is the observation window ending at ticks[i];
    # z is materialized so comparisons round exactly like the scalar path,
    # but only SWEEP_TICK_CHUNK ticks at a time — per-tick decisions are
    # independent, so chunking bounds peak memory without changing a bit
    Wall = np.lib.stride_tricks.sliding_window_view(x, wn)
    fire = np.empty(nt, bool)
    score = np.empty(nt)
    onset = np.empty(nt, np.intp)
    for lo in range(0, nt, SWEEP_TICK_CHUNK):
        sl = slice(lo, min(lo + SWEEP_TICK_CHUNK, nt))
        z = (Wall[ticks[sl] - wn] - mu[sl, None]) / sigma[sl, None]
        score[sl] = z.max(axis=1)
        hot = z > threshold
        frac = hot.mean(axis=1)
        fire[sl] = (score[sl] > threshold) & (frac >= persistence)
        onset[sl] = np.where(hot.any(axis=1), hot.argmax(axis=1), -1)
    return fire, score, onset


def detect_sweep_at(x: np.ndarray, window_n: int, ticks: np.ndarray,
                    mu: np.ndarray, sigma: np.ndarray,
                    threshold: float = DEFAULT_THRESHOLD,
                    persistence: float = 0.0,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """:func:`detect_sweep`'s per-tick decision at given ticks against
    *given* baseline moments — bitwise the same z / score / fire / onset
    math, without re-running the prefix-sum pass.

    The batched slab sweep uses this to re-decide its epsilon-marginal
    ticks and to stamp exact f64 scores at detection ticks: the rolling
    (mu, sigma) are already computed once for the whole slab
    (``kernels.sweep.ops.rolling_moments``), so an exactness fix-up
    costs O(#ticks * wn), not another O(T) pass per row.
    """
    x = np.asarray(x, dtype=np.float64)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn = int(window_n)
    W = np.lib.stride_tricks.sliding_window_view(x, wn)[ticks - wn]
    z = (W - np.asarray(mu)[:, None]) / np.asarray(sigma)[:, None]
    score = z.max(axis=1)
    hot = z > threshold
    frac = hot.mean(axis=1)
    fire = (score > threshold) & (frac >= persistence)
    onset = np.where(hot.any(axis=1), hot.argmax(axis=1), -1)
    return fire, score, onset.astype(np.intp)


def detect_rows(windows: np.ndarray, baselines: np.ndarray,
                threshold: float = DEFAULT_THRESHOLD,
                persistence: float = 0.0,
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Row-batched :func:`detect`: one decision per (window, baseline) row.

    ``windows`` (H, Nw) vs ``baselines`` (H, Nb); returns ``(fire, score,
    onset)`` arrays of length H under exactly the scalar rule (sigma floor,
    max-z, persistence fraction).  ``onset`` is the first above-threshold
    sample, falling back to the arg-max z when no sample crosses — the
    fleet monitor wants a timestamp estimate even for marginal rows.

    The fallback is a *deliberate divergence* from :func:`detect`, which
    returns ``None`` when nothing crosses (the streaming engine only
    timestamps real detections; a fleet operator triaging a near-threshold
    host wants the most-suspicious instant regardless).  The sweep kernels
    (:mod:`repro.kernels.sweep`) reproduce whichever convention the caller
    selects via ``argmax_fallback`` — pinned by tests so neither this
    function nor the kernels can drift against :func:`detect`.
    """
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    mu = b.mean(axis=1)
    sigma = np.maximum(b.std(axis=1),
                       np.maximum(SIGMA_FLOOR_ABS,
                                  SIGMA_FLOOR_REL * np.abs(mu)))
    z = (w - mu[:, None]) / sigma[:, None]
    score = z.max(axis=1)
    hot = z > threshold
    fire = (score > threshold) & (hot.mean(axis=1) >= persistence)
    onset = np.where(hot.any(axis=1), hot.argmax(axis=1), z.argmax(axis=1))
    return fire, score, onset.astype(np.intp)


def spike_scores_matrix(windows: np.ndarray, baselines: np.ndarray) -> np.ndarray:
    """Per-row spike scores for a (M, N) window matrix vs (M, Nb) baselines.

    Used by Layer 3 to score every host metric M_i alongside the latency
    channel.  Vectorized numpy; the Pallas kernel in kernels/spike mirrors
    this for (hosts x metrics) batches.
    """
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    mu = b.mean(axis=1)
    sigma = b.std(axis=1)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    sigma = np.maximum(sigma, floor)
    return ((w - mu[:, None]) / sigma[:, None]).max(axis=1)
