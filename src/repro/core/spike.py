"""3-sigma spike detection (paper §2.2, Layer 2).

    S_L = max_{t in W} (L(t) - mu_L) / sigma_L ,   spike iff S_L > 3

where (mu_L, sigma_L) come from a baseline window W_b preceding the
observation window W.  All functions are numpy (the per-host engine runs on
the host CPU, exactly as the paper's agent does); the batched fleet-scale
versions live in :mod:`repro.kernels.spike`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

DEFAULT_THRESHOLD = 3.0
#: floor on sigma, relative to |mu| — a perfectly flat baseline must not turn
#: numerical dust into spikes (sigma=0 would make any deviation infinite).
SIGMA_FLOOR_REL = 1e-3
SIGMA_FLOOR_ABS = 1e-9
#: f32 -inf surrogate the kernels use to mask padded lanes out of max/argmax
#: reductions — one definition so every kernel/ref pair stays in sync.
MASK_NEG = -3.4e38
#: evaluation ticks per ``detect_sweep`` chunk — bounds the (#ticks, wn)
#: z materialization at streaming cadence (a 10-sample-tick sweep over a
#: long trial would otherwise allocate the full matrix at once); chunking
#: is bitwise-invisible because every tick's decision is independent.
SWEEP_TICK_CHUNK = 1024


def baseline_stats(baseline: np.ndarray) -> Tuple[float, float]:
    """(mu, sigma) over the baseline window, with a sigma floor."""
    x = np.asarray(baseline, dtype=np.float64)
    if x.size == 0:
        return 0.0, SIGMA_FLOOR_ABS
    mu = float(np.mean(x))
    sigma = float(np.std(x))
    floor = max(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * abs(mu))
    return mu, max(sigma, floor)


def spike_score(window: np.ndarray, mu: float, sigma: float) -> float:
    """S = max_t (x(t) - mu)/sigma.  One-sided: spikes are increases.

    (For metrics where the anomaly is a *drop* — e.g. dev_clock under
    power-cap throttling — callers pass the negated series; see
    `engine._oriented`.)"""
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return 0.0
    return float(np.max((x - mu) / sigma))


def detect(window: np.ndarray, baseline: np.ndarray,
           threshold: float = DEFAULT_THRESHOLD,
           persistence: float = 0.0,
           ) -> Tuple[bool, float, Optional[int]]:
    """Full Layer-2 check.

    ``persistence`` is the fraction of window samples that must exceed the
    threshold before a spike is declared.  0 reproduces the bare max-score
    rule; the production default (engine) uses 0.4 so a single noise sample
    cannot fire the detector — this is also what gives the paper's ~5 s
    detection latency with a 5 s window: the anomaly must *fill* a good part
    of the window before the boundary evaluation trips.

    Returns ``(is_spike, score, onset_index)`` where ``onset_index`` is the
    first sample in ``window`` whose z-score exceeds the threshold (the
    engine converts it to an onset timestamp).  When no sample crosses,
    ``onset_index`` is ``None`` — the streaming engine has nothing to
    timestamp.  This deliberately differs from :func:`detect_rows`, whose
    fleet-monitor convention falls back to the arg-max-z sample so marginal
    hosts still carry a timestamp estimate; the batched sweep kernels
    (:mod:`repro.kernels.sweep`) expose both conventions behind an explicit
    flag so neither caller can drift.
    """
    mu, sigma = baseline_stats(baseline)
    x = np.asarray(window, dtype=np.float64)
    if x.size == 0:
        return False, 0.0, None
    z = (x - mu) / sigma
    score = float(np.max(z))
    hot = z > threshold
    frac = float(np.mean(hot))
    if score > threshold and frac >= persistence:
        onset = int(np.argmax(hot))
        return True, score, onset
    return False, score, None


def sliding_baseline_stats(x: np.ndarray, starts: np.ndarray, n: int,
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """(mu, sigma) of ``x[s:s+n]`` for every start in ``starts`` — O(T + #starts).

    One prefix-sum pass replaces per-tick ``np.mean``/``np.std`` recomputation.
    The series is shifted by its global mean before the squared pass so the
    sum-of-squares difference does not cancel catastrophically for large-mean
    channels (byte counters); this is the rolling-moment analogue of the
    Welford kernel's chunk merge.  Applies the same sigma floor as
    :func:`baseline_stats`.
    """
    x = np.asarray(x, dtype=np.float64)
    starts = np.asarray(starts, dtype=np.intp)
    n = int(n)
    if n <= 0 or (starts.size and (starts.min() < 0 or starts.max() + n > x.size)):
        raise ValueError(f"invalid baseline spans: n={n}, x.size={x.size}")
    shift = float(x.mean()) if x.size else 0.0
    y = x - shift
    c1 = np.concatenate(([0.0], np.cumsum(y)))
    c2 = np.concatenate(([0.0], np.cumsum(y * y)))
    m = (c1[starts + n] - c1[starts]) / n
    var = np.maximum((c2[starts + n] - c2[starts]) / n - m * m, 0.0)
    mu = m + shift
    sigma = np.sqrt(var)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    return mu, np.maximum(sigma, floor)


def detect_sweep(x: np.ndarray, window_n: int, baseline_n: int,
                 ticks: np.ndarray, threshold: float = DEFAULT_THRESHOLD,
                 persistence: float = 0.0,
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Vectorized :func:`detect` over many evaluation ticks at once.

    For every tick ``t`` the decision is over ``window = x[t-wn:t]`` against
    ``baseline = x[t-wn-bn:t-wn]`` — exactly the scalar rule, but baseline
    moments come from one prefix-sum pass and the window reductions from a
    strided view, so a full-trial sweep costs O(T + #ticks * wn) instead of
    re-slicing the baseline at every tick.

    Returns ``(is_spike, score, onset)`` arrays over ticks; ``onset`` is the
    first window index whose z exceeds the threshold (-1 where none does).
    """
    x = np.asarray(x, dtype=np.float64)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn, bn = int(window_n), int(baseline_n)
    nt = ticks.size
    if nt == 0:
        e = np.empty(0)
        return e.astype(bool), e, e.astype(np.intp)
    if ticks.min() < wn + bn or ticks.max() > x.size:
        raise ValueError(f"ticks must lie in [{wn + bn}, {x.size}]")
    if bn > 0:
        mu, sigma = sliding_baseline_stats(x, ticks - wn - bn, bn)
    else:  # empty baseline: scalar baseline_stats() convention
        mu = np.zeros(nt)
        sigma = np.full(nt, SIGMA_FLOOR_ABS)
    # strided view: row i is the observation window ending at ticks[i];
    # z is materialized so comparisons round exactly like the scalar path,
    # but only SWEEP_TICK_CHUNK ticks at a time — per-tick decisions are
    # independent, so chunking bounds peak memory without changing a bit
    Wall = np.lib.stride_tricks.sliding_window_view(x, wn)
    fire = np.empty(nt, bool)
    score = np.empty(nt)
    onset = np.empty(nt, np.intp)
    for lo in range(0, nt, SWEEP_TICK_CHUNK):
        sl = slice(lo, min(lo + SWEEP_TICK_CHUNK, nt))
        z = (Wall[ticks[sl] - wn] - mu[sl, None]) / sigma[sl, None]
        score[sl] = z.max(axis=1)
        hot = z > threshold
        frac = hot.mean(axis=1)
        fire[sl] = (score[sl] > threshold) & (frac >= persistence)
        onset[sl] = np.where(hot.any(axis=1), hot.argmax(axis=1), -1)
    return fire, score, onset


def detect_sweep_at(x: np.ndarray, window_n: int, ticks: np.ndarray,
                    mu: np.ndarray, sigma: np.ndarray,
                    threshold: float = DEFAULT_THRESHOLD,
                    persistence: float = 0.0,
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """:func:`detect_sweep`'s per-tick decision at given ticks against
    *given* baseline moments — bitwise the same z / score / fire / onset
    math, without re-running the prefix-sum pass.

    The batched slab sweep uses this to re-decide its epsilon-marginal
    ticks and to stamp exact f64 scores at detection ticks: the rolling
    (mu, sigma) are already computed once for the whole slab
    (``kernels.sweep.ops.rolling_moments``), so an exactness fix-up
    costs O(#ticks * wn), not another O(T) pass per row.
    """
    x = np.asarray(x, dtype=np.float64)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn = int(window_n)
    W = np.lib.stride_tricks.sliding_window_view(x, wn)[ticks - wn]
    z = (W - np.asarray(mu)[:, None]) / np.asarray(sigma)[:, None]
    score = z.max(axis=1)
    hot = z > threshold
    frac = hot.mean(axis=1)
    fire = (score > threshold) & (frac >= persistence)
    onset = np.where(hot.any(axis=1), hot.argmax(axis=1), -1)
    return fire, score, onset.astype(np.intp)


def detect_rows(windows: np.ndarray, baselines: np.ndarray,
                threshold: float = DEFAULT_THRESHOLD,
                persistence: float = 0.0,
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Row-batched :func:`detect`: one decision per (window, baseline) row.

    ``windows`` (H, Nw) vs ``baselines`` (H, Nb); returns ``(fire, score,
    onset)`` arrays of length H under exactly the scalar rule (sigma floor,
    max-z, persistence fraction).  ``onset`` is the first above-threshold
    sample, falling back to the arg-max z when no sample crosses — the
    fleet monitor wants a timestamp estimate even for marginal rows.

    The fallback is a *deliberate divergence* from :func:`detect`, which
    returns ``None`` when nothing crosses (the streaming engine only
    timestamps real detections; a fleet operator triaging a near-threshold
    host wants the most-suspicious instant regardless).  The sweep kernels
    (:mod:`repro.kernels.sweep`) reproduce whichever convention the caller
    selects via ``argmax_fallback`` — pinned by tests so neither this
    function nor the kernels can drift against :func:`detect`.
    """
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    mu = b.mean(axis=1)
    sigma = np.maximum(b.std(axis=1),
                       np.maximum(SIGMA_FLOOR_ABS,
                                  SIGMA_FLOOR_REL * np.abs(mu)))
    z = (w - mu[:, None]) / sigma[:, None]
    score = z.max(axis=1)
    hot = z > threshold
    fire = (score > threshold) & (hot.mean(axis=1) >= persistence)
    onset = np.where(hot.any(axis=1), hot.argmax(axis=1), z.argmax(axis=1))
    return fire, score, onset.astype(np.intp)


# ---------------------------------------------------------------------------
# Validity-masked detection (chaos hardening)
#
# Same Layer-2 rule, but every cell carries a validity bit (from
# repro.core.sanitize or the fleet aggregator's staging mask).  Invalid
# cells contribute to NOTHING: not the baseline moments, not the max-z
# score, not the persistence fraction's numerator, not the onset.  The
# persistence denominator stays the FULL window length — an anomaly must
# still fill 35% of real time before firing, so corruption can only make
# the detector more conservative, never less.  Ticks whose baseline has
# fewer than MIN_VALID_BASELINE_N valid samples are refused outright
# (fire=False, score=0, onset=-1): a baseline you cannot estimate is not
# a baseline you may fire against.
# ---------------------------------------------------------------------------

#: minimum valid baseline samples before a masked tick may fire — mirrors
#: the engine's MIN_BASELINE_N warm-up gate (kept separate to avoid a
#: core -> engine import cycle; test-pinned equal).
MIN_VALID_BASELINE_N = 32


def masked_sliding_baseline_stats(x: np.ndarray, valid: np.ndarray,
                                  starts: np.ndarray, n: int,
                                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Masked :func:`sliding_baseline_stats`: ``(mu, sigma, n_valid)`` of the
    valid cells of ``x[s:s+n]`` for every start.

    Invalid cells are zeroed out of the prefix sums and the count prefix
    divides per-span, so a NaN/frozen cell shifts no moment.  The global
    shift is the mean of the valid cells (same cancellation guard as the
    unmasked path).  Spans with zero valid cells return (0, floor, 0).
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(valid, dtype=bool)
    if v.shape != x.shape:
        raise ValueError(f"valid shape {v.shape} != x shape {x.shape}")
    starts = np.asarray(starts, dtype=np.intp)
    n = int(n)
    if n <= 0 or (starts.size and (starts.min() < 0 or starts.max() + n > x.size)):
        raise ValueError(f"invalid baseline spans: n={n}, x.size={x.size}")
    vf = v.astype(np.float64)
    y = np.where(v, x, 0.0)
    tot = vf.sum()
    shift = float(y.sum() / tot) if tot > 0 else 0.0
    yc = np.where(v, x - shift, 0.0)
    c0 = np.concatenate(([0.0], np.cumsum(vf)))
    c1 = np.concatenate(([0.0], np.cumsum(yc)))
    c2 = np.concatenate(([0.0], np.cumsum(yc * yc)))
    cnt = c0[starts + n] - c0[starts]
    denom = np.maximum(cnt, 1.0)
    m = (c1[starts + n] - c1[starts]) / denom
    var = np.maximum((c2[starts + n] - c2[starts]) / denom - m * m, 0.0)
    mu = np.where(cnt > 0, m + shift, 0.0)
    sigma = np.sqrt(var)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    return mu, np.maximum(sigma, floor), cnt.astype(np.intp)


def detect_sweep_at_masked(x: np.ndarray, valid: np.ndarray, window_n: int,
                           ticks: np.ndarray, mu: np.ndarray, sigma: np.ndarray,
                           threshold: float = DEFAULT_THRESHOLD,
                           persistence: float = 0.0,
                           baseline_count: Optional[np.ndarray] = None,
                           min_baseline_n: int = MIN_VALID_BASELINE_N,
                           ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Masked :func:`detect_sweep_at`: per-tick decisions against given
    baseline moments, with invalid window cells pinned to -inf z.

    ``baseline_count`` (when given) gates each tick on
    ``>= min_baseline_n`` valid baseline samples; gated or all-invalid
    ticks report ``(False, 0.0, -1)``.
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(valid, dtype=bool)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn = int(window_n)
    W = np.lib.stride_tricks.sliding_window_view(x, wn)[ticks - wn]
    V = np.lib.stride_tricks.sliding_window_view(v, wn)[ticks - wn]
    mu = np.asarray(mu, dtype=np.float64)
    sigma = np.asarray(sigma, dtype=np.float64)
    with np.errstate(invalid="ignore"):
        z = np.where(V, (np.where(V, W, 0.0) - mu[:, None]) / sigma[:, None],
                     -np.inf)
    score = z.max(axis=1)
    hot = z > threshold
    # full-window denominator: invalid cells can never count as hot, so
    # corruption only lowers the fraction
    frac = hot.sum(axis=1) / float(wn)
    ok = V.any(axis=1)
    if baseline_count is not None:
        ok &= np.asarray(baseline_count) >= int(min_baseline_n)
    fire = ok & (score > threshold) & (frac >= persistence)
    score = np.where(ok, score, 0.0)
    onset = np.where(ok & hot.any(axis=1), hot.argmax(axis=1), -1)
    return fire, score, onset.astype(np.intp)


def detect_sweep_masked(x: np.ndarray, valid: np.ndarray, window_n: int,
                        baseline_n: int, ticks: np.ndarray,
                        threshold: float = DEFAULT_THRESHOLD,
                        persistence: float = 0.0,
                        min_baseline_n: int = MIN_VALID_BASELINE_N,
                        ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Masked :func:`detect_sweep` — the poisoned-input detection oracle.

    All three engine eval paths route corrupted latency rows through this
    one function, which is what keeps their verdict streams bitwise
    identical under chaos.  With an all-true mask the *decisions* match
    :func:`detect_sweep` (scores of non-firing all-valid ticks too).
    """
    x = np.asarray(x, dtype=np.float64)
    v = np.asarray(valid, dtype=bool)
    ticks = np.asarray(ticks, dtype=np.intp)
    wn, bn = int(window_n), int(baseline_n)
    nt = ticks.size
    if nt == 0:
        e = np.empty(0)
        return e.astype(bool), e, e.astype(np.intp)
    if ticks.min() < wn + bn or ticks.max() > x.size:
        raise ValueError(f"ticks must lie in [{wn + bn}, {x.size}]")
    if bn > 0:
        mu, sigma, cnt = masked_sliding_baseline_stats(x, v, ticks - wn - bn, bn)
    else:
        mu = np.zeros(nt)
        sigma = np.full(nt, SIGMA_FLOOR_ABS)
        cnt = np.full(nt, np.iinfo(np.intp).max, np.intp)
    fire = np.empty(nt, bool)
    score = np.empty(nt)
    onset = np.empty(nt, np.intp)
    for lo in range(0, nt, SWEEP_TICK_CHUNK):
        sl = slice(lo, min(lo + SWEEP_TICK_CHUNK, nt))
        fire[sl], score[sl], onset[sl] = detect_sweep_at_masked(
            x, v, wn, ticks[sl], mu[sl], sigma[sl], threshold, persistence,
            baseline_count=cnt[sl], min_baseline_n=min_baseline_n)
    return fire, score, onset


def detect_masked(window: np.ndarray, baseline: np.ndarray,
                  window_valid: np.ndarray, baseline_valid: np.ndarray,
                  threshold: float = DEFAULT_THRESHOLD,
                  persistence: float = 0.0,
                  min_baseline_n: int = MIN_VALID_BASELINE_N,
                  ) -> Tuple[bool, float, Optional[int]]:
    """Masked scalar :func:`detect` (the per-tick slow-path oracle).

    Same decision rule as one tick of :func:`detect_sweep_masked`; baseline
    moments are computed directly (no prefix shift), so scores can differ
    in the last ulp from the sweep — decisions agree, as on the unmasked
    fast/slow pair.
    """
    b = np.asarray(baseline, dtype=np.float64)
    bv = np.asarray(baseline_valid, dtype=bool)
    x = np.asarray(window, dtype=np.float64)
    wv = np.asarray(window_valid, dtype=bool)
    nb = int(bv.sum())
    if x.size == 0 or nb < int(min_baseline_n) or not wv.any():
        return False, 0.0, None
    bb = b[bv]
    mu = float(np.mean(bb))
    sigma = float(np.std(bb))
    sigma = max(sigma, max(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * abs(mu)))
    with np.errstate(invalid="ignore"):
        z = np.where(wv, (np.where(wv, x, 0.0) - mu) / sigma, -np.inf)
    score = float(np.max(z))
    hot = z > threshold
    frac = float(hot.sum()) / float(x.size)
    if score > threshold and frac >= persistence:
        return True, score, int(np.argmax(hot))
    return False, score, None


def detect_rows_masked(windows: np.ndarray, baselines: np.ndarray,
                       window_valid: np.ndarray, baseline_valid: np.ndarray,
                       threshold: float = DEFAULT_THRESHOLD,
                       persistence: float = 0.0,
                       min_baseline_n: int = MIN_VALID_BASELINE_N,
                       ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Masked :func:`detect_rows` (fleet-monitor convention: argmax-z onset
    fallback over *valid* cells; rows failing the baseline gate or with no
    valid window cell report ``(False, 0.0, 0)``)."""
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    wv = np.asarray(window_valid, dtype=bool)
    bv = np.asarray(baseline_valid, dtype=bool)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    cnt = bv.sum(axis=1)
    denom = np.maximum(cnt, 1)
    bz = np.where(bv, b, 0.0)
    mu = bz.sum(axis=1) / denom
    var = np.where(bv, (bz - mu[:, None]) ** 2, 0.0).sum(axis=1) / denom
    mu = np.where(cnt > 0, mu, 0.0)
    sigma = np.maximum(np.sqrt(var),
                       np.maximum(SIGMA_FLOOR_ABS,
                                  SIGMA_FLOOR_REL * np.abs(mu)))
    with np.errstate(invalid="ignore"):
        z = np.where(wv, (np.where(wv, w, 0.0) - mu[:, None]) / sigma[:, None],
                     -np.inf)
    score = z.max(axis=1)
    hot = z > threshold
    frac = hot.sum(axis=1) / float(w.shape[1])
    ok = wv.any(axis=1) & (cnt >= int(min_baseline_n))
    fire = ok & (score > threshold) & (frac >= persistence)
    score = np.where(ok, score, 0.0)
    onset = np.where(hot.any(axis=1), hot.argmax(axis=1), z.argmax(axis=1))
    onset = np.where(ok, onset, 0)
    return fire, score, onset.astype(np.intp)


def spike_scores_matrix(windows: np.ndarray, baselines: np.ndarray) -> np.ndarray:
    """Per-row spike scores for a (M, N) window matrix vs (M, Nb) baselines.

    Used by Layer 3 to score every host metric M_i alongside the latency
    channel.  Vectorized numpy; the Pallas kernel in kernels/spike mirrors
    this for (hosts x metrics) batches.
    """
    w = np.asarray(windows, dtype=np.float64)
    b = np.asarray(baselines, dtype=np.float64)
    if w.ndim != 2 or b.ndim != 2 or w.shape[0] != b.shape[0]:
        raise ValueError(f"shape mismatch: windows {w.shape} baselines {b.shape}")
    mu = b.mean(axis=1)
    sigma = b.std(axis=1)
    floor = np.maximum(SIGMA_FLOOR_ABS, SIGMA_FLOOR_REL * np.abs(mu))
    sigma = np.maximum(sigma, floor)
    return ((w - mu[:, None]) / sigma[:, None]).max(axis=1)
