"""Incremental O(delta) streaming moments for the live 100 Hz detect path.

The fleet monitor's Layer-2 round needs per-host baseline moments
(mu, sd) over the trailing ``bn`` ticks every round.  Recomputing them
directly is O(rows * bn) per round even though the window is mostly
unchanged — the cost that left quiet-fleet detect at 0.5-0.7x vs the
oracle at B <= 256 (PR 5's recorded price).  This module replaces that
pass with persistent per-(host, block) state so a round that appends
``delta`` ticks pays O(delta) new work plus an O(bn / block) combine.

Design — block-anchored exact moments
-------------------------------------
Plain f64 running sums (add the new tick, subtract the evicted one)
drift in the last ulp and can never be bitwise-compared against a fresh
recomputation.  Instead, the absolute tick axis is partitioned into
fixed blocks of ``g = REPRO_MOMENT_BLOCK`` ticks aligned to the absolute
tick index, and the cache holds one f64 ``(sum, sum_of_squares)`` pair
per (host, block).  Each entry is a pure function of that block's values
at fixed absolute positions — independent of the current window bounds,
the round it was computed in, and every other block.  Baseline moments
are then a head partial + the cached full blocks + a tail partial,
combined in a fixed order.  Consequences, all by construction:

* an incrementally-carried cache entry is bitwise-identical to a
  from-scratch rebuild (same values, same fixed-length reduction);
* window-bound changes (``wn``/``bn`` growing during warmup) never
  invalidate the cache — only the combine range moves;
* shard-local advancement, restore-then-replay, and single-slab vs
  sharded execution all land on identical moments.

The periodic **re-anchor** (every ``REPRO_REANCHOR_ROUNDS`` rounds)
recomputes every needed block from scratch and bitwise-compares against
the carried entries before adopting the rebuild — a cache-coherence
proof, not a drift tolerance.  Any mismatch (state-machine bug, memory
corruption, a mutated slab) trips :attr:`IncrementalMoments.parity`,
which CI gates as ``fleet/incremental_parity == 1.0``.  Chaos/masked
rounds, ``reset_host``, and checkpoint restore *invalidate* the affected
rows instead — the next clean round rebuilds them from scratch, which is
the forced re-anchor.

Decision safety: the moments differ from the direct ``mean``/``std``
pass by ~1e-12 relative at most; every consumer routes them through the
sweep's epsilon marginality guard (rows within ``SWEEP_GUARD_EPS`` of
the threshold are re-decided by the exact f64 oracle), so verdicts stay
byte-exact against ``detect_rows`` exactly as the direct path's do.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.core import spike as spike_mod
from repro.kernels import tuning

__all__ = ["IncrementalMoments"]


class IncrementalMoments:
    """Persistent per-(host, block) baseline-moment state.

    One instance serves a whole fleet: rows are addressed by a global
    host index (``base + local`` for sharded slabs), the block cache is
    a circular (rows, ncap) array keyed by absolute block index modulo
    capacity, and invalidation is per-row.  All methods are pure numpy;
    nothing here is serialized — checkpoints stay flat and a restored
    monitor starts cold (see :meth:`invalidate_all`).
    """

    def __init__(self, block: Optional[int] = None,
                 reanchor_rounds: Optional[int] = None,
                 cap_ticks: Optional[int] = None):
        """``block``/``reanchor_rounds`` override the env knobs
        (``REPRO_MOMENT_BLOCK`` / ``REPRO_REANCHOR_ROUNDS``);
        ``cap_ticks`` hints the largest baseline length expected so the
        circular cache is sized once instead of growing during warmup.
        """
        self.block = int(tuning.moment_block(block))
        self.reanchor_every = int(tuning.reanchor_rounds(reanchor_rounds))
        self._cap_hint = int(cap_ticks) if cap_ticks else 0
        self._rows = 0
        self._ncap = 0
        self._sum = np.zeros((0, 0), np.float64)
        self._sumsq = np.zeros((0, 0), np.float64)
        self._bid = np.full((0, 0), -1, np.int64)
        # stats (monotonic; snapshot via .stats())
        self.rounds = 0
        self.reanchors = 0
        self.forced_invalidations = 0
        self.parity_failures = 0
        self.blocks_computed = 0
        self.blocks_cached = 0
        self.last_round_computed = 0
        self.last_round_rebuilt_rows = 0

    # ------------------------------------------------------------------
    # state management
    # ------------------------------------------------------------------
    @property
    def parity(self) -> float:
        """1.0 while every re-anchor bitwise-matched the carried state."""
        return 1.0 if self.parity_failures == 0 else 0.0

    def invalidate(self, rows) -> None:
        """Cold-invalidate specific global row indices (chaos/masked
        rounds, ``reset_host``): their next clean round rebuilds every
        block from scratch — the forced per-row re-anchor."""
        rows = np.asarray(rows, np.intp)
        rows = rows[(rows >= 0) & (rows < self._rows)]
        if rows.size:
            self._bid[rows, :] = -1
            self.forced_invalidations += int(rows.size)

    def invalidate_all(self) -> None:
        """Drop the whole cache (checkpoint restore, config change).

        Moments are never serialized, so a warm restart lands here: the
        first post-restore round recomputes from scratch, keeping replay
        parity trivially intact.
        """
        if self._rows:
            self.forced_invalidations += self._rows
        self._bid[:, :] = -1

    def _ensure(self, rows: int, bn: int) -> None:
        """Grow the (rows, ncap) cache to cover ``rows`` hosts and a
        ``bn``-tick baseline, preserving existing entries when only the
        row axis grows (shards arriving) and invalidating on capacity
        growth (rare: baseline outgrew the hint)."""
        need_cap = max(bn // self.block + 3, 8)
        if self._cap_hint:
            need_cap = max(need_cap, self._cap_hint // self.block + 3)
        if need_cap > self._ncap:
            self._ncap = need_cap
            self._sum = np.zeros((max(rows, self._rows), need_cap),
                                 np.float64)
            self._sumsq = np.zeros_like(self._sum)
            self._bid = np.full(self._sum.shape, -1, np.int64)
            self._rows = self._sum.shape[0]
            return
        if rows > self._rows:
            grow = max(rows, self._rows * 2)
            for name in ("_sum", "_sumsq", "_bid"):
                old = getattr(self, name)
                new = np.full((grow, self._ncap),
                              -1 if name == "_bid" else 0.0, old.dtype)
                new[:self._rows] = old
                setattr(self, name, new)
            self._rows = grow

    # ------------------------------------------------------------------
    # the per-round pass
    # ------------------------------------------------------------------
    def moments(self, tail: np.ndarray, tick_end: int, wn: int, bn: int,
                base: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Advance state through one round and return ``(mu, sd)``.

        ``tail`` is the (n, wn + bn) trailing slab whose last column is
        absolute tick ``tick_end - 1`` (``tick_end`` = the exclusive
        end-tick the caller derived from the round's timestamps); rows
        occupy global indices ``base .. base + n``.  Cached blocks inside
        the baseline range are reused, missing ones (the round's delta,
        or everything for invalidated rows) are computed from the slab,
        and every ``reanchor_every``-th call instead rebuilds all blocks
        from scratch, bitwise-compares them against the carried entries
        (recording any mismatch in :attr:`parity_failures`) and adopts
        the rebuild.  Returns f64 arrays of length n, ``sd`` already
        sigma-floored exactly as the direct detect path floors it.
        """
        tail = np.asarray(tail)
        n, t = tail.shape
        if t != wn + bn:
            raise ValueError(f"tail {tail.shape} vs wn+bn={wn + bn}")
        e = int(tick_end)
        g = self.block
        s, b_end = e - wn - bn, e - wn          # baseline = ticks [s, b_end)
        c_off = e - t                           # slab col 0 = abs tick c_off
        self._ensure(base + n, bn)
        rows = np.arange(base, base + n)
        self.rounds += 1
        reanchor = (self.reanchor_every > 0
                    and self.rounds % self.reanchor_every == 0)
        if reanchor:
            self.reanchors += 1
        # full blocks strictly inside the baseline
        k0 = -(-s // g)
        k1 = b_end // g
        nblk = max(0, k1 - k0)
        computed = 0
        ks = np.arange(k0, k1)
        slots = ks % max(self._ncap, 1)
        ri = rows[:, None]
        have = (self._bid[ri, slots[None, :]] == ks[None, :]
                if nblk else np.zeros((n, 0), bool))
        rebuilt_rows = (~have).any(axis=1) if nblk else np.zeros(n, bool)
        missing = np.flatnonzero(~have.all(axis=0))
        if nblk and (reanchor or missing.size * 4 > nblk):
            # bulk path: one reshaped reduction over every block — the
            # re-anchor / cold-rebuild cost, bitwise-identical per block
            # to the delta path's per-block reduction (same contiguous
            # 64-element pairwise sum, only batched)
            off0 = k0 * g - c_off
            view = tail[:, off0:off0 + nblk * g].astype(np.float64)
            view = view.reshape(n, nblk, g)
            bs_all = view.sum(axis=2)
            bss_all = (view * view).sum(axis=2)
            if reanchor:
                bad = have & ((self._sum[ri, slots] != bs_all)
                              | (self._sumsq[ri, slots] != bss_all))
                self.parity_failures += int(bad.sum())
            self._sum[ri, slots] = bs_all
            self._sumsq[ri, slots] = bss_all
            self._bid[ri, slots] = ks[None, :]
            computed = n * nblk
            blk_parts, blk_parts_sq = bs_all, bss_all
        else:
            # delta path: only the round's new / invalidated blocks are
            # reduced; everything else is one gathered cache read
            for j in missing:
                k = k0 + int(j)
                slot = int(slots[j])
                need = ~have[:, j]
                nr = rows[need]
                c0 = k * g - c_off
                seg = tail[need, c0:c0 + g].astype(np.float64)
                bs = seg.sum(axis=1)
                bss = (seg * seg).sum(axis=1)
                self._sum[nr, slot] = bs
                self._sumsq[nr, slot] = bss
                self._bid[nr, slot] = k
                computed += int(need.sum())
            blk_parts = self._sum[ri, slots]
            blk_parts_sq = self._sumsq[ri, slots]
            self.blocks_cached += int(have.sum())
        # head/tail partial blocks, recomputed every round from the slab
        if nblk:
            h_lo, h_hi = s, k0 * g
            t_lo, t_hi = k1 * g, b_end
        else:
            h_lo, h_hi = s, b_end
            t_lo, t_hi = b_end, b_end
        parts = np.zeros((n, nblk + 2), np.float64)
        parts_sq = np.zeros((n, nblk + 2), np.float64)
        if h_hi > h_lo:
            seg = tail[:, h_lo - c_off:h_hi - c_off].astype(np.float64)
            parts[:, 0] = seg.sum(axis=1)
            parts_sq[:, 0] = (seg * seg).sum(axis=1)
        if t_hi > t_lo:
            seg = tail[:, t_lo - c_off:t_hi - c_off].astype(np.float64)
            parts[:, -1] = seg.sum(axis=1)
            parts_sq[:, -1] = (seg * seg).sum(axis=1)
        parts[:, 1:nblk + 1] = blk_parts
        parts_sq[:, 1:nblk + 1] = blk_parts_sq
        ssum = parts.sum(axis=1)
        ssq = parts_sq.sum(axis=1)
        mu = ssum / bn
        var = np.maximum(ssq / bn - mu * mu, 0.0)
        sd = np.maximum(np.sqrt(var),
                        np.maximum(spike_mod.SIGMA_FLOOR_ABS,
                                   spike_mod.SIGMA_FLOOR_REL * np.abs(mu)))
        self.blocks_computed += computed
        self.last_round_computed = computed
        self.last_round_rebuilt_rows = int(rebuilt_rows.sum())
        return mu, sd

    def stats(self) -> dict:
        """Counters snapshot (rounds, re-anchors, parity, cache traffic)
        for monitor stats surfaces and the bench rows."""
        return {
            "rounds": self.rounds,
            "reanchors": self.reanchors,
            "forced_invalidations": self.forced_invalidations,
            "parity_failures": self.parity_failures,
            "parity": self.parity,
            "blocks_computed": self.blocks_computed,
            "blocks_cached": self.blocks_cached,
            "last_round_computed": self.last_round_computed,
            "last_round_rebuilt_rows": self.last_round_rebuilt_rows,
        }
