"""Cause taxonomy and diagnosis result types (paper §2.2, Layer 4)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

# Re-export the canonical CauseClass so core/ is self-contained for callers.
from repro.telemetry.schema import CauseClass, SignalGroup, GROUP_TO_CAUSE


@dataclasses.dataclass(frozen=True)
class SpikeEvent:
    """A detected latency spike (Layer 2 output)."""

    t_onset: float       # engine's estimate of onset (first sample with z>thr)
    t_detect: float      # when the sliding window first crossed the threshold
    score: float         # S_L = max_t (L(t)-mu)/sigma over the window
    metric: str          # the latency channel that spiked

    @property
    def detection_latency(self) -> float:
        return self.t_detect - self.t_onset


@dataclasses.dataclass(frozen=True)
class RankedCause:
    cause: CauseClass
    confidence: float                 # conf = alpha*S + (1-alpha)*c, in [0,~)
    top_metric: str                   # strongest evidence channel
    spike_score: float                # S_{M_i} of that channel
    correlation: float                # c_i = max_k |rho(k)|
    lag_s: float                      # arg-max lag in seconds (M leads L if >0)


@dataclasses.dataclass(frozen=True)
class Diagnosis:
    """Layer-4 output: ranked root causes for one spike event."""

    event: SpikeEvent
    ranked: List[RankedCause]
    per_metric: Dict[str, Dict[str, float]]  # name -> {spike,corr,conf,lag_s}
    t_rca: float                             # when the diagnosis completed
    analysis_seconds: float                  # pure compute cost of L3+L4
    #: virtual trial time the verdict's evidence window closed (detection +
    #: post-detection accumulation).  Deterministic — identical across the
    #: per-event, event-batched and slab execution paths — unlike ``t_rca``,
    #: which adds the measured analysis wall on top; operational scoring
    #: (sim/scoring) stamps RCA latency with it for exactly that reason.
    t_ready: Optional[float] = None

    @property
    def top_cause(self) -> CauseClass:
        return self.ranked[0].cause if self.ranked else CauseClass.UNKNOWN

    @property
    def time_to_rca(self) -> float:
        """Paper's Time-to-RCA: spike onset -> diagnosis complete."""
        return self.t_rca - self.event.t_onset

    def summary(self) -> str:
        lines = [
            f"spike on {self.event.metric}: S={self.event.score:.2f} "
            f"onset={self.event.t_onset:.2f}s detect={self.event.t_detect:.2f}s "
            f"rca={self.t_rca:.2f}s (time-to-RCA {self.time_to_rca:.2f}s)",
        ]
        for i, rc in enumerate(self.ranked):
            lines.append(
                f"  #{i + 1} {rc.cause.value:<16} conf={rc.confidence:.3f} "
                f"(S={rc.spike_score:.2f}, c={rc.correlation:.2f}, "
                f"lag={rc.lag_s * 1e3:+.0f}ms via {rc.top_metric})")
        return "\n".join(lines)
