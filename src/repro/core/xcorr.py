"""Lagged cross-correlation (paper §2.2, Layer 3).

    rho_{L,M_i}(k) = sum_{t=1}^{N-k} (L(t)-mu_L)(M_i(t+k)-mu_{M_i})
                     / ( sqrt(sum (L-mu_L)^2) * sqrt(sum (M_i-mu_{M_i})^2) )

    c_i = max_{|k| <= K} |rho_{L,M_i}(k)| ,  K = 20 samples @ 100 Hz (200 ms)

Sign convention: positive k means the *metric leads the latency* by k
samples — L(t) is paired with M_i(t - k), the metric's value k samples
earlier.  A root cause should lead or be simultaneous, so the arg-max lag
is diagnostic output too.

Numpy here (per-host engine); the batched fleet path is
:func:`lagged_xcorr_batch` which dispatches to the Pallas kernel.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

DEFAULT_MAX_LAG = 20  # samples @ 100 Hz -> +/-200 ms (paper)
_EPS = 1e-12


def _center_norm(x: np.ndarray) -> Tuple[np.ndarray, float]:
    xc = x - x.mean()
    return xc, float(np.sqrt(np.sum(xc * xc)) + _EPS)


def lagged_xcorr(latency: np.ndarray, metrics: np.ndarray,
                 max_lag: int = DEFAULT_MAX_LAG) -> np.ndarray:
    """Correlation matrix rho[(M), 2K+1] for lags k = -K..K.

    ``latency``: (N,), ``metrics``: (M, N).  rho[:, K+k] pairs L(t) with
    M(t-k) (positive k: metric leads).  Edge handling follows the paper:
    the overlapping region only, normalized by the full-window energies (so
    |rho| can be < 1 even for a perfect lagged copy — consistent, and
    monotone in alignment quality).
    """
    L = np.asarray(latency, dtype=np.float64)
    M = np.asarray(metrics, dtype=np.float64)
    if M.ndim == 1:
        M = M[None, :]
    n = L.shape[0]
    if M.shape[1] != n:
        raise ValueError(f"latency N={n} but metrics {M.shape}")
    K = int(max_lag)
    if K >= n:
        raise ValueError(f"max_lag {K} must be < window length {n}")
    Lc, Ln = _center_norm(L)
    Mc = M - M.mean(axis=1, keepdims=True)
    Mn = np.sqrt(np.sum(Mc * Mc, axis=1)) + _EPS
    out = np.zeros((M.shape[0], 2 * K + 1), dtype=np.float64)
    for k in range(-K, K + 1):
        if k >= 0:
            num = Mc[:, 0:n - k] @ Lc[k:n]
        else:
            num = Mc[:, -k:n] @ Lc[0:n + k]
        out[:, K + k] = num / (Mn * Ln)
    return out


def max_abs_xcorr(latency: np.ndarray, metrics: np.ndarray,
                  max_lag: int = DEFAULT_MAX_LAG,
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """c_i = max_k |rho_i(k)| and the arg-max lag (in samples)."""
    rho = lagged_xcorr(latency, metrics, max_lag)
    k_idx = np.argmax(np.abs(rho), axis=1)
    c = np.abs(rho)[np.arange(rho.shape[0]), k_idx]
    lags = k_idx - max_lag
    return c, lags


def lagged_xcorr_batch(latency, metrics, max_lag: int = DEFAULT_MAX_LAG,
                       use_kernel: bool = True):
    """Fleet-scale batched version: latency (B, N), metrics (B, M, N).

    Returns rho (B, M, 2K+1).  Dispatches to the Pallas TPU kernel when
    requested (validated in interpret mode on CPU); otherwise the pure-jnp
    reference.  This is the §5.1 multi-node path: one correlation engine
    ingesting B hosts' windows at once.
    """
    from repro.kernels.xcorr import ops as _ops
    return _ops.lagged_xcorr(latency, metrics, max_lag=max_lag,
                             use_kernel=use_kernel)
