"""Multi-node extension (paper §5.1): straggler localization across a
simulated 8-host fleet, batched RCA through the Pallas kernels.

    PYTHONPATH=src python examples/fleet_monitor_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
from repro.monitor.fleet import FleetMonitor
from repro.sim.scenario import make_trial

HOSTS, BAD = 8, 5
trials = [make_trial(100 + h, "io",
                     intensity=(2.0 if h == BAD else 0.0),
                     t_on=40.0, confuser_prob=0.0) for h in range(HOSTS)]
t_hi = int(46.0 * 100)
data = np.stack([t.data[:, :t_hi] for t in trials])

mon = FleetMonitor(use_kernels=True)
fd = mon.diagnose_fleet(trials[0].ts[:t_hi], data, trials[0].channels)
print("per-host latency spike scores:",
      np.round(fd.per_host_scores, 1).tolist())
print(f"straggler: host {fd.straggler_host} (injected: host {BAD})")
if fd.diagnosis:
    print(fd.diagnosis.summary())
print("mitigation:", fd.mitigation.value)
