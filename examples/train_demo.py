"""Train a ~100M-param model for a few hundred steps with the telemetry
agent live, then print the loss curve and measured agent overhead.

    PYTHONPATH=src python examples/train_demo.py --steps 300

Fault-tolerance drill: add --fail-at 150, rerun the same command and watch
it resume from the checkpoint.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
import argparse

from repro.checkpoint import FailureInjector
from repro.configs import get_config
from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
from repro.models.registry import build_model
from repro.monitor.fleet import FleetMonitor
from repro.train.loop import LoopConfig, run_training
from repro.train.optimizer import OptConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--fail-at", type=int, default=None)
args = ap.parse_args()

# ~100M params: mamba2-370m backbone narrowed
cfg = get_config("mamba2-370m").replace(n_layers=12, d_model=768,
                                        vocab=8192)
model = build_model(cfg)
print(f"model: {cfg.name} variant, {model.param_count()/1e6:.0f}M params")

pipe = SyntheticLMPipeline(PipelineConfig(batch=8, seq_len=128,
                                          vocab=cfg.vocab, seed=0))
inj = FailureInjector(args.fail_at) if args.fail_at else None
res = run_training(model, pipe, OptConfig(lr=3e-4, warmup_steps=50),
                   LoopConfig(steps=args.steps, checkpoint_every=50,
                              ckpt_dir="/tmp/repro_train_demo"),
                   injector=inj, monitor=FleetMonitor())

n = max(len(res.losses) // 10, 1)
for i in range(0, len(res.losses), n):
    chunk = res.losses[i:i + n]
    print(f"step {res.final_step - len(res.losses) + i + 1:4d}  "
          f"loss {sum(chunk)/len(chunk):.4f}")
print(f"telemetry overhead: {res.telemetry_overhead_pct:.2f}% "
      f"(paper: 1.21% @ 100 Hz)")
