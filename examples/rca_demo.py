"""Fig 3 reproduction: timeline of signals during a NIC burst.

    PYTHONPATH=src python examples/rca_demo.py

Prints an ASCII timeline of NCCL latency vs NET_RX softirqs around the
event plus the engine's diagnosis — the paper's Figure 3, in a terminal.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
from repro.core.engine import CorrelationEngine
from repro.sim.scenario import make_trial

trial = make_trial(seed=3, disturbance="nic", intensity=1.8,
                   confuser_prob=0.0)
li = trial.channels.index("coll_allreduce_ms")
ni = trial.channels.index("net_rx_softirq")

lo = int((trial.t_on - 8) * 100)
hi = int((trial.t_on + 14) * 100)
L = trial.data[li, lo:hi]
N = trial.data[ni, lo:hi]

def sparkline(x, width=110):
    x = x[: (len(x) // width) * width]
    x = x.reshape(width, -1).mean(axis=1)
    lv = " .:-=+*#%@"
    z = (x - x.min()) / (np.ptp(x) + 1e-9)
    return "".join(lv[int(v * (len(lv) - 1))] for v in z)

print(f"t = [{trial.t_on - 8:.0f}s .. {trial.t_on + 14:.0f}s], "
      f"injection at t={trial.t_on:.1f}s")
print("nccl latency :", sparkline(L))
print("net_rx softirq:", sparkline(N))

diags = CorrelationEngine().process(trial.ts, trial.data, trial.channels)
for d in diags:
    print()
    print(d.summary())
