"""Quickstart: the paper's pipeline end to end in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds one injected-disturbance trial (NIC burst under an all-reduce
workload), runs the correlation engine, prints the ranked diagnosis.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.engine import CorrelationEngine
from repro.sim.scenario import make_trial

# one trial: tc-style NIC bursts injected at a random onset
trial = make_trial(seed=7, disturbance="nic", intensity=1.5)
print(f"injected: {trial.truth.value} at t={trial.t_on:.1f}s "
      f"(intensity {trial.intensity:.2f}, msg {trial.msg_bytes >> 20} MiB)")

engine = CorrelationEngine()          # paper defaults: 5s window, 3sigma,
diags = engine.process(trial.ts, trial.data, trial.channels)  # K=20, a=0.5

for d in diags:
    print(d.summary())
    print(f"verdict: {d.top_cause.value}  "
          f"(time-to-RCA {d.t_rca - trial.t_on:.1f}s vs injection)")
