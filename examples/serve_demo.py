"""Serve a small model with batched requests + live telemetry.

    PYTHONPATH=src python examples/serve_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np
from repro.configs import get_config
from repro.models.registry import build_model
from repro.monitor.hooks import StepTelemetry
from repro.serve.engine import ServeEngine

cfg = get_config("yi-9b", smoke=True).replace(n_layers=4, d_model=256,
                                              n_heads=8, n_kv=4,
                                              head_dim=32, d_ff=512,
                                              vocab=4096)
model = build_model(cfg)
params = model.init(jax.random.key(0))
tele = StepTelemetry()
tele.start()
eng = ServeEngine(model, params, max_len=128, telemetry=tele)

rng = np.random.default_rng(0)
for batch in (1, 4, 8):
    prompts = rng.integers(0, cfg.vocab, (batch, 12)).astype(np.int32)
    r = eng.generate(prompts, n_new=24)
    ms = float(np.mean(r.per_token_ms))
    print(f"batch={batch}: prefill {r.prefill_ms:6.1f} ms, "
          f"{ms:5.1f} ms/token, {1000/ms*batch:7.1f} tok/s")
stats = tele.stop()
print(f"telemetry overhead {100*stats.overhead_frac:.2f}%")
