"""Checkpoint/restart + fault-tolerance drill."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import Checkpointer, FailureInjector, resume_or_init


def _state(step):
    return {"step": jnp.asarray(step, jnp.int32),
            "params": {"w": jnp.full((4, 4), float(step)),
                       "b": jnp.arange(3.0)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _state(5))
    out = ck.restore(_state(0))
    assert int(out["step"]) == 5
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  np.full((4, 4), 5.0))


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _state(s))
    assert ck.latest_step() == 4
    kept = sorted(p.name for p in tmp_path.glob("step_*"))
    assert len(kept) == 2


def test_half_written_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, _state(1))
    # simulate a crash mid-write: tmp dir without manifest
    bad = tmp_path / "step_00000009.tmp"
    bad.mkdir()
    (bad / "garbage.npy").write_bytes(b"xx")
    # and a dir missing its manifest
    bad2 = tmp_path / "step_00000007"
    bad2.mkdir()
    assert ck.latest_step() == 1
    out = ck.restore(_state(0))
    assert int(out["step"]) == 1


def test_resume_or_init(tmp_path):
    ck = Checkpointer(tmp_path)
    state, start = resume_or_init(ck, lambda: _state(0))
    assert start == 0
    ck.save(3, _state(3))
    state, start = resume_or_init(ck, lambda: _state(0))
    assert start == 4 and int(state["step"]) == 3


def test_training_survives_injected_failure(tmp_path):
    """End-to-end drill: train, die at step 7, restart, finish; the loss
    trajectory continues from the checkpoint."""
    from repro.configs import get_config
    from repro.data.pipeline import PipelineConfig, SyntheticLMPipeline
    from repro.models.registry import build_model
    from repro.train.loop import LoopConfig, run_training
    from repro.train.optimizer import OptConfig

    cfg = get_config("mamba2-370m", smoke=True)
    model = build_model(cfg)
    pipe = SyntheticLMPipeline(PipelineConfig(batch=2, seq_len=32,
                                              vocab=cfg.vocab, seed=1))
    lc = LoopConfig(steps=10, checkpoint_every=3, ckpt_dir=str(tmp_path),
                    telemetry=False, diagnose_every=10 ** 9)
    opt = OptConfig(lr=1e-3, warmup_steps=1)

    inj = FailureInjector(fail_at_step=7, phase="after_step")
    with pytest.raises(RuntimeError, match="injected"):
        run_training(model, pipe, opt, lc, injector=inj)
    # restart: same command, no injector
    pipe2 = SyntheticLMPipeline(PipelineConfig(batch=2, seq_len=32,
                                               vocab=cfg.vocab, seed=1))
    res = run_training(model, pipe2, opt, lc)
    assert res.final_step == 9
    # must have resumed from step 6's checkpoint, not from scratch
    assert len(res.losses) <= 4
