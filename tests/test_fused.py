"""Fused spike+xcorr kernel: interpret-mode vs pure-jnp oracle, and
vs the two single-purpose kernels it replaces."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused.ops import fused_rca, fused_rca_max
from repro.kernels.fused.ref import fused_rca_ref
from repro.kernels.spike.ops import spike_scores
from repro.kernels.xcorr.ops import lagged_xcorr


@pytest.mark.parametrize("B,M,N,Nb,K", [
    (1, 1, 128, 128, 4), (2, 7, 500, 2000, 20), (3, 16, 512, 512, 20),
    (1, 33, 500, 1500, 31), (2, 5, 257, 300, 10),
])
def test_fused_matches_ref(B, M, N, Nb, K):
    rng = np.random.default_rng(B * 100 + M)
    L = rng.standard_normal((B, N)).astype(np.float32)
    Mx = (rng.standard_normal((B, M, N)) * 3 + 1).astype(np.float32)
    Bs = (rng.standard_normal((B, M, Nb)) * 2 + 10).astype(np.float32)
    s, rho = fused_rca(jnp.asarray(L), jnp.asarray(Mx), jnp.asarray(Bs), K,
                       use_kernel=True)
    s0, rho0 = fused_rca_ref(jnp.asarray(L), jnp.asarray(Mx),
                             jnp.asarray(Bs), K)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rho), np.asarray(rho0),
                               rtol=1e-4, atol=1e-5)


def test_fused_equals_separate_kernels():
    """Fusion changes data movement, not results."""
    rng = np.random.default_rng(9)
    B, M, N, Nb, K = 2, 9, 512, 1024, 20
    L = rng.standard_normal((B, N)).astype(np.float32)
    Mx = rng.standard_normal((B, M, N)).astype(np.float32)
    Bs = (rng.standard_normal((B, M, Nb)) + 5).astype(np.float32)
    s, rho = fused_rca(jnp.asarray(L), jnp.asarray(Mx), jnp.asarray(Bs), K)
    s_sep = spike_scores(jnp.asarray(Mx), jnp.asarray(Bs), use_kernel=True)
    rho_sep = lagged_xcorr(jnp.asarray(L), jnp.asarray(Mx), K,
                           use_kernel=True)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_sep),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(rho), np.asarray(rho_sep),
                               rtol=1e-5, atol=1e-6)


def test_fused_max_recovers_lag_and_spike():
    rng = np.random.default_rng(1)
    N, K = 512, 20
    sig = rng.standard_normal(N + K)
    L = sig[:N][None].astype(np.float32)
    M = np.zeros((1, 2, N), np.float32)
    M[0, 0] = sig[5:N + 5]                  # leads latency by 5 samples
    M[0, 1] = rng.standard_normal(N)
    Bs = rng.standard_normal((1, 2, 256)).astype(np.float32)
    Bs[0, 0] -= sig[:256] * 0               # keep baseline quiet
    s, c, lags = fused_rca_max(jnp.asarray(L), jnp.asarray(M),
                               jnp.asarray(Bs), K)
    assert int(lags[0, 0]) == 5
    assert float(c[0, 0]) > 0.9
    assert np.all(np.isfinite(np.asarray(s)))
