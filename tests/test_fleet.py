"""Fleet-level RCA: straggler localization + mitigation mapping (paper
§5.1 extension)."""
import numpy as np
import pytest

from repro.core.taxonomy import CauseClass
from repro.monitor.fleet import FleetMonitor, Mitigation
from repro.sim.scenario import make_trial


def _fleet_data(n_hosts, bad_host, cls, seed=0):
    """Fixed onset at t=40s; quiet hosts get intensity 0 (pure ambient).
    Windows are clipped to shortly after the event so the streaming
    trailing-window monitor sees it (as it would live)."""
    trials = []
    for h in range(n_hosts):
        inten = 2.0 if h == bad_host else 0.0
        t = make_trial(seed + h, cls, intensity=inten, t_on=40.0,
                       confuser_prob=0.0)
        trials.append(t)
    # clip shortly after onset so the trailing baseline window stays clean
    t_hi = int(46.0 * trials[0].rate_hz)
    data = np.stack([t.data[:, :t_hi] for t in trials])
    return trials[0].ts[:t_hi], data, trials[0].channels, trials[bad_host]


def test_straggler_localized_and_explained():
    ts, data, channels, bad = _fleet_data(4, 2, "nic", seed=100)
    mon = FleetMonitor(use_kernels=True)
    fd = mon.diagnose_fleet(ts, data, channels)
    assert fd.straggler_host == 2
    assert fd.diagnosis is not None
    assert fd.diagnosis.top_cause == CauseClass.NIC
    assert fd.mitigation == Mitigation.HIERARCHICAL_ALLREDUCE


def test_mitigation_escalates_on_persistence():
    mon = FleetMonitor(use_kernels=False, persistent_threshold=2)
    ts, data, channels, _ = _fleet_data(3, 1, "cpu", seed=200)
    fd1 = mon.diagnose_fleet(ts, data, channels)
    fd2 = mon.diagnose_fleet(ts, data, channels)
    assert fd1.mitigation == Mitigation.REPIN_CPU
    assert fd2.mitigation == Mitigation.EXCLUDE_AND_RESCALE


def test_kernel_and_numpy_paths_agree():
    ts, data, channels, _ = _fleet_data(3, 0, "io", seed=300)
    a = FleetMonitor(use_kernels=True).diagnose_fleet(ts, data, channels)
    b = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    assert a.straggler_host == b.straggler_host
    np.testing.assert_allclose(a.per_host_scores, b.per_host_scores,
                               rtol=1e-4, atol=1e-4)
