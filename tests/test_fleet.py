"""Fleet-level RCA: straggler localization + mitigation mapping (paper
§5.1 extension)."""
import numpy as np
import pytest

from repro.core.taxonomy import CauseClass
from repro.monitor.fleet import FleetMonitor, Mitigation
from repro.sim.scenario import make_trial


def _fleet_data(n_hosts, bad_host, cls, seed=0):
    """Fixed onset at t=40s; quiet hosts get intensity 0 (pure ambient).
    Windows are clipped to shortly after the event so the streaming
    trailing-window monitor sees it (as it would live)."""
    trials = []
    for h in range(n_hosts):
        inten = 2.0 if h == bad_host else 0.0
        t = make_trial(seed + h, cls, intensity=inten, t_on=40.0,
                       confuser_prob=0.0)
        trials.append(t)
    # clip shortly after onset so the trailing baseline window stays clean
    t_hi = int(46.0 * trials[0].rate_hz)
    data = np.stack([t.data[:, :t_hi] for t in trials])
    return trials[0].ts[:t_hi], data, trials[0].channels, trials[bad_host]


def test_straggler_localized_and_explained():
    ts, data, channels, bad = _fleet_data(4, 2, "nic", seed=100)
    mon = FleetMonitor(use_kernels=True)
    fd = mon.diagnose_fleet(ts, data, channels)
    assert fd.straggler_host == 2
    assert fd.diagnosis is not None
    assert fd.diagnosis.top_cause == CauseClass.NIC
    assert fd.mitigation == Mitigation.HIERARCHICAL_ALLREDUCE


def test_mitigation_escalates_on_persistence():
    mon = FleetMonitor(use_kernels=False, persistent_threshold=2)
    ts, data, channels, _ = _fleet_data(3, 1, "cpu", seed=200)
    fd1 = mon.diagnose_fleet(ts, data, channels)
    fd2 = mon.diagnose_fleet(ts, data, channels)
    assert fd1.mitigation == Mitigation.REPIN_CPU
    assert fd2.mitigation == Mitigation.EXCLUDE_AND_RESCALE


def test_kernel_and_numpy_paths_agree():
    ts, data, channels, _ = _fleet_data(3, 0, "io", seed=300)
    a = FleetMonitor(use_kernels=True).diagnose_fleet(ts, data, channels)
    b = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    assert a.straggler_host == b.straggler_host
    np.testing.assert_allclose(a.per_host_scores, b.per_host_scores,
                               rtol=1e-4, atol=1e-4)
    assert a.flagged_hosts == b.flagged_hosts
    for h in a.flagged_hosts:
        assert a.diagnoses[h].top_cause == b.diagnoses[h].top_cause


@pytest.mark.parametrize("cls", ["io", "cpu", "nic", "gpu"])
def test_batched_rca_agrees_with_per_host_engine(cls):
    """Every flagged host's batched verdict == a scalar engine.process
    replay of that host's slab — the fused dispatch changes throughput,
    not diagnoses."""
    from repro.core.engine import CorrelationEngine
    for seed in (100, 400):
        ts, data, channels, _ = _fleet_data(3, 1, cls, seed=seed)
        fd = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
        assert fd.flagged_hosts, f"{cls}/{seed}: no host flagged"
        eng = CorrelationEngine()
        for h in fd.flagged_hosts:
            diags = eng.process(ts, data[h], channels)
            assert diags, f"{cls}/{seed}: engine found nothing on host {h}"
            assert diags[0].top_cause == fd.diagnoses[h].top_cause


def test_multiple_stragglers_one_dispatch():
    """Two injected stragglers with different causes: both flagged, both
    explained from the same batched dispatch, each with its own verdict."""
    t_nic = make_trial(500, "nic", intensity=2.0, t_on=40.0, confuser_prob=0.0)
    t_io = make_trial(501, "io", intensity=2.0, t_on=40.0, confuser_prob=0.0)
    quiet = [make_trial(510 + h, "nic", intensity=0.0, t_on=40.0,
                        confuser_prob=0.0) for h in range(2)]
    t_hi = int(46.0 * t_nic.rate_hz)
    data = np.stack([t.data[:, :t_hi]
                     for t in (quiet[0], t_nic, quiet[1], t_io)])
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(
        t_nic.ts[:t_hi], data, t_nic.channels)
    assert set(fd.flagged_hosts) == {1, 3}
    assert fd.diagnoses[1].top_cause == CauseClass.NIC
    assert fd.diagnoses[3].top_cause == CauseClass.IO
    assert fd.mitigations[1] == Mitigation.HIERARCHICAL_ALLREDUCE
    assert fd.mitigations[3] == Mitigation.REBALANCE_INPUT
    # the worst host leads the flagged list and fills the legacy fields
    assert fd.straggler_host == fd.flagged_hosts[0]
    assert fd.diagnosis is fd.diagnoses[fd.straggler_host]


def test_transient_glitch_does_not_outrank_persistent_straggler():
    """A single-sample latency glitch can carry the fleet's highest max-z
    but must not be named straggler over a persistent spike."""
    ts, data, channels, _ = _fleet_data(3, 1, "nic", seed=700)
    li = channels.index("coll_allreduce_ms")
    data = data.copy()
    data[0, li, -10] += 1e4                 # one-sample glitch on host 0
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(ts, data, channels)
    assert fd.per_host_scores[0] > fd.per_host_scores[1]
    assert 0 not in fd.flagged_hosts
    assert fd.straggler_host == 1
    assert fd.diagnosis is fd.diagnoses[1]


def test_no_evidence_channels_degrades_gracefully():
    """Latency-only telemetry: a flagged host gets no verdict, not a crash."""
    ts, data, channels, _ = _fleet_data(2, 1, "cpu", seed=950)
    li = channels.index("coll_allreduce_ms")
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(
        ts, data[:, [li], :], ["coll_allreduce_ms"])
    assert fd.flagged_hosts == [1]
    assert fd.diagnosis is None
    assert fd.mitigation == Mitigation.NONE


@pytest.mark.parametrize("T", [8, 40, 62])
def test_short_window_returns_quiet_verdict_not_spurious_stragglers(T):
    """Satellite bug: at tiny T the clamps ``wn = T//2; bn = T - wn`` can
    leave a baseline below MIN_BASELINE_N, whose sigma-floored z-scores
    flagged perfectly quiet hosts.  Short snapshots must yield an explicit
    quiet verdict with the skip marker instead."""
    ts, data, channels, _ = _fleet_data(3, 1, "cpu", seed=250)
    ts, data = ts[:T], data[:, :, :T]
    for fast in (True, False):
        fd = FleetMonitor(use_kernels=False,
                          fast_detect=fast).diagnose_fleet(ts, data, channels)
        assert fd.flagged_hosts == []
        assert fd.diagnosis is None
        assert fd.mitigation == Mitigation.NONE
        assert np.all(fd.per_host_scores == 0.0)
        assert "short_baseline_skip" in fd.stage_seconds


def test_short_window_round_clears_strike_history():
    """The short-baseline quiet verdict is a 'not flagged this round'
    round: strike counts must reset exactly as on a quiet full window,
    or a short snapshot between two flagged rounds would let stale
    strikes escalate to EXCLUDE_AND_RESCALE."""
    mon = FleetMonitor(use_kernels=False, persistent_threshold=2)
    ts, data, channels, _ = _fleet_data(3, 1, "cpu", seed=200)
    fd1 = mon.diagnose_fleet(ts, data, channels)
    assert fd1.mitigation == Mitigation.REPIN_CPU     # strike 1
    mon.diagnose_fleet(ts[:40], data[:, :, :40], channels)  # short round
    assert mon._strikes == {}
    fd2 = mon.diagnose_fleet(ts, data, channels)
    assert fd2.mitigation == Mitigation.REPIN_CPU     # strike restarts at 1


def test_quiet_fleet_flags_nothing():
    ts, data, channels, _ = _fleet_data(4, 0, "cpu", seed=900)
    quiet = data.copy()
    # neutralize the injected host by replacing it with another quiet one
    quiet[0] = data[3]
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(ts, quiet, channels)
    assert fd.flagged_hosts == []
    assert fd.diagnosis is None
    assert fd.mitigation == Mitigation.NONE
