"""Event-batched Layer 3: the eval stacks every trial's pending event into
ONE fused dispatch per diagnoser, with per-class accuracy identical to the
per-event sequential path.  The columnar TrialStore path additionally
replaces the per-event evidence reslicing with slab indexing."""
import numpy as np
import pytest

from repro.core import engine as engine_mod
from repro.core.baselines import make_baseline
from repro.core.engine import CorrelationEngine
from repro.kernels.fused import ops as fused_ops
from repro.sim.scenario import (
    TrialStore, accuracy_by_class, make_trial, run_eval,
)


@pytest.fixture(scope="module")
def paired_records():
    dgs = lambda: [make_baseline(n) for n in ["ours", "b3"]]
    batched = run_eval(dgs(), n_per_class=3, seed=5, batch_events=True)
    sequential = run_eval(dgs(), n_per_class=3, seed=5, batch_events=False)
    return batched, sequential


def test_accuracy_identical_to_per_event_path(paired_records):
    batched, sequential = paired_records
    for name in ("ours", "B3-deep-profiling"):
        assert accuracy_by_class(batched, name) \
            == accuracy_by_class(sequential, name)


def test_per_trial_predictions_identical(paired_records):
    batched, sequential = paired_records
    key = lambda r: (r.diagnoser, r.trial_seed)
    preds_b = {key(r): r.pred for r in batched}
    preds_s = {key(r): r.pred for r in sequential}
    assert preds_b == preds_s


def test_one_fused_dispatch_per_diagnoser():
    """The 12-trial eval issues exactly ONE batched Layer-3 dispatch per
    engine-backed diagnoser (events are rows, not separate calls)."""
    dgs = [make_baseline(n) for n in ["ours", "b3"]]
    c0 = fused_ops.DISPATCH_COUNT
    run_eval(dgs, n_per_class=3, seed=5, batch_events=True)
    assert fused_ops.DISPATCH_COUNT - c0 == len(dgs)


def test_detect_events_process_equivalence():
    """process == detect_events + per-event _diagnose, byte-identical."""
    trial = make_trial(11, "nic", intensity=1.5, t_on=40.0)
    eng = CorrelationEngine()
    diags = eng.process(trial.ts, trial.data, trial.channels)
    events = eng.detect_events(trial.ts, trial.data, trial.channels)
    assert len(diags) == len(events) >= 1
    for d, (ev, t) in zip(diags, events):
        assert d.event == ev
        assert d.t_rca == pytest.approx(float(trial.ts[t]),
                                        abs=d.analysis_seconds + 1e-9)


def test_diagnose_events_batch_matches_scalar_diagnose():
    """Batched verdicts == per-event _diagnose replays on the same events
    (top cause and ranked order; confidences agree to f32 tolerance)."""
    trials = [make_trial(200 + i, cls, intensity=1.8, t_on=40.0,
                         confuser_prob=0.0)
              for i, cls in enumerate(["io", "cpu", "nic", "gpu"])]
    eng = CorrelationEngine()
    items, scalar = [], []
    for tr in trials:
        events = eng.detect_events(tr.ts, tr.data, tr.channels)
        assert events, "expected a detection in every injected trial"
        ev, t = events[0]
        li = list(tr.channels).index(eng.cfg.latency_metric)
        items.append((tr.ts, tr.data, list(tr.channels), t, ev))
        scalar.append(eng._diagnose(tr.ts, tr.data, list(tr.channels),
                                    li, t, ev))
    for use_kernel in (False, True):
        batched = eng.diagnose_events_batch(items, use_kernel=use_kernel)
        for db, ds in zip(batched, scalar):
            assert db.top_cause == ds.top_cause
            assert [r.cause for r in db.ranked] == [r.cause for r in ds.ranked]
            np.testing.assert_allclose(
                [r.confidence for r in db.ranked],
                [r.confidence for r in ds.ranked], rtol=1e-3, atol=1e-3)
            assert db.event == ds.event


def test_trial_store_slab_matches_trials():
    trials = [make_trial(60 + i, cls, confuser_prob=0.0)
              for i, cls in enumerate(["io", "nic"])]
    store = TrialStore.from_trials(trials)
    assert store.slab.shape == (2,) + trials[0].data.shape
    assert store.slab.dtype == np.float32
    for i, t in enumerate(trials):
        np.testing.assert_array_equal(store.slab[i],
                                      t.data.astype(np.float32))
    ts, row, channels = store.rows()[1]
    assert row.base is store.slab and channels == trials[0].channels


def test_store_predictions_identical_with_fewer_slice_ops():
    """Acceptance: the store path's per-trial predictions equal the
    per-event batched path's, with *counted* fewer python-level evidence
    slice ops (O(events) reslices -> 3 fancy-index gathers)."""
    trials = [make_trial(300 + 7 * ci + k, cls)
              for ci, cls in enumerate(["io", "cpu", "nic", "gpu"])
              for k in range(3)]
    store = TrialStore.from_trials(trials)
    for name in ("ours", "b3"):
        dg = make_baseline(name)
        c0 = engine_mod.SLICE_OPS
        per_event = dg.diagnose_trials([(t.ts, t.data, t.channels)
                                        for t in trials])
        ops_event = engine_mod.SLICE_OPS - c0
        c0 = engine_mod.SLICE_OPS
        by_store = dg.diagnose_store(store)
        ops_store = engine_mod.SLICE_OPS - c0
        assert [r.pred for r in by_store] == [r.pred for r in per_event], name
        # 2 reslices per event vs 3 gathers per layout group
        assert ops_event == 2 * len(trials)
        assert ops_store == 3
        assert ops_store < ops_event


def test_diagnose_events_slab_matches_diagnose_events_batch():
    """Slab-indexed gather == per-event reslice gather on the same events
    (same kernel dispatch; confidences agree to f32 tolerance)."""
    trials = [make_trial(400 + i, cls, intensity=1.8, t_on=40.0,
                         confuser_prob=0.0)
              for i, cls in enumerate(["io", "cpu", "nic", "gpu"])]
    store = TrialStore.from_trials(trials)
    eng = CorrelationEngine()
    items, events = [], []
    for i, tr in enumerate(trials):
        evs = eng.detect_events(store.ts, store.slab[i], store.channels)
        assert evs, "expected a detection in every injected trial"
        ev, t = evs[0]
        items.append((store.ts, store.slab[i], store.channels, t, ev))
        events.append((i, t, ev))
    batched = eng.diagnose_events_batch(items)
    by_slab = eng.diagnose_events_slab(store.ts, store.slab, store.channels,
                                       events)
    for db, ds in zip(batched, by_slab):
        assert db.top_cause == ds.top_cause
        assert [r.cause for r in db.ranked] == [r.cause for r in ds.ranked]
        np.testing.assert_allclose([r.confidence for r in db.ranked],
                                   [r.confidence for r in ds.ranked],
                                   rtol=1e-3, atol=1e-3)
        assert db.event == ds.event


def test_run_eval_store_path_matches_sequential_on_b1():
    """A non-engine diagnoser (no diagnose_store override) must take the
    legacy path unchanged under batch_events=True."""
    dg = lambda: [make_baseline("b1")]
    a = run_eval(dg(), n_per_class=2, seed=3, batch_events=True)
    b = run_eval(dg(), n_per_class=2, seed=3, batch_events=False)
    assert [r.pred for r in a] == [r.pred for r in b]


def test_diagnose_events_batch_no_evidence_channels():
    trial = make_trial(33, "cpu", intensity=2.0, t_on=40.0)
    li = trial.channels.index("coll_allreduce_ms")
    data = trial.data[[li]]
    eng = CorrelationEngine()
    events = eng.detect_events(trial.ts, data, ["coll_allreduce_ms"])
    assert events
    ev, t = events[0]
    out = eng.diagnose_events_batch(
        [(trial.ts, data, ["coll_allreduce_ms"], t, ev)])
    assert len(out) == 1 and out[0].ranked == []
