"""Event-batched Layer 3: the eval stacks every trial's pending event into
ONE fused dispatch per diagnoser, with per-class accuracy identical to the
per-event sequential path."""
import numpy as np
import pytest

from repro.core.baselines import make_baseline
from repro.core.engine import CorrelationEngine
from repro.kernels.fused import ops as fused_ops
from repro.sim.scenario import accuracy_by_class, make_trial, run_eval


@pytest.fixture(scope="module")
def paired_records():
    dgs = lambda: [make_baseline(n) for n in ["ours", "b3"]]
    batched = run_eval(dgs(), n_per_class=3, seed=5, batch_events=True)
    sequential = run_eval(dgs(), n_per_class=3, seed=5, batch_events=False)
    return batched, sequential


def test_accuracy_identical_to_per_event_path(paired_records):
    batched, sequential = paired_records
    for name in ("ours", "B3-deep-profiling"):
        assert accuracy_by_class(batched, name) \
            == accuracy_by_class(sequential, name)


def test_per_trial_predictions_identical(paired_records):
    batched, sequential = paired_records
    key = lambda r: (r.diagnoser, r.trial_seed)
    preds_b = {key(r): r.pred for r in batched}
    preds_s = {key(r): r.pred for r in sequential}
    assert preds_b == preds_s


def test_one_fused_dispatch_per_diagnoser():
    """The 12-trial eval issues exactly ONE batched Layer-3 dispatch per
    engine-backed diagnoser (events are rows, not separate calls)."""
    dgs = [make_baseline(n) for n in ["ours", "b3"]]
    c0 = fused_ops.DISPATCH_COUNT
    run_eval(dgs, n_per_class=3, seed=5, batch_events=True)
    assert fused_ops.DISPATCH_COUNT - c0 == len(dgs)


def test_detect_events_process_equivalence():
    """process == detect_events + per-event _diagnose, byte-identical."""
    trial = make_trial(11, "nic", intensity=1.5, t_on=40.0)
    eng = CorrelationEngine()
    diags = eng.process(trial.ts, trial.data, trial.channels)
    events = eng.detect_events(trial.ts, trial.data, trial.channels)
    assert len(diags) == len(events) >= 1
    for d, (ev, t) in zip(diags, events):
        assert d.event == ev
        assert d.t_rca == pytest.approx(float(trial.ts[t]),
                                        abs=d.analysis_seconds + 1e-9)


def test_diagnose_events_batch_matches_scalar_diagnose():
    """Batched verdicts == per-event _diagnose replays on the same events
    (top cause and ranked order; confidences agree to f32 tolerance)."""
    trials = [make_trial(200 + i, cls, intensity=1.8, t_on=40.0,
                         confuser_prob=0.0)
              for i, cls in enumerate(["io", "cpu", "nic", "gpu"])]
    eng = CorrelationEngine()
    items, scalar = [], []
    for tr in trials:
        events = eng.detect_events(tr.ts, tr.data, tr.channels)
        assert events, "expected a detection in every injected trial"
        ev, t = events[0]
        li = list(tr.channels).index(eng.cfg.latency_metric)
        items.append((tr.ts, tr.data, list(tr.channels), t, ev))
        scalar.append(eng._diagnose(tr.ts, tr.data, list(tr.channels),
                                    li, t, ev))
    for use_kernel in (False, True):
        batched = eng.diagnose_events_batch(items, use_kernel=use_kernel)
        for db, ds in zip(batched, scalar):
            assert db.top_cause == ds.top_cause
            assert [r.cause for r in db.ranked] == [r.cause for r in ds.ranked]
            np.testing.assert_allclose(
                [r.confidence for r in db.ranked],
                [r.confidence for r in ds.ranked], rtol=1e-3, atol=1e-3)
            assert db.event == ds.event


def test_diagnose_events_batch_no_evidence_channels():
    trial = make_trial(33, "cpu", intensity=2.0, t_on=40.0)
    li = trial.channels.index("coll_allreduce_ms")
    data = trial.data[[li]]
    eng = CorrelationEngine()
    events = eng.detect_events(trial.ts, data, ["coll_allreduce_ms"])
    assert events
    ev, t = events[0]
    out = eng.diagnose_events_batch(
        [(trial.ts, data, ["coll_allreduce_ms"], t, ev)])
    assert len(out) == 1 and out[0].ranked == []
