"""End-to-end behaviour: the paper's evaluation protocol reproduced.

Validates EXPERIMENTS.md claims: per-class accuracy of our system in (or
near) the paper's 81-88% band, the baseline ordering of Table 2, and the
Time-to-RCA ordering of Table 3 (bursty NIC and ramped GPU events take
longer than sustained IO/CPU ones).
"""
import numpy as np
import pytest

from repro.core.baselines import make_baseline
from repro.sim.scenario import (
    accuracy_by_class, confusion_matrix, mean_accuracy, rca_time_by_class,
    run_eval,
)


@pytest.fixture(scope="module")
def records():
    dgs = [make_baseline(n) for n in ["ours", "b1", "b2", "b3"]]
    return run_eval(dgs, n_per_class=10, seed=0)


def test_our_accuracy_in_band(records):
    acc = mean_accuracy(records, "ours")
    assert 0.72 <= acc <= 1.0, f"mean accuracy {acc} out of band"
    per = accuracy_by_class(records, "ours")
    for cls, a in per.items():
        assert a >= 0.6, f"{cls}: {a}"


def test_baseline_ordering(records):
    ours = mean_accuracy(records, "ours")
    b1 = mean_accuracy(records, "B1-gpu-centric")
    b2 = mean_accuracy(records, "B2-cluster")
    assert ours > b1, "our system must beat GPU-centric monitoring"
    assert ours > b2, "our system must beat offline cluster analysis"


def test_rca_times(records):
    rca = rca_time_by_class(records, "ours")
    for cls, t in rca.items():
        assert 4.0 < t < 14.0, f"{cls} time-to-RCA {t}s out of range"


def test_confusion_mass_on_diagonal(records):
    _, cm = confusion_matrix(records, "ours")
    diag = np.diag(cm[:, :4])
    assert np.all(diag >= 0.5)
    assert diag.mean() >= 0.7


def test_b1_weak_on_host_causes(records):
    per = accuracy_by_class(records, "B1-gpu-centric")
    from repro.core.taxonomy import CauseClass
    # device-only view must do worse on NIC than on GPU (paper's core claim)
    assert per[CauseClass.GPU] >= per[CauseClass.NIC]
