"""Batched Layer-2 sweep: kernel/ref/exact paths vs the per-row oracle
(`detect_sweep`), the onset-convention pin, and the slab event resolve
(`detect_events_store` / `detect_events_slab`) vs per-row `detect_events`."""
import numpy as np
import pytest

from repro.core import spike
from repro.core.engine import CorrelationEngine, EngineConfig
from repro.kernels.sweep import ops as sweep_ops
from repro.sim.scenario import TrialStore, make_trial


def _mk(R=6, T=4000, wn=300, bn=1000, seed=0, spikes=((0, 2500, 2900, 6.0),)):
    rng = np.random.default_rng(seed)
    X = rng.normal(10, 1, (R, T))
    for r, lo, hi, amp in spikes:
        X[r, lo:hi] += amp
    return X.astype(np.float32), wn, bn


def _oracle(X32, wn, bn, ticks, thr=3.0, pers=0.3):
    outs = [spike.detect_sweep(np.asarray(x, np.float64), wn, bn, ticks,
                               thr, pers) for x in X32]
    return (np.stack([o[0] for o in outs]), np.stack([o[1] for o in outs]),
            np.stack([o[2] for o in outs]))


# ------------------------------------------------------------ jit sweep paths
@pytest.mark.parametrize("use_kernel", [False, True])
def test_sweep_rows_matches_oracle_off_guard_band(use_kernel):
    """f32 decisions equal the f64 oracle everywhere the epsilon guard
    does not fire; scores agree to f32 tolerance (the slab-vs-oracle
    tolerance contract)."""
    X32, wn, bn = _mk(spikes=((0, 2500, 2900, 6.0), (3, 1500, 1800, 8.0)))
    ticks = np.arange(wn + bn, X32.shape[1] + 1, 37)
    fire, score, onset, marg = sweep_ops.sweep_rows(
        X32, wn, bn, ticks, 3.0, 0.3, use_kernel=use_kernel)
    f0, s0, o0 = _oracle(X32, wn, bn, ticks)
    nm = ~marg
    np.testing.assert_array_equal(fire[nm], f0[nm])
    np.testing.assert_array_equal(onset[nm], o0[nm])
    np.testing.assert_allclose(score, s0, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sweep_rows_marginal_flags_near_threshold(use_kernel):
    """A window z engineered inside the guard band must be flagged
    marginal — the exactness contract depends on it."""
    R, T, wn, bn = 2, 2000, 200, 1000
    rng = np.random.default_rng(5)
    X = rng.normal(0, 1, (R, T))
    # plant one sample whose z sits ~1e-4 over the threshold at tick T
    # (the tick's baseline is the bn samples preceding its window)
    mu, sd = spike.baseline_stats(X[0, T - wn - bn:T - wn])
    X[0, T - 5] = mu + (3.0 + 1e-4) * sd
    X32 = X.astype(np.float32)
    ticks = np.array([T])
    _, _, _, marg = sweep_ops.sweep_rows(X32, wn, bn, ticks, 3.0, 0.0,
                                         use_kernel=use_kernel)
    assert bool(marg[0, 0])


def test_sweep_rows_onset_convention_pin():
    """argmax_fallback=True reproduces detect_rows' arg-max fallback;
    False reproduces detect/detect_sweep's -1 — the documented deliberate
    divergence between the streaming engine and the fleet monitor."""
    rng = np.random.default_rng(2)
    wn, bn = 256, 1024
    X = rng.normal(5, 0.5, (8, bn + wn))
    X32 = X.astype(np.float32)
    ticks = np.array([bn + wn])
    # "quiet" = no sample crosses at all (max z at or under the
    # threshold); rows that merely fail persistence still carry a
    # first-hot onset in both conventions
    quiet = _oracle(X32, wn, bn, ticks, thr=3.0, pers=0.35)[1][:, 0] <= 3.0
    assert quiet.any()
    f_eng, _, o_eng, _ = sweep_ops.sweep_rows(X32, wn, bn, ticks, 3.0, 0.35)
    f_fl, _, o_fl, _ = sweep_ops.sweep_rows(X32, wn, bn, ticks, 3.0, 0.35,
                                            argmax_fallback=True)
    f0, _, o0 = spike.detect_rows(np.asarray(X32[:, bn:], np.float64),
                                  np.asarray(X32[:, :bn], np.float64),
                                  3.0, 0.35)
    np.testing.assert_array_equal(f_fl[:, 0], f0)
    np.testing.assert_array_equal(o_fl[:, 0], o0)     # arg-max fallback
    assert all(o_eng[quiet, 0] == -1)                 # engine convention
    # and the scalar engine rule returns None for the same quiet windows
    for r in np.flatnonzero(quiet):
        is_spike, _, onset = spike.detect(X32[r, bn:], X32[r, :bn],
                                          3.0, 0.35)
        assert not is_spike and onset is None


# ----------------------------------------------------------- exact CPU path
def test_sweep_rows_exact_bitwise_at_fired_ticks():
    X32, wn, bn = _mk(R=8, spikes=((0, 2500, 2900, 6.0), (5, 1400, 1450, 9.0)))
    X64 = np.asarray(X32, np.float64)
    ticks = np.arange(wn + bn, X32.shape[1] + 1, 23)
    fire, score, onset = sweep_ops.sweep_rows_exact(X64, wn, bn, ticks,
                                                    3.0, 0.3)
    f0, s0, o0 = _oracle(X32, wn, bn, ticks)
    np.testing.assert_array_equal(fire, f0)           # fire exact everywhere
    hit = fire
    assert np.array_equal(score[hit], s0[hit])        # bitwise at fired
    assert np.array_equal(onset[hit], o0[hit])


@pytest.mark.parametrize("case", ["cadence_gt_wn", "final_tick_at_T",
                                  "single_tick", "bn0"])
def test_sweep_edge_cases(case):
    """cadence > wn (disjoint windows), the final tick landing exactly at
    T, a single-tick trial, and the bn=0 empty-baseline convention."""
    R, T, wn, bn = 3, 3000, 200, 800
    if case == "bn0":
        bn = 0
    X32, wn, bn = _mk(R=R, T=T, wn=wn, bn=bn,
                      spikes=((1, 2000, 2400, 7.0),))[0], wn, bn
    if case == "cadence_gt_wn":
        ticks = np.arange(wn + bn, T + 1, 3 * wn)
    elif case == "final_tick_at_T":
        ticks = np.concatenate([np.arange(wn + bn, T, 700), [T]])
    elif case == "single_tick":
        ticks = np.array([wn + bn])
    else:                                   # bn0: scalar floor convention
        ticks = np.arange(wn, T + 1, 500)
    f0, s0, o0 = _oracle(X32, wn, bn, ticks)
    for use_kernel in (False, True):
        fire, score, onset, marg = sweep_ops.sweep_rows(
            X32, wn, bn, ticks, 3.0, 0.3, use_kernel=use_kernel)
        nm = ~marg
        np.testing.assert_array_equal(fire[nm], f0[nm])
        np.testing.assert_array_equal(onset[nm], o0[nm])
        np.testing.assert_allclose(score, s0, rtol=1e-4, atol=1e-4)
    fire, score, onset = sweep_ops.sweep_rows_exact(
        np.asarray(X32, np.float64), wn, bn, ticks, 3.0, 0.3)
    np.testing.assert_array_equal(fire, f0)
    assert np.array_equal(score[fire], s0[fire])
    assert np.array_equal(onset[fire], o0[fire])


def test_sweep_ragged_valid_lengths():
    """Rows with ragged valid lengths are swept as if truncated: masked
    ticks never fire, valid ticks match the truncated-row oracle."""
    X32, wn, bn = _mk(R=4, spikes=((0, 2500, 2900, 6.0),
                                   (2, 2500, 2900, 6.0)))
    T = X32.shape[1]
    valid = np.array([T, 2200, 3500, 1500])
    ticks = np.arange(wn + bn, T + 1, 171)
    for path in ("jit", "kernel", "exact"):
        if path == "exact":
            fire, score, onset = sweep_ops.sweep_rows_exact(
                np.asarray(X32, np.float64), wn, bn, ticks, 3.0, 0.3,
                valid_n=valid)
            marg = np.zeros_like(fire)
        else:
            fire, score, onset, marg = sweep_ops.sweep_rows(
                X32, wn, bn, ticks, 3.0, 0.3, valid_n=valid,
                use_kernel=(path == "kernel"))
        for r in range(4):
            nv = int(valid[r])
            live = ticks <= nv
            assert not fire[r, ~live].any()
            assert (onset[r, ~live] == -1).all()
            if not live.any():
                continue
            f0, s0, o0 = spike.detect_sweep(
                np.asarray(X32[r, :nv], np.float64), wn, bn, ticks[live],
                3.0, 0.3)
            keep = (~marg[r, live]) if path != "exact" else f0
            np.testing.assert_array_equal(fire[r, live][keep], f0[keep])


def test_detect_sweep_chunking_is_invisible(monkeypatch):
    """The SWEEP_TICK_CHUNK memory bound must not change a bit."""
    rng = np.random.default_rng(3)
    x = rng.normal(10, 1, 6000)
    x[4000:4400] += 6.0
    wn, bn = 500, 2000
    ticks = np.arange(wn + bn, x.size, 7)       # 500 ticks, several chunks
    ref = spike.detect_sweep(x, wn, bn, ticks, 3.0, 0.3)
    monkeypatch.setattr(spike, "SWEEP_TICK_CHUNK", 64)
    got = spike.detect_sweep(x, wn, bn, ticks, 3.0, 0.3)
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(a, b)


# ------------------------------------------------------------- event resolve
def _events_sig(evs):
    return [(ev.t_onset, ev.t_detect, ev.score, int(t)) for ev, t in evs]


@pytest.mark.parametrize("eval_every", [0, 10])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_detect_events_store_byte_exact(eval_every, use_kernel):
    """Slab detection reproduces per-row detect_events byte-exactly —
    stamps, scores and rca indices — on multi-event trials (cascade/flap
    exercise cooldown + pending machinery, the trailing event the
    end-of-trial pending flush)."""
    trials = [make_trial(900 + i, cls, confuser_prob=0.0)
              for i, cls in enumerate(("nic", "cpu", "io", "gpu"))]
    # recurring + trailing faults: multi-event rows and a pending flush
    trials += [make_trial(77, "nic", t_on=84.0, intensity=2.0,
                          confuser_prob=0.0)]
    store = TrialStore.from_trials(trials)
    eng = CorrelationEngine(EngineConfig(eval_every=eval_every))
    ref = [eng.detect_events(store.ts, store.slab[i], store.channels)
           for i in range(len(store))]
    got = eng.detect_events_store(store.ts, store.slab, store.channels,
                                  use_kernel=use_kernel)
    assert [_events_sig(e) for e in ref] == [_events_sig(e) for e in got]
    triples = eng.detect_events_slab(store.ts, store.slab, store.channels,
                                     use_kernel=use_kernel)
    flat = [(r, ev.t_detect, t) for r, evs in enumerate(ref)
            for ev, t in evs]
    assert [(r, ev.t_detect, t) for r, ev, t in triples] == flat


def test_detect_events_store_ragged_matches_truncated_oracle():
    """A ragged row is evaluated exactly as detect_events on the
    truncated row — including when the shared tick grid lands exactly on
    a row's valid length (the oracle's arange(t0, T_r) grid excludes it;
    an off-by-one here produced phantom events)."""
    cfg = EngineConfig(eval_every=10)
    eng = CorrelationEngine(cfg)
    trials = [make_trial(50 + i, cls, t_on=40.0, intensity=2.0,
                         confuser_prob=0.0)
              for i, cls in enumerate(("nic", "cpu", "io"))]
    store = TrialStore.from_trials(trials)
    t0 = cfg.window_n + cfg.baseline_n
    # one valid length ON the tick grid, one off it, one full
    T = store.ts.shape[0]
    valid = np.array([t0 + 2000, t0 + 2005, T])
    got = eng.detect_events_store(store.ts, store.slab, store.channels,
                                  valid_n=valid)
    for r in range(3):
        nv = int(valid[r])
        ref = eng.detect_events(store.ts[:nv], store.slab[r][:, :nv],
                                store.channels)
        assert _events_sig(ref) == _events_sig(got[r]), r


def test_detect_events_rows_groups_trials():
    """process_batch's grouped slab sweep equals the per-trial loop even
    with heterogeneous trial layouts in one call."""
    a = make_trial(11, "nic", confuser_prob=0.0)
    b = make_trial(12, "cpu", confuser_prob=0.0)
    c = make_trial(13, "io", duration_s=60.0, confuser_prob=0.0)  # 2nd group
    eng = CorrelationEngine()
    trials = [(t.ts, t.data, t.channels) for t in (a, b, c)]
    got = eng.detect_events_rows(trials)
    ref = [eng.detect_events(*t) for t in trials]
    assert [_events_sig(e) for e in ref] == [_events_sig(e) for e in got]


def test_resolve_row_cooldown_and_pending_jumps():
    """The hit-to-hit resolve replays the tick loop's state machine:
    fires inside an active hypothesis's cooldown are skipped (flat signal
    never clears the step gate), a hypothesis blocks re-detection until
    its accumulation tick, and one open at row end flushes with T-1."""
    cfg = EngineConfig(eval_every=10)
    eng = CorrelationEngine(cfg)
    rate = cfg.rate_hz
    T = 9000
    ts = np.arange(T) / rate
    ticks = np.arange(cfg.window_n + cfg.baseline_n, T, 10)
    rca_n = int(cfg.rca_extra_s * rate)
    wn = cfg.window_n
    # flat latency row: every hot slice has the same mean, so the step
    # gate never opens a second concurrent hypothesis and the resolve
    # must degenerate to the single-pending machine
    L = np.full(T, 5.0)
    onset = np.zeros(ticks.size, np.int64)
    fire = np.ones(ticks.size, bool)       # every tick fires
    out = eng._resolve_row(ts, ticks, fire, onset, L, ticks.size, T, wn,
                           rca_n, cfg.cooldown_s, cfg.max_hypotheses,
                           cfg.step_sigma)
    assert len(out) >= 2
    t_first = int(ticks[out[0][0]])
    assert out[0][1] == t_first + rca_n
    # consecutive detections at least a cooldown apart
    for (i, _), (j, _) in zip(out, out[1:]):
        assert ts[int(ticks[j])] - ts[int(ticks[i])] >= cfg.cooldown_s
    # max_hypotheses=1 must reproduce the same stream exactly
    out1 = eng._resolve_row(ts, ticks, fire, onset, L, ticks.size, T, wn,
                            rca_n, cfg.cooldown_s, 1, cfg.step_sigma)
    assert out1 == out
    # a clear step above the first hypothesis's level opens a second
    # concurrent hypothesis: the second fire lands INSIDE the first's
    # cooldown (which would swallow it in the flat case above), yet both
    # accumulate and emit
    idx2 = 50                              # 5 s after the first fire
    assert ts[int(ticks[idx2])] - ts[int(ticks[0])] < cfg.cooldown_s
    L2 = np.full(T, 5.0)
    L2[int(ticks[idx2]) - wn:] = 50.0      # step at the second fire's window
    fire3 = np.zeros(ticks.size, bool)
    fire3[0] = fire3[idx2] = True
    out3 = eng._resolve_row(ts, ticks, fire3, onset, L2, ticks.size, T,
                            wn, rca_n, cfg.cooldown_s,
                            cfg.max_hypotheses, cfg.step_sigma)
    assert [i for i, _ in out3] == [0, idx2]
    # with a single hypothesis slot the in-cooldown step is swallowed
    out3_k1 = eng._resolve_row(ts, ticks, fire3, onset, L2, ticks.size, T,
                               wn, rca_n, cfg.cooldown_s, 1,
                               cfg.step_sigma)
    assert [i for i, _ in out3_k1] == [0]
    # a single fire so late no tick reaches its accumulation index: flush
    fire2 = np.zeros(ticks.size, bool)
    fire2[-1] = True
    out2 = eng._resolve_row(ts, ticks, fire2, onset, L, ticks.size, T, wn,
                            rca_n, cfg.cooldown_s, cfg.max_hypotheses,
                            cfg.step_sigma)
    assert out2 == [(ticks.size - 1, T - 1)]


def test_zero_accumulation_zero_cooldown_matches_oracle():
    """rca_extra_s=0 + cooldown_s=0 (detection-latency-only experiments):
    the resolve must advance tick to tick like the oracle loop, not spin
    on the same maturation index forever."""
    cfg = EngineConfig(eval_every=10, rca_extra_s=0.0, cooldown_s=0.0)
    eng = CorrelationEngine(cfg)
    trial = make_trial(21, "nic", t_on=40.0, intensity=2.0,
                       confuser_prob=0.0)
    store = TrialStore.from_trials([trial])
    ref = eng.detect_events(store.ts, store.slab[0], store.channels)
    got = eng.detect_events_store(store.ts, store.slab, store.channels)[0]
    assert len(ref) > 1
    assert _events_sig(ref) == _events_sig(got)
