import os
import sys

# tests run on the single real CPU device (the dry-run manages its own
# placeholder devices in a separate process — never set XLA_FLAGS here)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
