"""Operational scoring: nearest-truth matching edge cases, soak behaviour,
window-edge events, and batched/slab verdict-stream parity."""
import numpy as np

from repro.core.engine import CorrelationEngine
from repro.core.taxonomy import CauseClass
from repro.sim import scenarios as scen
from repro.sim import scoring
from repro.sim.scenario import TrialStore


def _v(t_onset, pred=CauseClass.NIC, lat=5.0):
    return scoring.VerdictEvent(t_onset=t_onset, t_detect=t_onset + lat,
                                t_ready=t_onset + lat + 2.0, pred=pred)


def _t(cls, t_on, dur=10.0, intensity=1.5):
    return scen.FaultEvent(cls, t_on, dur, intensity)


# ---------------------------------------------------------------- matcher
def test_fully_overlapping_truth_single_verdict():
    """Two events at the same instant, one verdict: exactly one match (the
    deterministic tie-break), one miss, no spurious verdicts."""
    truth = [_t("io", 40.0), _t("cpu", 40.1)]
    m = scoring.match_events(truth, [_v(40.5)])
    assert len(m.pairs) == 1
    assert m.pairs[0] == (1, 0)           # nearest truth onset wins
    assert m.missed == [0]
    assert m.spurious == []


def test_fully_overlapping_truth_two_verdicts_one_to_one():
    truth = [_t("io", 40.0), _t("cpu", 40.2)]
    verds = [_v(40.1), _v(40.4)]
    m = scoring.match_events(truth, verds)
    assert len(m.pairs) == 2
    assert {i for i, _ in m.pairs} == {0, 1}
    assert {j for _, j in m.pairs} == {0, 1}
    assert not m.missed and not m.spurious


def test_nearest_truth_wins():
    truth = [_t("io", 30.0), _t("cpu", 60.0)]
    m = scoring.match_events(truth, [_v(58.0)])
    assert m.pairs == [(1, 0)]


def test_out_of_tolerance_verdict_is_spurious():
    truth = [_t("io", 30.0, dur=10.0)]
    m = scoring.match_events(truth, [_v(60.0)], tol_s=5.0)
    assert m.pairs == []
    assert m.missed == [0]
    assert m.spurious == [0]


def test_verdict_inside_active_span_matches_even_late():
    """A verdict whose onset estimate lands mid-event (late but inside the
    widened active span) still matches."""
    truth = [_t("io", 30.0, dur=20.0)]
    m = scoring.match_events(truth, [_v(45.0)], tol_s=2.0)
    assert m.pairs == [(0, 0)]


def test_score_trial_latencies_and_accuracy():
    truth = [_t("nic", 40.0)]
    verds = [_v(40.5, pred=CauseClass.NIC, lat=4.5)]
    s = scoring.score_trial(truth, verds)
    assert s.n_matched == 1 and s.n_correct == 1
    np.testing.assert_allclose(s.detect_latencies, [5.0])
    np.testing.assert_allclose(s.rca_latencies, [7.0])
    agg = scoring.summarize([s])
    assert agg["precision"] == 1.0 and agg["recall"] == 1.0
    assert agg["accuracy"] == 1.0
    assert agg["detect_within_target"] == 1.0
    assert agg["rca_within_target"] == 1.0


def test_summarize_soak_semantics():
    """No truth: recall/accuracy are null, every verdict is false."""
    clean = scoring.summarize([scoring.score_trial([], [])])
    assert clean["false_verdicts"] == 0
    assert clean["recall"] is None and clean["precision"] is None
    noisy = scoring.summarize([scoring.score_trial([], [_v(50.0)])])
    assert noisy["false_verdicts"] == 1
    assert noisy["precision"] == 0.0


# ------------------------------------------------------------- end to end
def test_soak_produces_zero_verdicts():
    """The no-fault control: ambient telemetry must not fire the engine."""
    for seed in (1, 2, 3):
        t = scen.compose_trial(seed, [], duration_s=90.0, scenario="soak")
        assert t.truth == []
        diags = CorrelationEngine().process(t.ts, t.data, t.channels)
        assert diags == [], f"soak seed {seed} produced a false verdict"


def test_event_straddling_trial_edge_scores():
    """An event whose active window runs past the end of the trial: the
    pending detection is flushed at the last sample and still matches."""
    # sustained envelope so the final 5 s window is solidly hot
    ev = [scen.FaultEvent("cpu", 51.0, 10.0, 2.0)]   # t_off = 61 > 60
    t = scen.compose_trial(17, ev, duration_s=60.0, confuser_prob=0.0)
    diags = CorrelationEngine().process(t.ts, t.data, t.channels)
    assert len(diags) == 1
    d = diags[0]
    assert d.t_ready == float(t.ts[-1])     # flushed at the trial edge
    s = scoring.score_trial(t.truth, scoring.verdict_events(diags))
    assert s.n_matched == 1


def test_batched_and_slab_verdict_streams_identical():
    """The acceptance invariant: predictions AND timestamps of the
    event-batched and slab paths match the per-event oracle exactly, on
    multi-event scenarios."""
    trials = []
    for cls in ("overlap_pair", "flap", "soak"):
        trials += scen.make_scenario(23, cls, confuser_prob=0.15)
    store = TrialStore.from_trials(trials)
    eng = CorrelationEngine()
    rows = store.rows()

    def sig(diags):
        return [(d.top_cause, d.event.t_onset, d.event.t_detect, d.t_ready)
                for d in diags]

    oracle = [sig(eng.process(*r)) for r in rows]
    assert any(len(s) > 1 for s in oracle), "expected a multi-event trial"
    batched = [sig(ds) for ds in eng.process_batch(rows)]
    slab = [sig(ds) for ds in
            eng.process_store(store.ts, store.slab, store.channels)]
    assert batched == oracle
    assert slab == oracle


def test_verdict_events_prefer_t_ready():
    ev = [scen.FaultEvent("io", 32.0, 12.0, 2.0)]
    t = scen.compose_trial(29, ev, duration_s=60.0, confuser_prob=0.0)
    diags = CorrelationEngine().process(t.ts, t.data, t.channels)
    assert diags
    v = scoring.verdict_events(diags)[0]
    assert v.t_ready == diags[0].t_ready
    # deterministic virtual stamp: t_rca adds wall clock on top
    assert diags[0].t_rca >= v.t_ready


def test_restart_windows_charge_downtime_to_latency():
    """A verdict whose virtual stamp falls inside a monitor-downtime
    window is charged the restore time; stamps outside are untouched."""
    truth = [scen.FaultEvent("nic", 30.0, 12.0, 2.0)]
    v = [scoring.VerdictEvent(t_onset=30.5, t_detect=35.0, t_ready=37.0,
                              pred=CauseClass.NIC)]
    plain = scoring.score_trial(truth, v)
    assert plain.detect_latencies == [5.0]
    assert plain.rca_latencies == [7.0]
    # downtime 33-40 s swallows both stamps -> both charged to 40 s
    s = scoring.score_trial(truth, v, restart_windows=[(33.0, 40.0)])
    assert s.detect_latencies == [10.0]
    assert s.rca_latencies == [10.0]
    assert s.n_matched == 1 and s.n_correct == 1
    # a window that closed before the stamps changes nothing
    s2 = scoring.score_trial(truth, v, restart_windows=[(20.0, 31.0)])
    assert s2.detect_latencies == plain.detect_latencies
    assert s2.rca_latencies == plain.rca_latencies
    # half-open [t0, t1): a stamp exactly at the restore time is live
    s3 = scoring.score_trial(truth, v, restart_windows=[(33.0, 35.0)])
    assert s3.detect_latencies == [5.0]


def test_restart_windows_do_not_affect_matching():
    """Windows shift latency charges only — match cardinality, precision
    and class accuracy are computed on the raw virtual stamps."""
    truth = [scen.FaultEvent("io", 30.0, 10.0, 2.0)]
    v = [scoring.VerdictEvent(t_onset=30.2, t_detect=34.0, t_ready=36.0,
                              pred=CauseClass.CPU)]
    a = scoring.score_trial(truth, v)
    b = scoring.score_trial(truth, v, restart_windows=[(33.0, 50.0)])
    assert (a.n_matched, a.n_correct) == (b.n_matched, b.n_correct)
