"""Distribution-layer tests.

Multi-device behaviour runs in a subprocess (device count is locked at
first jax init, so the main test process stays single-device).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.parallel.sharding import LOGICAL_RULES, logical_to_pspec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_logical_to_pspec_dedup():
    from jax.sharding import PartitionSpec as P
    rules = {"embed": "data", "mlp": "model", "heads": "model"}
    # duplicate mesh axis must be dropped from the second occurrence
    spec = logical_to_pspec(("embed", "mlp", "heads"), rules)
    assert spec == P("data", "model", None)


def test_make_rules_head_divisibility():
    import jax
    from repro.configs import get_config
    from repro.parallel.sharding import make_rules
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # single-device mesh: everything still resolves
    r = make_rules(get_config("starcoder2-7b"), mesh)
    assert isinstance(r, dict)


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P, NamedSharding
    from repro.configs import get_config
    from repro.launch.inputs import make_real_batch
    from repro.models.registry import build_model
    from repro.parallel.ctx import mesh_context
    from repro.parallel.sharding import make_rules, param_pspecs, logical_to_pspec
    from repro.train.optimizer import OptConfig
    from repro.train.step import build_train_step, init_train_state

    cfg = get_config("yi-9b", smoke=True)
    model = build_model(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    batch_np = make_real_batch(cfg, 8, 32, seed=3)

    # single-device reference
    state = init_train_state(model, jax.random.key(0), opt)
    step = jax.jit(build_train_step(model, opt))
    _, m_ref = step(state, {{k: jnp.asarray(v) for k, v in batch_np.items()}})
    loss_ref = float(m_ref["loss"])

    # sharded run on a 4x2 mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rules = make_rules(cfg, mesh)
    with mesh_context(mesh, rules):
        pspecs = param_pspecs(model.param_logical, rules)
        state2 = init_train_state(model, jax.random.key(0), opt)
        tok_sh = NamedSharding(mesh, logical_to_pspec(("act_batch", "act_seq"), rules))
        batch = {{k: jax.device_put(jnp.asarray(v), tok_sh)
                 for k, v in batch_np.items()}}
        step2 = jax.jit(build_train_step(model, opt))
        _, m_sh = step2(state2, batch)
        loss_sh = float(m_sh["loss"])
    print("RESULT", loss_ref, loss_sh)
    assert abs(loss_ref - loss_sh) < 0.05 * abs(loss_ref) + 0.05, (loss_ref, loss_sh)
""")


@pytest.mark.slow
def test_sharded_step_matches_single_device(tmp_path):
    script = tmp_path / "probe.py"
    script.write_text(_SUBPROC.format(src=os.path.abspath(SRC)))
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "RESULT" in out.stdout


@pytest.mark.slow
def test_dryrun_smoke_small_devices(tmp_path):
    """dryrun machinery end-to-end with 8 placeholder devices (the full
    512-device sweep runs via the launcher; this guards the plumbing)."""
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=os.path.abspath(SRC))
    script = textwrap.dedent("""
        import repro.launch.dryrun as dr
        import jax
        # shrink the production mesh to the debug size for this probe
        import repro.launch.mesh as mesh_mod
        mesh_mod.make_production_mesh = \
            lambda multi_pod=False: jax.make_mesh(
                (2, 2, 2) if multi_pod else (4, 2),
                ("pod", "data", "model") if multi_pod else ("data", "model"))
        rec = dr.run_cell("whisper-base", "train_4k", False, save=False)
        assert rec["status"] == "ok", rec
        rec2 = dr.run_cell("whisper-base", "train_4k", True, save=False)
        assert rec2["status"] == "ok", rec2
        print("DRYRUN-SMOKE-OK")
    """)
    p = tmp_path / "dr.py"
    p.write_text(script)
    out = subprocess.run([sys.executable, str(p)], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "DRYRUN-SMOKE-OK" in out.stdout
