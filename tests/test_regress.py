"""Regression gate + protocol-constant hoist: the committed scorecard
validates, tampering fails, and the eval protocol has ONE definition."""
import copy
import inspect
import json
import os

import pytest

from benchmarks import diagnostics, fleetbench, regress
from repro.sim import scenario

ARTIFACT = os.path.join(os.path.dirname(__file__), "..",
                        "EVAL_scorecard.json")


@pytest.fixture(scope="module")
def committed():
    with open(ARTIFACT) as f:
        return json.load(f)


def test_committed_scorecard_passes_gate(committed):
    assert regress.check_scorecard(committed, label="committed") == []


def test_committed_scorecard_meets_acceptance(committed):
    """>= 6 scenario classes with latency percentiles, >= 2 multi-fault,
    a no-fault soak, and every parity bit exactly 1.0."""
    scen_doc = committed["scenarios"]
    with_lat = [n for n, b in scen_doc.items() if b["detect_latency_s"]]
    assert len(with_lat) >= 6
    assert sum(1 for b in scen_doc.values() if b.get("multi_fault")) >= 2
    assert scen_doc["soak"]["n_verdicts"] == 0
    assert all(v == 1.0 for v in committed["parity"].values())
    for name in with_lat:
        assert set(scen_doc[name]["rca_latency_s"]) == {"p50", "p90", "max"}


def test_tampered_parity_fails(committed):
    doc = copy.deepcopy(committed)
    doc["parity"]["batched_ts"] = 0.9
    bad = regress.check_scorecard(doc, label="t")
    assert any("parity/batched_ts" in m for m in bad)


def test_tampered_soak_fails(committed):
    doc = copy.deepcopy(committed)
    doc["scenarios"]["soak"]["n_verdicts"] = 1
    doc["scenarios"]["soak"]["false_verdicts"] = 1
    bad = regress.check_scorecard(doc, label="t")
    assert any("soak" in m for m in bad)


def test_missing_parity_key_fails(committed):
    doc = copy.deepcopy(committed)
    del doc["parity"]["slab_ts"]
    bad = regress.check_scorecard(doc, label="t")
    assert any("parity/slab_ts missing" in m for m in bad)


def test_missing_class_fails(committed):
    doc = copy.deepcopy(committed)
    del doc["scenarios"]["cascade"]
    bad = regress.check_scorecard(doc, label="t")
    assert any("cascade" in m for m in bad)


def test_tampered_chaos_soak_fails(committed):
    doc = copy.deepcopy(committed)
    doc["scenarios"]["chaos_soak"]["n_verdicts"] = 2
    doc["scenarios"]["chaos_soak"]["false_verdicts"] = 2
    bad = regress.check_scorecard(doc, label="t")
    assert any("chaos_soak" in m and "false-positive" in m for m in bad)


def test_tampered_chaos_overlap_latency_fails(committed):
    doc = copy.deepcopy(committed)
    doc["scenarios"]["chaos_overlap"]["detect_latency_s"]["max"] = 9.5
    bad = regress.check_scorecard(doc, label="t")
    assert any("chaos_overlap detect_latency_s" in m for m in bad)


def test_tampered_chaos_overlap_recall_fails(committed):
    doc = copy.deepcopy(committed)
    doc["scenarios"]["chaos_overlap"]["recall"] = 0.5
    bad = regress.check_scorecard(doc, label="t")
    assert any("chaos_overlap recall" in m for m in bad)


def test_missing_chaos_class_fails(committed):
    doc = copy.deepcopy(committed)
    del doc["scenarios"]["frozen_channel"]
    doc["protocol"]["classes"] = [c for c in doc["protocol"]["classes"]
                                  if c != "frozen_channel"]
    bad = regress.check_scorecard(doc, label="t")
    assert any("frozen_channel" in m for m in bad)


def test_check_chaos_rows():
    good = [("chaos/soak_false_verdicts", 0.0, ""),
            ("chaos/masked_parity", 1.0, ""),
            ("chaos/sanitize_overhead_frac", 0.4, "")]
    assert regress.check_chaos_rows(good) == []
    bad = regress.check_chaos_rows(
        [("chaos/soak_false_verdicts", 1.0, "")] + good[1:])
    assert any("fault verdict" in m for m in bad)
    bad = regress.check_chaos_rows(
        good[:1] + [("chaos/masked_parity", 0.0, "")] + good[2:])
    assert any("byte-identical" in m for m in bad)
    bad = regress.check_chaos_rows(
        good[:2] + [("chaos/sanitize_overhead_frac",
                     regress.SANITIZE_OVERHEAD_MAX + 1.0, "")])
    assert any("sanitization cost" in m for m in bad)
    missing = regress.check_chaos_rows(good[1:])
    assert any("no row matched chaos/soak_false_verdicts" in m
               for m in missing)


def test_tampered_overlap_recall_fails(committed):
    """Dropping either overlap class back to one-verdict-per-incident
    recall (the single-pending detector's ~0.5) must fail the gate."""
    for name in regress.OVERLAP_CLASSES:
        doc = copy.deepcopy(committed)
        doc["scenarios"][name]["recall"] = 0.5
        bad = regress.check_scorecard(doc, label="t")
        assert any(f"{name} recall" in m for m in bad), name
        doc["scenarios"][name]["recall"] = None
        bad = regress.check_scorecard(doc, label="t")
        assert any(f"{name} recall" in m for m in bad), name


def test_committed_overlap_recall_meets_floor(committed):
    for name in regress.OVERLAP_CLASSES:
        assert committed["scenarios"][name]["recall"] >= \
            regress.OVERLAP_RECALL_MIN


def test_tampered_replay_parity_fails(committed):
    doc = copy.deepcopy(committed)
    doc["parity"]["replay"] = 0.75
    bad = regress.check_scorecard(doc, label="t")
    assert any("parity/replay" in m for m in bad)


def test_missing_replay_parity_fails(committed):
    doc = copy.deepcopy(committed)
    del doc["parity"]["replay"]
    bad = regress.check_scorecard(doc, label="t")
    assert any("parity/replay missing" in m for m in bad)


def test_tampered_restart_duplicates_fails(committed):
    doc = copy.deepcopy(committed)
    doc["restart"]["restart_duplicates"] = 2
    bad = regress.check_scorecard(doc, label="t")
    assert any("restart_duplicates" in m for m in bad)


def test_missing_restart_block_fails(committed):
    doc = copy.deepcopy(committed)
    doc["restart"] = None
    bad = regress.check_scorecard(doc, label="t")
    assert any("restart block missing" in m for m in bad)


def test_tampered_crash_latency_fails(committed):
    doc = copy.deepcopy(committed)
    doc["scenarios"]["crash_during_incident"]["detect_latency_s"]["max"] = \
        regress.CRASH_DETECT_MAX_S + 1.0
    bad = regress.check_scorecard(doc, label="t")
    assert any("crash_during_incident detect_latency_s" in m for m in bad)


def test_check_restart_rows():
    good = [("restart/fleet_replay_parity", 1.0, ""),
            ("restart/duplicate_verdicts", 0.0, ""),
            ("restart/shed_rounds", 3.0, ""),
            ("restart/deferred_rca", 1.0, ""),
            ("restart/rearmed", 1.0, "")]
    assert regress.check_restart_rows(good) == []
    bad = regress.check_restart_rows(
        [("restart/fleet_replay_parity", 0.5, "")] + good[1:])
    assert any("diverged" in m for m in bad)
    bad = regress.check_restart_rows(
        good[:1] + [("restart/duplicate_verdicts", 1.0, "")] + good[2:])
    assert any("re-delivered" in m for m in bad)
    bad = regress.check_restart_rows(
        good[:2] + [("restart/shed_rounds", 0.0, "")] + good[3:])
    assert any("never shed" in m for m in bad)
    bad = regress.check_restart_rows(
        good[:3] + [("restart/deferred_rca", 0.0, "")] + good[4:])
    assert any("deferred" in m for m in bad)
    bad = regress.check_restart_rows(
        good[:4] + [("restart/rearmed", 0.0, "")])
    assert any("stuck degraded" in m for m in bad)
    missing = regress.check_restart_rows(good[1:])
    assert any("no row matched restart/fleet_replay_parity" in m
               for m in missing)


def test_check_bench_parity_rows():
    good = [("fleet/detect_parity/B8", 1.0, ""),
            ("fleet/shard_parity", 1.0, ""),
            ("fleet/incremental_parity", 1.0, ""),
            ("eval/pred_parity", 1.0, ""),
            ("eval/store_pred_parity", 1.0, ""),
            ("eval/sweep_parity", 1.0, "")]
    assert regress.check_bench_parity(good) == []
    bad = regress.check_bench_parity(
        [("fleet/detect_parity/B8", 0.5, "")] + good[1:])
    assert any("detect_parity" in m for m in bad)
    missing = regress.check_bench_parity(good[:4] + good[5:])
    assert any("store_pred_parity" in m for m in missing)


def test_tampered_shard_parity_fails():
    """The sharded-vs-single-slab fingerprint bit is gated: a sharded
    round that drifts from the single-slab verdict must fail CI, and so
    must a run that silently stops emitting the row."""
    rows = [("fleet/detect_parity/B8", 1.0, ""),
            ("fleet/shard_parity", 0.0, ""),
            ("fleet/incremental_parity", 1.0, ""),
            ("eval/pred_parity", 1.0, ""),
            ("eval/store_pred_parity", 1.0, ""),
            ("eval/sweep_parity", 1.0, "")]
    bad = regress.check_bench_parity(rows)
    assert any("fleet/shard_parity" in m for m in bad)
    gone = regress.check_bench_parity(rows[:1] + rows[2:])
    assert any("no row matched fleet/shard_parity" in m for m in gone)


def test_tampered_sweep_parity_fails():
    """The slab detection sweep's byte-exact bit is gated: a drifted
    sweep (events or timestamps off the per-row oracle) must fail CI."""
    rows = [("fleet/detect_parity/B8", 1.0, ""),
            ("fleet/incremental_parity", 1.0, ""),
            ("eval/pred_parity", 1.0, ""),
            ("eval/store_pred_parity", 1.0, ""),
            ("eval/sweep_parity", 0.5, "")]
    bad = regress.check_bench_parity(rows)
    assert any("eval/sweep_parity" in m for m in bad)
    # and a run that silently stops emitting the row fails too
    gone = regress.check_bench_parity(rows[:4])
    assert any("eval/sweep_parity" in m for m in gone)


def test_protocol_constants_single_definition():
    """The 17-per-class protocol exists in exactly one place; the
    benchmarks reference it instead of restating it."""
    assert scenario.N_PER_CLASS == 17
    assert tuple(scenario.PROTOCOL_CLASSES) == ("io", "cpu", "nic", "gpu")
    sig = inspect.signature(scenario.run_eval)
    assert sig.parameters["n_per_class"].default == scenario.N_PER_CLASS
    assert tuple(sig.parameters["classes"].default) == \
        tuple(scenario.PROTOCOL_CLASSES)
    # the benchmarks import the constants rather than hard-coding them
    assert fleetbench.N_PER_CLASS == scenario.N_PER_CLASS
    assert tuple(fleetbench.PROTOCOL_CLASSES) == \
        tuple(scenario.PROTOCOL_CLASSES)
    assert (inspect.signature(diagnostics._records).parameters["n"].default
            == scenario.N_PER_CLASS)


def test_cooldown_constant_single_definition():
    """The verdict cooldown has ONE definition (engine.COOLDOWN_S):
    EngineConfig defaults to it, the scorer's match tolerance derives
    from it, and the fleet session's (host, cause) dedup horizon inherits
    it through the engine config — nothing restates the number."""
    import dataclasses

    from repro.core import engine
    from repro.monitor.checkpoint import MonitorSession
    from repro.monitor.fleet import FleetMonitor
    from repro.sim import scoring

    fields = {f.name: f for f in dataclasses.fields(engine.EngineConfig)}
    assert fields["cooldown_s"].default == engine.COOLDOWN_S
    assert scoring.TOL_S == engine.COOLDOWN_S / 2.0
    # the session's default dedup horizon follows the config, not a copy
    cfg = engine.EngineConfig(cooldown_s=engine.COOLDOWN_S + 7.0)
    sess = MonitorSession(FleetMonitor(cfg, use_kernels=False),
                          ["coll_allreduce_ms"])
    assert sess.cooldown_s == cfg.cooldown_s


def test_tampered_incremental_parity_fails():
    """The incremental-vs-from-scratch moment bit is gated: a carried
    state that drifts from the re-anchor rebuild (or a verdict split
    against the direct monitor) must fail CI, and so must a run that
    silently stops emitting the row."""
    rows = [("fleet/detect_parity/B8", 1.0, ""),
            ("fleet/shard_parity", 1.0, ""),
            ("fleet/incremental_parity", 0.0, ""),
            ("eval/pred_parity", 1.0, ""),
            ("eval/store_pred_parity", 1.0, ""),
            ("eval/sweep_parity", 1.0, "")]
    bad = regress.check_bench_parity(rows)
    assert any("fleet/incremental_parity" in m and "0.0" in m for m in bad)
    gone = regress.check_bench_parity(rows[:2] + rows[3:])
    assert any("no row matched fleet/incremental_parity" in m
               for m in gone)


def test_committed_bench_artifact_gated():
    """The committed BENCH_fleet.json is validated too: a hand-edited
    parity value or a deleted parity row fails the gate even when this
    commit's code is healthy."""
    with open(os.path.join(os.path.dirname(__file__), os.pardir,
                           "BENCH_fleet.json")) as f:
        doc = json.load(f)
    assert regress.check_committed_bench(doc, label="BENCH_fleet.json") \
        == []
    tampered = copy.deepcopy(doc)
    tampered["fleet/incremental_parity"]["value"] = 0.5
    bad = regress.check_committed_bench(tampered, label="BENCH_fleet.json")
    assert any("BENCH_fleet.json" in m and "incremental_parity" in m
               for m in bad)
    removed = copy.deepcopy(doc)
    del removed["fleet/incremental_parity"]
    gone = regress.check_committed_bench(removed, label="BENCH_fleet.json")
    assert any("no row matched fleet/incremental_parity" in m
               for m in gone)
