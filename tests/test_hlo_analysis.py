"""Validation of the trip-count-corrected HLO roofline analyzer —
the measurement layer every §Roofline number depends on."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze, parse_computations, trip_count


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


def test_scan_trip_count_multiplied():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    c = _compile(f, (128, 128), (128, 128))
    cost = analyze(c.as_text())
    # 10 matmuls of 2*128^3 flops
    assert cost.flops == pytest.approx(10 * 2 * 128 ** 3, rel=1e-6)


def test_nested_scans():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ w), None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y
    c = _compile(g, (128, 128), (128, 128))
    assert analyze(c.as_text()).flops == pytest.approx(
        15 * 2 * 128 ** 3, rel=1e-6)


def test_grad_of_scan():
    def h(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return jnp.sum(y * y)
    c = _compile(jax.grad(h), (128, 128), (128, 128))
    # 10 fwd + 20 bwd matmuls
    assert analyze(c.as_text()).flops == pytest.approx(
        30 * 2 * 128 ** 3, rel=1e-6)


def test_against_xla_cost_analysis_unrolled():
    """For a loop-free program the analyzer must agree with XLA's count."""
    def f(x, w):
        y = x
        for _ in range(4):
            y = y @ w
        return y
    c = _compile(f, (256, 256), (256, 256))
    ours = analyze(c.as_text()).flops
    ca = c.cost_analysis()
    if isinstance(ca, list):          # newer jaxlib returns one dict/device
        ca = ca[0]
    xla = ca["flops"]
    assert ours == pytest.approx(xla, rel=1e-6)


def test_trip_count_parse():
    def f(x):
        def body(c, _):
            return c + 1.0, None
        y, _ = jax.lax.scan(body, x, None, length=37)
        return y
    c = _compile(f, (8,))
    comps, _ = parse_computations(c.as_text())
    counts = [trip_count(comp) for name, comp in comps.items()
              if "cond" in name or "region_1" in name]
    assert 37 in counts
