import numpy as np
import pytest

from repro.core.xcorr import lagged_xcorr, max_abs_xcorr


def test_recovers_known_lag():
    rng = np.random.default_rng(0)
    N, K, lag = 600, 20, 7
    sig = rng.normal(0, 1, N + K)
    L = sig[:N]
    # metric leads latency by `lag` samples: M(t + lag) ~ L(t)
    M = np.stack([sig[lag:N + lag], rng.normal(0, 1, N)])
    c, lags = max_abs_xcorr(L, M, max_lag=K)
    assert lags[0] == lag
    assert c[0] > 0.9
    assert c[1] < 0.4


def test_bounded_by_one():
    rng = np.random.default_rng(1)
    L = rng.normal(0, 1, 400)
    M = rng.normal(0, 1, (8, 400))
    rho = lagged_xcorr(L, M, 20)
    assert np.all(np.abs(rho) <= 1.0 + 1e-9)


def test_zero_lag_is_pearson():
    rng = np.random.default_rng(2)
    L = rng.normal(0, 1, 500)
    M = (2 * L + rng.normal(0, 0.1, 500))[None]
    rho = lagged_xcorr(L, M, 5)
    pearson = np.corrcoef(L, M[0])[0, 1]
    assert rho[0, 5] == pytest.approx(pearson, abs=1e-6)


def test_scale_shift_invariance():
    rng = np.random.default_rng(3)
    L = rng.normal(5, 2, 500)
    M = rng.normal(0, 1, (3, 500))
    r1 = lagged_xcorr(L, M, 10)
    r2 = lagged_xcorr(L * 3 + 100, M * 0.01 - 5, 10)
    np.testing.assert_allclose(r1, r2, atol=1e-8)


def test_anticorrelation_detected():
    rng = np.random.default_rng(4)
    L = rng.normal(0, 1, 500)
    M = (-L)[None]
    c, lags = max_abs_xcorr(L, M, 10)
    assert c[0] > 0.99
    assert lags[0] == 0
