"""Concurrent incident hypotheses: Layer-2 multi-hypothesis detection,
Layer-3 reconciliation, K=1 degeneracy, and fleet multi-cause verdicts.

The refactor's contract, end to end: a second fault arriving during an
active incident opens a second hypothesis instead of dying in the
cooldown; reconciliation attributes each matured hypothesis to a distinct
cause (or suppresses the continuation phantom); with ``max_hypotheses=1``
every stream is byte-identical to the single-pending machine's.
"""
import numpy as np
import pytest

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.reconcile import CO_GAP, symptom_table
from repro.core.taxonomy import CauseClass
from repro.monitor.fleet import FleetMonitor
from repro.sim import scoring
from repro.sim.scenario import protocol_seed
from repro.sim.scenarios import SCENARIO_CLASSES, make_scenario

SEED = 41


def _trial(cls, k=0, seed=SEED):
    ci = SCENARIO_CLASSES.index(cls)
    return make_scenario(protocol_seed(seed, ci, k), cls)[0]


# ------------------------------------------------------------------ Layer 2
def test_second_fault_opens_second_hypothesis():
    """overlap_pair: the second fault's step fires INSIDE the first
    incident's cooldown and must still produce its own detection."""
    eng = CorrelationEngine()
    hit = 0
    for k in range(4):
        t = _trial("overlap_pair", k)
        evs = eng.detect_events(np.asarray(t.ts), t.data, t.channels)
        if len(evs) >= 2:
            gaps = [b[0].t_detect - a[0].t_detect
                    for a, b in zip(evs, evs[1:])]
            hit += any(0.0 < g < eng.cfg.cooldown_s for g in gaps)
    assert hit >= 2, "no trial detected a second fault inside the cooldown"


def test_hypothesis_count_bounded():
    eng = CorrelationEngine()
    for cls in ("flap", "cascade", "overlap_full"):
        for k in range(4):
            t = _trial(cls, k)
            evs = eng.detect_events(np.asarray(t.ts), t.data, t.channels)
            # no two emissions may share a detection tick, and the live
            # set can never exceed max_hypotheses concurrent accumulations
            detects = [e.t_detect for e, _ in evs]
            assert len(detects) == len(set(detects))


def test_k1_degeneracy_single_fault_byte_identical():
    """On single-fault timelines a K=3 engine's detection stream equals a
    K=1 engine's byte for byte — the step gate never opens a phantom."""
    eng3 = CorrelationEngine(EngineConfig(max_hypotheses=3))
    eng1 = CorrelationEngine(EngineConfig(max_hypotheses=1))
    sig = lambda evs: [(e.t_onset, e.t_detect, e.score, int(r))
                       for e, r in evs]
    for cls in ("single", "soak"):
        for k in range(4):
            t = _trial(cls, k)
            ts = np.asarray(t.ts)
            assert sig(eng3.detect_events(ts, t.data, t.channels)) == \
                sig(eng1.detect_events(ts, t.data, t.channels))


def test_k1_degeneracy_verdict_stream_identical():
    """process() with K=1 skips reconciliation entirely: verdict streams
    on single-fault trials match the K=3 engine's exactly."""
    eng3 = CorrelationEngine()
    eng1 = CorrelationEngine(EngineConfig(max_hypotheses=1))
    sig = lambda ds: [(d.top_cause, d.event.t_onset, d.event.t_detect,
                       d.t_ready) for d in ds]
    for k in range(4):
        t = _trial("single", k)
        assert sig(eng3.process(t.ts, t.data, t.channels)) == \
            sig(eng1.process(t.ts, t.data, t.channels))


# ------------------------------------------------------- Layer 3 attribution
@pytest.mark.parametrize("cls", ["overlap_pair", "overlap_full"])
def test_overlap_verdicts_cover_both_causes(cls):
    """Every concurrent fault earns a verdict with ITS cause — recall and
    accuracy 1.0 over the suite seed's trials."""
    eng = CorrelationEngine()
    scores = []
    for k in range(4):
        t = _trial(cls, k)
        diags = eng.process(t.ts, t.data, t.channels)
        scores.append(scoring.score_trial(
            t.truth, scoring.verdict_events(diags)))
    s = scoring.summarize(scores)
    assert s["recall"] == 1.0, s
    assert s["accuracy"] == 1.0, s
    assert s["false_verdicts"] == 0, s


def test_verdict_causes_distinct_within_incident():
    """Reconciliation never emits the same cause twice for one incident."""
    eng = CorrelationEngine()
    for cls in ("overlap_pair", "overlap_full", "cascade", "flap"):
        for k in range(4):
            t = _trial(cls, k)
            diags = eng.process(t.ts, t.data, t.channels)
            cool = eng.cfg.cooldown_s
            seen: list = []
            for d in diags:
                # causes repeat only across incidents (a cooldown apart)
                for prev_t, prev_c in seen:
                    if prev_c == d.top_cause:
                        assert d.event.t_detect - prev_t >= cool
                seen.append((d.event.t_detect, d.top_cause))


def test_soak_emits_nothing():
    eng = CorrelationEngine()
    for k in range(4):
        t = _trial("soak", k)
        assert eng.process(t.ts, t.data, t.channels) == []


def test_symptom_table_covers_all_interference_causes():
    tab = symptom_table()
    assert set(tab) == {CauseClass.NIC, CauseClass.CPU, CauseClass.IO,
                        CauseClass.GPU}
    assert set(CO_GAP) == set(tab)
    for chans in tab.values():
        assert all(floor > 0 for _, floor in chans)


# ----------------------------------------------------------------- fleet
def _fleet_slab():
    quiet = _trial("soak", 0, seed=7)
    hot = _trial("overlap_full", 0)
    on = int(hot.truth[0].t_on * 100)
    T = on + 400             # onset inside the trailing detection window
    slab = np.stack([np.asarray(quiet.data)[:, :T],
                     np.asarray(hot.data)[:, :T]]).astype(np.float32)
    return np.asarray(hot.ts)[:T], slab, hot.channels, hot.truth


def test_fleet_multi_cause_verdict_lists():
    """A host under two overlapping faults carries both causes in its
    verdict list, primary first; with K=1 the list is primary-only."""
    ts, slab, channels, truth = _fleet_slab()
    fd = FleetMonitor(EngineConfig()).diagnose_fleet(ts, slab, channels)
    assert fd.flagged_hosts == [1]
    causes = fd.causes[1]
    assert causes[0] == fd.diagnoses[1].top_cause
    assert set(causes) == {e.kind for e in truth}
    assert len(causes) == len(set(causes))

    fd1 = FleetMonitor(EngineConfig(max_hypotheses=1)).diagnose_fleet(
        ts, slab, channels)
    assert fd1.causes[1] == [fd1.diagnoses[1].top_cause]


def test_fleet_causes_parity_fast_vs_oracle():
    """The co-cause corroboration runs in f64 on both detect paths, so
    the fast f32 gather and the f64 oracle agree on every cause list."""
    ts, slab, channels, _ = _fleet_slab()
    fa = FleetMonitor(EngineConfig(), fast_detect=True,
                      use_kernels=False).diagnose_fleet(ts, slab, channels)
    fb = FleetMonitor(EngineConfig(), fast_detect=False,
                      use_kernels=False).diagnose_fleet(ts, slab, channels)
    assert fa.causes == fb.causes
