"""The documentation layer is load-bearing: the docs-lint floors CI
enforces, the docs/ tree's existence and README linkage, and the
TUNING.md ↔ tuning.py knob inventory staying in sync."""
import os
import re
import sys
import textwrap

REPO = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(REPO, "tools"))

import docs_lint  # noqa: E402


def _read(*parts):
    with open(os.path.join(REPO, *parts)) as f:
        return f.read()


def test_monitor_package_fully_documented():
    """The CI gate's 100% floor on monitor/ holds from tier-1 too, with
    the missing names in the failure message."""
    records = docs_lint.collect([os.path.join(REPO, "src/repro/monitor")])
    missing = [f"{r[0]}:{r[1]} {r[3]}" for r in records if not r[4]]
    assert docs_lint.coverage(records) == 100.0, missing


def test_tree_wide_coverage_floor():
    """The whole-tree floor CI pins (65%) — raising docs coverage is
    fine, silently shedding it is not."""
    paths = [os.path.join(REPO, p) for p in ("src", "benchmarks", "tools")]
    assert docs_lint.coverage(docs_lint.collect(paths)) >= 65.0


def test_docs_lint_flags_undocumented(tmp_path):
    """The linter actually counts: a bare public function fails a 100%
    gate, documenting it passes, private/nested defs are skipped."""
    mod = tmp_path / "m.py"
    mod.write_text(textwrap.dedent('''\
        """Module docstring."""
        def documented():
            """Yes."""
            def nested():   # implementation detail, not counted
                pass
        def bare():
            pass
        def _private():
            pass
    '''))
    records = docs_lint.collect([str(mod)])
    names = {r[3] for r in records}
    assert names == {"m.py", "documented", "bare"}
    assert docs_lint.coverage(records) < 100.0
    assert docs_lint.main([str(mod), "--fail-under", "100"]) == 1
    mod.write_text(mod.read_text().replace(
        'def bare():\n    pass', 'def bare():\n    """Now."""'))
    assert docs_lint.main([str(mod), "--fail-under", "100"]) == 0


def test_docs_tree_linked_from_readme():
    readme = _read("README.md")
    for doc in ("ARCHITECTURE", "TUNING", "OPERATIONS"):
        assert os.path.exists(os.path.join(REPO, "docs", f"{doc}.md")), doc
        assert f"docs/{doc}.md" in readme, doc


def test_tuning_doc_covers_every_env_knob():
    """Every REPRO_* env var the code reads is documented in TUNING.md
    (and vice versa no stale knob survives in the doc)."""
    src = ""
    for root, _, names in os.walk(os.path.join(REPO, "src")):
        for n in names:
            if n.endswith(".py"):
                src += _read(os.path.relpath(os.path.join(root, n), REPO))
    knobs_in_code = set(re.findall(r'"(REPRO_[A-Z_]+)"', src))
    assert knobs_in_code, "expected at least the tuning.py knobs"
    doc = _read("docs", "TUNING.md")
    knobs_in_doc = set(re.findall(r"`(REPRO_[A-Z_]+)`", doc))
    assert knobs_in_code == knobs_in_doc


def test_shard_parity_row_documented():
    """The CI-gated shard parity bit is discoverable from the README's
    CI section and the operations runbook."""
    assert "fleet/shard_parity" in _read("README.md")
    assert "fleet/shard_parity" in _read("docs", "OPERATIONS.md")
