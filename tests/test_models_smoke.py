"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, output shapes + finiteness; one decode step w/ cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_CONFIGS, get_config
from repro.launch.inputs import make_real_batch
from repro.models.registry import build_model

ARCHS = sorted(ALL_CONFIGS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.key(0)


@pytest.mark.parametrize("name", ARCHS)
def test_forward_and_loss(name, rng):
    cfg = get_config(name, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    B, S = 2, 64
    batch = {k: jnp.asarray(v)
             for k, v in make_real_batch(cfg, B, S, seed=1).items()}
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{name}: non-finite loss"
    # loss should be near ln(vocab_padded) at init
    assert 1.0 < float(loss) < np.log(cfg.vocab_padded) + 3.0


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name, rng):
    cfg = get_config(name, smoke=True)
    model = build_model(cfg)
    params = model.init(rng)
    B = 2
    cache = model.init_cache(B, 64)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode)(params, tok, cache)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache position advanced
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nans(name, rng):
    from repro.train.optimizer import OptConfig
    from repro.train.step import build_train_step, init_train_state
    cfg = get_config(name, smoke=True)
    model = build_model(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=1)
    state = init_train_state(model, rng, opt)
    step = jax.jit(build_train_step(model, opt))
    batch = {k: jnp.asarray(v)
             for k, v in make_real_batch(cfg, 2, 32, seed=2).items()}
    state, m = step(state, batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(jnp.isfinite(m["grad_norm"])) and float(m["grad_norm"]) > 0
    assert int(state.step) == 1


def test_param_counts_full_configs():
    """Full-config parameter counts are in the right ballpark (catches
    wiring errors in specs without instantiating weights)."""
    expect = {
        "grok-1-314b": (250e9, 400e9),
        "mixtral-8x7b": (40e9, 55e9),
        "starcoder2-7b": (6e9, 9e9),
        "phi4-mini-3.8b": (3e9, 6e9),
        "nemotron-4-340b": (280e9, 400e9),
        "yi-9b": (8e9, 11e9),
        "jamba-v0.1-52b": (45e9, 60e9),
        "whisper-base": (50e6, 200e6),
        "mamba2-370m": (300e6, 500e6),
        "paligemma-3b": (2e9, 4e9),
    }
    for name, (lo, hi) in expect.items():
        n = build_model(get_config(name)).param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]B"
