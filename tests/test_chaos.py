"""Chaos-hardening tests: a broken probe must never read as a broken host.

Covers the chaos DSL (sim.chaos), the sanitize layer, the masked
detection paths (sweep_rows / sweep_rows_exact / kernel / slab vs the f64
oracle in core.spike), agent crash isolation + watchdog + clock/counter
guards, the bounded seqlock reader, aggregator validity staging, and the
FleetMonitor telemetry quarantine — plus the clean-path contract: with an
all-true mask every path is byte-identical to the unmasked one.
"""
import numpy as np
import pytest

from repro.core import sanitize
from repro.core import spike
from repro.core.engine import MIN_BASELINE_N, CorrelationEngine, EngineConfig
from repro.kernels.detect import ops as detect_ops
from repro.kernels.sweep import ops as sweep_ops
from repro.monitor.aggregator import FleetAggregator
from repro.monitor.fleet import FleetMonitor, Mitigation
from repro.sim import chaos
from repro.sim import scenarios as scen
from repro.sim.chaos import ChaosCollector, ChaosEvent, ChaosPolicy
from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import Collector, SimCollector
from repro.telemetry.ringbuffer import MultiChannelRing
from repro.telemetry.schema import (
    LATENCY_METRIC, CauseClass, MetricSpec, SignalGroup,
)


# --------------------------------------------------------------- chaos DSL

def test_chaos_event_validation():
    with pytest.raises(ValueError):
        ChaosEvent("gremlin", 1.0, 2.0)
    ev = ChaosEvent("nan", 1.0, 2.0, channel="x")
    assert ev.t_off == 3.0
    assert ev.active(1.0) and ev.active(2.999) and not ev.active(3.0)


def test_chaos_policy_compose_and_overlap():
    a = ChaosPolicy((ChaosEvent("drop", 5.0, 1.0),))
    b = ChaosPolicy((ChaosEvent("nan", 1.0, 1.0, channel="x"),))
    p = a.compose(b)
    assert [e.t_on for e in p.events] == [1.0, 5.0]     # time-sorted
    assert p.overlaps(5.5, 7.0) and not p.overlaps(2.5, 4.5)
    assert [e.kind for e in p.active(1.5)] == ["nan"]
    assert p.active(1.5, kinds=("drop",)) == []


def test_apply_chaos_ground_truth_mask():
    rate = 10.0
    C, T = 3, 100
    data = np.full((C, T), 5.0)
    chans = ["a", "b", "c"]
    events = [
        ChaosEvent("nan", 1.0, 0.5, channel="a"),
        ChaosEvent("inf", 2.0, 0.5, channel="b", magnitude=-1.0),
        ChaosEvent("freeze", 3.0, 1.0, channel="c", magnitude=1.0),
        ChaosEvent("drop", 6.0, 0.5),
        ChaosEvent("exception", 8.0, 0.5),          # behavioral: no-op here
    ]
    hit = chaos.apply_chaos(data, chans, rate, events)
    assert np.isnan(data[0, 10:15]).all() and hit[0, 10:15].all()
    assert (data[1, 20:25] == -np.inf).all()
    assert (data[2, 30:40] == 10.0).all()           # 5 * (1 + magnitude)
    assert np.isnan(data[:, 60:65]).all() and hit[:, 60:65].all()
    assert hit[:, 80:85].sum() == 0                 # behavioral kinds ignored
    clean = ~hit
    assert np.isfinite(data[clean]).all() and (data[clean] == 5.0).all()


def test_apply_clock_jumps():
    ts = np.arange(0.0, 10.0, 1.0)
    out = chaos.apply_clock_jumps(
        ts, [ChaosEvent("clock_jump", 5.0, 0.0, magnitude=-2.0)])
    np.testing.assert_array_equal(out[:5], ts[:5])
    np.testing.assert_array_equal(out[5:], ts[5:] - 2.0)
    assert out is not ts and (np.diff(out) <= 0).any()


# ---------------------------------------------------------------- sanitize

def test_validity_mask_clean_is_none():
    x = np.random.default_rng(0).normal(10.0, 1.0, (4, 256))
    assert sanitize.validity_mask(x) is None


def test_validity_mask_flags_nonfinite_and_freeze():
    rng = np.random.default_rng(1)
    x = rng.normal(10.0, 1.0, 512)
    x[10] = np.nan
    x[20] = np.inf
    n = sanitize.FREEZE_RUN_N
    x[100:100 + n + 5] = 42.0                       # frozen run >= run_n
    x[300:300 + n // 2] = 43.0                      # short run: legitimate
    v = sanitize.validity_mask(x)
    assert v is not None
    assert not v[10] and not v[20]
    # the WHOLE run is retroactively invalid, head included — a frozen
    # baseline must not poison the sigma floor
    assert not v[100:100 + n + 5].any()
    assert v[300:300 + n // 2].all()


def test_forward_fill_contract():
    x = np.random.default_rng(2).normal(0.0, 1.0, (3, 64))
    assert sanitize.forward_fill(x) is x            # clean: same object
    y = x.copy()
    y[0, 10] = np.nan
    y[1, 0] = np.nan                                # leading hole: backfill
    y[2, :] = np.nan                                # dead row: zeros
    f = sanitize.forward_fill(y)
    assert np.isfinite(f).all()
    assert f[0, 10] == y[0, 9]
    assert f[1, 0] == y[1, 1]
    assert (f[2] == 0.0).all()


def test_min_valid_baseline_pinned_to_engine():
    # the masked oracle's baseline gate mirrors the engine's short-window
    # skip; the two constants drifting apart would let one path fire on a
    # micro-baseline the other refuses
    assert spike.MIN_VALID_BASELINE_N == MIN_BASELINE_N


# ------------------------------------------- masked sweep paths vs oracle

def _poisoned_slab(R=6, wn=64, bn=256, seed=3):
    rng = np.random.default_rng(seed)
    T = bn + 4 * wn
    lat = rng.normal(10.0, 1.0, (R, T))
    lat[2, bn + wn:bn + 2 * wn] += 8.0              # genuine spike
    lat[4, bn + 2 * wn:bn + 2 * wn + 20] += 8.0     # spike we then poison
    valid = np.ones((R, T), bool)
    hit = chaos.apply_chaos(
        lat, [f"r{i}" for i in range(R)], 1.0,
        [ChaosEvent("nan", bn + 2 * wn, 20.0, channel="r4"),
         ChaosEvent("freeze", 50.0, 100.0, channel="r1", magnitude=1.5),
         ChaosEvent("inf", float(bn), 10.0, channel="r3")])
    valid &= ~hit
    lat = np.where(valid, lat, np.nan)              # poison is non-finite
    ticks = np.arange(wn + bn, T + 1, wn)
    return lat, valid, ticks, wn, bn


def _oracle_rows(lat, valid, ticks, wn, bn, persistence=0.2):
    R = lat.shape[0]
    fire = np.zeros((R, ticks.size), bool)
    for r in range(R):
        fire[r], _, _ = spike.detect_sweep_masked(
            np.nan_to_num(lat[r]), valid[r], wn, bn, ticks,
            persistence=persistence)
    return fire


@pytest.mark.parametrize("use_kernel", [False, True])
def test_sweep_rows_masked_matches_oracle(use_kernel):
    lat, valid, ticks, wn, bn = _poisoned_slab()
    staged = np.nan_to_num(lat)
    want = _oracle_rows(staged, valid, ticks, wn, bn)
    fire, score, onset, _ = sweep_ops.sweep_rows(
        staged, wn, bn, ticks, persistence=0.2, valid=valid,
        use_kernel=use_kernel, interpret=True)
    np.testing.assert_array_equal(fire, want)
    assert fire[2].any()                            # clean spike still fires
    assert not fire[4].any()                        # poisoned spike quiet
    assert not fire[1].any() and not fire[3].any()


def test_sweep_rows_exact_masked_matches_oracle():
    lat, valid, ticks, wn, bn = _poisoned_slab()
    staged = np.nan_to_num(lat)
    fire, score, onset = sweep_ops.sweep_rows_exact(
        staged, wn, bn, ticks, persistence=0.2, valid=valid)
    for r in range(staged.shape[0]):
        f, s, o = spike.detect_sweep_masked(
            staged[r], valid[r], wn, bn, ticks, persistence=0.2)
        np.testing.assert_array_equal(fire[r], f)
        fired = np.flatnonzero(f)
        np.testing.assert_array_equal(score[r, fired], s[fired])
        np.testing.assert_array_equal(onset[r, fired], o[fired])


def test_sweep_rows_all_true_mask_byte_identical():
    rng = np.random.default_rng(5)
    wn, bn = 64, 256
    T = bn + 3 * wn
    lat = rng.normal(10.0, 1.0, (5, T))
    lat[1, bn + wn:bn + 2 * wn] += 8.0
    ticks = np.arange(wn + bn, T + 1, wn)
    ones = np.ones_like(lat, bool)
    a = sweep_ops.sweep_rows(lat, wn, bn, ticks, persistence=0.2)
    b = sweep_ops.sweep_rows(lat, wn, bn, ticks, persistence=0.2, valid=ones)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
    ea = sweep_ops.sweep_rows_exact(lat, wn, bn, ticks, persistence=0.2)
    eb = sweep_ops.sweep_rows_exact(lat, wn, bn, ticks, persistence=0.2,
                                    valid=ones)
    for x, y in zip(ea, eb):
        np.testing.assert_array_equal(x, y)


def test_masked_baseline_gate_refuses_thin_baselines():
    # with fewer than MIN_VALID_BASELINE_N valid baseline samples even a
    # monster spike stays quiet — a sigma-floored micro-baseline lies
    rng = np.random.default_rng(7)
    wn, bn = 64, 256
    T = bn + 2 * wn
    lat = rng.normal(10.0, 1.0, (1, T))
    lat[0, bn:] += 50.0
    valid = np.ones((1, T), bool)
    valid[0, :spike.MIN_VALID_BASELINE_N - 1] = False
    ticks = np.array([wn + bn])
    fire, _, _ = sweep_ops.sweep_rows_exact(lat, wn, bn, ticks, valid=valid)
    assert fire.any()                               # 31 invalid: still >= gate
    valid[0, :bn] = False
    valid[0, bn - spike.MIN_VALID_BASELINE_N + 1:bn] = True   # only 31 valid
    fire, score, _ = sweep_ops.sweep_rows_exact(lat, wn, bn, ticks,
                                                valid=valid)
    assert not fire.any() and (score == 0.0).all()


def test_detect_hosts_slab_masked_matches_rows_oracle():
    rng = np.random.default_rng(9)
    H, wn, bn = 4, 64, 256
    tail = rng.normal(10.0, 1.0, (H, bn + wn))
    tail[1, bn:] += 8.0                             # clean straggler
    tail[2, bn:] += 8.0                             # straggler, poisoned win
    valid = np.ones((H, bn + wn), bool)
    valid[2, bn:] = False
    valid[3, 100:110] = False                       # benign baseline nicks
    f, s, o = detect_ops.detect_hosts_slab(tail, wn, bn, persistence=0.2,
                                           valid=valid)
    wf, ws, wo = spike.detect_rows_masked(
        tail[:, bn:].astype(np.float64), tail[:, :bn].astype(np.float64),
        valid[:, bn:], valid[:, :bn], 3.0, 0.2)
    np.testing.assert_array_equal(f, wf)
    np.testing.assert_array_equal(s, ws)
    np.testing.assert_array_equal(o, wo)
    assert f[1] and not f[2]
    # all-true mask: dropped, byte-identical to valid=None
    a = detect_ops.detect_hosts_slab(tail, wn, bn, persistence=0.2)
    b = detect_ops.detect_hosts_slab(tail, wn, bn, persistence=0.2,
                                     valid=np.ones_like(valid))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


# --------------------------------------------------------- engine under chaos

def test_engine_zero_verdicts_on_pure_corruption():
    eng = CorrelationEngine(EngineConfig())
    for name in ("chaos_soak", "frozen_channel", "crash_restart"):
        t = scen.make_scenario(11, name)[0]
        assert t.truth == [] and t.chaos
        assert eng.process(t.ts, t.data, t.channels) == []


def test_engine_detects_fault_under_chaos_overlap():
    eng = CorrelationEngine(EngineConfig())
    t = scen.make_scenario(7, "chaos_overlap")[0]
    assert len(t.truth) == 1 and t.chaos
    diags = eng.process(t.ts, t.data, t.channels)
    assert len(diags) >= 1
    d = diags[0]
    assert d.event.t_detect - t.truth[0].t_on <= 5.0 + 1e-6


def test_chaos_classes_extend_protocol_stably():
    # chaos classes append AFTER the committed classes: fleet_nic keeps
    # index 6, so protocol_seed(seed, class_index, k) stays byte-stable
    classes = list(scen.SCENARIO_CLASSES)
    assert classes.index("fleet_nic") == 6
    assert classes[7:11] == ["chaos_soak", "chaos_overlap",
                             "frozen_channel", "crash_restart"]
    for name in classes:
        assert scen.scenario_spec(name).description


# -------------------------------------------------------- agent hardening

def _sim_collector(T=400, rate=100.0, chan=LATENCY_METRIC, base=10.0):
    ts = np.arange(T) / rate
    data = np.full((1, T), base, np.float32)
    return SimCollector([chan], ts, data), ts


class _CounterCollector(Collector):
    """Feeds an explicit cumulative-counter sequence, one value per call."""

    def __init__(self, values):
        self.metrics = [MetricSpec("chaos_test_bytes", SignalGroup.NET,
                                   "B", 100.0, monotonic_counter=True)]
        self.values = list(values)
        self.i = 0

    def sample(self, now):
        v = self.values[min(self.i, len(self.values) - 1)]
        self.i += 1
        return {"chaos_test_bytes": float(v)}


def test_agent_isolates_collector_exceptions():
    inner, _ = _sim_collector()
    policy = ChaosPolicy((ChaosEvent("exception", 0.05, 0.02),))
    agent = TelemetryAgent([ChaosCollector(inner, policy)], rate_hz=100.0,
                           history_s=4.0)
    for i in range(40):
        agent.step(now=i * 0.01)
    assert agent.stats.collector_errors >= 1
    assert agent.stats.backoff_skips >= 1
    _, data = agent.ring.window(40)
    li = agent.ring.index[LATENCY_METRIC]
    assert np.isnan(data[li]).any()                 # crash marked invalid
    assert np.isfinite(data[li, -5:]).all()         # recovered after backoff


def test_agent_watchdog_trips_on_slow_collector():
    inner, _ = _sim_collector()
    policy = ChaosPolicy((ChaosEvent("slow", 0.10, 0.011, magnitude=0.03),))
    agent = TelemetryAgent([ChaosCollector(inner, policy)], rate_hz=100.0,
                           history_s=2.0)
    for i in range(15):
        agent.step(now=i * 0.01)
    assert agent.stats.watchdog_trips >= 1
    assert agent.stats.backoff_skips >= 1           # sat out the next tick


def test_agent_counter_reset_and_clock_guards():
    agent = TelemetryAgent([_CounterCollector([100, 200, 50, 150])],
                           rate_hz=100.0, history_s=1.0)
    rows = [agent.step(now=t) for t in (0.00, 0.01, 0.02, 0.03)]
    assert rows[1]["chaos_test_bytes"] == pytest.approx(100.0 / 0.01)
    assert rows[2]["chaos_test_bytes"] == 0.0       # reset: clamp, not -inf
    assert agent.stats.counter_resets == 1
    # backward clock jump: rates are garbage over dt <= 0 — emit 0, flag
    agent2 = TelemetryAgent([_CounterCollector([0, 100, 200, 300])],
                            rate_hz=100.0, history_s=1.0)
    grid = chaos.apply_clock_jumps(
        np.array([0.0, 0.01, 0.02, 0.03]),
        [ChaosEvent("clock_jump", 0.02, 0.0, magnitude=-0.015)])
    rows = [agent2.step(now=t) for t in grid]
    assert agent2.stats.clock_anomalies >= 1
    assert all(np.isfinite(r["chaos_test_bytes"]) and
               r["chaos_test_bytes"] >= 0.0 for r in rows)


def test_chaos_collector_blocks_columnar_fallback():
    inner, ts = _sim_collector()
    cc = ChaosCollector(inner, ChaosPolicy(
        (ChaosEvent("nan", 1.0, 0.5, channel=LATENCY_METRIC),)))
    assert cc.sample_block(ts[:300]) is None        # overlap: per-tick path
    out = cc.sample_block(ts[:50])                  # pre-chaos grid passes
    assert out is not None and np.isfinite(out[LATENCY_METRIC]).all()
    assert np.isnan(cc.sample(1.2)[LATENCY_METRIC])


# -------------------------------------------------- ring + aggregator

def test_ring_read_window_bounded_giveup():
    r = MultiChannelRing(["a"], capacity=16)
    for i in range(8):
        r.push_row(i * 0.01, {"a": float(i)})
    r._write_begin()                                # writer dies mid-write
    ts, data, retries = r.read_window(4, max_retries=3)
    assert ts.size == 0 and data.shape[1] == 0
    assert retries == 3 and r.torn_giveups == 1
    r._write_end()                                  # writer resumes: reads heal
    ts, data, _ = r.read_window(4, max_retries=3)
    assert ts.size == 4


def test_aggregator_valid_mask_and_idempotent_stop():
    rate, window_s = 100.0, 2.0
    agents = []
    for h in range(2):
        inner, _ = _sim_collector(T=600, rate=rate)
        policy = ChaosPolicy(
            (ChaosEvent("nan", 1.0, 0.3, channel=LATENCY_METRIC),)
            if h == 0 else ())
        agents.append(TelemetryAgent([ChaosCollector(inner, policy)],
                                     rate_hz=rate, history_s=4.0))
    agg = FleetAggregator(agents, window_s=window_s)
    agg.run_virtual(0.0, 3.0)
    snap = agg.assemble()
    assert snap.valid_mask is not None and snap.valid_mask.dtype == bool
    li = agg.channels.index(LATENCY_METRIC)
    assert not snap.valid_mask[0, li].all()         # chaos host has holes
    assert snap.valid_mask[1].all()                 # clean host fully valid
    assert np.isnan(snap.slab[0, li][~snap.valid_mask[0, li]]).all()
    agg.stop()
    agg.stop()                                      # second stop: no-op
    assert agg.stats.hung_agents == 0


# ------------------------------------------------------- fleet quarantine

def test_quarantine_hysteresis_state_machine():
    mon = FleetMonitor(EngineConfig())
    bad = np.array([0.5])
    ok = np.array([0.0])
    assert not mon._update_quarantine(bad)[0]       # 1st bad round: candidate
    assert mon._update_quarantine(bad)[0]           # 2nd: quarantined
    assert mon._update_quarantine(ok)[0]            # clean 1/2: still held
    assert not mon._update_quarantine(ok)[0]        # clean 2/2: re-admitted
    assert not mon._update_quarantine(bad)[0]
    assert mon._update_quarantine(bad)[0]           # re-quarantined
    # backoff doubled: now needs 4 clean rounds
    for _ in range(3):
        assert mon._update_quarantine(ok)[0]
    assert not mon._update_quarantine(ok)[0]
    # a single mid-streak bad round resets the clean streak
    mon2 = FleetMonitor(EngineConfig())
    mon2._update_quarantine(bad), mon2._update_quarantine(bad)
    mon2._update_quarantine(ok)
    mon2._update_quarantine(bad)                    # streak reset
    assert mon2._update_quarantine(ok)[0]           # 1 clean again: held


def _fleet_slab(seed=13, hosts=3, T=900):
    rng = np.random.default_rng(seed)
    cfg = EngineConfig(window_s=1.0, baseline_s=5.0)
    channels = [LATENCY_METRIC, "cpu_util_other"]
    data = rng.normal(10.0, 1.0, (hosts, len(channels), T))
    data[:, 1, :] = rng.uniform(0.0, 0.2, (hosts, T))
    return cfg, channels, data, np.arange(T) / cfg.rate_hz


def test_fleet_quarantine_suppresses_verdict_and_mitigates():
    cfg, channels, data, ts = _fleet_slab()
    hosts, C, T = data.shape
    data[0, 0, -cfg.window_n:] += 9.0               # spike on the BAD host
    data[2, 0, -cfg.window_n:] += 9.0               # spike on a clean host
    valid = np.ones_like(data, bool)
    valid[0, 0, T - cfg.window_n - cfg.baseline_n:] = (
        np.arange(cfg.window_n + cfg.baseline_n) % 3 != 0)  # ~33% invalid
    mon = FleetMonitor(cfg, use_kernels=False)
    d1 = mon.diagnose_fleet(ts, data, channels, valid=valid)
    assert d1.quarantined == []                     # round 1: candidate only
    d2 = mon.diagnose_fleet(ts, data, channels, valid=valid)
    assert d2.quarantined == [0]
    assert 0 not in d2.flagged_hosts                # never a straggler
    assert d2.per_host_scores[0] == 0.0
    assert d2.mitigations[0] == Mitigation.RESTART_TELEMETRY
    assert 2 in d2.flagged_hosts                    # real fault still caught
    assert CauseClass.TELEMETRY.value == "telemetry_fault"


def test_fleet_all_true_mask_byte_identical():
    cfg, channels, data, ts = _fleet_slab(seed=17)
    data[1, 0, -cfg.window_n:] += 9.0
    a = FleetMonitor(cfg, use_kernels=False).diagnose_fleet(
        ts, data, channels)
    b = FleetMonitor(cfg, use_kernels=False).diagnose_fleet(
        ts, data, channels, valid=np.ones_like(data, bool))
    np.testing.assert_array_equal(a.per_host_scores, b.per_host_scores)
    assert a.flagged_hosts == b.flagged_hosts
    assert a.straggler_host == b.straggler_host
    assert b.quarantined == []
