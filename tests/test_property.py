"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core.confidence import combine_confidence, squash_spike
from repro.core.spike import baseline_stats, detect, spike_scores_matrix
from repro.core.xcorr import lagged_xcorr, max_abs_xcorr

finite = st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False,
                   width=32)


@given(hnp.arrays(np.float64, st.integers(30, 200), elements=finite))
@settings(max_examples=50, deadline=None)
def test_baseline_stats_sigma_positive(x):
    mu, sd = baseline_stats(x)
    assert sd > 0
    assert np.isfinite(mu)


@given(hnp.arrays(np.float64, (6, 300), elements=finite),
       hnp.arrays(np.float64, 300,
                  elements=st.floats(-100, 100, allow_nan=False, width=32)))
@settings(max_examples=30, deadline=None)
def test_xcorr_always_bounded(M, L):
    rho = lagged_xcorr(L, M, 20)
    assert np.all(np.abs(rho) <= 1.0 + 1e-6)
    assert np.all(np.isfinite(rho))


@given(st.floats(0.1, 100.0), st.floats(-1000, 1000))
@settings(max_examples=50, deadline=None)
def test_xcorr_affine_invariance(scale, shift):
    rng = np.random.default_rng(0)
    L = rng.normal(0, 1, 400)
    M = rng.normal(0, 1, (3, 400))
    r1 = lagged_xcorr(L, M, 10)
    r2 = lagged_xcorr(L, scale * M + shift, 10)
    np.testing.assert_allclose(r1, r2, atol=1e-7)


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_spike_detect_never_fires_below_threshold_mean(seed):
    """A window identical in distribution to its baseline must (almost)
    never produce a persistent 3-sigma detection."""
    rng = np.random.default_rng(seed)
    base = rng.normal(10, 1, 2000)
    win = rng.normal(10, 1, 500)
    hit, _, _ = detect(win, base, threshold=3.0, persistence=0.3)
    assert not hit


@given(hnp.arrays(np.float64, (4, 100),
                  elements=st.floats(0, 50, allow_nan=False, width=32)))
@settings(max_examples=30, deadline=None)
def test_squash_monotone_bounded(x):
    s = squash_spike(x)
    assert np.all((0 <= s) & (s < 1))
    flat = np.sort(x.ravel())
    sq = squash_spike(flat)
    assert np.all(np.diff(sq) >= -1e-12)


@given(st.floats(0.0, 1.0))
@settings(max_examples=25, deadline=None)
def test_confidence_interpolates(alpha):
    s = np.array([10.0, 0.0])
    c = np.array([0.2, 0.9])
    conf = combine_confidence(s, c, alpha)
    assert np.all(conf >= 0) and np.all(conf <= 1.0)
    # alpha=0 -> pure correlation; alpha=1 -> pure (squashed) spike
    if alpha == 0.0:
        np.testing.assert_allclose(conf, c)


@given(st.integers(1, 8), st.integers(130, 400))
@settings(max_examples=20, deadline=None)
def test_scores_matrix_shape_contract(m, n):
    rng = np.random.default_rng(m * n)
    W = rng.normal(0, 1, (m, n))
    B = rng.normal(0, 1, (m, 3 * n))
    s = spike_scores_matrix(W, B)
    assert s.shape == (m,)
    assert np.all(np.isfinite(s))


@given(st.integers(0, 10 ** 6))
@settings(max_examples=15, deadline=None)
def test_trial_determinism(seed):
    """Same seed -> bit-identical trial (restart stability)."""
    from repro.sim.scenario import make_trial
    t1 = make_trial(seed, "nic")
    t2 = make_trial(seed, "nic")
    np.testing.assert_array_equal(t1.data, t2.data)
    assert t1.t_on == t2.t_on and t1.intensity == t2.intensity
