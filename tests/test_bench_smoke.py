"""Perf-path regression canary: every benchmark family (kernel microbench,
engine sweep, fleet + event-batched eval, scenario scorecard) at tiny
sizes.

Marked ``bench_smoke`` so CI can select it (`-m bench_smoke`); it also runs
in plain tier-1 — the whole module stays well under the 30 s budget of
``python -m benchmarks.run --smoke``, whose code paths it exercises.
"""
import math

import pytest

from benchmarks import fleetbench, kernelbench


def _check(rows, prefix):
    assert rows, f"{prefix}: no rows"
    for name, value, _ in rows:
        assert name.startswith(prefix.split("/")[0]), name
        assert math.isfinite(value), f"{name} = {value}"


@pytest.mark.bench_smoke
def test_kernel_family_smoke():
    rows = kernelbench.kernel_microbench(B=2, M=4, N=128, K=6, detect_h=16)
    _check(rows, "kernel/")
    rows = kernelbench.tile_sweep_rows()
    _check(rows, "kernel/tile_sweep")


@pytest.mark.bench_smoke
def test_sweep_family_smoke():
    rows = fleetbench.sweep_rows(n_trials=1, reps=1)
    _check(rows, "sweep/")


@pytest.mark.bench_smoke
def test_slab_sweep_family_smoke():
    """Suite-scale Layer-2 slab sweep vs the per-trial loop: finite rows
    and the byte-exact event/stamp parity bit at both cadences."""
    rows = fleetbench.sweep_slab_rows(n_per_class=1, reps=1,
                                      fleet_hosts=32)
    assert rows
    for name, value, _ in rows:
        assert name.startswith(("eval/sweep", "fleet/sweep")), name
        assert math.isfinite(value), f"{name} = {value}"
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["eval/sweep_parity"] == 1.0
    assert vals["fleet/sweep_single_tick_parity/H32"] == 1.0


@pytest.mark.bench_smoke
def test_fleet_family_smoke():
    rows = fleetbench.fleet_rows(batch_sizes=(8,), reps=1,
                                 sequential_baseline=False)
    _check(rows, "fleet/")
    vals = dict((n, v) for n, v, _ in rows)
    # parity holds exactly on these fixed-seed slabs (a z within one f32
    # ulp of the threshold is the only thing that could split the paths)
    assert vals["fleet/detect_parity/B8"] == 1.0


@pytest.mark.bench_smoke
def test_shard_family_smoke():
    """Sharded fleet rows at tiny sizes: the CI-gated byte-exact parity
    bit (ragged shards, quarantine, top-K deferral, oracle re-visit all
    covered) and a bounded cross-shard traffic fraction."""
    rows = fleetbench.shard_rows(parity_hosts=24, storm_hosts=(64,),
                                 shard_hosts=16, reps=1)
    _check(rows, "fleet/shard")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["fleet/shard_parity"] == 1.0
    assert 0.0 < vals["fleet/shard_xfer_frac/B64"] < 1.0
    assert vals["fleet/shard_hosts_per_s/B64"] > 0


@pytest.mark.bench_smoke
def test_live_family_smoke():
    """Aggregator staging + writer-storm retry loop at tiny sizes — the
    live fleet path's fail-fast canary."""
    rows = fleetbench.live_rows(n_hosts=2, window_s=10.0, reps=1,
                                storm_s=0.15)
    _check(rows, "fleet/live")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["fleet/live_storm_reads_per_s"] > 0


@pytest.mark.bench_smoke
def test_scorecard_family_smoke():
    """Tiny scenario-suite scorecard: parity bits exact, soak clean."""
    from benchmarks import scorecard

    rows = scorecard.smoke_rows()
    _check(rows, "scorecard/")
    vals = dict((n, v) for n, v, _ in rows)
    for key in ("batched_pred", "batched_ts", "slab_pred", "slab_ts",
                "replay"):
        assert vals[f"scorecard/parity/{key}"] == 1.0
    assert vals["scorecard/false_verdicts/soak"] == 0.0
    assert vals["scorecard/restart/duplicates"] == 0.0


@pytest.mark.bench_smoke
def test_chaos_family_smoke():
    """Chaos-hardening invariant rows: pure corruption yields zero
    verdicts, the all-true mask stays byte-identical, sanitization cost
    stays bounded."""
    rows = fleetbench.chaos_rows(reps=1)
    _check(rows, "chaos/")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["chaos/soak_false_verdicts"] == 0.0
    assert vals["chaos/masked_parity"] == 1.0
    assert vals["chaos/sanitize_overhead_frac"] <= 0.9


@pytest.mark.bench_smoke
def test_restart_family_smoke():
    """Survivability invariant rows: crash/restore replay parity, zero
    duplicate verdicts, checkpoint wall costs finite, degraded-mode
    shedding + deferral exercised and re-armed."""
    rows = fleetbench.restart_rows(reps=1)
    _check(rows, "restart/")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["restart/fleet_replay_parity"] == 1.0
    assert vals["restart/duplicate_verdicts"] == 0.0
    assert vals["restart/suppressed_replay"] >= 1.0
    assert vals["restart/shed_rounds"] >= 1.0
    assert vals["restart/deferred_rca"] >= 1.0
    assert vals["restart/rearmed"] == 1.0


@pytest.mark.bench_smoke
def test_eval_family_smoke():
    rows = fleetbench.eval_rows(n_per_class=1, reps=1)
    _check(rows, "eval/")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["eval/pred_parity"] == 1.0
    assert vals["eval/store_pred_parity"] == 1.0
    assert vals["eval/slice_ops_store"] < vals["eval/slice_ops_per_event"]


@pytest.mark.bench_smoke
def test_incremental_family_smoke():
    """Incremental streaming-moment rows at tiny sizes: the CI-gated
    bitwise parity bit (re-anchor compare, chaos invalidation, verdict
    fingerprints vs the from-scratch monitor), plus finite speedup /
    re-anchor-cost / round-budget rows.  The speedup VALUE is only
    asserted finite here — at B=8 the python dispatch overhead dominates;
    the >= 1.5x quiet-fleet claim is recorded by the full bench run at
    B=256 (BENCH_fleet.json)."""
    rows = fleetbench.incremental_rows(batch_sizes=(8,), shard_batch=0)
    _check(rows, "fleet/incremental")
    vals = dict((n, v) for n, v, _ in rows)
    assert vals["fleet/incremental_parity"] == 1.0
    assert vals["fleet/incremental_speedup/B8"] > 0
    assert vals["fleet/incremental_reanchor_s"] > 0
    assert 0 < vals["fleet/incremental_round_cpu_frac/B8"] < 1.0
