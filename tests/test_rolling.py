"""Rolling-statistics fast path == seed scalar path, bit-for-bit where it
matters: same detections, same onsets, same ranked causes."""
import numpy as np
import pytest

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.spike import baseline_stats, detect, detect_sweep, \
    sliding_baseline_stats
from repro.sim.scenario import make_trial


def test_sliding_baseline_stats_matches_scalar():
    rng = np.random.default_rng(0)
    # large-mean/small-std regime: the cancellation trap for naive sumsq
    x = rng.normal(1e8, 30.0, 5000)
    starts = np.arange(0, 3000, 37)
    mu, sd = sliding_baseline_stats(x, starts, 2000)
    for s, m, d in zip(starts, mu, sd):
        m0, d0 = baseline_stats(x[s:s + 2000])
        assert m == pytest.approx(m0, rel=1e-12)
        assert d == pytest.approx(d0, rel=1e-6)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_detect_sweep_matches_scalar_detect(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(10, 1, 6000)
    x[4000:4400] += 6.0                     # injected spike
    wn, bn = 500, 2000
    ticks = np.arange(wn + bn, x.size, 113)
    fire, score, onset = detect_sweep(x, wn, bn, ticks,
                                      threshold=3.0, persistence=0.3)
    for i, t in enumerate(ticks):
        f0, s0, o0 = detect(x[t - wn:t], x[t - wn - bn:t - wn],
                            threshold=3.0, persistence=0.3)
        assert bool(fire[i]) == f0, f"tick {t}"
        assert score[i] == pytest.approx(s0, rel=1e-9)
        if f0:
            assert int(onset[i]) == o0


@pytest.mark.parametrize("seed,cls", [
    (123, "io"), (5, "nic"), (7, "cpu"), (9, "gpu"),
    (321, "nic"), (654, "io"),
])
def test_engine_fast_path_identical_diagnoses(seed, cls):
    """The vectorized sweep must reproduce the seed scalar replay exactly:
    same events, same timing, same cause ranking."""
    trial = make_trial(seed, cls)
    eng = CorrelationEngine()
    fast = eng.process(trial.ts, trial.data, trial.channels, fast=True)
    slow = eng.process(trial.ts, trial.data, trial.channels, fast=False)
    assert len(fast) == len(slow)
    for a, b in zip(fast, slow):
        assert a.event.t_onset == b.event.t_onset
        assert a.event.t_detect == b.event.t_detect
        assert a.event.score == pytest.approx(b.event.score, rel=1e-9)
        assert [rc.cause for rc in a.ranked] == [rc.cause for rc in b.ranked]
        for ra, rb in zip(a.ranked, b.ranked):
            assert ra.confidence == pytest.approx(rb.confidence, rel=1e-12)
            assert ra.top_metric == rb.top_metric


def test_engine_fast_path_fine_cadence():
    """Streaming cadence (evaluate every 10 samples) — the regime the
    rolling pass exists for — still agrees with the scalar replay."""
    trial = make_trial(42, "nic", intensity=1.5, confuser_prob=0.0)
    eng = CorrelationEngine(EngineConfig(eval_every=10))
    fast = eng.process(trial.ts, trial.data, trial.channels, fast=True)
    slow = eng.process(trial.ts, trial.data, trial.channels, fast=False)
    assert len(fast) == len(slow) >= 1
    for a, b in zip(fast, slow):
        assert a.event.t_detect == b.event.t_detect
        assert a.top_cause == b.top_cause


def test_diagnose_no_history_uses_preonset_baseline():
    """lo == blo == 0: the baseline must be the quiet pre-onset head, not
    the spiky window itself (the seed np.resize hack degenerated here)."""
    trial = make_trial(77, "cpu", intensity=2.0, t_on=30.0,
                       confuser_prob=0.0)
    # clip the trial so no history exists before the RCA window
    lo = int((30.0 - 2.5) * 100)            # pre_onset_s before onset
    hi = int(38.0 * 100)
    ts = trial.ts[lo:hi] - trial.ts[lo]
    data = trial.data[:, lo:hi]
    eng = CorrelationEngine(EngineConfig(baseline_s=0.0, window_s=2.0))
    diags = eng.process(ts, data, trial.channels)
    if diags:   # evidence scores must be finite and the verdict sane
        for rc in diags[0].ranked:
            assert np.isfinite(rc.confidence)


# ----------------------------------------- incremental streaming moments
from repro.core.rolling import IncrementalMoments  # noqa: E402
from repro.core import spike as spike_mod  # noqa: E402


def _direct_moments(tail, wn, bn):
    """The detect path's direct f64 pass over the baseline columns."""
    base = np.asarray(tail[:, :bn], np.float64)
    mu = base.mean(axis=1)
    sd = np.maximum(base.std(axis=1),
                    np.maximum(spike_mod.SIGMA_FLOOR_ABS,
                               spike_mod.SIGMA_FLOOR_REL * np.abs(mu)))
    return mu, sd


def test_incremental_bitwise_equals_from_scratch_property():
    """Seeded random schedules: appends of any delta (0, sub-block,
    multi-block), window/baseline growth, per-row invalidation, circular
    slot wrap-around and periodic re-anchors — every round's (mu, sd)
    must be BITWISE equal to a cold instance fed the same slab, and
    numerically equal to the direct pass."""
    rng = np.random.default_rng(4207)
    n, total = 13, 9000
    x = (rng.standard_normal((n, total)) * 3.0 + 1.5).astype(np.float32)
    base_off = 5                      # rows live at global ids 5..18
    warm = IncrementalMoments(block=64, reanchor_rounds=5, cap_ticks=1400)
    e = 1800
    wn, bn = 137, 1100
    for rnd in range(48):
        e = min(total, e + int(rng.choice([0, 1, 7, 64, 130, 400])))
        if rng.random() < 0.15:       # warmup growth: bounds change only
            wn = int(rng.choice([137, 200]))
            bn = int(rng.choice([1100, 1300, 1400]))
        if rng.random() < 0.2:
            warm.invalidate(base_off
                            + rng.integers(0, n, size=rng.integers(1, 4)))
        tail = x[:, e - wn - bn:e]
        mu_w, sd_w = warm.moments(tail, e, wn, bn, base=base_off)
        cold = IncrementalMoments(block=64, reanchor_rounds=0)
        mu_c, sd_c = cold.moments(tail, e, wn, bn)
        assert np.array_equal(mu_w, mu_c), rnd
        assert np.array_equal(sd_w, sd_c), rnd
        mu_d, sd_d = _direct_moments(tail, wn, bn)
        np.testing.assert_allclose(mu_w, mu_d, rtol=1e-10, atol=1e-9)
        np.testing.assert_allclose(sd_w, sd_d, rtol=1e-7, atol=1e-9)
    st = warm.stats()
    assert st["parity"] == 1.0 and st["parity_failures"] == 0
    assert st["reanchors"] >= 8                  # cadence actually ran
    assert st["forced_invalidations"] > 0        # invalidation exercised
    assert st["blocks_cached"] > st["blocks_computed"] // 4


def test_incremental_delta_round_is_o_delta():
    """A round appending less than one block recomputes at most the two
    partial-adjacent blocks per row, not the whole baseline."""
    inc = IncrementalMoments(block=64, reanchor_rounds=0)
    rng = np.random.default_rng(1)
    x = rng.standard_normal((7, 5000)).astype(np.float32)
    wn, bn = 200, 2000
    inc.moments(x[:, 2800 - wn - bn:2800], 2800, wn, bn)
    first = inc.last_round_computed
    assert first >= 7 * (bn // 64 - 2)           # cold build did the work
    inc.moments(x[:, 2830 - wn - bn:2830], 2830, wn, bn)
    assert inc.last_round_computed <= 7 * 2      # delta round did not


def test_reanchor_detects_and_repairs_corruption():
    """Perturbing one cached f64 sum must trip the parity bit on the
    next re-anchor, and the adopted rebuild must repair the state."""
    inc = IncrementalMoments(block=64, reanchor_rounds=0)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((5, 4000)).astype(np.float32)
    wn, bn = 100, 1500
    inc.moments(x[:, 3000 - wn - bn:3000], 3000, wn, bn)
    r, slot = 2, int(np.flatnonzero(inc._bid[2] >= 0)[3])
    inc._sum[r, slot] += 1.0                     # simulated corruption
    inc.reanchor_every = 1                       # next round re-anchors
    inc.rounds = 0
    mu, sd = inc.moments(x[:, 3000 - wn - bn:3000], 3000, wn, bn)
    assert inc.parity_failures >= 1 and inc.parity == 0.0
    cold = IncrementalMoments(block=64, reanchor_rounds=0)
    mu_c, sd_c = cold.moments(x[:, 3000 - wn - bn:3000], 3000, wn, bn)
    assert np.array_equal(mu, mu_c) and np.array_equal(sd, sd_c)


def test_monitor_masked_round_invalidates_then_rebuilds():
    """Chaos interplay at monitor level: a masked round forces per-host
    invalidation (oracle verdicts, no incremental advance), the next
    clean round rebuilds from scratch, and every verdict matches a
    monitor running the direct pass."""
    from benchmarks.fleetbench import _make_fleet
    from repro.monitor.fleet import FleetMonitor
    from repro.monitor.shard import verdict_fingerprint

    ts, data, channels = _make_fleet(8, bad_host=3, seed=41)
    li = list(channels).index("coll_allreduce_ms")
    T = data.shape[2]
    warm = FleetMonitor(use_kernels=False)
    cold = FleetMonitor(use_kernels=False, incremental=False)
    assert warm.incremental_stats() is not None
    assert cold.incremental_stats() is None
    for rnd, tk in enumerate((T - 150, T - 75, T)):
        vmask = None
        if rnd == 1:
            vmask = np.ones((8, len(channels), tk), bool)
            vmask[4, li, -120:] = False
        a = warm.diagnose_fleet(ts[:tk], data[:, :, :tk], channels,
                                valid=vmask)
        b = cold.diagnose_fleet(ts[:tk], data[:, :, :tk], channels,
                                valid=vmask)
        assert verdict_fingerprint(a) == verdict_fingerprint(b), rnd
        st = warm.incremental_stats()
        if rnd == 1:
            assert st["forced_invalidations"] == 8    # every host dropped
        if rnd == 2:
            assert st["last_round_rebuilt_rows"] == 8  # forced re-anchor


def test_monitor_reset_host_invalidates_rows():
    from benchmarks.fleetbench import _make_fleet
    from repro.monitor.fleet import FleetMonitor

    ts, data, channels = _make_fleet(6, bad_host=2, seed=9)
    mon = FleetMonitor(use_kernels=False)
    mon.diagnose_fleet(ts, data, channels)
    before = mon._inc.forced_invalidations
    mon.reset_host(4)
    assert mon._inc.forced_invalidations == before + 1
    assert (mon._inc._bid[4] == -1).all()


def test_tick_end_grid_guards():
    """Off-grid timestamps (skew, dropped ticks) must disable the
    incremental anchor — the round falls back to the direct pass."""
    from repro.monitor.fleet import FleetMonitor

    mon = FleetMonitor(use_kernels=False)
    rate = mon.cfg.rate_hz
    ts = np.arange(4000) / rate
    assert mon._tick_end(ts, 4000) == 4000
    assert mon._tick_end(ts + 0.2, 4000) == 4000 + int(0.2 * rate)
    assert mon._tick_end(ts + 0.003, 4000) is None       # off-grid edge
    assert mon._tick_end(np.delete(ts, 100), 3999) is None  # dropped tick
    assert mon._tick_end(ts[:1], 1) is None              # too short
    direct = FleetMonitor(use_kernels=False, incremental=False)
    assert direct._tick_end(ts, 4000) is None            # state disabled
