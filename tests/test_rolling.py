"""Rolling-statistics fast path == seed scalar path, bit-for-bit where it
matters: same detections, same onsets, same ranked causes."""
import numpy as np
import pytest

from repro.core.engine import CorrelationEngine, EngineConfig
from repro.core.spike import baseline_stats, detect, detect_sweep, \
    sliding_baseline_stats
from repro.sim.scenario import make_trial


def test_sliding_baseline_stats_matches_scalar():
    rng = np.random.default_rng(0)
    # large-mean/small-std regime: the cancellation trap for naive sumsq
    x = rng.normal(1e8, 30.0, 5000)
    starts = np.arange(0, 3000, 37)
    mu, sd = sliding_baseline_stats(x, starts, 2000)
    for s, m, d in zip(starts, mu, sd):
        m0, d0 = baseline_stats(x[s:s + 2000])
        assert m == pytest.approx(m0, rel=1e-12)
        assert d == pytest.approx(d0, rel=1e-6)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_detect_sweep_matches_scalar_detect(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(10, 1, 6000)
    x[4000:4400] += 6.0                     # injected spike
    wn, bn = 500, 2000
    ticks = np.arange(wn + bn, x.size, 113)
    fire, score, onset = detect_sweep(x, wn, bn, ticks,
                                      threshold=3.0, persistence=0.3)
    for i, t in enumerate(ticks):
        f0, s0, o0 = detect(x[t - wn:t], x[t - wn - bn:t - wn],
                            threshold=3.0, persistence=0.3)
        assert bool(fire[i]) == f0, f"tick {t}"
        assert score[i] == pytest.approx(s0, rel=1e-9)
        if f0:
            assert int(onset[i]) == o0


@pytest.mark.parametrize("seed,cls", [
    (123, "io"), (5, "nic"), (7, "cpu"), (9, "gpu"),
    (321, "nic"), (654, "io"),
])
def test_engine_fast_path_identical_diagnoses(seed, cls):
    """The vectorized sweep must reproduce the seed scalar replay exactly:
    same events, same timing, same cause ranking."""
    trial = make_trial(seed, cls)
    eng = CorrelationEngine()
    fast = eng.process(trial.ts, trial.data, trial.channels, fast=True)
    slow = eng.process(trial.ts, trial.data, trial.channels, fast=False)
    assert len(fast) == len(slow)
    for a, b in zip(fast, slow):
        assert a.event.t_onset == b.event.t_onset
        assert a.event.t_detect == b.event.t_detect
        assert a.event.score == pytest.approx(b.event.score, rel=1e-9)
        assert [rc.cause for rc in a.ranked] == [rc.cause for rc in b.ranked]
        for ra, rb in zip(a.ranked, b.ranked):
            assert ra.confidence == pytest.approx(rb.confidence, rel=1e-12)
            assert ra.top_metric == rb.top_metric


def test_engine_fast_path_fine_cadence():
    """Streaming cadence (evaluate every 10 samples) — the regime the
    rolling pass exists for — still agrees with the scalar replay."""
    trial = make_trial(42, "nic", intensity=1.5, confuser_prob=0.0)
    eng = CorrelationEngine(EngineConfig(eval_every=10))
    fast = eng.process(trial.ts, trial.data, trial.channels, fast=True)
    slow = eng.process(trial.ts, trial.data, trial.channels, fast=False)
    assert len(fast) == len(slow) >= 1
    for a, b in zip(fast, slow):
        assert a.event.t_detect == b.event.t_detect
        assert a.top_cause == b.top_cause


def test_diagnose_no_history_uses_preonset_baseline():
    """lo == blo == 0: the baseline must be the quiet pre-onset head, not
    the spiky window itself (the seed np.resize hack degenerated here)."""
    trial = make_trial(77, "cpu", intensity=2.0, t_on=30.0,
                       confuser_prob=0.0)
    # clip the trial so no history exists before the RCA window
    lo = int((30.0 - 2.5) * 100)            # pre_onset_s before onset
    hi = int(38.0 * 100)
    ts = trial.ts[lo:hi] - trial.ts[lo]
    data = trial.data[:, lo:hi]
    eng = CorrelationEngine(EngineConfig(baseline_s=0.0, window_s=2.0))
    diags = eng.process(ts, data, trial.channels)
    if diags:   # evidence scores must be finite and the verdict sane
        for rc in diags[0].ranked:
            assert np.isfinite(rc.confidence)
