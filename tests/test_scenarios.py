"""Multi-fault scenario generator: timeline shapes, composition, fleet."""
import numpy as np
import pytest

from repro.core.engine import CorrelationEngine
from repro.monitor.fleet import FleetMonitor
from repro.sim import scenarios as scen
from repro.sim.disturbances import CLASS_ORDER
from repro.sim.scenario import TrialStore, make_trial


def test_registry_covers_required_classes():
    # >= 6 classes, incl. >= 2 multi-fault/overlap classes + a no-fault soak
    assert len(scen.SCENARIO_CLASSES) >= 6
    assert "soak" in scen.SCENARIO_CLASSES
    assert "fleet_nic" in scen.SCENARIO_CLASSES
    multi = [s for s in scen.SCENARIOS.values() if s.multi_fault]
    assert len(multi) >= 2


def test_scenario_class_order_is_append_only():
    """Class indices feed ``protocol_seed(seed, class_index, k)`` — the
    committed eval artifacts depend on these exact positions.  New classes
    may only be APPENDED; reordering silently re-seeds every trial."""
    assert scen.SCENARIO_CLASSES[:12] == (
        "single", "overlap_pair", "overlap_full", "cascade", "flap",
        "soak", "fleet_nic", "chaos_soak", "chaos_overlap",
        "frozen_channel", "crash_restart", "crash_during_incident")


def test_crash_during_incident_schedules_monitor_crash():
    """The monitor-survivability class: one real fault, one monitor crash
    shortly after its onset, telemetry itself untouched — and the monitor
    draw comes from a dedicated rng stream (same fault/data bytes as a
    hypothetical crash-free sampling of the same seed)."""
    trials = scen.make_scenario(123, "crash_during_incident")
    assert len(trials) == 1
    t = trials[0]
    assert len(t.truth) == 1 and len(t.monitor) == 1
    m = t.monitor[0]
    assert m.kind == "monitor_crash"
    assert t.truth[0].t_on + 1.5 <= m.t <= t.truth[0].t_on + 3.5
    assert 4.0 <= m.dur_s <= 8.0
    assert m.t_end == m.t + m.dur_s
    # deterministic per seed
    t2 = scen.make_scenario(123, "crash_during_incident")[0]
    np.testing.assert_array_equal(t.data, t2.data)
    assert t.monitor == t2.monitor
    # non-monitor classes schedule no monitor failures
    assert scen.make_scenario(123, "single")[0].monitor == []


@pytest.mark.parametrize("name", list(scen.SCENARIOS))
def test_sampled_timelines_are_well_formed(name):
    spec = scen.SCENARIOS[name]
    for seed in range(30):
        rng = np.random.default_rng(seed)
        events = spec.sampler(rng)
        if name == "soak":
            assert events == []
            continue
        assert all(e.cls in CLASS_ORDER for e in events)
        assert all(e.intensity > 0 for e in events)
        # every event fits the scenario duration with detector warm-up room
        assert all(25.0 < e.t_on and e.t_off < scen.DURATION_S
                   for e in events)
        if name == "overlap_pair" or name == "overlap_full":
            assert len(events) == 2
            assert events[0].overlaps(events[1])
            assert events[0].cls != events[1].cls
        if name == "overlap_full":
            assert abs(events[0].t_on - events[1].t_on) <= 0.5
        if name == "cascade":
            assert len(events) == 3
            assert len({e.cls for e in events}) == 3
            srt = sorted(events, key=lambda e: e.t_on)
            assert all(not a.overlaps(b) for a, b in zip(srt, srt[1:]))
        if name == "flap":
            assert len(events) == 3
            assert len({e.cls for e in events}) == 1
            srt = sorted(events, key=lambda e: e.t_on)
            # recurrence spaced past the engine's 15 s cooldown
            assert all(b.t_on - a.t_off > 15.0 for a, b in zip(srt, srt[1:]))


def test_compose_is_deterministic_and_protocol_shaped():
    ev = [scen.FaultEvent("io", 35.0, 15.0, 1.5)]
    a = scen.compose_trial(7, ev, duration_s=50.0, scenario="single")
    b = scen.compose_trial(7, ev, duration_s=50.0, scenario="single")
    np.testing.assert_array_equal(a.data, b.data)
    # same channel layout as the paper-protocol trial builder
    ref = make_trial(7, "io", duration_s=50.0)
    assert a.channels == ref.channels
    assert a.data.shape[0] == ref.data.shape[0]
    assert a.truth == ev


def test_compose_multipliers_compound():
    """Concurrent faults slow the collective more than either alone."""
    e1 = scen.FaultEvent("io", 30.0, 15.0, 2.0)
    e2 = scen.FaultEvent("cpu", 33.0, 15.0, 2.0)
    li = -2  # LATENCY_CH row
    one = scen.compose_trial(3, [e1], duration_s=60.0, confuser_prob=0.0)
    both = scen.compose_trial(3, [e1, e2], duration_s=60.0,
                              confuser_prob=0.0)
    sl = slice(int(34.0 * 100), int(42.0 * 100))    # both active
    assert (np.mean(both.data[li, sl]) > np.mean(one.data[li, sl]))


def test_suite_stacks_into_trial_store():
    trials = scen.build_suite(1, seed=5, n_hosts=3, n_affected=2)
    # one trial per registry class (incl. chaos + monitor) + fleet rows
    assert len(trials) == (len(scen.SCENARIOS) + len(scen.CHAOS_SCENARIOS)
                           + len(scen.MONITOR_SCENARIOS) + 3)
    store = TrialStore.from_trials(trials)
    assert store.slab.shape[0] == len(trials)
    assert store.slab.dtype == np.float32
    assert store.channels == trials[0].channels
    by_class = {t.scenario for t in trials}
    assert by_class == set(scen.SCENARIO_CLASSES)


def test_min_duration_enforced():
    with pytest.raises(ValueError):
        scen.make_scenario(0, "cascade", duration_s=60.0)


def test_fleet_scenario_correlated_burst_and_slab_path():
    trials = scen.make_scenario(11, "fleet_nic", n_hosts=4, n_affected=2)
    assert len(trials) == 4
    # one shared incident id, so a flat suite regroups without seed math
    assert {t.group for t in trials} == {11}
    affected = {t.host for t in trials if t.truth}
    assert len(affected) == 2
    # the SAME burst on every affected host (cross-host correlation)
    bursts = [t.truth[0] for t in trials if t.truth]
    assert all(b == bursts[0] for b in bursts)

    # the fleet monitor, fed the stacked (hosts, C, T) slab clipped just
    # after the burst, flags exactly the affected hosts and calls NIC
    burst = bursts[0]
    t_hi = int((burst.t_on + 6.0) * 100)
    slab = np.ascontiguousarray(
        np.stack([t.data[:, :t_hi] for t in trials]), np.float32)
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(
        trials[0].ts[:t_hi], slab, trials[0].channels)
    assert set(fd.flagged_hosts) == affected
    for h in affected:
        assert fd.diagnoses[h].top_cause == burst.kind
        assert fd.diagnoses[h].t_ready is not None


def test_single_strong_event_detected_end_to_end():
    ev = [scen.FaultEvent("nic", 35.0, 15.0, 2.0)]
    t = scen.compose_trial(9, ev, duration_s=60.0, confuser_prob=0.0)
    diags = CorrelationEngine().process(t.ts, t.data, t.channels)
    assert diags, "a clearly-injected fault must be detected"
    assert diags[0].top_cause == ev[0].kind
    assert diags[0].t_ready is not None
    assert diags[0].event.t_detect >= ev[0].t_on
