"""Per-kernel validation: shape/dtype sweeps, interpret-mode vs pure-jnp
oracle (the required allclose contract for every Pallas kernel)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.spike.ops import spike_scores
from repro.kernels.spike.ref import spike_scores_ref
from repro.kernels.welford.ops import welford
from repro.kernels.welford.ref import welford_ref
from repro.kernels.xcorr.ops import lagged_xcorr, max_abs_xcorr
from repro.kernels.xcorr.ref import lagged_xcorr_ref, max_abs_xcorr_ref


@pytest.mark.parametrize("B,M,N,K", [
    (1, 1, 128, 4), (2, 7, 500, 20), (3, 16, 512, 20),
    (1, 33, 500, 31), (4, 8, 1024, 20), (2, 5, 257, 10),
])
def test_xcorr_matches_ref(B, M, N, K):
    rng = np.random.default_rng(B * 1000 + M)
    L = rng.standard_normal((B, N)).astype(np.float32)
    Mx = (rng.standard_normal((B, M, N)) * 3 + 1).astype(np.float32)
    got = lagged_xcorr(jnp.asarray(L), jnp.asarray(Mx), K, use_kernel=True)
    want = lagged_xcorr_ref(jnp.asarray(L), jnp.asarray(Mx), K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16])
def test_xcorr_dtypes(dtype):
    rng = np.random.default_rng(0)
    L = rng.standard_normal((2, 256)).astype(dtype)
    Mx = rng.standard_normal((2, 4, 256)).astype(dtype)
    got = lagged_xcorr(jnp.asarray(L), jnp.asarray(Mx), 8, use_kernel=True)
    want = lagged_xcorr_ref(jnp.asarray(L, jnp.float32),
                            jnp.asarray(Mx, jnp.float32), 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-3, atol=5e-3)


def test_xcorr_recovers_lag_batched():
    rng = np.random.default_rng(1)
    N, K = 512, 20
    sig = rng.standard_normal(N + K)
    L = np.stack([sig[:N], rng.standard_normal(N)]).astype(np.float32)
    M = np.zeros((2, 2, N), np.float32)
    M[0, 0] = sig[5:N + 5]      # leads host-0 latency by 5
    M[0, 1] = rng.standard_normal(N)
    M[1] = rng.standard_normal((2, N))
    c, lags = max_abs_xcorr(jnp.asarray(L), jnp.asarray(M), K)
    assert int(lags[0, 0]) == 5
    assert float(c[0, 0]) > 0.9


@pytest.mark.parametrize("B,M,Nw,Nb", [
    (1, 3, 500, 2000), (2, 9, 128, 128), (3, 17, 300, 1500),
])
def test_spike_matches_ref(B, M, Nw, Nb):
    rng = np.random.default_rng(M)
    W = (rng.standard_normal((B, M, Nw)) * 2 + 10).astype(np.float32)
    Bs = (rng.standard_normal((B, M, Nb)) * 2 + 10).astype(np.float32)
    got = spike_scores(jnp.asarray(W), jnp.asarray(Bs), use_kernel=True)
    want = spike_scores_ref(jnp.asarray(W), jnp.asarray(Bs))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,M,N", [(1, 2, 128), (2, 5, 700), (3, 11, 2048)])
def test_welford_matches_ref_and_f64(B, M, N):
    rng = np.random.default_rng(N)
    # large mean, small std: the catastrophic-cancellation regime
    X = (rng.standard_normal((B, M, N)) * 3 + 1e4).astype(np.float32)
    mk, vk = welford(jnp.asarray(X), use_kernel=True)
    mr, vr = welford_ref(jnp.asarray(X))
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr), rtol=1e-3)
    v64 = X.astype(np.float64).var(-1)
    np.testing.assert_allclose(np.asarray(vk), v64, rtol=1e-3)


def test_engine_and_kernel_agree():
    """The numpy engine's Layer-3 math == the batched kernel path."""
    from repro.core.xcorr import lagged_xcorr as np_xcorr
    rng = np.random.default_rng(5)
    L = rng.standard_normal(500)
    M = rng.standard_normal((6, 500))
    want = np_xcorr(L, M, 20)                       # numpy per-host engine
    got = lagged_xcorr(jnp.asarray(L[None]), jnp.asarray(M[None]), 20,
                       use_kernel=True)[0]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-4, atol=1e-5)
