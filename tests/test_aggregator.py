"""FleetAggregator: live multi-host slab assembly over seqlock rings —
ragged fleets, wrap-spanning windows, exact parity with copying snapshots,
and end-to-end fleet RCA through the staged slab."""
import numpy as np
import pytest

from repro.core.taxonomy import CauseClass
from repro.monitor.aggregator import FleetAggregator
from repro.monitor.fleet import FleetMonitor, Mitigation
from repro.sim.scenario import make_trial
from repro.telemetry.agent import TelemetryAgent
from repro.telemetry.collectors import SimCollector


def _agent(trial, history_s=60.0):
    sim = SimCollector(trial.channels, trial.ts, trial.data)
    return TelemetryAgent([sim], rate_hz=100.0, history_s=history_s)


def _fleet(n_hosts, bad_host, cls="nic", seed=800, history_s=60.0):
    trials = [make_trial(seed + h, cls,
                         intensity=(2.0 if h == bad_host else 0.0),
                         t_on=40.0, confuser_prob=0.0)
              for h in range(n_hosts)]
    return trials, [_agent(t, history_s) for t in trials]


def test_assembled_slab_parity_with_copying_snapshots():
    """Virtual clock: every staged host row equals the per-host
    ``window(copy=True)`` snapshot bit for bit, and the reference clock is
    the hosts' shared timestamp grid."""
    _, agents = _fleet(3, bad_host=1)
    agg = FleetAggregator(agents, window_s=30.0)
    agg.run_virtual(0.0, 46.0)
    snap = agg.assemble()
    assert snap.slab.shape == (3, len(agg.channels), 3000)
    assert snap.skipped == [] and list(snap.valid) == [3000] * 3
    for h, a in enumerate(agents):
        ts, d = a.window(30.0)
        np.testing.assert_array_equal(snap.slab[h], d)
        np.testing.assert_array_equal(snap.ts, ts)


def test_wrap_spanning_window_stages_consistently():
    """History shorter than the drive span: the ring wraps mid-window and
    the staged row must still be the chronological trailing window."""
    trials, agents = _fleet(2, bad_host=0, history_s=35.0)
    agg = FleetAggregator(agents, window_s=30.0)
    agg.run_virtual(0.0, 46.0)          # 4600 pushes into 3500-slot rings
    snap = agg.assemble()
    for h, a in enumerate(agents):
        ts, d = a.window(30.0)
        np.testing.assert_array_equal(snap.slab[h], d)
    # the window's absolute position is right: newest sample at ~45.99 s
    assert snap.ts[-1] == pytest.approx(45.99, abs=1e-6)


def test_late_joiner_backfilled_and_valid_reported():
    trials, agents = _fleet(3, bad_host=2)
    agg = FleetAggregator(agents, window_s=30.0)
    for a in agents[:2]:
        a.run_virtual(0.0, 46.0)
    agents[2].run_virtual(41.0, 46.0)    # joined 5 s ago
    snap = agg.assemble()
    assert snap.skipped == []
    assert list(snap.valid[:2]) == [3000, 3000]
    assert snap.valid[2] == 500
    # the late joiner's head is backfilled flat with its oldest sample
    row = snap.slab[2]
    np.testing.assert_array_equal(row[:, :2500],
                                  np.repeat(row[:, 2500:2501], 2500, axis=1))
    ts, d = agents[2].window(5.0)
    np.testing.assert_array_equal(row[:, 2500:], d)


def test_dead_agent_masked_out_of_slab():
    """A host whose agent stopped sampling long ago must not contribute a
    stale window (its old spike would read as live)."""
    trials, agents = _fleet(3, bad_host=1, cls="cpu")
    agg = FleetAggregator(agents, window_s=30.0, dead_after_s=2.0)
    for h, a in enumerate(agents):
        a.run_virtual(0.0, 46.0 if h != 0 else 20.0)   # host 0 died at t=20
    snap = agg.assemble()
    assert snap.skipped == [0]
    assert snap.valid[0] == 0
    assert np.all(snap.slab[0] == 0.0)
    # the live straggler is still found through the staged slab
    fd = FleetMonitor(use_kernels=False).diagnose_fleet(
        snap.ts, snap.slab, agg.channels)
    assert fd.straggler_host == 1
    assert fd.diagnosis is not None
    assert fd.diagnosis.top_cause == CauseClass.CPU
    assert agg.stats.dead_hosts == 1


def test_clock_skew_right_aligned_at_common_edge():
    """One host has sampled a little further than the others: its newest
    samples past the fleet-common edge are dropped so columns align."""
    trials, agents = _fleet(2, bad_host=0)
    agents[0].run_virtual(0.0, 46.5)     # 50 samples ahead
    agents[1].run_virtual(0.0, 46.0)
    agg = FleetAggregator(agents, window_s=30.0)
    snap = agg.assemble()
    # both rows end at the common edge (host 1's newest sample)
    assert snap.ts[-1] == pytest.approx(45.99, abs=1e-6)
    ts1, d1 = agents[1].window(30.0)
    np.testing.assert_array_equal(snap.slab[1], d1)
    # host 0's staged row ends at the same instant, not at its own newest:
    # equal to its own ring read skipped past the 50 newer samples
    ts0, d0, _ = agents[0].ring.read_window(3000, skip_newest=50)
    assert ts0[-1] == pytest.approx(snap.ts[-1], abs=1e-9)
    np.testing.assert_array_equal(snap.slab[0], d0)


def test_diagnose_through_aggregator_localizes_straggler():
    trials, agents = _fleet(4, bad_host=2, cls="nic")
    agg = FleetAggregator(agents, window_s=30.0)
    agg.run_virtual(0.0, 46.0)
    fd = agg.diagnose(FleetMonitor(use_kernels=False), min_valid_s=10.0)
    assert fd is not None
    assert fd.straggler_host == 2
    assert fd.diagnosis.top_cause == CauseClass.NIC
    assert fd.mitigation == Mitigation.HIERARCHICAL_ALLREDUCE
    assert agg.stats.assemblies == 1


def test_diagnose_clamps_to_accumulated_span_no_backfill_baseline():
    """Startup: with 12 s of real telemetry in a 30 s window, diagnose()
    must run on the genuine 12 s span — identical to diagnosing the
    actual accumulated window directly — so the backfilled flat head
    never enters the baseline statistics."""
    trials, agents = _fleet(2, bad_host=1, cls="io", seed=870)
    agg = FleetAggregator(agents, window_s=30.0)
    agg.run_virtual(34.0, 46.0)          # joined late: 12 s of real data
    mon = FleetMonitor(use_kernels=False)
    fd = agg.diagnose(mon, min_valid_s=10.0)
    assert fd is not None
    ref = np.stack([a.window(12.0)[1] for a in agents])
    ref_fd = FleetMonitor(use_kernels=False).diagnose_fleet(
        agents[0].window(12.0)[0], ref, agg.channels)
    assert fd.flagged_hosts == ref_fd.flagged_hosts
    assert fd.straggler_host == ref_fd.straggler_host
    np.testing.assert_array_equal(fd.per_host_scores, ref_fd.per_host_scores)


def test_diagnose_late_joiner_not_falsely_flagged():
    """Mixed valid spans on a quiet fleet: the late joiner's backfilled
    flat head must never enter the diagnosed slab.  (Max-valid clamping
    had this hole: the constant backfill hit the sigma floor and flagged
    the healthy newcomer as a straggler.)"""
    for seed in (900, 901, 902, 903):
        trials, agents = _fleet(2, bad_host=-1, seed=seed)   # all quiet
        agg = FleetAggregator(agents, window_s=30.0)
        agents[0].run_virtual(0.0, 46.0)
        agents[1].run_virtual(40.0, 46.0)    # healthy, joined 6 s ago
        fd = agg.diagnose(FleetMonitor(use_kernels=False), min_valid_s=5.0)
        assert fd is not None
        assert fd.flagged_hosts == [], f"seed {seed} falsely flagged"
        # the joiner is reported masked, not silently "healthy"
        assert agg.last_snapshot.masked == [1]


def test_diagnose_young_host_masked_not_blinding_fleet():
    """A restarting agent must not blind or narrow the established fleet:
    hosts younger than ``min_valid_s`` are masked quiet this round while
    the rest diagnose on their full span."""
    trials, agents = _fleet(3, bad_host=1, cls="nic", seed=910)
    agg = FleetAggregator(agents, window_s=30.0)
    for a in agents[:2]:
        a.run_virtual(0.0, 46.0)
    agents[2].run_virtual(43.0, 46.0)    # restarted 3 s ago
    fd = agg.diagnose(FleetMonitor(use_kernels=False), min_valid_s=10.0)
    assert fd is not None
    assert fd.straggler_host == 1        # real straggler still caught
    assert 2 not in fd.flagged_hosts     # young host quiet, not flagged
    assert fd.diagnosis.top_cause == CauseClass.NIC
    # the established hosts kept their full window (span not narrowed)
    assert agg.last_snapshot.masked == [2]
    assert agg.stats.masked_hosts == 1


def test_diagnose_returns_none_before_enough_telemetry():
    trials, agents = _fleet(2, bad_host=0)
    agg = FleetAggregator(agents, window_s=30.0)
    assert agg.diagnose(FleetMonitor(use_kernels=False)) is None  # empty
    agg.run_virtual(0.0, 2.0)
    assert agg.diagnose(FleetMonitor(use_kernels=False),
                        min_valid_s=10.0) is None                 # too short


def test_live_background_agents_stage_aligned_and_consistent():
    """Real writer threads: assemble() while every agent's sampling thread
    pushes.  Staged rows must stay mutually aligned at the fleet-common
    clock edge (within one period) even though samples keep arriving
    between the probe and the staging read."""
    src_ts = np.arange(0.0, 64.0, 0.01)
    src = np.vstack([np.sin(src_ts) + 5.0, np.cos(src_ts)]).astype(np.float32)
    agents = [TelemetryAgent(
        [SimCollector(["dev_power", "dev_temp"], src_ts, src)],
        rate_hz=500.0, history_s=4.0) for _ in range(3)]
    agg = FleetAggregator(agents, window_s=1.0)
    agg.start_background()
    try:
        import time
        time.sleep(0.4)
        for _ in range(20):
            snap = agg.assemble()
            live = [h for h in range(3) if h not in snap.skipped]
            assert live, "all hosts skipped under live sampling"
            ends = [agg._ts_rows[h, -1] for h in live]
            # a tight bound is impossible under wall-clock sampling (a
            # GIL stall right before the common edge legitimately lags
            # one host by the stall length) — the exact-alignment
            # contract is proven by the deterministic virtual-clock skew
            # test above; here assert the spread stays bounded by a
            # generous scheduling ceiling, catching systematic drift
            assert max(ends) - min(ends) <= 0.05, ends
    finally:
        agg.stop()


def test_channel_layout_mismatch_rejected():
    t = make_trial(990, "io", confuser_prob=0.0)
    a1 = _agent(t)
    sim = SimCollector(["dev_power"], t.ts,
                       np.ones((1, t.ts.size), np.float32))
    a2 = TelemetryAgent([sim], rate_hz=100.0, history_s=60.0)
    with pytest.raises(ValueError):
        FleetAggregator([a1, a2], window_s=10.0)


# --------------------------------------------------- delta-read staging

def _force_full(agg):
    """Disable the delta fast path for one assemble (bench/test trick)."""
    agg._staged_full[:] = False


def _snap_state(agg):
    return (agg._slab.copy(), agg._ts_rows.copy(), agg._valid.copy())


def test_delta_restage_bitwise_equals_full_restage():
    """Seqlock-watermark delta reads (including ring wrap-around and
    unchanged-seq skips) must stage a slab bitwise-identical to a full
    restage of the same rings — every round, every buffer."""
    _, agents_a = _fleet(4, bad_host=1, history_s=20.0)
    _, agents_b = _fleet(4, bad_host=1, history_s=20.0)
    a = FleetAggregator(agents_a, window_s=15.0)
    b = FleetAggregator(agents_b, window_s=15.0)
    t = 0.0
    # dt=0 -> unchanged-seq skip; tiny dt -> 1-tick delta; 19.99 ->
    # nearly a full ring of fresh ticks; by t=70 the 20 s rings have
    # wrapped 3 times over
    schedule = [18.0, 0.0, 0.37, 1.0, 5.0, 19.99, 0.01, 0.0, 0.5,
                19.99, 0.25, 5.0]
    for dt in schedule:
        t += dt
        a.run_virtual(t - dt, t)
        b.run_virtual(t - dt, t)
        _force_full(b)
        sa, sb = a.assemble(), b.assemble()
        np.testing.assert_array_equal(sa.slab, sb.slab)
        np.testing.assert_array_equal(sa.ts, sb.ts)
        assert list(sa.valid) == list(sb.valid)
        for x, y in zip(_snap_state(a), _snap_state(b)):
            np.testing.assert_array_equal(x, y)
    assert a.stats.delta_reads > 0
    assert a.stats.unchanged_skips > 0
    assert a.stats.full_restages < len(schedule) * 4
    assert b.stats.delta_reads == 0
    assert b.stats.full_restages == len(schedule) * 4


def test_restart_agent_voids_staged_row():
    _, agents = _fleet(3, bad_host=0, history_s=30.0)
    agg = FleetAggregator(agents, window_s=20.0)
    agg.run_virtual(0.0, 25.0)
    agg.assemble()
    agg.run_virtual(25.0, 25.5)
    agg.assemble()
    assert agg.stats.delta_reads >= 1
    assert agg._staged_full[1]
    agg.restart_agent(1)
    assert not agg._staged_full[1]
    # the restarted host's next row is a full restage, others may delta
    fr = agg.stats.full_restages
    agg.run_virtual(25.5, 26.0)
    snap = agg.assemble()
    assert agg.stats.full_restages > fr
    assert snap.slab.shape[0] == 3
